"""Serving example: batched generation with a KV cache from a reduced
Mamba2 (O(1)-state decode) and a reduced Llama3 (paged-nothing, plain cache)
— the same decode_step the dry-run lowers at decode_32k/long_500k scale.

  PYTHONPATH=src python examples/serve_batch.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.launch.serve import generate
from repro.launch.steps import serve_config
from repro.models.model import init_params

for arch in ("llama3-8b", "mamba2-1.3b"):
    cfg = serve_config(get_reduced_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    prompts = jax.random.randint(key, (4, 16), 0, cfg.vocab_size_raw,
                                 dtype=jnp.int32)
    out = generate(params, cfg, prompts, gen_len=24, key=key, temperature=0.9)
    print(f"{arch}: generated {out.shape} tokens; sample tail:",
          out[0, -8:].tolist())
