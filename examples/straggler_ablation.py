"""Paper-experiment example: reproduce the straggler-robustness story
(Fig. 2): sweep the slow-client fraction and compare FAVAS vs FedBuff final
accuracy under the simulated clock. FedBuff's buffer is fed by fast clients,
so it degrades as slow clients dominate; FAVAS's unbiased reweighting keeps
slow-client information flowing.

  PYTHONPATH=src python examples/straggler_ablation.py
"""
import numpy as np

from repro.core.fl_sim import SimConfig, run_simulation
from repro.data import make_classification, partition_label_skew

x, y, xt, yt = make_classification("mnist-like", n_train=5000, n_test=1200)
N = 18

print(f"{'slow_frac':>9} | {'FAVAS':>7} | {'FedBuff':>7}")
# slow_step_time=64: the severe-straggler regime of the paper's Fig. 2
# (its geometric speeds give slow clients a long staleness tail; see
# EXPERIMENTS.md §Repro for the mapping).
for slow_frac in (1 / 3, 2 / 3, 8 / 9):
    accs = {}
    parts = partition_label_skew(y, N, 2, seed=0)
    for method in ("favas", "fedbuff"):
        cfg = SimConfig(method=method, n_clients=N, s_selected=5, K=20,
                        buffer_z=10, eta=0.5, total_time=1400, eval_every=700,
                        slow_fraction=slow_frac, slow_step_time=64.0,
                        batch_size=48, seed=0)
        r = run_simulation(cfg, (x, y, xt, yt, parts), d_hidden=64)
        accs[method] = r["final_accuracy"]
    print(f"{slow_frac:9.2f} | {accs['favas']:7.3f} | {accs['fedbuff']:7.3f}")
