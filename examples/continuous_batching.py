"""Serving example: continuous batching — 8 requests of different prompt and
output lengths stream through 3 decode slots (vLLM-style, TPU static
shapes). Watch slot utilization as requests retire and new ones are
admitted mid-flight.

  PYTHONPATH=src python examples/continuous_batching.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.launch.steps import serve_config
from repro.models.model import init_params
from repro.serving import Request, ContinuousBatcher
from repro.serving.engine import DecodeEngine

cfg = serve_config(get_reduced_config("llama3-8b"))
key = jax.random.PRNGKey(0)
params = init_params(key, cfg)

engine = DecodeEngine(params, cfg, batch_slots=3, max_seq=64)
sched = ContinuousBatcher(3, engine.step_fn, vocab_raw=cfg.vocab_size_raw)

rng = jax.random.PRNGKey(7)
for uid in range(8):
    rng, sub = jax.random.split(rng)
    plen = 2 + uid % 5
    prompt = jax.random.randint(sub, (plen,), 0, cfg.vocab_size_raw).tolist()
    sched.submit(Request(uid=uid, prompt=prompt, max_new_tokens=4 + uid % 7))

while sched.has_work():
    sched.step(temperature=0.0)
    if sched.steps % 5 == 0:
        print(f"step {sched.steps:3d} | slots busy {sched.utilization():.2f} "
              f"| finished {len(sched.finished)}/8")

print()
for uid in sorted(sched.finished):
    r = sched.finished[uid]
    print(f"req {uid}: prompt[{len(r.prompt)}] -> {r.output}")
print(f"\ntotal engine steps: {sched.steps} "
      f"(naive one-at-a-time would need "
      f"{sum(len(r.prompt)+len(r.output) for r in sched.finished.values())})")
