"""End-to-end driver (deliverable b): train a ~100M-parameter llama-family
model with FAVAS for a few hundred server rounds, with checkpointing and
loss curve artifact. This is the single-host configuration of the same
trainer the dry-run lowers onto the 256/512-chip meshes.

~100M config: 8 layers, d_model 512, 8 heads, d_ff 2048, 32k vocab
  -> 59M transformer + 33M (tied) embedding params.

  PYTHONPATH=src python examples/train_e2e.py            # 200 rounds (~30 min CPU)
  PYTHONPATH=src python examples/train_e2e.py --rounds 40  # shorter demo
"""
import argparse
import dataclasses
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing import save_checkpoint
from repro.configs import get_config
from repro.core import FavasConfig, favas_init, favas_round, client_lambdas
from repro.data import make_lm_corpus
from repro.data.pipeline import lm_round_batch
from repro.models.model import init_params, loss_fn
from repro.utils.tree import tree_param_count

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=200)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--batch", type=int, default=2)
ap.add_argument("--out", default="experiments/train_e2e")
args = ap.parse_args()

cfg = dataclasses.replace(
    get_config("llama3-8b"), name="llama-100m",
    n_layers=10, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
    d_ff=2816, vocab_size_raw=32000)   # ~104M params (tied embeddings)
fcfg = FavasConfig(n_clients=4, s_selected=2, local_steps=4, eta=0.03)

key = jax.random.PRNGKey(0)
params = init_params(key, cfg)
print(f"model: {cfg.name}, {tree_param_count(params)/1e6:.1f}M params")

state = favas_init(params, fcfg, key)
lambdas = jnp.asarray(client_lambdas(fcfg))
step = jax.jit(functools.partial(
    favas_round, cfg=fcfg, loss_fn=lambda p, b: loss_fn(p, cfg, b),
    lambdas=lambdas))

tokens, domains = make_lm_corpus(cfg.vocab_size_raw, 2_000_000, n_domains=8)
rng = np.random.default_rng(0)
losses = []
t0 = time.time()
for t in range(args.rounds):
    batch = lm_round_batch(tokens, domains, fcfg.n_clients, fcfg.R,
                           args.batch, args.seq, rng)
    state, m = step(state, {"tokens": jnp.asarray(batch)})
    losses.append(float(m["loss"]))
    if (t + 1) % 10 == 0:
        print(f"round {t+1:4d} | loss {np.mean(losses[-10:]):.4f} | "
              f"{(t+1)/(time.time()-t0):.2f} rounds/s")
        os.makedirs(args.out, exist_ok=True)      # incremental artifacts
        with open(os.path.join(args.out, "losses.json"), "w") as f:
            json.dump(losses, f)

os.makedirs(args.out, exist_ok=True)
save_checkpoint(args.out, args.rounds, state.server)
with open(os.path.join(args.out, "losses.json"), "w") as f:
    json.dump(losses, f)
print(f"first-20 mean {np.mean(losses[:20]):.4f} -> "
      f"last-20 mean {np.mean(losses[-20:]):.4f}")
if args.rounds >= 40:
    assert np.mean(losses[-20:]) < np.mean(losses[:20]), "loss must improve"
print("checkpoint + loss curve written to", args.out)
