"""Quickstart: FAVAS in ~40 lines of public API.

Trains a reduced Qwen3-family model with 4 asynchronous clients (1/3 slow)
on a synthetic non-IID LM corpus, for 30 server rounds on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.core import (FavasConfig, favas_init, favas_round, favas_variance,
                        client_lambdas)
from repro.data import make_lm_corpus
from repro.data.pipeline import lm_round_batch
from repro.models.model import init_params, loss_fn

ARCH = "qwen3-4b"

cfg = get_reduced_config(ARCH)
fcfg = FavasConfig(n_clients=4, s_selected=2, local_steps=4, eta=0.05)

key = jax.random.PRNGKey(0)
state = favas_init(init_params(key, cfg), fcfg, key)
lambdas = jnp.asarray(client_lambdas(fcfg))   # 1/3 slow clients

step = jax.jit(functools.partial(
    favas_round, cfg=fcfg,
    loss_fn=lambda p, b: loss_fn(p, cfg, b),
    lambdas=lambdas))

tokens, domains = make_lm_corpus(cfg.vocab_size_raw, 200_000, n_domains=4)
rng = np.random.default_rng(0)

for t in range(30):
    batch = lm_round_batch(tokens, domains, fcfg.n_clients, fcfg.R,
                           batch=2, seq=64, rng=rng)
    state, metrics = step(state, {"tokens": jnp.asarray(batch)})
    if (t + 1) % 5 == 0:
        print(f"round {t+1:3d}  loss={float(metrics['loss']):.3f}  "
              f"client-dispersion={float(favas_variance(state)):.3e}")

print("done — the server model in state.server is the trained artifact")
