"""Small classifier models matching the paper's experimental section:
a shallow MLP (MNIST, Sec. 5 ``shallow neural network``) and a small
conv-net proxy (CIFAR-10 / TinyImageNet ResNets are scaled down for the
offline CPU benchmark — relative method ordering is what we validate).

These are the models the FAVAS *reproduction* benchmarks train; the ten
assigned production architectures live in ``repro.models.model``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mlp_init(key, d_in: int, d_hidden: int, n_classes: int, depth: int = 2):
    ks = jax.random.split(key, depth + 1)
    dims = [d_in] + [d_hidden] * depth + [n_classes]
    return {
        f"l{i}": {
            "w": jax.random.normal(ks[i], (dims[i], dims[i + 1])) / jnp.sqrt(dims[i]),
            "b": jnp.zeros((dims[i + 1],)),
        }
        for i in range(depth + 1)
    }


def mlp_apply(params, x):
    n = len(params)
    for i in range(n):
        p = params[f"l{i}"]
        x = x @ p["w"] + p["b"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def classifier_loss(params, apply_fn, x, y, n_classes: int):
    logits = apply_fn(params, x)
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(y, n_classes)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def accuracy(params, apply_fn, x, y):
    logits = apply_fn(params, x)
    return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
