"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
a_t = exp(-c * softplus(Lambda) * r_t),  r_t, i_t input-dependent gates.

Prefill uses `lax.associative_scan` over the sequence (log-depth on TPU);
decode is the single recurrence step. Channels shard over "model".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, dense_apply, _normal

_C = 8.0  # Griffin's fixed temperature


def rglru_init(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    d, w = cfg.d_model, cfg.rnn_width
    return {
        "in_x": dense_init(ks[0], d, w, dtype=dtype),
        "in_gate": dense_init(ks[1], d, w, dtype=dtype),
        "conv_w": _normal(ks[2], (cfg.conv_width, w), 0.1, dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "gate_r": dense_init(ks[3], w, w, dtype=dtype),
        "gate_i": dense_init(ks[4], w, w, dtype=dtype),
        # Lambda init so a^(1/c) in (0.9, 0.999)
        "lam": jnp.log(jnp.expm1(-jnp.log(
            jnp.linspace(0.9, 0.999, w)) )).astype(dtype),
        "out": dense_init(jax.random.fold_in(key, 7), w, d, dtype=dtype),
    }


def _gates(p, xw):
    r = jax.nn.sigmoid(dense_apply(p["gate_r"], xw).astype(jnp.float32))
    i = jax.nn.sigmoid(dense_apply(p["gate_i"], xw).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = i * xw.astype(jnp.float32)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    return a, mult * gated


def _causal_conv(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :]


def rglru_apply(p, cfg, x, *, compute_dtype=jnp.bfloat16):
    """Full recurrent block, prefill/train. x: (B, S, d)."""
    gate_branch = jax.nn.gelu(
        dense_apply(p["in_gate"], x, compute_dtype=compute_dtype).astype(jnp.float32))
    xw = dense_apply(p["in_x"], x, compute_dtype=compute_dtype)
    xw = _causal_conv(xw.astype(jnp.float32), p["conv_w"].astype(jnp.float32),
                      p["conv_b"].astype(jnp.float32)).astype(compute_dtype)
    a, u = _gates(p, xw)                                        # (B,S,w) f32

    def combine(c1, c2):
        a1, u1 = c1
        a2, u2 = c2
        return a1 * a2, u1 * a2 + u2
    aS, hS = jax.lax.associative_scan(combine, (a, u), axis=1)
    y = hS * gate_branch
    return dense_apply(p["out"], y.astype(compute_dtype), compute_dtype=compute_dtype)


def rglru_init_cache(cfg, batch, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, cfg.rnn_width), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.rnn_width), dtype),
    }


def rglru_decode(p, cfg, x, cache, *, compute_dtype=jnp.bfloat16):
    """Single decode step. x: (B, 1, d)."""
    gate_branch = jax.nn.gelu(
        dense_apply(p["in_gate"], x, compute_dtype=compute_dtype).astype(jnp.float32))
    xw = dense_apply(p["in_x"], x, compute_dtype=compute_dtype)[:, 0]   # (B,w)
    hist = jnp.concatenate([cache["conv"], xw[:, None].astype(cache["conv"].dtype)], axis=1)
    w = p["conv_w"].astype(jnp.float32)
    xw = (jnp.sum(hist.astype(jnp.float32) * w[None], axis=1)
          + p["conv_b"].astype(jnp.float32)).astype(compute_dtype)
    a, u = _gates(p, xw)                                        # (B,w)
    h = cache["h"].astype(jnp.float32) * a + u
    y = h[:, None] * gate_branch
    y = dense_apply(p["out"], y.astype(compute_dtype), compute_dtype=compute_dtype)
    return y, {"h": h.astype(cache["h"].dtype), "conv": hist[:, 1:]}
