"""Unified model substrate: one ModelConfig + init/forward/loss/decode for all
six assigned architecture families (dense / moe / ssm / hybrid / vlm / audio).

Framework conventions:
* params are nested dicts; uniform-depth stacks use a leading layer axis and
  `lax.scan` over layers (small HLO — essential for 40 dry-run compiles);
  hybrids (periodic patterns) and enc-dec unroll.
* `forward(params, cfg, batch)` -> logits for train/prefill;
  `decode_step(params, cfg, cache, token, pos)` -> (logits, cache) for serve.
* [audio]/[vlm] frontends are stubs per the task carve-out: the batch carries
  precomputed frame/patch embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import rglru as R


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    arch_type: str = "dense"           # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 512
    vocab_size_raw: int = 1024         # paper/model-card vocab
    # attention
    rope_theta: float = 1e6
    qk_norm: bool = False
    mrope: bool = False
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    attn_bias: bool = False
    window: int = 0                    # >0: sliding-window on ALL attn layers
    # mlp / norm
    mlp_type: str = "swiglu"           # swiglu|gelu
    norm_type: str = "rms"             # rms|ln
    norm_eps: float = 1e-6
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    # hybrid (griffin)
    rnn_width: int = 0
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rglru","rglru","local_attn")
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500
    dec_pos_len: int = 32768          # learned decoder positions table
    # policy
    tie_embeddings: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # "full": save only layer boundaries; "dots": additionally save matmul
    # outputs with no batch dims (§Perf iter 6 — trades HBM for recompute).
    remat_policy: str = "full"
    scan_layers: bool = True
    vocab_pad_to: int = 256
    # §Perf iter 2: shard the residual stream's sequence dim over this mesh
    # axis between blocks (Megatron-SP analog): activations/remat residuals
    # shrink by the axis size and boundary all-reduces lower to RS+AG.
    # "" = baseline (unsharded). Enable only when seq % axis_size == 0.
    act_seq_axis: str = ""
    # §Perf iter 5 (measured, see EXPERIMENTS.md): sequence-sharding the
    # residual stream trades boundary all-reduces for per-layer weight + K/V
    # gathers. That LOSES when K/V are full-width (MHA: codeqwen, whisper),
    # when the token mixer is a cross-chunk scan (mamba2 SSD), or when
    # expert weights dominate the gather (phi3.5-moe 42B). Those configs set
    # this False and the "opt" variant leaves them at baseline sharding.
    seq_shard_friendly: bool = True
    # §Perf iter (decode): "int8" stores the KV cache quantized with a
    # per-(token, head) scale — halves decode's dominant HBM term.
    kv_cache_dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def vocab_size(self) -> int:
        return _round_up(self.vocab_size_raw, self.vocab_pad_to)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer temporal-mixing kind for the decoder stack."""
        if self.arch_type == "ssm":
            return ("ssm",) * self.n_layers
        if self.arch_type == "hybrid":
            pat = self.block_pattern or ("rglru", "rglru", "local_attn")
            return tuple(pat[i % len(pat)] for i in range(self.n_layers))
        if self.arch_type == "moe":
            return ("attn",) * self.n_layers
        return ("attn",) * self.n_layers   # dense / vlm / audio decoder

    def uniform_stack(self) -> bool:
        """True when all decoder layers are identical -> scan over layers."""
        return (self.scan_layers and self.arch_type in
                ("dense", "moe", "ssm", "vlm"))


def make_reduced(cfg: ModelConfig, *, n_layers=2, d_model=256, n_heads=4,
                 n_kv_heads=None, d_ff=512, vocab=512, n_experts=4) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (task spec: <=2 layers,
    d_model<=512, <=4 experts)."""
    kv = n_kv_heads or max(1, min(cfg.n_kv_heads, n_heads))
    if cfg.n_kv_heads == cfg.n_heads:
        kv = n_heads
    updates = dict(
        n_layers=n_layers, d_model=d_model, n_heads=n_heads, n_kv_heads=kv,
        head_dim=d_model // n_heads, d_ff=d_ff, vocab_size_raw=vocab,
        vocab_pad_to=64,
    )
    if cfg.arch_type == "moe":
        updates.update(n_experts=min(n_experts, 4), top_k=min(cfg.top_k, 2))
    if cfg.arch_type == "ssm":
        updates.update(ssm_head_dim=32, ssm_state=16)
    if cfg.arch_type == "hybrid":
        updates.update(rnn_width=d_model, window=64,
                       block_pattern=("rglru", "local_attn"))
    if cfg.arch_type == "audio":
        updates.update(enc_layers=2, enc_seq=16, dec_pos_len=4096)
    if cfg.mrope:
        updates.update(mrope_sections=(8, 12, 12))  # head_dim 64 -> half 32
    return dataclasses.replace(cfg, **updates)


# ======================================================================
# Init
# ======================================================================

def _mlp_init(key, cfg, dtype):
    if cfg.arch_type == "moe":
        return M.moe_init(key, cfg, dtype)
    if cfg.mlp_type == "gelu":
        return L.gelu_mlp_init(key, cfg.d_model, cfg.d_ff, dtype, bias=cfg.attn_bias)
    return L.swiglu_init(key, cfg.d_model, cfg.d_ff, dtype)


def _norm_init(cfg, dtype):
    if cfg.norm_type == "ln":
        return L.layernorm_init(cfg.d_model, dtype)
    return L.rmsnorm_init(cfg.d_model, dtype)


def _norm_apply(cfg, p, x):
    if cfg.norm_type == "ln":
        return L.layernorm_apply(p, x, eps=cfg.norm_eps)
    return L.rmsnorm_apply(p, x, eps=cfg.norm_eps)


def _layer_init(key, cfg: ModelConfig, kind: str, dtype):
    ks = jax.random.split(key, 4)
    p = {"norm1": _norm_init(cfg, dtype)}
    if kind == "attn" or kind == "local_attn":
        p["attn"] = A.attn_init(ks[0], cfg, dtype)
    elif kind == "ssm":
        p["ssm"] = S.ssm_init(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["rnn"] = R.rglru_init(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if kind != "ssm":                      # mamba2 blocks have no separate FFN
        p["norm2"] = _norm_init(cfg, dtype)
        p["mlp"] = _mlp_init(ks[1], cfg, dtype)
    if cfg.arch_type == "audio":           # decoder cross-attention
        p["norm_x"] = _norm_init(cfg, dtype)
        p["xattn"] = A.attn_init(ks[2], cfg, dtype)
    return p


def _enc_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "norm1": _norm_init(cfg, dtype),
        "attn": A.attn_init(ks[0], cfg, dtype),
        "norm2": _norm_init(cfg, dtype),
        "mlp": L.gelu_mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype, bias=True),
    }


def init_params(key, cfg: ModelConfig):
    dtype = cfg.pdtype
    k_emb, k_layers, k_head, k_enc, k_pos = jax.random.split(key, 5)
    params = {"embed": L.embedding_init(k_emb, cfg.vocab_size, cfg.d_model, dtype)}
    kinds = cfg.layer_kinds()

    if cfg.uniform_stack():
        keys = jax.random.split(k_layers, cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, kinds[0], dtype))(keys)
    else:
        keys = jax.random.split(k_layers, cfg.n_layers)
        params["layers"] = [
            _layer_init(keys[i], cfg, kinds[i], dtype) for i in range(cfg.n_layers)]

    params["final_norm"] = _norm_init(cfg, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype=dtype)

    if cfg.arch_type == "audio":
        ekeys = jax.random.split(k_enc, cfg.enc_layers)
        params["encoder"] = {
            "pos": L._normal(k_pos, (cfg.enc_seq, cfg.d_model), 0.02, dtype),
            "layers": [_enc_layer_init(ekeys[i], cfg, dtype)
                       for i in range(cfg.enc_layers)],
            "final_norm": _norm_init(cfg, dtype),
        }
        params["dec_pos"] = L._normal(jax.random.fold_in(k_pos, 1),
                                      (cfg.dec_pos_len, cfg.d_model), 0.02, dtype)
    return params


# ======================================================================
# Forward (train / prefill)
# ======================================================================

def _constrain_acts(cfg: ModelConfig, x):
    """Optionally pin the residual stream's seq dim to cfg.act_seq_axis."""
    if not cfg.act_seq_axis or x.ndim < 3 or x.shape[-2] <= 1:
        return x
    from jax.sharding import PartitionSpec as P
    U = P.UNCONSTRAINED
    spec = P(*([U] * (x.ndim - 2)), cfg.act_seq_axis, U)
    return jax.lax.with_sharding_constraint(x, spec)


def _layer_apply(p, cfg: ModelConfig, kind: str, x, positions, enc_out=None):
    """One decoder block. Returns (x, aux_loss)."""
    cd = cfg.cdtype
    aux = jnp.zeros((), jnp.float32)
    h = _norm_apply(cfg, p["norm1"], x)
    if kind == "attn":
        h = A.attn_apply(p["attn"], cfg, h, positions, window=cfg.window,
                         compute_dtype=cd)
    elif kind == "local_attn":
        h = A.attn_apply(p["attn"], cfg, h, positions, window=cfg.window or 2048,
                         compute_dtype=cd)
    elif kind == "ssm":
        h = S.ssm_apply(p["ssm"], cfg, h, compute_dtype=cd)
    elif kind == "rglru":
        h = R.rglru_apply(p["rnn"], cfg, h, compute_dtype=cd)
    x = x + h
    if "xattn" in p:                       # whisper decoder cross-attn
        h = _norm_apply(cfg, p["norm_x"], x)
        h = A.attn_apply(p["xattn"], cfg, h, None, kv=enc_out, compute_dtype=cd)
        x = x + h
    if "mlp" in p:
        h = _norm_apply(cfg, p["norm2"], x)
        if cfg.arch_type == "moe":
            h, aux = M.moe_apply(p["mlp"], cfg, h,
                                 capacity_factor=cfg.capacity_factor,
                                 compute_dtype=cd)
        elif cfg.mlp_type == "gelu":
            h = L.gelu_mlp_apply(p["mlp"], h, compute_dtype=cd)
        else:
            h = L.swiglu_apply(p["mlp"], h, compute_dtype=cd)
        x = x + h
    return x, aux


def _encode(params, cfg, enc_frames):
    """Whisper encoder over stubbed conv-frontend frames (B, T_enc, d)."""
    enc = params["encoder"]
    x = enc_frames.astype(cfg.cdtype) + enc["pos"][None, :enc_frames.shape[1]].astype(cfg.cdtype)
    for lp in enc["layers"]:
        h = _norm_apply(cfg, lp["norm1"], x)
        h = A.attn_apply(lp["attn"], cfg, h, None, causal=False, compute_dtype=cfg.cdtype)
        x = x + h
        h = _norm_apply(cfg, lp["norm2"], x)
        x = x + L.gelu_mlp_apply(lp["mlp"], h, compute_dtype=cfg.cdtype)
    return _norm_apply(cfg, enc["final_norm"], x)


def _embed_inputs(params, cfg, batch):
    tokens = batch["tokens"]
    x = L.embedding_apply(params["embed"], tokens, compute_dtype=cfg.cdtype)
    if cfg.arch_type == "vlm" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(cfg.cdtype)      # (B, N_img, d)
        x = jax.lax.dynamic_update_slice(x, pe, (0, 0, 0))  # image tokens first
    if cfg.arch_type == "audio":
        Ssz = tokens.shape[1]
        x = x + params["dec_pos"][None, :Ssz].astype(cfg.cdtype)
    return x


def _positions_for(cfg, batch):
    tokens = batch["tokens"]
    B, Ssz = tokens.shape
    if cfg.arch_type == "audio":
        return None                                       # learned abs pos
    if cfg.mrope:
        if "mrope_positions" in batch:
            return batch["mrope_positions"]
        pos = jnp.broadcast_to(jnp.arange(Ssz, dtype=jnp.int32), (B, Ssz))
        return jnp.broadcast_to(pos[None], (3, B, Ssz))
    if "positions" in batch:
        return batch["positions"]
    return jnp.broadcast_to(jnp.arange(Ssz, dtype=jnp.int32), (B, Ssz))


def forward(params, cfg: ModelConfig, batch):
    """batch: dict with "tokens" (B, S) plus modality extras. -> (logits, aux)."""
    x = _embed_inputs(params, cfg, batch)
    positions = _positions_for(cfg, batch)
    enc_out = None
    if cfg.arch_type == "audio":
        eo = _encode(params, cfg, batch["enc_frames"])
        B, Te, _ = eo.shape
        hd = cfg.head_dim
        enc_out = eo  # projected per-layer below

    kinds = cfg.layer_kinds()
    aux_total = jnp.zeros((), jnp.float32)

    x = _constrain_acts(cfg, x)
    remat_kwargs = {}
    if cfg.remat and cfg.remat_policy == "dots":
        remat_kwargs["policy"] = jax.checkpoint_policies.checkpoint_dots
    if cfg.uniform_stack():
        def body(carry, lp):
            x, aux = carry
            fn = lambda q, xx: _layer_apply(q, cfg, kinds[0], xx, positions)
            if cfg.remat:
                fn = jax.checkpoint(fn, **remat_kwargs)
            x, a = fn(lp, x)
            x = _constrain_acts(cfg, x)
            return (x, aux + a), None
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["layers"])
    else:
        for i, lp in enumerate(params["layers"]):
            fn = lambda q, xx, eo=enc_out, kind=kinds[i]: _layer_apply(
                q, cfg, kind, xx, positions,
                enc_out=None if eo is None else _cross_kv(q, cfg, eo))
            if cfg.remat:
                fn = jax.checkpoint(fn, **remat_kwargs)
            x, a = fn(lp, x)
            x = _constrain_acts(cfg, x)
            aux_total = aux_total + a

    x = _norm_apply(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = L.unembed_apply(params["embed"], x, compute_dtype=cfg.cdtype)
    else:
        logits = L.dense_apply(params["lm_head"], x, compute_dtype=cfg.cdtype)
        logits = logits.astype(jnp.float32)
    return logits.astype(jnp.float32), aux_total


def _cross_kv(layer_p, cfg, enc_out):
    """Project encoder states to this decoder layer's cross K/V."""
    cd = cfg.cdtype
    B, Te, _ = enc_out.shape
    hd = cfg.head_dim
    k = L.dense_apply(layer_p["xattn"]["wk"], enc_out, compute_dtype=cd)
    v = L.dense_apply(layer_p["xattn"]["wv"], enc_out, compute_dtype=cd)
    return (k.reshape(B, Te, cfg.n_kv_heads, hd), v.reshape(B, Te, cfg.n_kv_heads, hd))


def loss_fn(params, cfg: ModelConfig, batch):
    """Next-token cross-entropy + MoE aux. Labels = tokens shifted left."""
    logits, aux = forward(params, cfg, batch)
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = jnp.ones_like(ll) if "loss_mask" not in batch else batch["loss_mask"]
    loss = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + cfg.aux_loss_coef * aux


# ======================================================================
# Decode (serve)
# ======================================================================

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Per-layer decode caches, stacked along layer axis when scanning."""
    kinds = cfg.layer_kinds()

    def one(kind):
        if kind == "ssm":
            return S.ssm_init_cache(cfg, batch, dtype)
        if kind == "rglru":
            return R.rglru_init_cache(cfg, batch, dtype)
        win = cfg.window or (2048 if kind == "local_attn" else 0)
        Ssz = min(max_seq, win) if (win and kind == "local_attn") else max_seq
        if cfg.window and kind == "attn":
            Ssz = min(max_seq, cfg.window)
        if cfg.kv_cache_dtype == "int8":
            return {
                "k": jnp.zeros((batch, Ssz, cfg.n_kv_heads, cfg.head_dim),
                               jnp.int8),
                "v": jnp.zeros((batch, Ssz, cfg.n_kv_heads, cfg.head_dim),
                               jnp.int8),
                "k_scale": jnp.zeros((batch, Ssz, cfg.n_kv_heads), jnp.bfloat16),
                "v_scale": jnp.zeros((batch, Ssz, cfg.n_kv_heads), jnp.bfloat16),
            }
        return {"k": jnp.zeros((batch, Ssz, cfg.n_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((batch, Ssz, cfg.n_kv_heads, cfg.head_dim), dtype)}

    if cfg.uniform_stack():
        c = one(kinds[0])
        cache = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), c)
    else:
        cache = [one(k) for k in kinds]
    out = {"layers": cache}
    if cfg.arch_type == "audio":
        out["cross_kv"] = None   # filled by prefill_audio
    return out


def _layer_decode(p, cfg, kind, x, pos, cache, cross_kv=None):
    cd = cfg.cdtype
    h = _norm_apply(cfg, p["norm1"], x)
    if kind in ("attn", "local_attn"):
        win = cfg.window or (2048 if kind == "local_attn" else 0)
        h, cache = A.attn_decode(p["attn"], cfg, h, pos, cache,
                                 window=win, compute_dtype=cd)
    elif kind == "ssm":
        h, cache = S.ssm_decode(p["ssm"], cfg, h, cache, compute_dtype=cd)
    elif kind == "rglru":
        h, cache = R.rglru_decode(p["rnn"], cfg, h, cache, compute_dtype=cd)
    x = x + h
    if "xattn" in p and cross_kv is not None:
        h = _norm_apply(cfg, p["norm_x"], x)
        B = x.shape[0]
        hd = cfg.head_dim
        q = L.dense_apply(p["xattn"]["wq"], h, compute_dtype=cd)
        q = q.reshape(B, 1, cfg.n_heads, hd)
        o = A.decode_attention(q, cross_kv[0], cross_kv[1],
                               cross_kv[0].shape[1])
        o = o.reshape(B, 1, cfg.n_heads * hd)
        x = x + L.dense_apply(p["xattn"]["wo"], o, compute_dtype=cd)
    if "mlp" in p:
        h = _norm_apply(cfg, p["norm2"], x)
        if cfg.arch_type == "moe":
            h, _ = M.moe_apply(p["mlp"], cfg, h,
                               capacity_factor=cfg.capacity_factor, compute_dtype=cd)
        elif cfg.mlp_type == "gelu":
            h = L.gelu_mlp_apply(p["mlp"], h, compute_dtype=cd)
        else:
            h = L.swiglu_apply(p["mlp"], h, compute_dtype=cd)
        x = x + h
    return x, cache


def decode_step(params, cfg: ModelConfig, cache, token, pos):
    """One serve step: token (B, 1) int32, pos scalar int32.
    Returns (logits (B, 1, V), new_cache)."""
    x = L.embedding_apply(params["embed"], token, compute_dtype=cfg.cdtype)
    if cfg.arch_type == "audio":
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], pos % params["dec_pos"].shape[0], 1)[None].astype(cfg.cdtype)
    kinds = cfg.layer_kinds()

    if cfg.uniform_stack():
        def body(x, inp):
            lp, lc = inp
            x, lc = _layer_decode(lp, cfg, kinds[0], x, pos, lc)
            return x, lc
        x, new_lc = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": new_lc}
    else:
        new_list = []
        xkv = cache.get("cross_kv")
        for i, lp in enumerate(params["layers"]):
            ck = xkv[i] if xkv is not None else None
            x, lc = _layer_decode(lp, cfg, kinds[i], x, pos, cache["layers"][i],
                                  cross_kv=ck)
            new_list.append(lc)
        new_cache = dict(cache)
        new_cache["layers"] = new_list

    x = _norm_apply(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = L.unembed_apply(params["embed"], x, compute_dtype=cfg.cdtype)
    else:
        logits = L.dense_apply(params["lm_head"], x, compute_dtype=cfg.cdtype)
    return logits.astype(jnp.float32), new_cache


def prefill_audio(params, cfg: ModelConfig, cache, enc_frames):
    """Run the (stub-fed) encoder once and precompute per-layer cross K/V."""
    eo = _encode(params, cfg, enc_frames)
    cache = dict(cache)
    cache["cross_kv"] = [_cross_kv(lp, cfg, eo) for lp in params["layers"]]
    return cache
