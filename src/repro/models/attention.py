"""Attention substrate: GQA with RoPE / M-RoPE / qk-norm, blockwise
(flash-style, linear-memory) prefill attention, sliding windows, and decode
with a KV cache.

TPU adaptation notes (see DESIGN.md §6):
* prefill uses an online-softmax scan over KV blocks, never materializing
  the (S, S) score matrix — required for the 32k prefill shape;
* decode supports a sequence-sharded cache; the einsum contraction over the
  sharded S dim lowers to partial reductions + small all-reduces under pjit
  (flash-decode across chips); a shard_map variant is the perf-pass upgrade.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, dense_apply, rmsnorm_init, rmsnorm_apply

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv      # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (B,S,1,hd/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections=(16, 24, 24)):
    """Qwen2-VL multimodal RoPE. positions3: (3, B, S) — (t, h, w) position ids.

    Frequency dims are partitioned into 3 sections; each section rotates with
    its own position stream. ``sections`` are half-dim counts (sum = hd/2).
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    inv = rope_freqs(hd, theta)                                   # (hd/2,)
    # (3, B, S, hd/2)
    ang_all = positions3[..., None].astype(jnp.float32) * inv
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=hd // 2)              # (hd/2,)
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_all, 0, -1), sec_id[None, None, :, None], axis=-1
    )[..., 0]                                                     # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise (online-softmax) attention — linear memory in S
# ---------------------------------------------------------------------------

def blockwise_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        block_kv: int = 512, q_offset: int = 0):
    """q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd). Returns (B, Sq, Hq, hd).

    Scans KV blocks with running (max, sum) statistics — flash-attention
    dataflow expressed in jnp so XLA fuses it; peak memory is
    O(Sq * block_kv) instead of O(Sq * Skv).
    ``window > 0`` = sliding-window (local) attention.
    ``q_offset`` = absolute position of q[0] (for cross-chunk causal masks).
    """
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)

    nb = -(-Skv // block_kv)
    pad = nb * block_kv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nb, block_kv, Hkv, hd)
    vb = v.reshape(B, nb, block_kv, Hkv, hd)

    qg = q.reshape(B, Sq, Hkv, G, hd).astype(jnp.float32) * scale
    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, blk):
        m_prev, l_prev, o_prev = carry
        kblk, vblk, start = blk                      # (B, bkv, Hkv, hd), scalar
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kblk.astype(jnp.float32))
        kv_pos = start + jnp.arange(block_kv)
        mask = kv_pos[None, :] <= Skv - 1            # valid (un-padded)
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vblk.astype(jnp.float32))
        o_new = o_prev * corr[..., None] + pv
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    o0 = jnp.zeros((B, Hkv, G, Sq, hd), jnp.float32)
    starts = jnp.arange(nb) * block_kv
    kb_t = jnp.moveaxis(kb, 1, 0)                    # (nb, B, bkv, Hkv, hd)
    vb_t = jnp.moveaxis(vb, 1, 0)
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), (kb_t, vb_t, starts))
    o = o / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(o, 3, 1).reshape(B, Sq, Hq, hd)   # (B,Sq,Hkv,G,hd)->merge
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cur_len):
    """One-token decode. q: (B, 1, Hq, hd); caches: (B, S, Hkv, hd).

    Positions >= cur_len are masked. With the cache S dim sharded over the
    "model" mesh axis, the two contractions below lower to per-shard partials
    plus an all-reduce of (B, H, hd)-sized tensors: distributed flash-decode.
    """
    B, S, Hkv, hd = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache.astype(jnp.float32))
    valid = jnp.arange(S) < cur_len                      # (S,) — scalar cur_len
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgs,bshd->bhgd", p / jnp.maximum(l, 1e-30),
                   v_cache.astype(jnp.float32))
    return o.reshape(B, 1, Hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full GQA attention layer
# ---------------------------------------------------------------------------

def attn_init(key, cfg, dtype=jnp.float32):
    """cfg needs: d_model, n_heads, n_kv_heads, head_dim, qk_norm, attn_bias."""
    ks = jax.random.split(key, 4)
    hd = cfg.head_dim
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dtype=dtype, bias=cfg.attn_bias),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dtype=dtype, bias=cfg.attn_bias),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dtype=dtype, bias=cfg.attn_bias),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype=dtype, bias=cfg.attn_bias),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _project_qkv(p, cfg, x, positions, *, compute_dtype=jnp.bfloat16):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = dense_apply(p["wq"], x, compute_dtype=compute_dtype).reshape(B, S, cfg.n_heads, hd)
    k = dense_apply(p["wk"], x, compute_dtype=compute_dtype).reshape(B, S, cfg.n_kv_heads, hd)
    v = dense_apply(p["wv"], x, compute_dtype=compute_dtype).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q)
        k = rmsnorm_apply(p["k_norm"], k)
    if positions is not None:
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(p, cfg, x, positions, *, window: int = 0, causal: bool = True,
               kv: Optional[tuple] = None, compute_dtype=jnp.bfloat16,
               block_kv: int = 512):
    """Prefill/training attention. ``kv`` overrides k/v source (cross-attn)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions, compute_dtype=compute_dtype)
    if kv is not None:
        k, v = kv
        causal = False
    out = blockwise_attention(q, k, v, causal=causal, window=window, block_kv=block_kv)
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return dense_apply(p["wo"], out, compute_dtype=compute_dtype)


def quantize_kv(x):
    """Per-(token, head) symmetric int8 quantization. x: (B, 1, H, hd).
    Returns (int8 values, bf16 scales (B, 1, H))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q, scale):
    """q: (B, S, H, hd) int8; scale: (B, S, H). -> f32."""
    return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


def attn_decode(p, cfg, x, pos, cache, *, window: int = 0,
                compute_dtype=jnp.bfloat16):
    """One-token decode step.

    x: (B, 1, d); pos: scalar int (current absolute position);
    cache: {"k","v"} (B, S_cache, Hkv, hd) [+ "k_scale","v_scale" (B, S, Hkv)
    when cfg.kv_cache_dtype == "int8" — §Perf decode iteration: halves the
    dominant HBM term]. Returns (out, new_cache).
    For sliding-window layers the cache ring-buffers over ``S_cache ==
    min(window, S)`` slots.
    """
    B = x.shape[0]
    cache_k, cache_v = cache["k"], cache["v"]
    S_cache = cache_k.shape[1]
    if getattr(cfg, "arch_type", "dense") == "audio":
        positions = None                      # learned absolute positions, no RoPE
    elif cfg.mrope:
        positions = jnp.broadcast_to(pos, (3, B, 1)).astype(jnp.int32)
    else:
        positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions, compute_dtype=compute_dtype)
    slot = (pos % S_cache) if window > 0 else pos        # window is static
    int8 = cfg.kv_cache_dtype == "int8"
    new_cache = dict(cache)
    if int8:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        new_cache["k"] = jax.lax.dynamic_update_slice(cache_k, kq, (0, slot, 0, 0))
        new_cache["v"] = jax.lax.dynamic_update_slice(cache_v, vq, (0, slot, 0, 0))
        new_cache["k_scale"] = jax.lax.dynamic_update_slice(
            cache["k_scale"], ks, (0, slot, 0))
        new_cache["v_scale"] = jax.lax.dynamic_update_slice(
            cache["v_scale"], vs, (0, slot, 0))
        k_full = dequantize_kv(new_cache["k"], new_cache["k_scale"])
        v_full = dequantize_kv(new_cache["v"], new_cache["v_scale"])
    else:
        new_cache["k"] = jax.lax.dynamic_update_slice(
            cache_k, k_new.astype(cache_k.dtype), (0, slot, 0, 0))
        new_cache["v"] = jax.lax.dynamic_update_slice(
            cache_v, v_new.astype(cache_v.dtype), (0, slot, 0, 0))
        k_full, v_full = new_cache["k"], new_cache["v"]
    cur = jnp.minimum(pos + 1, S_cache)
    out = decode_attention(q, k_full, v_full, cur)
    out = out.reshape(B, 1, cfg.n_heads * cfg.head_dim)
    return dense_apply(p["wo"], out, compute_dtype=compute_dtype), new_cache
