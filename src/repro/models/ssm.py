"""Mamba-2 SSD (state-space duality) block — arXiv:2405.21060.

Chunked SSD scan: within-chunk computation is a (masked) matmul against the
decay matrix L = exp(segsum(A)); cross-chunk state is carried by a
`lax.scan`, giving O(S * chunk) compute on the MXU instead of a length-S
sequential recurrence. Decode is the O(1) state-space step.

Sharding (DESIGN.md §6): projections are kept as *separate* branches
(z, x, B, C, dt) instead of one packed in_proj so each can carry its own
PartitionSpec — z/x/dt and the conv over x shard their inner channels over
"model"; the small B/C (n_groups=1, state=128) stay replicated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, dense_apply, rmsnorm_init, rmsnorm_apply, _normal


def ssm_init(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    d_in = cfg.ssm_d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    G = cfg.ssm_groups
    return {
        "in_z": dense_init(ks[0], d, d_in, dtype=dtype),
        "in_x": dense_init(ks[1], d, d_in, dtype=dtype),
        "in_B": dense_init(ks[2], d, G * N, dtype=dtype),
        "in_C": dense_init(ks[3], d, G * N, dtype=dtype),
        "in_dt": dense_init(ks[4], d, H, dtype=dtype),
        "conv_x": {"w": _normal(ks[5], (cfg.ssm_conv, d_in), 0.1, dtype),
                   "b": jnp.zeros((d_in,), dtype)},
        "conv_B": {"w": _normal(ks[6], (cfg.ssm_conv, G * N), 0.1, dtype),
                   "b": jnp.zeros((G * N,), dtype)},
        "conv_C": {"w": _normal(ks[7], (cfg.ssm_conv, G * N), 0.1, dtype),
                   "b": jnp.zeros((G * N,), dtype)},
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "norm": rmsnorm_init(d_in, dtype),
        "out_proj": dense_init(jax.random.fold_in(key, 99), d_in, d, dtype=dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv1d. x: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :]


def _segsum(a):
    """a: (..., L) -> (..., L, L) lower-triangular segment sums."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, ss, -jnp.inf)


def ssd_scan(x, dt, A, Bc, Cc, *, chunk: int = 128):
    """Chunked SSD. x: (B,S,H,P); dt: (B,S,H); A: (H,) (negative);
    Bc, Cc: (B,S,G,N) with G groups broadcast over heads.
    Returns y: (B,S,H,P) and final state (B,H,P,N)."""
    Bsz, S, H, P = x.shape
    G, N = Bc.shape[2], Bc.shape[3]
    rep = H // G
    nb = S // chunk
    assert nb * chunk == S, (S, chunk)

    dA = dt * A[None, None, :]                                  # (B,S,H)

    def ch(t):
        return t.reshape((Bsz, nb, chunk) + t.shape[2:])
    xc, dtc, dAc = ch(x), ch(dt), ch(dA)
    Bcc = jnp.repeat(ch(Bc), rep, axis=3)                        # (B,nb,L,H,N)
    Ccc = jnp.repeat(ch(Cc), rep, axis=3)

    dAc_h = jnp.moveaxis(dAc, -1, 2)                             # (B,nb,H,L)
    A_cum = jnp.cumsum(dAc_h, axis=-1)
    Lmat = jnp.exp(_segsum(dAc_h))                               # (B,nb,H,L,L)

    xdt = xc * dtc[..., None]                                    # (B,nb,L,H,P)
    scores = jnp.einsum("bnlhs,bnmhs->bnhlm", Ccc, Bcc)
    y_diag = jnp.einsum("bnhlm,bnhlm,bnmhp->bnlhp", scores, Lmat, xdt)

    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)              # (B,nb,H,L)
    states = jnp.einsum("bnlhs,bnhl,bnlhp->bnhps", Bcc, decay_states, xdt)

    chunk_decay = jnp.exp(A_cum[..., -1])                        # (B,nb,H)

    def body(h_prev, inp):
        st, dec = inp                                           # (B,H,P,N),(B,H)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev
    h0 = jnp.zeros((Bsz, H, P, N), x.dtype)
    hT, h_prevs = jax.lax.scan(
        body, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                        # (B,nb,H,P,N)

    state_decay_in = jnp.exp(A_cum)                              # (B,nb,H,L)
    y_off = jnp.einsum("bnlhs,bnhps,bnhl->bnlhp", Ccc, h_prevs, state_decay_in)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, hT


def _branches(p, cfg, x, compute_dtype):
    """Shared projection + conv for prefill path."""
    z = dense_apply(p["in_z"], x, compute_dtype=compute_dtype)
    xin = dense_apply(p["in_x"], x, compute_dtype=compute_dtype)
    Bc = dense_apply(p["in_B"], x, compute_dtype=compute_dtype)
    Cc = dense_apply(p["in_C"], x, compute_dtype=compute_dtype)
    dt = dense_apply(p["in_dt"], x, compute_dtype=compute_dtype)
    f32 = jnp.float32
    xin = jax.nn.silu(_causal_conv(xin.astype(f32), p["conv_x"]["w"].astype(f32),
                                   p["conv_x"]["b"].astype(f32)))
    Bc = jax.nn.silu(_causal_conv(Bc.astype(f32), p["conv_B"]["w"].astype(f32),
                                  p["conv_B"]["b"].astype(f32)))
    Cc = jax.nn.silu(_causal_conv(Cc.astype(f32), p["conv_C"]["w"].astype(f32),
                                  p["conv_C"]["b"].astype(f32)))
    dt = jax.nn.softplus(dt.astype(f32) + p["dt_bias"].astype(f32))
    return z, xin, Bc, Cc, dt


def ssm_apply(p, cfg, x, *, compute_dtype=jnp.bfloat16, chunk: int = 128):
    """Full Mamba-2 block (train/prefill). x: (B, S, d)."""
    Bsz, S, d = x.shape
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    z, xin, Bc, Cc, dt = _branches(p, cfg, x, compute_dtype)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(Bsz, S, H, P)
    Bg = Bc.reshape(Bsz, S, G, N)
    Cg = Cc.reshape(Bsz, S, G, N)
    y, _ = ssd_scan(xh, dt, A, Bg, Cg, chunk=min(chunk, S))
    y = y + xh * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(Bsz, S, cfg.ssm_d_inner).astype(compute_dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(compute_dtype)
    y = rmsnorm_apply(p["norm"], y)
    return dense_apply(p["out_proj"], y, compute_dtype=compute_dtype)


def ssm_init_cache(cfg, batch, dtype=jnp.float32):
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    return {
        "state": jnp.zeros((batch, H, P, N), dtype),
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.ssm_d_inner), dtype),
        "conv_B": jnp.zeros((batch, cfg.ssm_conv - 1, G * N), dtype),
        "conv_C": jnp.zeros((batch, cfg.ssm_conv - 1, G * N), dtype),
    }


def _conv_step(hist, new, w, b):
    """hist: (B, K-1, C); new: (B, C). Returns (out (B,C), new hist)."""
    cat = jnp.concatenate([hist, new[:, None].astype(hist.dtype)], axis=1)
    out = jnp.sum(cat.astype(jnp.float32) * w[None].astype(jnp.float32), axis=1) \
        + b.astype(jnp.float32)
    return out, cat[:, 1:]


def ssm_decode(p, cfg, x, cache, *, compute_dtype=jnp.bfloat16):
    """O(1) decode step. x: (B, 1, d). Returns (y, new_cache)."""
    Bsz = x.shape[0]
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    z = dense_apply(p["in_z"], x, compute_dtype=compute_dtype)
    xin = dense_apply(p["in_x"], x, compute_dtype=compute_dtype)[:, 0]
    Bc = dense_apply(p["in_B"], x, compute_dtype=compute_dtype)[:, 0]
    Cc = dense_apply(p["in_C"], x, compute_dtype=compute_dtype)[:, 0]
    dt = dense_apply(p["in_dt"], x, compute_dtype=compute_dtype)[:, 0]

    xo, hx = _conv_step(cache["conv_x"], xin, p["conv_x"]["w"], p["conv_x"]["b"])
    Bo, hB = _conv_step(cache["conv_B"], Bc, p["conv_B"]["w"], p["conv_B"]["b"])
    Co, hC = _conv_step(cache["conv_C"], Cc, p["conv_C"]["w"], p["conv_C"]["b"])
    xo, Bo, Co = jax.nn.silu(xo), jax.nn.silu(Bo), jax.nn.silu(Co)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A[None])                                   # (B,H)
    xh = xo.reshape(Bsz, H, P)
    Bg = jnp.repeat(Bo.reshape(Bsz, G, N), H // G, axis=1)
    Cg = jnp.repeat(Co.reshape(Bsz, G, N), H // G, axis=1)
    dBx = jnp.einsum("bh,bhn,bhp->bhpn", dt, Bg, xh)
    state = cache["state"].astype(jnp.float32) * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bhn->bhp", state, Cg) + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(Bsz, 1, cfg.ssm_d_inner).astype(compute_dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(compute_dtype)
    y = rmsnorm_apply(p["norm"], y)
    y = dense_apply(p["out_proj"], y, compute_dtype=compute_dtype)
    new_cache = {"state": state.astype(cache["state"].dtype),
                 "conv_x": hx, "conv_B": hB, "conv_C": hC}
    return y, new_cache
