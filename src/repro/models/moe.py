"""Mixture-of-Experts FFN: top-k router + capacity-based scatter dispatch.

TPU-native design (DESIGN.md §6): expert weights are a stacked (E, d, ff)
tensor sharded on the ff dim over the "model" mesh axis (tensor-parallel
experts). Dispatch uses scatter-add / gather instead of the GShard one-hot
einsum, so memory is O(E * capacity * d), never O(T * E * C).

Expert-parallel (all-to-all) placement is rejected for the assigned configs:
40 (granite) and 16 (phi) experts don't tile a 16-way model axis together
with their top-k patterns; see the perf log for the measured comparison.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, dense_apply, _normal


def moe_init(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": dense_init(ks[0], d, E, dtype=dtype),
        "gate": _normal(ks[1], (E, d, f), 1.0 / (d ** 0.5), dtype),
        "up": _normal(ks[2], (E, d, f), 1.0 / (d ** 0.5), dtype),
        "down": _normal(ks[3], (E, f, d), 1.0 / (f ** 0.5), dtype),
    }


def moe_apply(p, cfg, x, *, capacity_factor: float = 1.25,
              compute_dtype=jnp.bfloat16):
    """x: (B, S, d) -> (y: (B, S, d), aux_loss: scalar).

    Top-k routing with per-expert capacity; overflow tokens are dropped
    (their contribution falls back to the residual stream), matching
    production dropping MoE behaviour.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = dense_apply(p["router"], xt, compute_dtype=compute_dtype).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    gate_vals, sel = jax.lax.top_k(probs, k)                     # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)        # renormalize

    # Load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    onehot = jax.nn.one_hot(sel, E, dtype=jnp.float32)           # (T, k, E)
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=1), axis=0)      # (E,)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)

    cap = int(capacity_factor * k * T / E) + 1
    # position of each (token, slot) within its expert queue
    flat_sel = sel.reshape(-1)                                   # (T*k,)
    eo = jax.nn.one_hot(flat_sel, E, dtype=jnp.int32)            # (T*k, E)
    pos_in_e = (jnp.cumsum(eo, axis=0) - eo)                     # exclusive cumsum
    pos = jnp.take_along_axis(pos_in_e, flat_sel[:, None], axis=1)[:, 0]
    keep = pos < cap

    # scatter tokens into (E, cap, d)
    tok_ids = jnp.repeat(jnp.arange(T), k)
    safe_e = jnp.where(keep, flat_sel, 0)
    safe_p = jnp.where(keep, pos, cap - 1)
    buf = jnp.zeros((E, cap, d), compute_dtype)
    contrib = jnp.where(keep[:, None], xt[tok_ids].astype(compute_dtype), 0)
    buf = buf.at[safe_e, safe_p].add(contrib, mode="drop")

    # expert FFN (SwiGLU), batched over experts
    wg = p["gate"].astype(compute_dtype)
    wu = p["up"].astype(compute_dtype)
    wd = p["down"].astype(compute_dtype)
    # compute-dtype outputs: TP partial-sum collectives move bf16 (§Perf iter 1)
    g = jnp.einsum("ecd,edf->ecf", buf, wg, preferred_element_type=compute_dtype)
    u = jnp.einsum("ecd,edf->ecf", buf, wu, preferred_element_type=compute_dtype)
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
         ).astype(compute_dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, wd, preferred_element_type=compute_dtype)

    # gather back and combine with gates
    got = ye[safe_e, safe_p]                                     # (T*k, d)
    got = jnp.where(keep[:, None], got, 0.0)
    w = gate_vals.reshape(-1)[:, None].astype(jnp.float32)
    y = jnp.zeros((T, d), jnp.float32).at[tok_ids].add(got * w)
    return y.reshape(B, S, d).astype(x.dtype), aux
