"""Common neural-net layers, pure JAX (no flax): init fns return param dicts,
apply fns are pure functions of (params, inputs).

Compute dtype policy: parameters are kept in ``param_dtype`` (f32 for
training, bf16 for serving); matmuls run in ``compute_dtype`` (bf16 on TPU)
with f32 accumulation via ``preferred_element_type``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


def dense_init(key, d_in: int, d_out: int, *, dtype=jnp.float32, bias: bool = False,
               scale: Optional[float] = None):
    scale = (1.0 / math.sqrt(d_in)) if scale is None else scale
    p = {"w": _normal(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p, x, *, compute_dtype=jnp.bfloat16):
    # Perf note (§Perf iter 1): matmul output dtype == compute dtype, so the
    # tensor-parallel partial-sum all-reduce moves bf16, not f32 (2x wire
    # bytes). The MXU still accumulates f32 internally on TPU.
    w = p["w"].astype(compute_dtype)
    y = jnp.einsum("...d,df->...f", x.astype(compute_dtype), w,
                   preferred_element_type=compute_dtype)
    if "b" in p:
        y = (y.astype(jnp.float32) + p["b"].astype(jnp.float32)
             ).astype(compute_dtype)
    return y


def rmsnorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm_apply(p, x, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_apply(p, x, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def embedding_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return {"table": _normal(key, (vocab, dim), 0.02, dtype)}


def embedding_apply(p, tokens, *, compute_dtype=jnp.bfloat16):
    return jnp.take(p["table"], tokens, axis=0).astype(compute_dtype)


def unembed_apply(p, x, *, compute_dtype=jnp.bfloat16):
    """Tied or untied LM head: x @ table^T."""
    w = p["table"].astype(compute_dtype)
    return jnp.einsum("...d,vd->...v", x.astype(compute_dtype), w,
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Feed-forward blocks
# ---------------------------------------------------------------------------

def swiglu_init(key, d: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d, d_ff, dtype=dtype),
        "up": dense_init(k2, d, d_ff, dtype=dtype),
        "down": dense_init(k3, d_ff, d, dtype=dtype),
    }


def swiglu_apply(p, x, *, compute_dtype=jnp.bfloat16):
    g = dense_apply(p["gate"], x, compute_dtype=compute_dtype)
    u = dense_apply(p["up"], x, compute_dtype=compute_dtype)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * u
    return dense_apply(p["down"], h, compute_dtype=compute_dtype)


def gelu_mlp_init(key, d: int, d_ff: int, dtype=jnp.float32, bias: bool = True):
    k1, k2 = jax.random.split(key)
    return {
        "up": dense_init(k1, d, d_ff, dtype=dtype, bias=bias),
        "down": dense_init(k2, d_ff, d, dtype=dtype, bias=bias),
    }


def gelu_mlp_apply(p, x, *, compute_dtype=jnp.bfloat16):
    h = dense_apply(p["up"], x, compute_dtype=compute_dtype)
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(compute_dtype)
    return dense_apply(p["down"], h, compute_dtype=compute_dtype)
