"""StarCoder2-7B [arXiv:2402.19173] — dense, GQA kv=4, RoPE, GeLU MLP, LN."""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", arch_type="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, head_dim=128,
    d_ff=18432, vocab_size_raw=49152,
    rope_theta=100_000.0, mlp_type="gelu", norm_type="ln", attn_bias=True,
)
