"""RecurrentGemma-2B [arXiv:2402.19427] — Griffin hybrid: RG-LRU + local attn
1:2 pattern, window 2048, GQA kv=1 (MQA), head_dim 256."""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", arch_type="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size_raw=256000,
    rnn_width=2560, conv_width=4, window=0,
    block_pattern=("rglru", "rglru", "local_attn"),
    rope_theta=10_000.0, scan_layers=False,
)
