"""Phi-3.5-MoE 42B-a6.6B [hf:microsoft/Phi-3.5-MoE-instruct] — 16 experts top-2."""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", arch_type="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=6400, vocab_size_raw=32064,
    n_experts=16, top_k=2, rope_theta=10_000.0,
    seq_shard_friendly=False,  # 42B expert weights dominate gathers (§Perf iter 5)
)
