"""Whisper-medium [arXiv:2212.04356] — enc-dec audio backbone.
Conv/mel frontend is STUBBED: input_specs supplies (B, 1500, d) frame embeds."""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", arch_type="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size_raw=51865,
    enc_layers=24, enc_seq=1500,
    mlp_type="gelu", norm_type="ln", attn_bias=True, scan_layers=False,
    seq_shard_friendly=False,  # MHA (kv=16=H): §Perf iter 5
)
