"""Qwen3-4B [hf:Qwen/Qwen3-8B family] — dense, qk_norm, GQA kv=8, head_dim 128."""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", arch_type="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab_size_raw=151936,
    rope_theta=1_000_000.0, qk_norm=True,
)
