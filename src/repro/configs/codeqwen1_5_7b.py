"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B] — dense, GQA kv=32 (MHA), qkv bias."""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", arch_type="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=13440, vocab_size_raw=92416,
    rope_theta=1_000_000.0, attn_bias=True,
    seq_shard_friendly=False,  # MHA: full-width K/V gathers lose (§Perf iter 5)
)
