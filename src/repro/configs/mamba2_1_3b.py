"""Mamba2-1.3B [arXiv:2405.21060] — attention-free SSD, 48 layers, state 128."""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", arch_type="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size_raw=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_groups=1, ssm_conv=4,
    seq_shard_friendly=False,  # SSD cross-chunk scan: seq-sharding regressed (§Perf iter 5)
)
