"""Qwen2-VL-7B [arXiv:2409.12191] — VLM backbone with M-RoPE (3D positions).
ViT encoder is STUBBED: input_specs supplies precomputed patch embeddings."""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", arch_type="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size_raw=152064,
    rope_theta=1_000_000.0, mrope=True, mrope_sections=(16, 24, 24),
    attn_bias=True,
)
