"""Architecture config registry: ``--arch <id>`` resolution.

Each module cites its source paper / model card; IDs match the task
assignment. ``favano`` is accepted as an alias namespace for the FL configs.
"""
from repro.models.model import ModelConfig, make_reduced

_REGISTRY = {
    "codeqwen1.5-7b": "repro.configs.codeqwen1_5_7b",
    "whisper-medium": "repro.configs.whisper_medium",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "llama3-8b": "repro.configs.llama3_8b",
    "llama3-8b-swa": "repro.configs.llama3_8b_swa",   # beyond-paper SWA variant
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3_5_moe",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
}

ASSIGNED = [k for k in _REGISTRY if k != "llama3-8b-swa"]


def list_archs():
    return list(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    import importlib
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return importlib.import_module(_REGISTRY[name]).CONFIG


def get_reduced_config(name: str) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests."""
    return make_reduced(get_config(name))
