"""Beyond-paper variant: Llama-3-8B with sliding-window attention (window
4096) so a dense arch can serve the long_500k shape sub-quadratically."""
import dataclasses
from repro.configs.llama3_8b import CONFIG as _BASE

CONFIG = dataclasses.replace(_BASE, name="llama3-8b-swa", window=4096)
