"""Llama-3-8B [arXiv:2407.21783] — dense, GQA kv=8, 128k vocab."""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", arch_type="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size_raw=128256,
    rope_theta=500_000.0,
)
