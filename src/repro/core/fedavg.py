"""Synchronous FedAvg (McMahan et al., 2017) at the distributed-trainer
level — the paper's primary synchronous baseline, with the same resident-
client layout as favas_round so the two are drop-in comparable on the mesh.

One round: the server broadcasts w_t to the s selected clients, each runs
exactly K local SGD steps on its shard, the server averages the s results.
On real hardware the round blocks on the slowest selected client — which is
the paper's whole point; the simulated-time benchmarks charge that cost via
the App. C.2 clock (core/fl_sim.py).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import sampler
from repro.core.favas import FavasConfig
from repro.utils.tree import tree_map


def fedavg_round(server, key, batch, *, cfg: FavasConfig, loss_fn: Callable):
    """server: model pytree; batch: (n, K, B, ...) like favas_round.
    Returns (new_server, new_key, metrics). All n resident slots compute
    (uniform cost on the mesh); only the s selected contribute."""
    n, s, K = cfg.n_clients, cfg.s_selected, cfg.local_steps
    key, k_sel = jax.random.split(key)
    m = sampler.sample_selection(k_sel, n, s)                # (n,)

    def one_client(data):
        def step(p, batch_k):
            loss, g = jax.value_and_grad(loss_fn)(p, batch_k)
            p = tree_map(lambda pp, gg: pp - cfg.eta * gg.astype(pp.dtype), p, g)
            return p, loss
        p, losses = jax.lax.scan(step, server, data)
        return p, jnp.mean(losses)

    trained, losses = jax.vmap(one_client)(batch)            # stacked (n, ...)

    def avg(w, T):
        mm = m.reshape((n,) + (1,) * (T.ndim - 1))
        return (jnp.sum(mm * T.astype(jnp.float32), 0) / s).astype(w.dtype)
    new_server = tree_map(avg, server, trained)
    metrics = {"loss": jnp.sum(m * losses) / s, "selected": jnp.sum(m)}
    return new_server, key, metrics
