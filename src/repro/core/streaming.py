"""Host-offloaded cold tier + overlapped page streaming (docs §13).

Moves a paged spec's cold pools out of accelerator HBM: the full
``(n_clients,)``-row encoded pools live in host memory
(:class:`HostColdPool`) and each dispatch sees only a device-resident
SLAB of the rows that actually churn in that chunk — device bytes scale
with ``s_max`` (the hot working set) instead of ``n``.

Three pieces compose the tier:

* :class:`HostColdPool` — the host-side pools, a registered pytree node
  so the :class:`~repro.core.round_engine.EngineState` carries it through
  checkpointing unchanged; it is STRIPPED before every jit dispatch (a
  numpy leaf inside a trace is a bug, and it fails loudly).
* :func:`build_chunk_plan` — turns the bookkeeping-only replay of
  :func:`repro.core.round_engine.plan_rounds` into slab-row schedules:
  every id that churns anywhere in the chunk owns exactly ONE slab row,
  so a round-t evict is visible to any later round's promotion of the
  same id — the read-after-write order device pools give for free.
* :class:`PageStreamer` + :func:`engine_run_stream` — the double-buffered
  driver: while the device runs chunk i's compiled superstep, one
  background thread plans chunk i+1, gathers its slab from the host pool
  and ``jax.device_put``-copies it. The producer follows the
  ``data.pipeline.BatchPrefetcher`` contract: strict index order on a
  single thread, errors re-raised at ``get()`` in stream position,
  hardened ``close()``. Correctness under overlap: chunk i+1's slab is
  gathered before chunk i writes back, so rows whose ids churn in BOTH
  chunks are patched on device from chunk i's final slab
  (:func:`_patch_slab`), and the producer never runs more than one
  writeback ahead (the ``mark_written`` gate).
"""
from __future__ import annotations

import functools
import queue
import threading
import time
import warnings
from typing import Any, Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

# slab-position ids are int32 client ids; the pad sentinel sorts AFTER
# every real id so padded id vectors stay ascending for searchsorted
ID_SENTINEL = np.iinfo(np.int32).max


@jax.tree_util.register_pytree_node_class
class HostColdPool:
    """Host-memory cold pools: a tuple of per-bucket encoded-row pytrees
    (the exact tree the device placement keeps in ``state.cold``), held as
    numpy arrays. Registered as a pytree node so checkpoint save/load and
    ``jax.device_get`` traverse it; unflattening coerces every leaf back
    to numpy, so a restored pool never silently becomes device-resident.

    The pool is MUTABLE host state: :meth:`writeback` updates rows in
    place (the engine's host prologue/epilogue and the streamer own the
    ordering). It must never cross into a jit trace — the engine strips
    it off the state before every dispatch."""

    def __init__(self, buckets):
        self.buckets = tuple(buckets)

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        leaves, treedef = jax.tree_util.tree_flatten(self.buckets)
        return leaves, treedef

    @classmethod
    def tree_unflatten(cls, treedef, leaves):
        # np.asarray of a jax array is a zero-copy READ-ONLY view — copy
        # when needed so a checkpoint-restored pool stays writeback-able
        def to_numpy(leaf):
            a = np.asarray(leaf)
            return a if a.flags.writeable else a.copy()

        return cls(jax.tree_util.tree_unflatten(
            treedef, [to_numpy(leaf) for leaf in leaves]))

    # -- accounting ---------------------------------------------------------
    @property
    def nbytes(self) -> int:
        return sum(leaf.nbytes
                   for leaf in jax.tree_util.tree_leaves(self.buckets))

    def __len__(self) -> int:
        return jax.tree_util.tree_leaves(self.buckets)[0].shape[0]

    # -- slab traffic --------------------------------------------------------
    def gather(self, uids, slab_rows: int):
        """Rows ``uids`` of every pool leaf, zero-padded to ``slab_rows``
        (the last row is the chunk's all-zero dummy: invalid churn slots
        read/write it and decode to finite zeros). Returns a numpy tree
        shaped like ``state.cold`` with ``slab_rows`` rows per leaf."""
        uids = np.asarray(uids, dtype=np.int64)
        if len(uids) > slab_rows - 1:
            raise ValueError(
                f"{len(uids)} churning ids exceed the slab's "
                f"{slab_rows - 1} payload rows")

        def one(leaf):
            out = np.zeros((slab_rows,) + leaf.shape[1:], leaf.dtype)
            out[:len(uids)] = leaf[uids]
            return out

        return jax.tree_util.tree_map(one, self.buckets)

    def writeback(self, uids, slab) -> None:
        """Scatter the chunk's final slab payload rows back into the pool
        (in place). ``slab`` must already be host-side (``jax.device_get``
        it first); rows past ``len(uids)`` — the zero tail and the dummy
        row — are dropped."""
        uids = np.asarray(uids, dtype=np.int64)
        k = len(uids)

        def one(pool_leaf, slab_leaf):
            if k:
                pool_leaf[uids] = np.asarray(slab_leaf)[:k]
            return pool_leaf

        jax.tree_util.tree_map(one, self.buckets, tuple(slab))


def chunk_slab_rows(spec, cfg, n_rounds: int) -> int:
    """Static slab height for a ``n_rounds`` chunk: at most ``s_churn``
    evictions + ``s_churn`` promotions per round can touch distinct ids,
    plus one all-zero dummy row for invalid churn slots."""
    s_churn = min(cfg.s_selected, spec.s_max)
    return 2 * n_rounds * s_churn + 1


def build_chunk_plan(plan, slab_rows: int):
    """Host-side (numpy) compilation of a chunk's churn schedule into slab
    positions. ``plan`` is the device_get of
    :func:`repro.core.round_engine.plan_rounds` output: ``(T, s_churn)``
    arrays ``evict_ids/evict_valid/promo_ids/promo_valid``.

    Returns ``(uids, {"evict_slab", "promo_slab"})``: ``uids`` is the
    sorted unique valid churn ids (the slab's payload rows, in order) and
    the two ``(T, s_churn)`` int32 arrays map every churn slot to its slab
    row — invalid slots to the dummy row ``slab_rows - 1``."""
    ev_ids = np.asarray(plan["evict_ids"])
    ev_ok = np.asarray(plan["evict_valid"]).astype(bool)
    pr_ids = np.asarray(plan["promo_ids"])
    pr_ok = np.asarray(plan["promo_valid"]).astype(bool)
    uids = np.unique(np.concatenate([ev_ids[ev_ok].ravel(),
                                     pr_ids[pr_ok].ravel()]))
    if len(uids) > slab_rows - 1:
        raise ValueError(f"{len(uids)} churning ids exceed the slab's "
                         f"{slab_rows - 1} payload rows")
    dummy = slab_rows - 1

    def pos(ids, ok):
        if len(uids) == 0:
            return np.full(ids.shape, dummy, np.int32)
        p = np.minimum(np.searchsorted(uids, ids), len(uids) - 1)
        return np.where(ok, p, dummy).astype(np.int32)

    return uids, {"evict_slab": pos(ev_ids, ev_ok),
                  "promo_slab": pos(pr_ids, pr_ok)}


def pad_ids(uids, slab_rows: int):
    """``uids`` padded to the slab's fixed ``slab_rows - 1`` payload height
    with :data:`ID_SENTINEL` (sorts last, so the padded vector stays
    ascending for the device-side searchsorted in :func:`_patch_slab`)."""
    out = np.full((slab_rows - 1,), ID_SENTINEL, np.int32)
    out[:len(uids)] = np.asarray(uids, np.int32)
    return out


@functools.partial(jax.jit, donate_argnums=(0,))
def _patch_slab(slab_new, ids_new, slab_old, ids_old):
    """Overwrite rows of the NEXT chunk's freshly gathered slab whose ids
    also churned in the PREVIOUS chunk with the previous chunk's final
    slab rows. This closes the overlap race: the streamer gathers chunk
    i+1 from the host pool before chunk i has written back, so ids live in
    both chunks would otherwise read stale pool bytes. The producer's
    ``mark_written`` gate guarantees the pool already holds every chunk
    ≤ i-1, so patching against chunk i alone is complete.

    ``ids_*``: ``(slab_rows - 1,)`` int32, ascending, sentinel-padded
    (:func:`pad_ids`) — all shapes static, so equal-length chunks compile
    this once."""
    pos = jnp.clip(jnp.searchsorted(ids_old, ids_new),
                   0, ids_old.shape[0] - 1)
    hit = (ids_old[pos] == ids_new) & (ids_new != ID_SENTINEL)

    def one(new_leaf, old_leaf):
        rows = old_leaf[pos]
        sel = hit.reshape((-1,) + (1,) * (new_leaf.ndim - 1))
        head = new_leaf[:ids_new.shape[0]]
        return new_leaf.at[:ids_new.shape[0]].set(
            jnp.where(sel, rows.astype(new_leaf.dtype), head))

    return jax.tree_util.tree_map(one, slab_new, tuple(slab_old))


class PageStreamer:
    """Double-buffered background-thread page streamer — the cold-tier
    sibling of ``data.pipeline.BatchPrefetcher``, same contract:

    * **order & determinism** — ``make_chunk(i)`` runs strictly in index
      order on ONE background thread, so the planner's bookkeeping chain
      (a closure carried across calls) replays exactly the synchronous
      stream;
    * **bounded lookahead** — at most ``depth`` chunks buffered;
    * **errors surface at get()** — a producer exception re-raises on the
      consumer thread at its position in the stream, never swallowed;
    * **hardened close()** — stop flag first, drain-and-join against a
      monotonic deadline, ``RuntimeWarning`` on a leaked thread, pending
      errors re-raised by ``__exit__``.

    On top of the prefetcher contract it adds the WRITEBACK GATE: the
    producer may gather chunk ``i`` from the host pool only once the
    consumer has called :meth:`mark_written` for chunk ``i - 2`` — the
    pool then already holds everything except chunk ``i - 1``, whose
    updates :func:`_patch_slab` applies on device. ``make_chunk`` is
    called only after the gate clears."""

    def __init__(self, make_chunk: Callable[[int], Any],
                 n_chunks: Optional[int] = None, depth: int = 2):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._n = n_chunks
        self._served = 0
        self._done = object()
        self._make = make_chunk
        self._wb = -1                     # last chunk written back to pool
        self._wb_cond = threading.Condition()
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def mark_written(self, i: int) -> None:
        """Consumer: the pool now holds every chunk ``<= i``."""
        with self._wb_cond:
            self._wb = max(self._wb, i)
            self._wb_cond.notify_all()

    def _gate(self, i: int) -> bool:
        """Wait until gathering chunk ``i`` is pool-consistent (writebacks
        through chunk ``i - 2`` applied). False if closed while waiting."""
        with self._wb_cond:
            while self._wb < i - 2:
                if self._stop.is_set():
                    return False
                self._wb_cond.wait(timeout=0.1)
        return not self._stop.is_set()

    def _produce(self):
        try:
            i = 0
            while not self._stop.is_set() and (self._n is None
                                               or i < self._n):
                if not self._gate(i):
                    break
                c = self._make(i)
                while not self._stop.is_set():
                    try:
                        self._q.put(c, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                i += 1
        except BaseException as e:  # noqa: BLE001 — re-raised at get()
            self._err = e
        finally:
            try:
                self._q.put(self._done, timeout=0.1)
            except queue.Full:
                pass

    def get(self):
        """Next chunk, blocking until the producer has one ready. Chunks
        built before a producer failure are still served (FIFO); the error
        surfaces at its position in the stream."""
        while True:
            if self._n is not None and self._served >= self._n:
                raise StopIteration
            try:
                c = self._q.get(timeout=0.1)
            except queue.Empty:
                if self._err is not None:
                    err, self._err = self._err, None
                    raise err
                if not self._thread.is_alive():
                    raise StopIteration from None
                continue
            if c is self._done:
                if self._err is not None:
                    err, self._err = self._err, None
                    raise err
                raise StopIteration
            self._served += 1
            return c

    def __iter__(self):
        while True:
            try:
                yield self.get()
            except StopIteration:
                return

    def close(self, timeout: float = 30.0) -> bool:
        """Stop the producer and drop buffered chunks. Deadlock-safe even
        with the producer blocked on a full queue OR parked on the
        writeback gate (both poll the stop flag every 0.1 s); monotonic
        deadline, ``RuntimeWarning`` + False on a leak. A pending producer
        error is NOT cleared here — ``__exit__`` re-raises it."""
        self._stop.set()
        with self._wb_cond:
            self._wb_cond.notify_all()
        deadline = time.monotonic() + timeout
        while self._thread.is_alive():
            while True:
                try:
                    self._q.get_nowait()
                except queue.Empty:
                    break
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            self._thread.join(timeout=min(0.25, remaining))
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        if self._thread.is_alive():
            warnings.warn(
                f"PageStreamer.close(): producer thread still alive after "
                f"{timeout:.1f}s (slow gather/device_put?)",
                RuntimeWarning, stacklevel=2)
            return False
        return True

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        if self._err is not None and exc_type is None:
            err, self._err = self._err, None
            raise err
        return False


def engine_run_stream(engine, state, *, n_chunks: int, chunk_rounds: int,
                      corpus=None, chunk_batches=None, depth: int = 2):
    """Overlapped host-tier driver: ``n_chunks`` supersteps of
    ``chunk_rounds`` rounds each, with the NEXT chunk's plan/gather/H2D
    running on a :class:`PageStreamer` thread while the device computes
    the current chunk. Bit-exact with ``n_chunks`` sequential
    ``engine.run_device`` / ``engine.run`` calls (the plan chain, slab
    bytes and key chain are identical; only the host scheduling differs —
    pinned by tests/test_streaming.py).

    ``corpus``: device data plane (one compile for all chunks);
    ``chunk_batches``: host plane, a length-``n_chunks`` list of per-chunk
    batch pytrees with a leading ``(chunk_rounds,)`` axis. Returns
    ``(state, metrics)`` with metrics concatenated to
    ``(n_chunks * chunk_rounds,)`` numpy arrays."""
    import dataclasses

    from repro.core.round_engine import slab_shardings

    spec, cfg = engine.spec, engine.cfg
    if not (spec.paged and spec.cold_placement == "host"):
        raise ValueError("engine_run_stream needs a paged spec with "
                         "cold_placement='host'")
    if (corpus is None) == (chunk_batches is None):
        raise ValueError("pass exactly one of corpus / chunk_batches")
    if chunk_batches is not None and len(chunk_batches) != n_chunks:
        raise ValueError(f"chunk_batches carries {len(chunk_batches)} "
                         f"chunks but n_chunks={n_chunks}")
    device_plane = corpus is not None
    pool = state.cold
    state = dataclasses.replace(state, cold=None)
    slab_rows = chunk_slab_rows(spec, cfg, chunk_rounds)
    shardings = slab_shardings(spec, engine.mesh)
    carry = (state.key, state.stale, state.hot_ids)

    def make_chunk(i):
        # strict-order closure: the bookkeeping chain rides across calls
        nonlocal carry
        carry, plan = engine._plan(carry[0], carry[1], carry[2],
                                   n_rounds=chunk_rounds,
                                   device_plane=device_plane)
        uids, slab_plan = build_chunk_plan(jax.device_get(plan),
                                           slab_rows=slab_rows)
        slab_np = pool.gather(uids, slab_rows)
        slab = (jax.device_put(slab_np, shardings)
                if shardings is not None else jax.device_put(slab_np))
        plans = jax.tree_util.tree_map(jnp.asarray, slab_plan)
        return uids, jnp.asarray(pad_ids(uids, slab_rows)), slab, plans

    metrics_all = []
    prev = None                       # (uids, ids_pad, final_slab) of i-1
    with PageStreamer(make_chunk, n_chunks, depth=depth) as streamer:
        for i in range(n_chunks):
            uids, ids_pad, slab, plans = streamer.get()
            if prev is not None:
                slab = _patch_slab(slab, ids_pad, prev[2], prev[1])
            engine.dispatch_count += 1
            if device_plane:
                state, slab_f, met = engine._multi_device_host(
                    state, slab, plans, corpus, n_rounds=chunk_rounds)
            else:
                state, slab_f, met = engine._multi_host(
                    state, slab, chunk_batches[i], plans)
            if prev is not None:
                # blocks on chunk i-1 only — chunk i is already enqueued,
                # and the producer (gated on mark_written) can now gather
                # chunk i+1 while the device runs chunk i
                pool.writeback(prev[0], jax.device_get(prev[2]))
                streamer.mark_written(i - 1)
            prev = (uids, ids_pad, slab_f)
            metrics_all.append(met)
    if prev is not None:
        pool.writeback(prev[0], jax.device_get(prev[2]))
    state = dataclasses.replace(state, cold=pool)
    metrics = jax.tree_util.tree_map(
        lambda *xs: np.concatenate([np.asarray(x) for x in xs]),
        *metrics_all) if metrics_all else {}
    return state, metrics
