"""FAVAS unbiased straggler reweighting — paper eq. (3) and Lemma 10.

The client message is  w_unbiased^i = w_init^i + (w^i - w_init^i) / alpha^i,
with two admissible alphas:
  * "stochastic":     alpha^i = P(E^i > 0) * (E^i ∧ K)   (uses realized steps)
  * "deterministic":  alpha^i = E[E^i ∧ K]               (analytic moment)
Both make the expected submitted progress equal one full local pass
(Lemma 10: M1, M2 unbiased), removing the fast-client bias.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.sampler import moments_at_poll


def alpha_stochastic(q_steps, p_pos) -> jnp.ndarray:
    """alpha^i = P(E>0) * (E ∧ K); ``q_steps`` already capped at K.
    With shifted-geometric increments P(E>0) = 1."""
    return jnp.maximum(q_steps.astype(jnp.float32), 1e-6) * p_pos


def alpha_deterministic(lambdas: np.ndarray, K: int, poll_prob: float) -> np.ndarray:
    """alpha^i = E[E^i ∧ K] for each client (numpy, computed once offline)."""
    out = np.empty(lambdas.shape[0], np.float32)
    cache = {}
    for i, lam in enumerate(lambdas):
        lam_f = float(lam)
        if lam_f not in cache:
            cache[lam_f] = moments_at_poll(lam_f, K, poll_prob)[1]
        out[i] = cache[lam_f]
    return out


def unbiased_message_leaf(w_init, w, alpha):
    """One pytree leaf of the client message; ``alpha`` broadcasts over the
    leading client axis."""
    a = alpha.reshape((alpha.shape[0],) + (1,) * (w.ndim - 1))
    return w_init + (w - w_init) / a
