"""FAVAS server round — Algorithm 1 of the paper, vectorized over resident
clients and jit/pjit-compatible.

State layout (all pytrees of jnp arrays):
  server    : current server model w_t                      (model-sharded)
  clients   : stacked client models w^i, leading axis n     (client+model sharded)
  inits     : stacked w_init^i (last server model received)
  counters  : q^i in {0..K} — local steps since last reset
  stale     : rounds since the client was last selected (observability)

One round (server timestep t -> t+1):
  1. draw per-round step increments d^i ~ shifted-Geom(lambda^i)  [App. C.2]
  2. every client runs up to R masked local SGD steps: step k executes iff
     q^i + k < min(q^i + d^i, K)  — stragglers simply mask out; cost is
     uniform across the client mesh axis (no stragglers on the TPU itself,
     heterogeneity is *modeled*, as in the paper's simulation)
  3. draw S_t (Gumbel top-s), each selected client submits
     w_unbiased^i = w_init^i + (w^i - w_init^i)/alpha^i        [eq. (3)]
  4. w_{t+1} = (w_t + sum_{i in S_t} w_unbiased^i) / (s+1)     [line 10]
  5. selected clients reset: w^i = w_init^i = w_{t+1}, q^i = 0

Steps 3–5 run as ONE fused pass over flat parameter buffers through
``core.round_engine`` (Pallas kernel on TPU, jnp oracle on CPU); this module
keeps the pytree API by flattening/unflattening at the call boundary. The
seed's per-leaf ``tree_map`` implementation survives only as
``favas_round_reference`` — the numerical oracle the engine is regression-
tested against (tests/test_round_engine.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sampler, reweight, round_engine
from repro.core.quant import quantize_tree
from repro.core.round_engine import EngineState, _local_training
from repro.utils.tree import tree_map


@dataclasses.dataclass(frozen=True)
class FavasConfig:
    n_clients: int = 16
    s_selected: int = 4
    local_steps: int = 8            # K
    max_steps_per_round: int = 0    # R; 0 -> R = K
    eta: float = 0.1                # client LR (plain SGD, as in the paper)
    reweight: str = "stochastic"    # "stochastic" | "deterministic"
    slow_fraction: float = 1.0 / 3.0
    lam_fast: float = 1.0 / 16.0
    lam_slow: float = 0.5
    quant_bits: int = 0             # >0: LUQ-quantize client messages
    server_momentum: float = 0.0    # beyond-paper server-side momentum (off)
    seed: int = 0

    @property
    def R(self) -> int:
        return self.max_steps_per_round or self.local_steps


def client_lambdas(cfg: FavasConfig) -> np.ndarray:
    return sampler.make_lambdas(cfg.n_clients, cfg.slow_fraction,
                                cfg.lam_fast, cfg.lam_slow, cfg.seed)


def deterministic_alphas(cfg: FavasConfig) -> np.ndarray:
    poll_prob = cfg.s_selected / cfg.n_clients
    return reweight.alpha_deterministic(client_lambdas(cfg), cfg.local_steps,
                                        poll_prob)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FavasState:
    server: Any
    clients: Any
    inits: Any
    counters: jnp.ndarray          # (n,) int32
    stale: jnp.ndarray             # (n,) int32 — rounds since last selection
    key: jnp.ndarray
    t: jnp.ndarray                 # scalar int32

    def tree_flatten(self):
        return ((self.server, self.clients, self.inits, self.counters,
                 self.stale, self.key, self.t), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def favas_init(params, cfg: FavasConfig, key) -> FavasState:
    """All clients start from the server model (Algorithm 1 line 16)."""
    n = cfg.n_clients
    def stack(x):
        return jnp.broadcast_to(x[None], (n,) + x.shape)
    # clients and inits are DISTINCT buffers so a donating jit (e.g.
    # launch/steps.py build_train_step) never sees the same buffer twice
    return FavasState(
        server=params,
        clients=tree_map(stack, params),
        inits=tree_map(lambda x: stack(x).copy(), params),
        counters=jnp.zeros((n,), jnp.int32),
        stale=jnp.zeros((n,), jnp.int32),
        key=key,
        t=jnp.zeros((), jnp.int32),
    )


def _on_engine(engine_fn, state: FavasState, batch, *, cfg: FavasConfig,
               mesh, **kw):
    """Run an engine entry point (``engine_round`` / ``engine_multi_round``)
    with the pytree API: flatten the FavasState to an EngineState at the
    call boundary and unflatten the result. The one place the
    FavasState <-> EngineState mapping lives."""
    spec = round_engine.make_flat_spec(state.server, n_clients=cfg.n_clients,
                                       mesh=mesh)
    est = EngineState(
        server=round_engine.flatten_tree(spec, state.server),
        clients=round_engine.flatten_stacked(spec, state.clients),
        inits=round_engine.flatten_stacked(spec, state.inits),
        counters=state.counters, stale=state.stale,
        key=state.key, t=state.t)
    est, metrics = engine_fn(spec, est, batch, cfg=cfg, mesh=mesh, **kw)
    new_state = FavasState(
        server=round_engine.unflatten_tree(spec, est.server),
        clients=round_engine.unflatten_stacked(spec, est.clients),
        inits=round_engine.unflatten_stacked(spec, est.inits),
        counters=est.counters, stale=est.stale, key=est.key, t=est.t)
    return new_state, metrics


def favas_round(state: FavasState, batch, *, cfg: FavasConfig, loss_fn: Callable,
                lambdas, det_alpha: Optional[jnp.ndarray] = None,
                use_kernel: Optional[bool] = None, mesh=None):
    """One server round on the flat-buffer engine, pytree API preserved.
    Returns (new_state, metrics). Jit/pjit this.

    ``use_kernel``: None -> Pallas kernel on TPU, jnp oracle elsewhere;
    True/False force the choice (True runs interpret mode off-TPU).
    ``mesh``: bucket the flat buffers by (dtype, sharding group) and keep
    model-sharded leaves sharded through the fused round (no full-buffer
    gather; see core/round_engine.py and docs/architecture.md §6)."""
    return _on_engine(round_engine.engine_round, state, batch, cfg=cfg,
                      mesh=mesh, loss_fn=loss_fn, lambdas=lambdas,
                      det_alpha=det_alpha, use_kernel=use_kernel)


def favas_multi_round(state: FavasState, batches=None, *, cfg: FavasConfig,
                      loss_fn: Callable, lambdas,
                      det_alpha: Optional[jnp.ndarray] = None,
                      use_kernel: Optional[bool] = None, mesh=None,
                      corpus=None, n_rounds: Optional[int] = None):
    """A chunk of server rounds as ONE on-device scan, pytree API preserved
    (``round_engine.engine_multi_round`` under the hood). ``batches`` leaves
    carry a leading (T,) rounds axis; metrics come back (T,)-stacked. Jit
    this with donation and a T-round chunk costs one dispatch — bit-exact
    with T sequential :func:`favas_round` calls (the per-round key split
    makes the RNG streams identical).

    Device data plane: pass ``corpus`` (a
    ``data.device_corpus.DeviceCorpus``) + a static ``n_rounds`` instead of
    ``batches`` — the scan body then samples each round's minibatches from
    the resident corpus (docs/architecture.md §8)."""
    return _on_engine(round_engine.engine_multi_round, state, batches,
                      cfg=cfg, mesh=mesh, loss_fn=loss_fn, lambdas=lambdas,
                      det_alpha=det_alpha, use_kernel=use_kernel,
                      corpus=corpus, n_rounds=n_rounds)


def favas_round_reference(state: FavasState, batch, *, cfg: FavasConfig,
                          loss_fn: Callable, lambdas,
                          det_alpha: Optional[jnp.ndarray] = None):
    """The seed's per-leaf tree_map round — NOT on the hot path. Kept as the
    numerical oracle for the engine's regression tests: same PRNG splits,
    same arithmetic, leaf-by-leaf."""
    n, s, K = cfg.n_clients, cfg.s_selected, cfg.local_steps
    key, k_inc, k_sel, k_q = jax.random.split(state.key, 4)

    d = sampler.sample_increments(k_inc, lambdas)              # (n,)
    new_counters = jnp.minimum(state.counters + d, K)

    trained, loss_sum, live = _local_training(
        loss_fn, cfg, state.clients, state.counters, new_counters, batch)

    if cfg.reweight == "deterministic":
        alpha = det_alpha
    else:
        alpha = reweight.alpha_stochastic(new_counters, p_pos=1.0)
    progress = tree_map(jnp.subtract, trained, state.inits)
    if cfg.quant_bits > 0:
        progress = quantize_tree(progress, cfg.quant_bits, k_q)
    msgs = tree_map(
        lambda init, prog: init + prog / alpha.reshape((n,) + (1,) * (prog.ndim - 1)),
        state.inits, progress)

    m = sampler.sample_selection(k_sel, n, s)                  # (n,) float
    def agg(server_leaf, msg_leaf):
        mm = m.reshape((n,) + (1,) * (msg_leaf.ndim - 1))
        total = jnp.sum(mm * msg_leaf.astype(jnp.float32), axis=0)
        return ((server_leaf.astype(jnp.float32) + total) / (s + 1.0)
                ).astype(server_leaf.dtype)
    server_new = tree_map(agg, state.server, msgs)

    def reset(new_global, cur):
        mm = m.reshape((n,) + (1,) * (cur.ndim - 1))
        return (mm * new_global[None].astype(jnp.float32)
                + (1.0 - mm) * cur.astype(jnp.float32)).astype(cur.dtype)
    clients_new = tree_map(reset, server_new, trained)
    inits_new = tree_map(reset, server_new, state.inits)
    counters_new = jnp.where(m > 0, 0, new_counters).astype(jnp.int32)
    stale_new = jnp.where(m > 0, 0, state.stale + 1).astype(jnp.int32)

    new_state = FavasState(server=server_new, clients=clients_new,
                           inits=inits_new, counters=counters_new,
                           stale=stale_new, key=key, t=state.t + 1)
    metrics = {
        "loss": jnp.sum(loss_sum) / jnp.maximum(jnp.sum(live), 1.0),
        "mean_steps": jnp.mean(new_counters.astype(jnp.float32)),
        "selected": jnp.sum(m),
        "stale_rounds": jnp.max(stale_new).astype(jnp.float32),
    }
    return new_state, metrics


def favas_variance(state: FavasState) -> jnp.ndarray:
    """Paper's reported dispersion  sum_i ||w^i - w_t||^2  (Sec. 5).
    Vectorized: sum over leaves of sum((W - w)^2)."""
    d = tree_map(lambda W, w: jnp.sum(
        jnp.square(W.astype(jnp.float32) - w[None].astype(jnp.float32))),
        state.clients, state.server)
    return sum(jax.tree_util.tree_leaves(d))


def favas_mu(state: FavasState):
    """mu_t = (w_t + sum_i w_t^i) / (n+1) — the averaged model the theory
    tracks (eq. 4)."""
    n = state.counters.shape[0]
    return tree_map(
        lambda w, W: (w.astype(jnp.float32) + jnp.sum(W.astype(jnp.float32), 0))
        / (n + 1.0), state.server, state.clients)
