"""FAVAS server round — Algorithm 1 of the paper, vectorized over resident
clients and jit/pjit-compatible.

State layout (all pytrees of jnp arrays):
  server    : current server model w_t                      (model-sharded)
  clients   : stacked client models w^i, leading axis n     (client+model sharded)
  inits     : stacked w_init^i (last server model received)
  counters  : q^i in {0..K} — local steps since last reset
  opt_state : stacked per-client local-optimizer state (reset on selection)

One round (server timestep t -> t+1):
  1. draw per-round step increments d^i ~ shifted-Geom(lambda^i)  [App. C.2]
  2. every client runs up to R masked local SGD steps: step k executes iff
     q^i + k < min(q^i + d^i, K)  — stragglers simply mask out; cost is
     uniform across the client mesh axis (no stragglers on the TPU itself,
     heterogeneity is *modeled*, as in the paper's simulation)
  3. draw S_t (Gumbel top-s), each selected client submits
     w_unbiased^i = w_init^i + (w^i - w_init^i)/alpha^i        [eq. (3)]
  4. w_{t+1} = (w_t + sum_{i in S_t} w_unbiased^i) / (s+1)     [line 10]
  5. selected clients reset: w^i = w_init^i = w_{t+1}, q^i = 0

The aggregation in step 4 is a masked weighted reduction over the client
mesh axis — on hardware an all-reduce over ("pod","data"); `kernels/ops.py`
provides the fused Pallas path for the per-leaf arithmetic.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sampler, reweight
from repro.core.quant import quantize_tree
from repro.utils.tree import tree_map, tree_sq_dist


@dataclasses.dataclass(frozen=True)
class FavasConfig:
    n_clients: int = 16
    s_selected: int = 4
    local_steps: int = 8            # K
    max_steps_per_round: int = 0    # R; 0 -> R = K
    eta: float = 0.1                # client LR (plain SGD, as in the paper)
    reweight: str = "stochastic"    # "stochastic" | "deterministic"
    slow_fraction: float = 1.0 / 3.0
    lam_fast: float = 1.0 / 16.0
    lam_slow: float = 0.5
    quant_bits: int = 0             # >0: LUQ-quantize client messages
    server_momentum: float = 0.0    # beyond-paper server-side momentum (off)
    seed: int = 0

    @property
    def R(self) -> int:
        return self.max_steps_per_round or self.local_steps


def client_lambdas(cfg: FavasConfig) -> np.ndarray:
    return sampler.make_lambdas(cfg.n_clients, cfg.slow_fraction,
                                cfg.lam_fast, cfg.lam_slow, cfg.seed)


def deterministic_alphas(cfg: FavasConfig) -> np.ndarray:
    poll_prob = cfg.s_selected / cfg.n_clients
    return reweight.alpha_deterministic(client_lambdas(cfg), cfg.local_steps,
                                        poll_prob)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FavasState:
    server: Any
    clients: Any
    inits: Any
    counters: jnp.ndarray          # (n,) int32
    key: jnp.ndarray
    t: jnp.ndarray                 # scalar int32

    def tree_flatten(self):
        return ((self.server, self.clients, self.inits, self.counters,
                 self.key, self.t), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def favas_init(params, cfg: FavasConfig, key) -> FavasState:
    """All clients start from the server model (Algorithm 1 line 16)."""
    n = cfg.n_clients
    stacked = tree_map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), params)
    return FavasState(
        server=params,
        clients=stacked,
        inits=stacked,
        counters=jnp.zeros((n,), jnp.int32),
        key=key,
        t=jnp.zeros((), jnp.int32),
    )


def _local_training(loss_fn: Callable, cfg: FavasConfig, clients, counters,
                    new_counters, batch):
    """Masked K-step local SGD, vmapped over the client axis.

    batch: pytree with leading dims (n, R, ...) — one microbatch per client
    per potential local step."""

    def one_client(params, data, q0, q1):
        def step(p, inp):
            k, batch_k = inp
            loss, g = jax.value_and_grad(loss_fn)(p, batch_k)
            live = ((q0 + k) < q1).astype(jnp.float32)
            p = tree_map(lambda pp, gg: pp - cfg.eta * live * gg.astype(pp.dtype),
                         p, g)
            return p, loss * live
        ks = jnp.arange(cfg.R)
        params, losses = jax.lax.scan(step, params, (ks, data))
        denom = jnp.maximum((q1 - q0).astype(jnp.float32), 1.0)
        return params, jnp.sum(losses) / denom

    return jax.vmap(one_client)(clients, batch, counters, new_counters)


def favas_round(state: FavasState, batch, *, cfg: FavasConfig, loss_fn: Callable,
                lambdas, det_alpha: Optional[jnp.ndarray] = None):
    """One server round. Returns (new_state, metrics). Jit/pjit this."""
    n, s, K = cfg.n_clients, cfg.s_selected, cfg.local_steps
    key, k_inc, k_sel, k_q = jax.random.split(state.key, 4)

    # 1. heterogeneous progress this round
    d = sampler.sample_increments(k_inc, lambdas)              # (n,)
    new_counters = jnp.minimum(state.counters + d, K)

    # 2. masked local SGD
    trained, mean_loss = _local_training(loss_fn, cfg, state.clients,
                                         state.counters, new_counters, batch)

    # 3. unbiased client messages (eq. 3)
    if cfg.reweight == "deterministic":
        alpha = det_alpha
    else:
        alpha = reweight.alpha_stochastic(new_counters, p_pos=1.0)
    progress = tree_map(jnp.subtract, trained, state.inits)
    if cfg.quant_bits > 0:
        progress = quantize_tree(progress, cfg.quant_bits, k_q)
    msgs = tree_map(
        lambda init, prog: init + prog / alpha.reshape((n,) + (1,) * (prog.ndim - 1)),
        state.inits, progress)

    # 4. server aggregation (line 10): masked sum over the client axis
    m = sampler.sample_selection(k_sel, n, s)                  # (n,) float
    def agg(server_leaf, msg_leaf):
        mm = m.reshape((n,) + (1,) * (msg_leaf.ndim - 1))
        total = jnp.sum(mm * msg_leaf.astype(jnp.float32), axis=0)
        return ((server_leaf.astype(jnp.float32) + total) / (s + 1.0)
                ).astype(server_leaf.dtype)
    server_new = tree_map(agg, state.server, msgs)

    # 5. reset selected clients to the fresh server model
    def reset(new_global, cur):
        mm = m.reshape((n,) + (1,) * (cur.ndim - 1))
        return (mm * new_global[None].astype(jnp.float32)
                + (1.0 - mm) * cur.astype(jnp.float32)).astype(cur.dtype)
    clients_new = tree_map(reset, server_new, trained)
    inits_new = tree_map(reset, server_new, state.inits)
    counters_new = jnp.where(m > 0, 0, new_counters).astype(jnp.int32)

    new_state = FavasState(server=server_new, clients=clients_new,
                           inits=inits_new, counters=counters_new,
                           key=key, t=state.t + 1)
    metrics = {
        "loss": jnp.mean(mean_loss),
        "mean_steps": jnp.mean(new_counters.astype(jnp.float32)),
        "selected": jnp.sum(m),
    }
    return new_state, metrics


def favas_variance(state: FavasState) -> jnp.ndarray:
    """Paper's reported dispersion  sum_i ||w^i - w_t||^2  (Sec. 5).
    Vectorized: sum over leaves of sum((W - w)^2)."""
    d = tree_map(lambda W, w: jnp.sum(
        jnp.square(W.astype(jnp.float32) - w[None].astype(jnp.float32))),
        state.clients, state.server)
    return sum(jax.tree_util.tree_leaves(d))


def favas_mu(state: FavasState):
    """mu_t = (w_t + sum_i w_t^i) / (n+1) — the averaged model the theory
    tracks (eq. 4)."""
    n = state.counters.shape[0]
    return tree_map(
        lambda w, W: (w.astype(jnp.float32) + jnp.sum(W.astype(jnp.float32), 0))
        / (n + 1.0), state.server, state.clients)
