# FAVAS — the paper's primary contribution as a composable JAX module.
from repro.core.favas import (
    FavasConfig,
    FavasState,
    favas_init,
    favas_round,
    favas_multi_round,
    favas_round_reference,
    favas_variance,
    favas_mu,
    client_lambdas,
    deterministic_alphas,
)
from repro.core.round_engine import (
    EngineState,
    FlatSpec,
    RoundEngine,
    engine_init,
    engine_round,
    engine_multi_round,
    make_flat_spec,
)
from repro.core.quant import luq_quantize, quantize_tree
from repro.core.fl_sim import SimConfig, run_simulation
