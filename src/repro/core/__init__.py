# FAVAS — the paper's primary contribution as a composable JAX module.
from repro.core.favas import (
    FavasConfig,
    FavasState,
    favas_init,
    favas_round,
    favas_variance,
    favas_mu,
    client_lambdas,
    deterministic_alphas,
)
from repro.core.quant import luq_quantize, quantize_tree
from repro.core.fl_sim import SimConfig, run_simulation
