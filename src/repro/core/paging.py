"""Cold-pool codecs for the paged client-state residency layer.

The paged engine (docs/architecture.md §9) keeps only a hot working set of
``s_max`` client rows in full precision; the remaining ``n - s_max``
clients live in a *cold pool* — one encoded row per client per bucket,
written on eviction and read on promotion. This module owns the encodings:

* :class:`PassthroughCodec` — stores the rows verbatim. Zero compression,
  but evict -> promote is bitwise identity, which is what makes the paged
  engine provably equal to the dense engine (the parity lattice in
  tests/test_paged_engine.py runs on this codec).
* :class:`LuqCodec` — LUQ logarithmic unbiased quantization (the same
  math as ``core.quant`` / ``kernels.luq``, FAVAS[QNN] paper Remark 1)
  at 2/4/8 bits, bit-packed into uint8, with a per-(row, shard) scale.
  A client row costs ``2 * D * bits / 8`` bytes (progress + init pools)
  instead of ``2 * D * 4`` — the resident-population lever of ROADMAP
  open item 1. The pair encoding stores the INIT row and the PROGRESS
  relative to the *decoded* init (``cli - dequant(init)``), so the
  reconstruction ``init_dec + prog_dec`` pays the progress quantization
  error once instead of compounding the init error.

Codecs are frozen (hashable) dataclasses so they can ride inside the
static ``FlatSpec``; the encoded representation is a plain dict-of-arrays
pytree so cold pools flow through jit/scan/donation like any buffer.
Per-shard scales keep encode/decode shard-local on a §6 mesh: the flat
lane axis is shard-major, so reshaping ``(rows, Dp)`` to ``(rows, S,
Dp/S)`` and reducing the last axis never crosses a device boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Bit packing: b-bit codes <-> uint8 lanes
# ---------------------------------------------------------------------------

def pack_codes(codes, bits: int):
    """(..., C) uint8 codes (< 2**bits) -> (..., C*bits/8) packed uint8.

    C must divide by 8//bits; the flat-buffer lane padding (multiples of
    the 128-lane kernel tile) guarantees that for bits in {2, 4, 8}."""
    k = 8 // bits
    if k == 1:
        return codes.astype(jnp.uint8)
    if codes.shape[-1] % k:
        raise ValueError(f"cannot pack {codes.shape[-1]} codes into "
                         f"{bits}-bit groups of {k}")
    parts = codes.reshape(codes.shape[:-1] + (-1, k)).astype(jnp.uint8)
    out = parts[..., 0]
    for i in range(1, k):
        out = out | (parts[..., i] << jnp.uint8(i * bits))
    return out


def unpack_codes(packed, bits: int):
    """Inverse of :func:`pack_codes`: (..., P) uint8 -> (..., P*8/bits)."""
    k = 8 // bits
    if k == 1:
        return packed
    mask = jnp.uint8((1 << bits) - 1)
    cols = [(packed >> jnp.uint8(i * bits)) & mask for i in range(k)]
    return jnp.stack(cols, axis=-1).reshape(packed.shape[:-1] + (-1,))


# ---------------------------------------------------------------------------
# Row-wise LUQ encode/decode (code-emitting variant of core.quant.luq_quantize)
# ---------------------------------------------------------------------------

def luq_encode_rows(x, bits: int, key, *, shards: int = 1) -> Dict:
    """LUQ-encode (rows, D) to packed codes + per-(row, shard) scales.

    Same stochastic prune + log2 stochastic rounding as ``kernels.ref.
    luq_ref`` (decode(encode(x)) equals ``luq_ref`` for the same uniforms
    — pinned by tests/test_quant_codec.py), but emitting the b-bit code
    ``sign << (bits-1) | m`` with magnitude index m in {0..L} (0 = exact
    zero, m -> exponent m - L) instead of the dequantized float. The scale
    is the guarded per-(row, shard) max |x| (``core.quant.luq_scale``
    semantics: all-zero segments map to scale 1.0, so decode is exact
    zeros, the PR 2 all-zero regression; a NaN max PROPAGATES so a
    poisoned segment decodes loudly non-finite instead of quantizing
    against 1.0 — pinned by tests/test_quant_codec.py)."""
    levels = 2 ** (bits - 1) - 1
    rows, D = x.shape
    if D % shards:
        raise ValueError(f"D={D} does not divide into {shards} shards")
    from repro.kernels.luq import guard_scale    # lazy: no import cycle
    xf = x.astype(jnp.float32)
    xs = xf.reshape(rows, shards, D // shards)
    scale = guard_scale(jnp.max(jnp.abs(xs), axis=2))
    m = jnp.abs(xs) / scale[..., None]
    min_level = 2.0 ** (-(levels - 1))
    k1, k2 = jax.random.split(key)
    # draw at (rows, D) so the uniforms line up element-for-element with a
    # caller passing explicit (rows, D) fields to kernels.ref.luq_ref
    up = jax.random.uniform(k1, (rows, D)).reshape(xs.shape)
    ur = jax.random.uniform(k2, (rows, D)).reshape(xs.shape)
    below = m < min_level
    keep = up < (m / min_level)
    m_pruned = jnp.where(below, jnp.where(keep, min_level, 0.0), m)
    e = jnp.floor(jnp.log2(jnp.maximum(m_pruned, min_level)))
    f = m_pruned / jnp.exp2(e)
    e_hat = jnp.clip(e + (ur < (f - 1.0)).astype(jnp.float32),
                     -(levels - 1), 0.0)
    midx = jnp.where(m_pruned == 0.0, 0,
                     (e_hat + levels).astype(jnp.int32))
    sign = (xs < 0).astype(jnp.int32)
    codes = ((sign << (bits - 1)) | midx).reshape(rows, D).astype(jnp.uint8)
    return {"codes": pack_codes(codes, bits), "scale": scale}


def luq_decode_rows(enc: Dict, bits: int, dtype, *, shards: int = 1):
    """Inverse of :func:`luq_encode_rows` -> (rows, D) in ``dtype``."""
    levels = 2 ** (bits - 1) - 1
    codes = unpack_codes(enc["codes"], bits)
    rows, D = codes.shape
    midx = (codes & jnp.uint8((1 << (bits - 1)) - 1)).astype(jnp.int32)
    sign = (codes >> jnp.uint8(bits - 1)).astype(jnp.float32)
    q = jnp.where(midx == 0, 0.0,
                  jnp.exp2(midx.astype(jnp.float32) - levels))
    v = ((1.0 - 2.0 * sign) * q).reshape(rows, shards, D // shards)
    v = v * enc["scale"][..., None].astype(jnp.float32)
    return v.reshape(rows, D).astype(dtype)


# ---------------------------------------------------------------------------
# Codecs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PassthroughCodec:
    """Identity cold codec: rows are stored verbatim (client AND init).

    No compression — this codec exists so the paged control flow (select ->
    gather -> fused round -> scatter-back) can be proven BIT-EXACT against
    the dense engine, independently of any quantization effect."""

    def encode_pair(self, cli, init, key, *, shards: int = 1,
                    use_kernel=None) -> Dict:
        del key, shards, use_kernel
        return {"cli": cli, "init": init}

    def decode_pair(self, enc: Dict, dtype, *, shards: int = 1,
                    use_kernel=None):
        del shards, use_kernel
        return enc["cli"].astype(dtype), enc["init"].astype(dtype)

    def bytes_per_row(self, d_padded: int, dtype, *, shards: int = 1) -> int:
        del shards                      # verbatim rows carry no scale
        return 2 * d_padded * jnp.dtype(dtype).itemsize

    def partition_specs(self, sharded: bool, axis: str = "model") -> Dict:
        from jax.sharding import PartitionSpec as P
        lane = P(None, axis if sharded else None)
        return {"cli": lane, "init": lane}


@dataclasses.dataclass(frozen=True)
class LuqCodec:
    """LUQ cold codec: init + progress pools, bit-packed at ``bits``.

    ``encode_pair`` stores (a) the init row LUQ-quantized and (b) the
    progress ``cli - dequant(init)`` LUQ-quantized — both with per-(row,
    shard) scales — so a cold client costs ``2 * D * bits / 8`` bytes plus
    two f32 scales per shard. Stochastic (unbiased) by construction: the
    requant noise of an evict/promote cycle has zero mean, the same
    principle that makes FAVAS[QNN]'s transmitted-progress quantization
    sound (paper Remark 1)."""
    bits: int = 4

    def __post_init__(self):
        if self.bits not in (2, 4, 8):
            raise ValueError(f"LuqCodec bits must be 2, 4 or 8 "
                             f"(got {self.bits})")

    def encode_pair(self, cli, init, key, *, shards: int = 1,
                    use_kernel=None) -> Dict:
        # route through kernels.ops so the requant dispatch point is shared
        # with the rest of the kernel surface: ``use_kernel`` picks the
        # code-emitting Pallas kernel exactly like the fused-round knob
        # (None = TPU auto, True = kernel / interpret off-TPU, False = jnp
        # oracle — the two are bit-identical under shared uniforms)
        from repro.kernels.ops import cold_dequant_rows, cold_requant_rows
        k_i, k_p = jax.random.split(key)
        ie = cold_requant_rows(init, self.bits, k_i, shards=shards,
                               use_kernel=use_kernel)
        init_dec = cold_dequant_rows(ie, self.bits, jnp.float32,
                                     shards=shards, use_kernel=use_kernel)
        prog = cli.astype(jnp.float32) - init_dec
        pe = cold_requant_rows(prog, self.bits, k_p, shards=shards,
                               use_kernel=use_kernel)
        return {"init": ie, "prog": pe}

    def decode_pair(self, enc: Dict, dtype, *, shards: int = 1,
                    use_kernel=None):
        from repro.kernels.ops import cold_dequant_rows
        init = cold_dequant_rows(enc["init"], self.bits, jnp.float32,
                                 shards=shards, use_kernel=use_kernel)
        cli = init + cold_dequant_rows(enc["prog"], self.bits, jnp.float32,
                                       shards=shards, use_kernel=use_kernel)
        return cli.astype(dtype), init.astype(dtype)

    def bytes_per_row(self, d_padded: int, dtype, *, shards: int = 1) -> int:
        del dtype
        # two pools (init + progress), each d_padded*bits/8 code bytes plus
        # ONE f32 scale per (row, shard) — on a §6 mesh the scale is
        # per-shard so encode/decode stay shard-local, and the cost scales
        # with the shard count (previously hard-coded to a single + 4)
        return 2 * (d_padded * self.bits // 8 + 4 * shards)

    def partition_specs(self, sharded: bool, axis: str = "model") -> Dict:
        from jax.sharding import PartitionSpec as P
        lane = P(None, axis if sharded else None)
        one = {"codes": lane, "scale": lane}
        return {"init": dict(one), "prog": dict(one)}


def make_codec(cold_bits: int):
    """CLI-facing factory: 0 -> passthrough, {2,4,8} -> LUQ at that width."""
    return PassthroughCodec() if cold_bits <= 0 else LuqCodec(bits=cold_bits)


def encoded_nbytes(enc) -> int:
    """Actual device bytes of an encoded pool (or any pytree of arrays)."""
    return sum(leaf.size * jnp.dtype(leaf.dtype).itemsize
               for leaf in jax.tree_util.tree_leaves(enc)
               if leaf is not None)
