"""LUQ — Logarithmic Unbiased Quantization (Chmiel et al., 2021), as used by
FAVAS[QNN] (paper Remark 1 / Remark 6 / Fig. 7).

Grid: sign * scale * 2^{-j}, j in {0 .. L-1}, L = 2^(bits-1) - 1 exponent
levels, plus 0. Two unbiasedness mechanisms:
  * values below the smallest level are *stochastically pruned*: kept at the
    smallest level with probability value/min_level (E[q] = value);
  * mantissas are *stochastically rounded* in log2 domain:
    round exponent up with prob (m/2^floor(log2 m) - 1), so E[2^e_hat] = m.

The paper's Remark 5 only needs ||Q(x) - x||^2 <= r_d; LUQ additionally
gives E[Q(x)] = x, which our property tests check.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def luq_scale(x):
    """Guarded LUQ global scale: max |x| in f32, with all-zero inputs mapped
    to scale 1.0 so the magnitude normalization never divides by zero. The
    one host-side scale computation shared by every LUQ entry point (this
    module's simulation path, ``kernels.ops.luq_quantize``'s oracle path,
    and ``kernels.luq.luq_pallas``'s scale reduction). ``ref.luq_ref`` and
    the kernel body take scale as an explicit operand and keep their own
    idempotent guard, since callers there may pass a raw max.

    Guard semantics (pinned by tests/test_quant_codec.py): zero -> 1.0,
    positive and +Inf pass through, and a NaN max PROPAGATES — an input
    poisoned with NaN must quantize to something loudly non-finite, never
    silently against scale 1.0 (``NaN > 0`` is False, so the plain
    zero-guard used to do exactly that)."""
    from repro.kernels.luq import guard_scale    # lazy: no import cycle
    return guard_scale(jnp.max(jnp.abs(x.astype(jnp.float32))))


def luq_quantize(x, bits: int, key):
    """Unbiased log quantization of ``x``. Returns dequantized values
    (same shape/dtype) — simulation of low-precision comms/training."""
    if bits <= 1:
        raise ValueError("LUQ needs >= 2 bits (sign + >=1 exponent bit)")
    levels = 2 ** (bits - 1) - 1                    # exponent levels
    xf = x.astype(jnp.float32)
    sign = jnp.sign(xf)
    mag = jnp.abs(xf)
    scale = luq_scale(x)
    m = mag / scale                                  # in [0, 1]
    min_level = 2.0 ** (-(levels - 1))

    k_prune, k_round = jax.random.split(key)
    u_prune = jax.random.uniform(k_prune, x.shape)
    u_round = jax.random.uniform(k_round, x.shape)

    # stochastic pruning of the underflow region (unbiased)
    below = m < min_level
    keep = u_prune < (m / min_level)
    m_pruned = jnp.where(below, jnp.where(keep, min_level, 0.0), m)

    # log-domain stochastic rounding (unbiased): m = 2^e * f, f in [1,2)
    e = jnp.floor(jnp.log2(jnp.maximum(m_pruned, min_level)))
    f = m_pruned / jnp.exp2(e)
    e_hat = e + (u_round < (f - 1.0)).astype(jnp.float32)
    q = jnp.where(m_pruned == 0.0, 0.0, jnp.exp2(jnp.clip(e_hat, -(levels - 1), 0.0)))
    return (sign * scale * q).astype(x.dtype)


def quantize_tree(tree, bits: int, key):
    """LUQ-quantize every floating leaf with independent keys."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [luq_quantize(l, bits, k) if jnp.issubdtype(l.dtype, jnp.floating) else l
           for l, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)
