"""Flat-buffer FAVAS round engine.

The FAVAS server round is memory-bound: every byte of every resident
client's parameters crosses HBM each round (eq. 3 reweight, line-10
aggregation, line-11/12 selected-client reset). The seed implementation did
that as ~6 separate full-parameter ``tree_map`` passes per round. This
engine instead:

* flattens the parameter pytree ONCE into contiguous flat buffers — a
  ``(Dp,)`` server vector and ``(n, Dp)`` clients / inits matrices per
  dtype bucket, pre-padded to the kernel lane tile so the Pallas path never
  re-pads — and holds them across rounds;
* runs the whole aggregation + reset as ONE streamed pass per tile through
  the multi-output Pallas kernel ``kernels.favas_agg.favas_fused_pallas``
  (TPU; interpret for validation) or its jnp oracle
  ``kernels.ref.favas_fused_ref`` (CPU default — XLA fuses the flat-buffer
  expression into a single loop, which is already the oracle's point);
* unflattens only at the boundaries that need model structure: the vmapped
  local-SGD step (which needs the pytree for the model's loss), evaluation,
  and checkpoint export.

``core.favas.favas_round`` keeps the seed's pytree API by wrapping
``engine_round`` with flatten/unflatten at the call boundary;
``launch.train`` uses ``RoundEngine`` directly so the buffers genuinely
persist across rounds and the jitted round donates them.

Beyond the fused single round, ``engine_multi_round`` /
``RoundEngine.run`` scan a whole CHUNK of rounds on-device — one jitted,
buffer-donating dispatch and one stacked metrics fetch per chunk instead
of per round ("supersteps", docs/architecture.md §7) — which removes the
per-round host dispatch + sync overhead that dominates FAVAS's cheap,
frequent server rounds.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import sampler, reweight
from repro.core.paging import PassthroughCodec, make_codec
from repro.core.quant import quantize_tree
from repro.kernels.favas_agg import CLIENT_TILE, TILE
from repro.kernels.ops import favas_fused_flat
from repro.utils.tree import tree_map


# ---------------------------------------------------------------------------
# FlatSpec: static description of the pytree <-> flat-buffer mapping
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static (hashable, trace-free) layout of a parameter pytree flattened
    into one contiguous buffer per (leaf dtype, sharding group) "bucket".

    Leaves keep their original dtype; mixed-precision trees get one buffer
    per dtype so no storage precision is lost. Buffer length is padded up to
    a multiple of the kernel lane tile; the padded tail is zero-initialized
    and provably stays zero under the fused round update (the masked padded
    "server" tail aggregates only zeros).

    When built with ``n_clients``, the spec is client-aware: stacked buffers
    additionally pad the client (row) axis up to a multiple of the kernel's
    ``client_tile`` once n exceeds one client block, so the tiled kernel
    never re-pads either axis. Padded rows are all-zero with zero selection
    mask and unit alpha — they contribute exactly nothing to the masked
    aggregation and provably stay zero across rounds.

    When built with ``mesh`` (or explicit ``shard_axes``/``model_shards``),
    the spec is additionally *sharding-aware* (docs/architecture.md §6):
    leaves whose resolved PartitionSpec (``sharding/rules.py``) puts a dim
    on the "model" mesh axis land in a separate bucket per dtype, laid out
    SHARD-MAJOR — the flat buffer is the concatenation over the S model
    shards of that shard's slice of every leaf, each per-shard segment
    independently padded to the lane tile. Partitioning the flat axis into S
    equal contiguous blocks (``PartitionSpec("model")``) therefore hands
    each device exactly its own leaf shards: flatten, the fused round, and
    unflatten all stay communication-free on the model axis (no full-buffer
    all-gather; see ``fused_bucket_update``). Invariant:
    ``bucket_padded[b] == bucket_shards[b] * bucket_shard_padded[b]``.
    """
    treedef: Any
    shapes: tuple                 # per leaf, original shape
    dtypes: tuple                 # per leaf, jnp dtype name (str, hashable)
    bucket_of: tuple              # per leaf, bucket index
    offsets: tuple                # per leaf, start offset within its bucket
    #                               (per-shard units for sharded buckets)
    bucket_dtypes: tuple          # per bucket, dtype name
    bucket_sizes: tuple           # per bucket, unpadded element count (total)
    bucket_padded: tuple          # per bucket, padded element count (total)
    n_clients: Optional[int] = None   # logical client rows (None: not stacked)
    n_padded: Optional[int] = None    # stored client rows incl. padding
    client_tile: Optional[int] = None  # kernel client-axis tile
    shard_axes: tuple = ()        # per leaf, model-sharded dim index or None
    bucket_shards: tuple = ()     # per bucket, model shard count (1 = replicated)
    bucket_shard_sizes: tuple = ()   # per bucket, unpadded elements PER SHARD
    bucket_shard_padded: tuple = ()  # per bucket, padded elements PER SHARD
    mesh_axis: Optional[str] = None  # mesh axis sharded buckets live on
    # residency axis (docs/architecture.md §9): "dense" keeps all n client
    # rows in full precision; "paged" keeps a hot working set of s_max rows
    # plus a codec-encoded cold pool covering all n clients
    residency: str = "dense"
    s_max: Optional[int] = None        # hot rows (logical), paged specs only
    s_hot_padded: Optional[int] = None  # hot rows incl. client-tile padding
    cold_codec: Any = None             # hashable codec (core.paging)

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_dtypes)

    def shards(self, b: int) -> int:
        """Model shard count of bucket ``b`` (1 for pre-sharding specs)."""
        return self.bucket_shards[b] if self.bucket_shards else 1

    @property
    def paged(self) -> bool:
        return self.residency == "paged"

    @property
    def stacked_logical(self) -> Optional[int]:
        """Logical rows of the client/init stacks the state carries: the hot
        working set for paged specs, all clients for dense ones."""
        return self.s_max if self.paged else self.n_clients

    @property
    def stacked_rows(self) -> Optional[int]:
        """Stored rows of the client/init stacks (incl. client-tile pad)."""
        return self.s_hot_padded if self.paged else self.n_padded


def make_flat_spec(tree, *, tile: int = TILE, n_clients: Optional[int] = None,
                   client_tile: int = CLIENT_TILE, mesh=None,
                   shard_axes: Optional[Sequence] = None,
                   model_shards: Optional[int] = None,
                   residency: str = "dense", s_max: Optional[int] = None,
                   cold_codec=None) -> FlatSpec:
    """Build the layout from a pytree of arrays / ShapeDtypeStructs.

    ``n_clients``: make the spec client-aware (see class docstring). Row
    padding only kicks in beyond one client block (n > client_tile), so
    small federations carry no extra rows.

    ``mesh``: make the spec sharding-aware — leaves are classified through
    ``sharding.rules.model_shard_axes`` (the same regex rules pjit uses)
    and model-sharded leaves get their own shard-major bucket per dtype.
    ``shard_axes`` (a per-leaf list of dim indices / None, aligned with
    ``tree_leaves``) overrides the rule lookup; ``model_shards`` overrides
    the shard count (needed when passing ``shard_axes`` without a mesh —
    layout is pure metadata and never touches devices). A leaf whose
    nominated dim does not divide by the shard count falls back to the
    replicated bucket, mirroring ``sharding.rules.check_divisible``.

    ``residency="paged"``: virtualize the client axis (docs/architecture.md
    §9) — the state's stacks hold only ``s_max`` hot rows (padded with the
    same client-tile formula as the dense n), and a ``cold_codec``-encoded
    pool covers all n clients. ``s_max`` defaults to (and is clamped at)
    ``n_clients``; at ``s_max == n_clients`` the hot set is the whole
    id-ordered population and the paged round is bit-exact with the dense
    one. ``cold_codec`` defaults to the passthrough (identity) codec."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    S0 = model_shards or 1
    if mesh is not None and model_shards is None:
        S0 = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if shard_axes is None:
        if mesh is not None and S0 > 1:
            from repro.sharding.rules import model_shard_axes  # lazy: no cycle
            shard_axes = model_shard_axes(tree, mesh)
        else:
            shard_axes = [None] * len(leaves)
    if len(shard_axes) != len(leaves):
        raise ValueError(
            f"shard_axes has {len(shard_axes)} entries for {len(leaves)} leaves")
    shapes, dtypes, bucket_of, offsets, axes_out = [], [], [], [], []
    keys, bucket_dtypes, shards_l, cursors = [], [], [], []
    for leaf, ax in zip(leaves, shard_axes):
        dt = jnp.dtype(leaf.dtype).name
        size = 1
        for d in leaf.shape:
            size *= int(d)
        if (ax is not None and (S0 <= 1 or ax >= len(leaf.shape)
                                or leaf.shape[ax] % S0 != 0)):
            ax = None                    # non-dividing dim: replicate
        key = (dt, ax is not None)
        if key not in keys:
            keys.append(key)
            bucket_dtypes.append(dt)
            shards_l.append(S0 if ax is not None else 1)
            cursors.append(0)
        b = keys.index(key)
        shapes.append(tuple(leaf.shape))
        dtypes.append(dt)
        bucket_of.append(b)
        offsets.append(cursors[b])
        cursors[b] += size // shards_l[b]
        axes_out.append(ax)
    shard_padded = tuple(c + ((-c) % tile) for c in cursors)
    padded = tuple(sp * s for sp, s in zip(shard_padded, shards_l))
    sizes = tuple(c * s for c, s in zip(cursors, shards_l))
    n_padded = None
    if n_clients is not None:
        n_padded = (n_clients if n_clients <= client_tile
                    else n_clients + ((-n_clients) % client_tile))
    s_hot_padded = None
    if residency == "paged":
        if n_clients is None:
            raise ValueError("residency='paged' requires n_clients")
        s_max = n_clients if s_max is None else min(int(s_max), n_clients)
        if s_max < 1:
            raise ValueError(f"s_max must be >= 1 (got {s_max})")
        # same padding formula as the dense client axis, so at s_max == n
        # the hot stacks have exactly the dense shapes (the parity regime)
        s_hot_padded = (s_max if s_max <= client_tile
                        else s_max + ((-s_max) % client_tile))
        cold_codec = cold_codec if cold_codec is not None else PassthroughCodec()
    else:
        s_max, cold_codec = None, None
    return FlatSpec(treedef=treedef, shapes=tuple(shapes), dtypes=tuple(dtypes),
                    bucket_of=tuple(bucket_of), offsets=tuple(offsets),
                    bucket_dtypes=tuple(bucket_dtypes),
                    bucket_sizes=sizes, bucket_padded=padded,
                    n_clients=n_clients, n_padded=n_padded,
                    client_tile=client_tile if n_clients is not None else None,
                    shard_axes=tuple(axes_out),
                    bucket_shards=tuple(shards_l),
                    bucket_shard_sizes=tuple(cursors),
                    bucket_shard_padded=shard_padded,
                    mesh_axis="model" if any(s > 1 for s in shards_l) else None,
                    residency=residency, s_max=s_max,
                    s_hot_padded=s_hot_padded, cold_codec=cold_codec)


def flatten_tree(spec: FlatSpec, tree) -> tuple:
    """Pytree -> tuple of (Dp_b,) flat buffers (one per spec bucket).

    Sharded buckets are laid out shard-major: leaf dims sharded on the model
    axis move to the front and split into S rows before concatenation, so
    every op here is shard-local under GSPMD (transpose + reshape of the
    sharded dim by exactly the shard count — no cross-device data motion)."""
    leaves = jax.tree_util.tree_leaves(tree)
    parts = [[] for _ in range(spec.n_buckets)]
    for leaf, b, ax in zip(leaves, spec.bucket_of, spec.shard_axes):
        S = spec.shards(b)
        if S > 1:
            parts[b].append(jnp.moveaxis(leaf, ax, 0).reshape(S, -1))
        else:
            parts[b].append(jnp.ravel(leaf))
    out = []
    for b in range(spec.n_buckets):
        S = spec.shards(b)
        if S > 1:
            buf = (jnp.concatenate(parts[b], axis=1) if len(parts[b]) > 1
                   else parts[b][0])
            pad = spec.bucket_shard_padded[b] - spec.bucket_shard_sizes[b]
            if pad:
                buf = jnp.pad(buf, ((0, 0), (0, pad)))
            out.append(buf.reshape(-1))
        else:
            buf = jnp.concatenate(parts[b]) if len(parts[b]) > 1 else parts[b][0]
            pad = spec.bucket_padded[b] - spec.bucket_sizes[b]
            if pad:
                buf = jnp.pad(buf, (0, pad))
            out.append(buf)
    return tuple(out)


def flatten_stacked(spec: FlatSpec, tree) -> tuple:
    """Client-stacked pytree (leading axis n) -> tuple of (Np_b, Dp_b).

    With a client-aware spec the row axis is zero-padded up to
    ``spec.n_padded`` so the tiled kernel path never re-pads."""
    leaves = jax.tree_util.tree_leaves(tree)
    n = leaves[0].shape[0]
    rpad = 0
    if spec.stacked_rows is not None:
        # loud failure instead of silently mis-padding: a client-aware spec
        # only describes trees with exactly stacked_logical rows (n_clients
        # dense, the s_max hot working set paged)
        if n != spec.stacked_logical:
            raise ValueError(
                f"stacked tree has {n} client rows but the spec stacks "
                f"{spec.stacked_logical} ({spec.residency})")
        rpad = spec.stacked_rows - n
    parts = [[] for _ in range(spec.n_buckets)]
    for leaf, b, ax in zip(leaves, spec.bucket_of, spec.shard_axes):
        S = spec.shards(b)
        if S > 1:
            parts[b].append(jnp.moveaxis(leaf, 1 + ax, 1).reshape(n, S, -1))
        else:
            parts[b].append(leaf.reshape(n, -1))
    out = []
    for b in range(spec.n_buckets):
        S = spec.shards(b)
        if S > 1:
            buf = (jnp.concatenate(parts[b], axis=2) if len(parts[b]) > 1
                   else parts[b][0])
            pad = spec.bucket_shard_padded[b] - spec.bucket_shard_sizes[b]
            if pad or rpad:
                buf = jnp.pad(buf, ((0, rpad), (0, 0), (0, pad)))
            out.append(buf.reshape(n + rpad, spec.bucket_padded[b]))
        else:
            buf = (jnp.concatenate(parts[b], axis=1) if len(parts[b]) > 1
                   else parts[b][0])
            pad = spec.bucket_padded[b] - spec.bucket_sizes[b]
            if pad or rpad:
                buf = jnp.pad(buf, ((0, rpad), (0, pad)))
            out.append(buf)
    return tuple(out)


def unflatten_tree(spec: FlatSpec, bufs: Sequence):
    """Tuple of (Dp_b,) buffers -> pytree with the original leaf layout.
    Sharded buckets invert the shard-major layout (shard-local under GSPMD,
    exact inverse of ``flatten_tree`` — round-trips are bit-exact)."""
    leaves = []
    for shape, dt, b, off, ax in zip(spec.shapes, spec.dtypes, spec.bucket_of,
                                     spec.offsets, spec.shard_axes):
        size = 1
        for d in shape:
            size *= d
        S = spec.shards(b)
        if S > 1:
            rows = bufs[b].reshape(S, spec.bucket_shard_padded[b])
            rows = jax.lax.dynamic_slice_in_dim(rows, off, size // S, axis=1)
            moved = (shape[ax],) + shape[:ax] + shape[ax + 1:]
            leaves.append(jnp.moveaxis(rows.reshape(moved), 0, ax))
        else:
            leaves.append(jax.lax.dynamic_slice_in_dim(bufs[b], off, size)
                          .reshape(shape))
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def unflatten_stacked(spec: FlatSpec, bufs: Sequence):
    """Tuple of (Np_b, Dp_b) buffers -> client-stacked pytree (padded client
    rows, if any, are dropped)."""
    leaves = []
    for shape, dt, b, off, ax in zip(spec.shapes, spec.dtypes, spec.bucket_of,
                                     spec.offsets, spec.shard_axes):
        buf = bufs[b]
        n = buf.shape[0]
        if spec.stacked_rows is not None:
            if n != spec.stacked_rows:
                raise ValueError(
                    f"stacked buffer has {n} rows but the spec stores "
                    f"{spec.stacked_rows} ({spec.residency})")
            if spec.stacked_logical < n:
                n = spec.stacked_logical
                buf = buf[:n]
        size = 1
        for d in shape:
            size *= d
        S = spec.shards(b)
        if S > 1:
            rows = buf.reshape(n, S, spec.bucket_shard_padded[b])
            rows = jax.lax.dynamic_slice_in_dim(rows, off, size // S, axis=2)
            moved = (n, shape[ax]) + shape[:ax] + shape[ax + 1:]
            leaves.append(jnp.moveaxis(rows.reshape(moved), 1, 1 + ax))
        else:
            leaves.append(
                jax.lax.dynamic_slice_in_dim(buf, off, size, axis=1)
                .reshape((n,) + shape))
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def pad_client_vec(spec: FlatSpec, v, fill: float = 0.0):
    """(n,) per-client vector -> (Np,) padded to the spec's stored rows.
    ``fill``: value for padded rows (0 for masks — padded rows are never
    selected; 1 for alphas — keeps the guarded division trivially exact)."""
    if spec.stacked_rows is None:
        return v
    if v.shape[0] != spec.stacked_logical:
        raise ValueError(
            f"per-client vector has {v.shape[0]} rows but the spec stacks "
            f"{spec.stacked_logical} ({spec.residency})")
    rpad = spec.stacked_rows - spec.stacked_logical
    if not rpad:
        return v
    return jnp.concatenate([v, jnp.full((rpad,), fill, v.dtype)])


def stack_server_rows(spec: FlatSpec, server_bufs: Sequence, n: int) -> tuple:
    """Server flat buffers -> client/init row stacks: the server row
    broadcast to n clients plus all-zero padded rows up to the spec's stored
    row count. Each result is a DISTINCT buffer (broadcasts are materialized)
    so a donating jit never sees the same buffer twice."""
    if spec.stacked_logical is not None and n != spec.stacked_logical:
        raise ValueError(
            f"stacking {n} client rows but the spec stacks "
            f"{spec.stacked_logical} ({spec.residency})")
    rows = spec.stacked_rows or n
    out = []
    for b in server_bufs:
        buf = jnp.broadcast_to(b[None], (n,) + b.shape)
        buf = (jnp.pad(buf, ((0, rows - n), (0, 0))) if rows > n
               else buf.copy())
        out.append(buf)
    return tuple(out)


# ---------------------------------------------------------------------------
# Mesh-aware execution: shardings, constraints, and the per-bucket fused call
# ---------------------------------------------------------------------------

def bucket_partition_specs(spec: FlatSpec, *, stacked: bool) -> tuple:
    """Per-bucket ``PartitionSpec`` for flat buffers: sharded buckets put the
    lane axis on the spec's model mesh axis, replicated buckets on nothing.
    ``stacked``: (n, Dp) client/init matrices (leading client axis is NOT
    model-sharded) vs (Dp,) server vectors."""
    from jax.sharding import PartitionSpec as P
    out = []
    for b in range(spec.n_buckets):
        ax = spec.mesh_axis if spec.shards(b) > 1 else None
        out.append(P(None, ax) if stacked else P(ax))
    return tuple(out)


def engine_sharding(spec: FlatSpec, mesh):
    """``NamedSharding`` pytree for an :class:`EngineState` on ``mesh`` —
    what ``jax.device_put`` of the initial state and the jitted round's
    output constraints use. Sharded buckets live with their lane axis on
    "model"; counters/stale/key/t are replicated."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())
    srv = tuple(NamedSharding(mesh, p)
                for p in bucket_partition_specs(spec, stacked=False))
    stk = tuple(NamedSharding(mesh, p)
                for p in bucket_partition_specs(spec, stacked=True))
    hot_ids, cold = None, None
    if spec.paged:
        hot_ids = rep
        # cold pools shard exactly like the dense stacked buckets (§6): the
        # encoded lane axis (packed codes / per-shard scales) splits on the
        # model axis, the client-id row axis replicates
        cold = tuple(
            jax.tree_util.tree_map(
                lambda p: NamedSharding(mesh, p),
                spec.cold_codec.partition_specs(
                    spec.shards(b) > 1, spec.mesh_axis or "model"),
                is_leaf=lambda x: isinstance(x, P))
            for b in range(spec.n_buckets))
    return EngineState(server=srv, clients=stk, inits=stk,
                       counters=rep, stale=rep, key=rep, t=rep,
                       hot_ids=hot_ids, cold=cold)


def _constrain_buckets(spec: FlatSpec, mesh, bufs, *, stacked: bool) -> tuple:
    """Pin per-bucket flat buffers to their mesh sharding (None entries pass
    through). Keeps GSPMD from replicating the buffers around the
    flatten/unflatten transposes in the round body."""
    if mesh is None:
        return tuple(bufs)
    from jax.sharding import NamedSharding
    specs = bucket_partition_specs(spec, stacked=stacked)
    return tuple(
        x if x is None or spec.shards(b) <= 1
        else jax.lax.with_sharding_constraint(x, NamedSharding(mesh, specs[b]))
        for b, x in enumerate(bufs))


def _constrain_cold(spec: FlatSpec, mesh, cold) -> tuple:
    """Pin per-bucket encoded cold pools to the §6 layout (lane axis on the
    model mesh axis for sharded buckets). Row-axis gathers/scatters and the
    per-shard encode reductions are then provably shard-local — the paged
    round adds no collectives over the dense engine's."""
    if mesh is None:
        return tuple(cold)
    from jax.sharding import NamedSharding, PartitionSpec as P
    out = []
    for b in range(spec.n_buckets):
        if spec.shards(b) <= 1:
            out.append(cold[b])
            continue
        specs = spec.cold_codec.partition_specs(True, spec.mesh_axis or "model")
        out.append(jax.tree_util.tree_map(
            lambda p, x: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, p)),
            specs, cold[b], is_leaf=lambda t: isinstance(t, P)))
    return tuple(out)


def fused_bucket_update(spec: FlatSpec, b: int, server_b, trained_b, inits_b,
                        alpha_p, mask_p, s: float, *, progress_b=None,
                        progress_codes_b=None, progress_bits: int = 0,
                        n_logical: Optional[int] = None, mesh=None,
                        use_kernel: Optional[bool] = None):
    """One bucket's fused aggregation + selected-client reset, mesh-aware.

    Dispatch (docs/architecture.md §6):

    * no mesh, or a replicated bucket -> plain ``favas_fused_flat`` (kernel
      or oracle; GSPMD replicates it on a mesh);
    * sharded bucket + kernel -> ``shard_map`` over the model axis: each
      device runs the Pallas kernel on its own (n, Dp_b/S) flat slice. The
      slice is lane-tile aligned by construction (per-shard padding), the
      client reduction is shard-local, and the body contains no collectives
      — the round cannot all-gather the buffer;
    * sharded bucket + oracle -> the jnp expression under pjit with explicit
      output ``PartitionSpec`` constraints; GSPMD partitions the elementwise
      lanes and the (unsharded) client-axis reduction locally.

    ``progress_codes_b`` (mutually exclusive with ``progress_b``): the
    transmitted progress as a ``{"codes", "scale"}`` encoding from
    ``kernels.ops.cold_requant_rows`` at ``progress_bits``, encoded with
    ``shards=spec.shards(b)``. The per-shard scale layout makes the codes-in
    shard_map body exactly per-device: each device's codes slice is a
    standalone shards=1 encoding of its own lane segment, so the kernel
    dequantizes shard-locally with no collectives.

    Returns (server_new, clients_new, inits_new) with the inputs' shardings.
    """
    if progress_b is not None and progress_codes_b is not None:
        raise ValueError("progress_b and progress_codes_b are mutually "
                         "exclusive")
    if mesh is None or spec.shards(b) <= 1:
        return favas_fused_flat(server_b, trained_b, inits_b, alpha_p, mask_p,
                                float(s), progress=progress_b,
                                progress_codes=progress_codes_b,
                                progress_bits=progress_bits,
                                progress_shards=max(1, spec.shards(b)),
                                client_tile=spec.client_tile,
                                n_logical=n_logical, use_kernel=use_kernel)
    kernel_active = (use_kernel if use_kernel is not None
                     else jax.default_backend() == "tpu")
    from jax.sharding import PartitionSpec as P
    lane, row, vec = P(spec.mesh_axis), P(None, spec.mesh_axis), P(None)
    if kernel_active:
        from jax.experimental.shard_map import shard_map

        def body(*ops):
            pr = pc = None
            if progress_b is not None:
                srv, cli, ini, pr, al, mk = ops
            elif progress_codes_b is not None:
                srv, cli, ini, cd, sc, al, mk = ops
                pc = {"codes": cd, "scale": sc}
            else:
                srv, cli, ini, al, mk = ops
            # per-device view: the local codes slice is one shard segment
            # with its own (rows, 1) scale column -> progress_shards=1
            return favas_fused_flat(srv, cli, ini, al, mk, float(s),
                                    progress=pr, progress_codes=pc,
                                    progress_bits=progress_bits,
                                    progress_shards=1,
                                    client_tile=spec.client_tile,
                                    n_logical=n_logical, use_kernel=True)

        operands = [server_b, trained_b, inits_b]
        in_specs = [lane, row, row]
        if progress_b is not None:
            operands.append(progress_b)
            in_specs.append(row)
        elif progress_codes_b is not None:
            # codes split on the lane axis like the row buffers; the
            # (rows, S) scale splits its shard column onto its shard
            operands += [progress_codes_b["codes"], progress_codes_b["scale"]]
            in_specs += [row, row]
        operands += [alpha_p, mask_p]
        in_specs += [vec, vec]
        return shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                         out_specs=(lane, row, row),
                         check_rep=False)(*operands)
    from jax.sharding import NamedSharding
    out = favas_fused_flat(server_b, trained_b, inits_b, alpha_p, mask_p,
                           float(s), progress=progress_b,
                           progress_codes=progress_codes_b,
                           progress_bits=progress_bits,
                           progress_shards=spec.shards(b),
                           client_tile=spec.client_tile,
                           n_logical=n_logical, use_kernel=False)
    return tuple(jax.lax.with_sharding_constraint(o, NamedSharding(mesh, p))
                 for o, p in zip(out, (lane, row, row)))


def _encode_progress(spec: FlatSpec, trained, inits, k_q, bits: int, *,
                     mesh=None, use_kernel: Optional[bool] = None) -> tuple:
    """Per-bucket LUQ encode of the transmitted progress (``quant_fused``
    transport): ``trained[b] - inits[b]`` in f32 -> packed codes +
    per-(row, shard) scales via ``kernels.ops.cold_requant_rows``. Padded
    client rows and lane tails are zero in both operands, so their delta is
    exactly zero, the guarded scale is 1.0 and the codes decode to exact
    zeros — padding stays a no-op through the codec. Keys: ``fold_in(k_q,
    0x7166)`` ('qf') then per-bucket fold — a stream disjoint from both the
    per-leaf ``quantize_tree`` split and the paged eviction fold."""
    from repro.kernels.ops import cold_requant_rows   # lazy: no import cycle
    k_qf = jax.random.fold_in(k_q, 0x7166)
    codes = []
    for b in range(spec.n_buckets):
        delta = (trained[b].astype(jnp.float32)
                 - inits[b].astype(jnp.float32))
        codes.append(cold_requant_rows(
            delta, bits, jax.random.fold_in(k_qf, b),
            shards=max(1, spec.shards(b)), use_kernel=use_kernel))
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        lane = P(None, spec.mesh_axis or "model")
        codes = [pc if spec.shards(b) <= 1 else jax.tree_util.tree_map(
                     lambda x: jax.lax.with_sharding_constraint(
                         x, NamedSharding(mesh, lane)), pc)
                 for b, pc in enumerate(codes)]
    return tuple(codes)


# ---------------------------------------------------------------------------
# Engine state (flat buffers held across rounds)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EngineState:
    server: tuple                  # per bucket (Dp_b,)
    clients: tuple                 # per bucket (rows, Dp_b) — all n rows on a
    #                                dense spec, the s_max hot rows on paged
    inits: tuple                   # per bucket (rows, Dp_b)
    counters: jnp.ndarray          # (n,) int32 — q^i, local steps since reset
    stale: jnp.ndarray             # (n,) int32 — rounds since last selection
    key: jnp.ndarray
    t: jnp.ndarray                 # scalar int32
    # paged residency only (None on dense states, docs/architecture.md §9):
    hot_ids: Any = None            # (s_max,) int32 resident client ids, sorted
    cold: Any = None               # per bucket codec-encoded pools, n rows

    def tree_flatten(self):
        return ((self.server, self.clients, self.inits, self.counters,
                 self.stale, self.key, self.t, self.hot_ids, self.cold), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def engine_init(spec: FlatSpec, params, cfg, key, *,
                use_kernel: Optional[bool] = None) -> EngineState:
    """Build the initial :class:`EngineState` from a parameter pytree.

    All clients start from the server model (Algorithm 1 line 16): the
    server buffer is ``params`` flattened per ``spec``; the client and init
    stacks are that row broadcast to ``cfg.n_clients`` distinct buffers.
    Client rows beyond ``n`` (the client-tile padding of a client-aware
    spec) are zero and stay zero across rounds; per-shard lane tails of a
    sharding-aware spec are likewise zero forever.

    Args:
      spec: layout from :func:`make_flat_spec` (must be client-aware with
        ``n_clients == cfg.n_clients`` if built with ``n_clients``).
      params: parameter pytree matching ``spec.treedef``.
      cfg: :class:`repro.core.favas.FavasConfig` (reads ``n_clients``).
      key: PRNG key stored in the state and split every round.
      use_kernel: cold-pool codec dispatch for the paged seeding encode —
        same contract as the round's (None = TPU auto); the kernel and
        oracle paths are bit-identical under shared uniforms so the choice
        never changes the seeded state's values.

    Returns an :class:`EngineState` on the default device; on a mesh,
    ``jax.device_put`` it with :func:`engine_sharding` (``RoundEngine``
    does both)."""
    n = cfg.n_clients
    server = flatten_tree(spec, params)
    hot_ids, cold = None, None
    if spec.paged:
        if cfg.s_selected > spec.s_max:
            raise ValueError(
                f"s_selected={cfg.s_selected} exceeds the hot working set "
                f"s_max={spec.s_max}: every selected client must fit hot")
        # hot working set: everyone starts equally fresh (stale 0), so the
        # staleness/id order picks the s_max lowest ids — at s_max == n this
        # is arange(n), the dense layout
        hot_ids = jnp.arange(spec.s_max, dtype=jnp.int32)
        clients = stack_server_rows(spec, server, spec.s_max)
        inits = stack_server_rows(spec, server, spec.s_max)
        # cold pools: every client is the server row with zero progress, so
        # ONE row is encoded per bucket and broadcast to all n ids (for the
        # LUQ codec the progress codes are exactly zero; identical per-row
        # uniforms are harmless since the rows are identical). fold_in keeps
        # the state's key chain untouched — bit-identical to the dense init.
        k_cold = jax.random.fold_in(key, 0x636f6c64)
        cold = []
        for b in range(spec.n_buckets):
            row = server[b][None]
            enc1 = spec.cold_codec.encode_pair(
                row, row, jax.random.fold_in(k_cold, b),
                shards=spec.shards(b), use_kernel=use_kernel)
            cold.append(jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (n,) + a.shape[1:]).copy(),
                enc1))
        cold = tuple(cold)
    else:
        clients = stack_server_rows(spec, server, n)
        inits = stack_server_rows(spec, server, n)
    # private copy of the key: the jitted round DONATES the state, and a
    # caller-owned key array shared between two states (or reused for a
    # second init) would be deleted by the first state's first dispatch
    return EngineState(
        server=server, clients=clients, inits=inits,
        counters=jnp.zeros((n,), jnp.int32),
        stale=jnp.zeros((n,), jnp.int32),
        key=jnp.array(key, copy=True), t=jnp.zeros((), jnp.int32),
        hot_ids=hot_ids, cold=cold)


# ---------------------------------------------------------------------------
# The round
# ---------------------------------------------------------------------------

def _local_training(loss_fn: Callable, cfg, clients_tree, counters,
                    new_counters, batch):
    """Masked R-step local SGD, vmapped over the client axis.

    Returns (trained_tree, loss_sum (n,), live_steps (n,)) — the raw masked
    loss sum and live-step count per client, so the caller can form a
    live-step-weighted aggregate instead of averaging in idle clients.

    batch: pytree with leading dims (n, R, ...) — one microbatch per client
    per potential local step."""

    def one_client(params, data, q0, q1):
        def step(p, inp):
            k, batch_k = inp
            loss, g = jax.value_and_grad(loss_fn)(p, batch_k)
            live = ((q0 + k) < q1).astype(jnp.float32)
            # update in f32, store back in the leaf dtype: keeps the scan
            # carry type stable for bf16 leaves (f32 leaves are unchanged —
            # the expression is the same f32 arithmetic as before)
            p = tree_map(
                lambda pp, gg: (pp - cfg.eta * live * gg.astype(jnp.float32)
                                ).astype(pp.dtype),
                p, g)
            return p, loss * live
        ks = jnp.arange(cfg.R)
        params, losses = jax.lax.scan(step, params, (ks, data))
        return params, jnp.sum(losses), (q1 - q0).astype(jnp.float32)

    return jax.vmap(one_client)(clients_tree, batch, counters, new_counters)


def engine_round(spec: FlatSpec, state: EngineState, batch=None, *, cfg,
                 loss_fn: Callable, lambdas,
                 det_alpha: Optional[jnp.ndarray] = None,
                 use_kernel: Optional[bool] = None, mesh=None,
                 quant_fused: bool = False, corpus=None, batch_key=None):
    """One FAVAS server round on flat buffers. Pure; jit/pjit this.

    The hot path is: unflatten clients -> vmapped local SGD -> flatten ->
    ONE fused aggregation+reset pass per bucket. No per-leaf tree_map
    touches the aggregation.

    Args:
      spec: the :func:`make_flat_spec` layout the buffers follow.
      state: current :class:`EngineState`; donate it when jitting.
      batch: pytree with leading dims (n, R, ...) — one microbatch per
        client per potential local step.
      cfg: :class:`FavasConfig` (n_clients, s_selected, local_steps, eta,
        reweight, quant_bits).
      loss_fn: ``loss_fn(params_pytree, microbatch) -> scalar``; vmapped
        over the client axis inside.
      lambdas: (n,) per-client heterogeneity rates for the step sampler.
      det_alpha: (n,) deterministic eq. 3 coefficients (used when
        ``cfg.reweight == "deterministic"``).
      use_kernel: None -> Pallas kernel on TPU / jnp oracle elsewhere;
        True/False force the choice (True runs interpret mode off-TPU).
      quant_fused: FAVAS[QNN] transport format. False (default, the seed
        semantics) quantizes the transmitted progress in tree space with
        per-leaf scales and hands the fused pass a dense dequantized
        (n, Dp) buffer. True encodes the progress per BUCKET as bit-packed
        LUQ codes + per-(row, shard) scales (``kernels.ops.
        cold_requant_rows``) and hands the fused pass the CODES — the
        kernel dequantizes per VMEM tile, so no full-precision (n, Dp)
        progress buffer ever materializes (different per-row-vs-per-leaf
        scale granularity and key stream, so an opt-in knob, not a drop-in
        replacement for the seed path).
      mesh: optional device mesh matching a sharding-aware ``spec``. Sharded
        buckets then run their fused pass via :func:`fused_bucket_update`
        (shard_map on the kernel path, pjit constraints on the oracle path)
        so the round never gathers a full buffer onto one device.
      corpus / batch_key: device data plane — instead of ``batch``, a
        resident :class:`repro.data.device_corpus.DeviceCorpus` plus the
        round's batch key; the round samples its own minibatches (and, on a
        paged spec, gathers corpus rows for the hot working set only).

    On a ``residency="paged"`` spec the round runs the hot/cold body
    (:func:`_paged_round`): select -> promote/evict the hot working set ->
    gather+dequant -> fused round over the s_max hot rows -> requant+
    scatter-back. With the passthrough codec at ``s_max == n`` it is
    bit-exact with this dense body (tests/test_paged_engine.py).

    Returns ``(new_state, metrics)`` where metrics holds the live-step-
    weighted ``loss``, ``mean_steps``, ``selected`` and ``stale_rounds``."""
    if spec.paged:
        return _paged_round(spec, state, batch, cfg=cfg, loss_fn=loss_fn,
                            lambdas=lambdas, det_alpha=det_alpha,
                            use_kernel=use_kernel, mesh=mesh,
                            quant_fused=quant_fused,
                            corpus=corpus, batch_key=batch_key)
    if corpus is not None:
        batch = corpus.sample_round_batch(batch_key, cfg.R)
    n, s, K = cfg.n_clients, cfg.s_selected, cfg.local_steps
    key, k_inc, k_sel, k_q = jax.random.split(state.key, 4)

    # 1. heterogeneous progress this round
    d = sampler.sample_increments(k_inc, lambdas)              # (n,)
    new_counters = jnp.minimum(state.counters + d, K)

    # 2. masked local SGD (needs model structure -> tree space)
    clients_tree = unflatten_stacked(spec, state.clients)
    trained_tree, loss_sum, live = _local_training(
        loss_fn, cfg, clients_tree, state.counters, new_counters, batch)

    # 3. eq. (3) reweight coefficients
    if cfg.reweight == "deterministic":
        alpha = det_alpha
    else:
        alpha = reweight.alpha_stochastic(new_counters, p_pos=1.0)

    trained = _constrain_buckets(spec, mesh, flatten_stacked(spec, trained_tree),
                                 stacked=True)

    progress = (None,) * spec.n_buckets
    progress_codes = (None,) * spec.n_buckets
    if cfg.quant_bits > 0 and quant_fused:
        # FAVAS[QNN], codes-in transport: LUQ-encode the transmitted
        # progress per BUCKET on the flat buffers (per-(row, shard) scales)
        # and keep it as packed codes all the way into the fused pass — the
        # dense (n, Dp) dequantized progress never materializes. Keys fold
        # off k_q under a dedicated tag so the stream can never collide
        # with the paged path's eviction fold (fold_in(k_q, 1)).
        progress_codes = _encode_progress(spec, trained, state.inits, k_q,
                                          cfg.quant_bits, mesh=mesh,
                                          use_kernel=use_kernel)
    elif cfg.quant_bits > 0:
        # FAVAS[QNN]: quantize the TRANSMITTED progress in tree space
        # (per-leaf LUQ scale, same per-leaf keys as the seed
        # implementation). Quantization is communication-only (Remark 1):
        # the fused pass aggregates Q(progress) but resets unselected
        # clients to their full-precision trained state.
        inits_tree = unflatten_stacked(spec, state.inits)
        prog = quantize_tree(tree_map(jnp.subtract, trained_tree, inits_tree),
                             cfg.quant_bits, k_q)
        progress = _constrain_buckets(spec, mesh, flatten_stacked(spec, prog),
                                      stacked=True)

    # 4+5. fused aggregation + selected-client reset: one pass per bucket.
    # alpha/mask ride to the kernel padded alongside the buffers' client
    # rows (unit alpha / zero mask => padded rows aggregate exactly nothing
    # and reset to themselves, i.e. stay zero).
    m = sampler.sample_selection(k_sel, n, s)                  # (n,) float
    alpha_p = pad_client_vec(spec, alpha, 1.0)
    m_p = pad_client_vec(spec, m, 0.0)
    server_new, clients_new, inits_new = [], [], []
    for b in range(spec.n_buckets):
        srv, cli, ini = fused_bucket_update(
            spec, b, state.server[b], trained[b], state.inits[b], alpha_p,
            m_p, float(s), progress_b=progress[b],
            progress_codes_b=progress_codes[b],
            progress_bits=cfg.quant_bits, n_logical=n, mesh=mesh,
            use_kernel=use_kernel)
        server_new.append(srv)
        clients_new.append(cli)
        inits_new.append(ini)

    counters_new = jnp.where(m > 0, 0, new_counters).astype(jnp.int32)
    stale_new = jnp.where(m > 0, 0, state.stale + 1).astype(jnp.int32)

    new_state = EngineState(server=tuple(server_new),
                            clients=tuple(clients_new),
                            inits=tuple(inits_new),
                            counters=counters_new, stale=stale_new,
                            key=key, t=state.t + 1)
    total_live = jnp.sum(live)
    metrics = {
        # live-step-weighted: clients that ran zero live steps this round
        # contribute nothing instead of dragging the mean toward 0, and a
        # stale straggler's high loss is weighted by its actual step count.
        "loss": jnp.sum(loss_sum) / jnp.maximum(total_live, 1.0),
        "mean_steps": jnp.mean(new_counters.astype(jnp.float32)),
        "selected": jnp.sum(m),
        "stale_rounds": jnp.max(stale_new).astype(jnp.float32),
    }
    return new_state, metrics


def _paged_round(spec: FlatSpec, state: EngineState, batch, *, cfg,
                 loss_fn: Callable, lambdas,
                 det_alpha: Optional[jnp.ndarray] = None,
                 use_kernel: Optional[bool] = None, mesh=None,
                 quant_fused: bool = False, corpus=None, batch_key=None):
    """One FAVAS round on a paged (hot/cold) spec — docs/architecture.md §9.

    Control flow inverts relative to the dense body: Gumbel top-s selection
    runs FIRST, then the hot working set is rebuilt (promote selected cold
    clients by gather+dequant, evict the stalest hot rows by requant+
    scatter-back), and only the ``s_max`` hot rows see local SGD and the
    fused aggregation+reset. Cold clients are frozen — their parameters,
    counters and progress do not move until promotion, which is exactly the
    dense semantics for never-selected clients once ``s_max`` covers every
    client touched between two selections of any given id.

    RNG streams: the round draws ``key, k_inc, k_sel, k_q`` from the SAME
    four-way split as the dense body — selection's key is merely consumed
    earlier — and all codec randomness is folded off ``k_q``, never split
    from the chain. With the passthrough codec at ``s_max == n`` (hot stacks
    = all clients in id order, identical shapes, identical reduction trees)
    the round is therefore bit-exact with the dense ``engine_round``."""
    n, s, K = cfg.n_clients, cfg.s_selected, cfg.local_steps
    s_hot = spec.s_max
    codec = spec.cold_codec
    key, k_inc, k_sel, k_q = jax.random.split(state.key, 4)

    # 1. heterogeneous progress + SELECT-FIRST
    d = sampler.sample_increments(k_inc, lambdas)               # (n,)
    _, m = sampler.sample_selection_indices(k_sel, n, s)        # (n,) 0/1
    stale_new = jnp.where(m > 0, 0, state.stale + 1).astype(jnp.int32)

    # 2. new hot membership: the s_max most recently selected clients.
    # Two-key lexsort (staleness, then id) instead of a composite score —
    # stale * n + id overflows int32 at populations this layer targets.
    # Membership stays ascending by id, so s_max == n degenerates to
    # arange(n), the dense row layout. Selected clients (staleness 0)
    # always fit: engine_init enforces s <= s_max.
    order = jnp.lexsort((jnp.arange(n, dtype=jnp.int32), stale_new))
    members = jnp.sort(order[:s_hot]).astype(jnp.int32)
    old_ids = state.hot_ids
    pos_in_old = jnp.clip(jnp.searchsorted(old_ids, members), 0, s_hot - 1)
    was_hot = old_ids[pos_in_old] == members                    # (s_max,)
    pos_in_new = jnp.clip(jnp.searchsorted(members, old_ids), 0, s_hot - 1)
    evicted = members[pos_in_new] != old_ids                    # (s_max,)

    # 3. evict: requant the rows leaving the hot set into the cold pools.
    # Membership churn is bounded by s_selected — only a client selected
    # THIS round can enter the hot set (staleness order among unselected
    # clients is preserved round to round), and the hot set has fixed size,
    # so at most s rows leave and at most s rows are promoted. The codec
    # therefore touches s_churn = min(s, s_max) rows, not the whole working
    # set. nonzero() pads the churn index vectors with out-of-range
    # positions; pad entries are routed to a row that is NOT churning this
    # round and write back its current value, so duplicate scatter indices
    # always carry identical values — deterministic, and a bit-exact no-op
    # in the s_max == n parity regime where nothing ever churns.
    s_churn = min(s, s_hot)

    def _churn_positions(flags):
        pos = jnp.nonzero(flags, size=s_churn, fill_value=s_hot)[0]
        valid = pos < s_hot
        safe = jnp.argmin(flags).astype(pos.dtype)  # first non-churning row
        return jnp.where(valid, jnp.minimum(pos, s_hot - 1), safe), valid

    evict_pos, evict_valid = _churn_positions(evicted)
    promo_pos, promo_valid = _churn_positions(~was_hot)

    # Unique sorted scatter ids + donation => in-place read-modify-write;
    # non-evicted clients' cold bytes are untouched. The encode key is
    # FOLDED off k_q (not split), leaving the dense key chain intact.
    k_evict = jax.random.fold_in(k_q, 1)
    evict_ids = old_ids[evict_pos]
    cold = []
    for b in range(spec.n_buckets):
        enc = codec.encode_pair(
            state.clients[b][evict_pos], state.inits[b][evict_pos],
            jax.random.fold_in(k_evict, b), shards=spec.shards(b),
            use_kernel=use_kernel)

        def scatter(pool, e):
            sel = evict_valid.reshape((-1,) + (1,) * (e.ndim - 1))
            return pool.at[evict_ids].set(
                jnp.where(sel, e.astype(pool.dtype), pool[evict_ids]))

        cold.append(jax.tree_util.tree_map(scatter, state.cold[b], enc))
    cold = _constrain_cold(spec, mesh, cold)

    # 4. promote: gather + dequant ONLY the rows entering the hot set. Rows
    # that never went cold keep their full-precision buffers — surviving
    # hot clients pay NO requant round-trip.
    rpad = spec.stacked_rows - s_hot
    promo_ids = members[promo_pos]
    clients_hot, inits_hot = [], []
    for b in range(spec.n_buckets):
        dt = jnp.dtype(spec.bucket_dtypes[b])
        enc_rows = jax.tree_util.tree_map(lambda p: p[promo_ids], cold[b])
        dec_cli, dec_ini = codec.decode_pair(enc_rows, dt,
                                             shards=spec.shards(b),
                                             use_kernel=use_kernel)
        base_cli = state.clients[b][pos_in_old]
        base_ini = state.inits[b][pos_in_old]
        sel = promo_valid[:, None]
        cli = base_cli.at[promo_pos].set(
            jnp.where(sel, dec_cli, base_cli[promo_pos]))
        ini = base_ini.at[promo_pos].set(
            jnp.where(sel, dec_ini, base_ini[promo_pos]))
        if rpad:
            cli = jnp.pad(cli, ((0, rpad), (0, 0)))
            ini = jnp.pad(ini, ((0, rpad), (0, 0)))
        clients_hot.append(cli)
        inits_hot.append(ini)
    clients_hot = _constrain_buckets(spec, mesh, clients_hot, stacked=True)
    inits_hot = _constrain_buckets(spec, mesh, inits_hot, stacked=True)

    # 5. hot-set bookkeeping + batch rows (the credit clock advances for
    # hot clients only — cold clients are frozen, not merely unselected)
    q0 = state.counters[members]
    q1 = jnp.minimum(q0 + d[members], K)
    m_hot = m[members]
    if corpus is not None:
        batch = corpus.sample_round_batch(batch_key, cfg.R, ids=members)
    else:
        batch = tree_map(lambda x: x[members], batch)

    # 6. masked local SGD over the hot rows only
    clients_tree = unflatten_stacked(spec, clients_hot)
    trained_tree, loss_sum, live = _local_training(
        loss_fn, cfg, clients_tree, q0, q1, batch)

    # 7. eq. (3) coefficients + optional FAVAS[QNN] transmitted progress,
    # all in hot space (at s_max == n these are the dense expressions,
    # k_q included)
    if cfg.reweight == "deterministic":
        alpha = det_alpha[members]
    else:
        alpha = reweight.alpha_stochastic(q1, p_pos=1.0)
    trained = _constrain_buckets(spec, mesh,
                                 flatten_stacked(spec, trained_tree),
                                 stacked=True)
    progress = (None,) * spec.n_buckets
    progress_codes = (None,) * spec.n_buckets
    if cfg.quant_bits > 0 and quant_fused:
        # codes-in transport over the HOT stacks (see engine_round): the
        # 0x7166 tag keeps the fold stream disjoint from k_evict above
        progress_codes = _encode_progress(spec, trained, inits_hot, k_q,
                                          cfg.quant_bits, mesh=mesh,
                                          use_kernel=use_kernel)
    elif cfg.quant_bits > 0:
        inits_tree = unflatten_stacked(spec, inits_hot)
        prog = quantize_tree(tree_map(jnp.subtract, trained_tree, inits_tree),
                             cfg.quant_bits, k_q)
        progress = _constrain_buckets(spec, mesh, flatten_stacked(spec, prog),
                                      stacked=True)

    # 8. fused aggregation + selected-client reset over the hot stacks
    alpha_p = pad_client_vec(spec, alpha, 1.0)
    m_p = pad_client_vec(spec, m_hot, 0.0)
    server_new, clients_new, inits_new = [], [], []
    for b in range(spec.n_buckets):
        srv, cli, ini = fused_bucket_update(
            spec, b, state.server[b], trained[b], inits_hot[b], alpha_p,
            m_p, float(s), progress_b=progress[b],
            progress_codes_b=progress_codes[b],
            progress_bits=cfg.quant_bits, n_logical=s_hot,
            mesh=mesh, use_kernel=use_kernel)
        server_new.append(srv)
        clients_new.append(cli)
        inits_new.append(ini)

    # 9. scatter the hot counter updates back into the full-n view
    counters_new = state.counters.at[members].set(
        jnp.where(m_hot > 0, 0, q1).astype(jnp.int32))

    new_state = EngineState(server=tuple(server_new),
                            clients=tuple(clients_new),
                            inits=tuple(inits_new),
                            counters=counters_new, stale=stale_new,
                            key=key, t=state.t + 1,
                            hot_ids=members, cold=cold)
    total_live = jnp.sum(live)
    metrics = {
        # live-step-weighted over the SELECTED HOT SET only: frozen cold
        # clients run zero live steps and contribute nothing — paging must
        # not reintroduce the zero-live-step masking bug (ROADMAP notes;
        # regression-pinned in tests/test_paged_engine.py)
        "loss": jnp.sum(loss_sum) / jnp.maximum(total_live, 1.0),
        "mean_steps": jnp.mean(q1.astype(jnp.float32)),
        "selected": jnp.sum(m),
        "stale_rounds": jnp.max(stale_new).astype(jnp.float32),
    }
    return new_state, metrics


def engine_multi_round(spec: FlatSpec, state: EngineState, batches=None, *,
                       cfg, loss_fn: Callable, lambdas,
                       det_alpha: Optional[jnp.ndarray] = None,
                       use_kernel: Optional[bool] = None, mesh=None,
                       quant_fused: bool = False,
                       corpus=None, n_rounds: Optional[int] = None):
    """A whole chunk of FAVAS rounds as ONE ``jax.lax.scan`` — the
    "superstep" (docs/architecture.md §7). Pure; jit/pjit this and donate
    ``state``: a T-round chunk then costs one dispatch instead of T.

    Two data planes feed the scan (docs/architecture.md §8):

    * **host plane** — ``batches`` is the per-round batch pytree with an
      extra LEADING rounds axis — leaves are (T, n, R, ...); round t
      consumes slice ``batches[t]``;
    * **device plane** — ``corpus`` is a
      :class:`repro.data.device_corpus.DeviceCorpus` and ``n_rounds`` the
      (static) chunk length: the scan body draws each round's per-client
      minibatch indices from the carried PRNG key and gathers the rows on
      device (``corpus.sample_round_batch``), so a compiled chunk does ZERO
      host batch-generation work between dispatches.

    The scan carries the :class:`EngineState` and stacks each round's
    metrics, so the caller fetches one (T,)-shaped metrics pytree per chunk
    instead of blocking on T scalar transfers.

    RNG equivalence: :func:`engine_round` derives everything it draws from
    ``state.key`` (split once per round, the new key rides in the carry), so
    the scanned host-plane stream is IDENTICAL to T sequential
    ``engine_round`` calls — superstep-vs-sequential parity is bit-exact,
    not approximate (tests/test_superstep.py). The device plane splits one
    extra batch key per round off the same chain (see
    tests/test_device_corpus.py for the sequential-parity proof), so it is
    *statistically equivalent* to the host plane, not stream-identical —
    the same contract PR 4 set for on-device selection. Composes with
    ``use_kernel`` and ``mesh`` exactly like ``engine_round``: the
    shard_map / pjit dispatch sits inside the scan body, compiled once for
    the whole chunk.

    Returns ``(new_state, metrics)`` with every metric stacked to (T,)."""
    if corpus is not None:
        if batches is not None:
            raise ValueError("pass either batches (host plane) or corpus "
                             "(device plane), not both")
        if n_rounds is None:
            raise ValueError("the device plane needs a static n_rounds "
                             "(there is no batches axis to infer it from)")

        def body_c(st, _):
            key, k_batch = jax.random.split(st.key)
            st = dataclasses.replace(st, key=key)
            # sampling happens INSIDE engine_round (same key, same draws as
            # sampling here): a paged spec must select its hot working set
            # before it knows which corpus rows to gather
            return engine_round(spec, st, None, cfg=cfg, loss_fn=loss_fn,
                                lambdas=lambdas, det_alpha=det_alpha,
                                use_kernel=use_kernel, mesh=mesh,
                                quant_fused=quant_fused,
                                corpus=corpus, batch_key=k_batch)
        return jax.lax.scan(body_c, state, None, length=n_rounds)

    def body(st, batch):
        return engine_round(spec, st, batch, cfg=cfg, loss_fn=loss_fn,
                            lambdas=lambdas, det_alpha=det_alpha,
                            use_kernel=use_kernel, mesh=mesh,
                            quant_fused=quant_fused)
    return jax.lax.scan(body, state, batches)


def engine_server_params(spec: FlatSpec, state: EngineState):
    """Current server model as the original parameter pytree."""
    return unflatten_tree(spec, state.server)


def engine_variance(state: EngineState) -> jnp.ndarray:
    """sum_i ||w^i - w_t||^2 straight off the flat buffers. Padded lane
    tails are identical between clients and server (zero contribution);
    padded client ROWS are all-zero, not copies of the server, so they are
    sliced off (the counters carry the logical n).

    On a paged state the sum runs over the HOT WORKING SET only — the rows
    that actually trained. Decoding the cold pool here would charge frozen
    clients' (possibly quantized) drift to a live-progress metric and
    reintroduce the zero-live-step averaging bug at the variance level; at
    ``s_max == n`` the hot set is everyone and this is the dense value."""
    rows = (state.counters.shape[0] if state.hot_ids is None
            else state.hot_ids.shape[0])
    tot = jnp.zeros((), jnp.float32)
    for srv, cli in zip(state.server, state.clients):
        diff = cli[:rows].astype(jnp.float32) - srv[None].astype(jnp.float32)
        tot = tot + jnp.sum(jnp.square(diff))
    return tot


def engine_resident_bytes(state: EngineState) -> int:
    """Actual bytes of every array in the state (hot stacks + cold pools +
    bookkeeping) — what the paged-vs-dense residency bench and the CI
    resident-bytes gate measure. Host-side accounting; not jittable."""
    return sum(leaf.size * jnp.dtype(leaf.dtype).itemsize
               for leaf in jax.tree_util.tree_leaves(state))


# ---------------------------------------------------------------------------
# RoundEngine: holds the static spec + a donated jitted round
# ---------------------------------------------------------------------------

class RoundEngine:
    """Convenience wrapper owning the FlatSpec and the jitted, buffer-donating
    round. The state never leaves flat form between rounds.

    ``mesh``: run the engine mesh-native — the spec buckets leaves by
    (dtype, sharding group), ``init_state`` places the buffers with
    :func:`engine_sharding`, and every round keeps sharded buckets on the
    model axis end-to-end (``--mesh`` in ``launch.train`` composes this with
    ``--use-kernel``: kernel -> shard_map per shard, oracle -> pjit)."""

    def __init__(self, params_template, cfg, loss_fn: Callable, *,
                 lambdas=None, det_alpha=None, use_kernel: Optional[bool] = None,
                 client_tile: int = CLIENT_TILE, mesh=None,
                 residency: str = "dense", s_max: Optional[int] = None,
                 cold_bits: int = 0, quant_fused: bool = False):
        from repro.core.favas import client_lambdas  # cycle-free at call time
        self.cfg = cfg
        self.mesh = mesh
        codec = make_codec(cold_bits) if residency == "paged" else None
        self.spec = make_flat_spec(params_template, n_clients=cfg.n_clients,
                                   client_tile=client_tile, mesh=mesh,
                                   residency=residency, s_max=s_max,
                                   cold_codec=codec)
        self.loss_fn = loss_fn
        self.lambdas = (jnp.asarray(lambdas) if lambdas is not None
                        else jnp.asarray(client_lambdas(cfg)))
        self.det_alpha = None if det_alpha is None else jnp.asarray(det_alpha)
        self.use_kernel = use_kernel
        self.quant_fused = quant_fused
        self._round = jax.jit(
            functools.partial(engine_round, self.spec, cfg=self.cfg,
                              loss_fn=self.loss_fn, lambdas=self.lambdas,
                              det_alpha=self.det_alpha,
                              use_kernel=self.use_kernel, mesh=self.mesh,
                              quant_fused=self.quant_fused),
            donate_argnums=(0,))
        self._multi = jax.jit(
            functools.partial(engine_multi_round, self.spec, cfg=self.cfg,
                              loss_fn=self.loss_fn, lambdas=self.lambdas,
                              det_alpha=self.det_alpha,
                              use_kernel=self.use_kernel, mesh=self.mesh,
                              quant_fused=self.quant_fused),
            donate_argnums=(0,))
        # device data plane: the corpus rides as a pytree ARGUMENT (not a
        # closure) so its buffers are shared inputs, never baked into the
        # executable as constants; n_rounds is static (scan length)
        self._multi_device = jax.jit(
            functools.partial(engine_multi_round, self.spec, cfg=self.cfg,
                              loss_fn=self.loss_fn, lambdas=self.lambdas,
                              det_alpha=self.det_alpha,
                              use_kernel=self.use_kernel, mesh=self.mesh,
                              quant_fused=self.quant_fused),
            static_argnames=("n_rounds",), donate_argnums=(0,))
        # dispatches into the jitted round/superstep — the regression guard
        # tests/test_superstep.py uses to pin "one chunk = one dispatch"
        self.dispatch_count = 0

    def init_state(self, params, key) -> EngineState:
        state = engine_init(self.spec, params, self.cfg, key,
                            use_kernel=self.use_kernel)
        if self.mesh is not None:
            state = jax.device_put(state, engine_sharding(self.spec, self.mesh))
        return state

    def step(self, state: EngineState, batch):
        """Jitted round; donates the previous state's buffers."""
        self.dispatch_count += 1
        return self._round(state, batch)

    def run(self, state: EngineState, batches,
            n_rounds: Optional[int] = None):
        """A chunk of rounds as one superstep dispatch (see
        :func:`engine_multi_round`); donates the previous state's buffers.

        ``batches``: per-round batch pytree with a leading (T,) rounds axis.
        ``n_rounds``: optional sanity check that T is what the caller thinks
        it is (chunks of different T compile once each — the scan length is
        static). Returns ``(new_state, metrics)`` with (T,)-stacked metrics;
        bit-exact with T sequential :meth:`step` calls."""
        T = jax.tree_util.tree_leaves(batches)[0].shape[0]
        if n_rounds is not None and n_rounds != T:
            raise ValueError(
                f"batches carry {T} rounds but n_rounds={n_rounds}")
        self.dispatch_count += 1
        return self._multi(state, batches)

    def run_device(self, state: EngineState, corpus, n_rounds: int):
        """A chunk of rounds on the DEVICE data plane: one superstep
        dispatch whose scan body samples each round's minibatches from the
        resident ``corpus`` (a ``data.device_corpus.DeviceCorpus``) — no
        host batch generation, no H2D batch traffic, no prefetcher.
        Donates the previous state's buffers; ``n_rounds`` is static (one
        compilation per distinct chunk length, like the host plane's batch
        shapes). Returns ``(new_state, metrics)`` with (T,)-stacked
        metrics."""
        self.dispatch_count += 1
        return self._multi_device(state, corpus=corpus, n_rounds=n_rounds)

    def server_params(self, state: EngineState):
        return engine_server_params(self.spec, state)

    def variance(self, state: EngineState) -> jnp.ndarray:
        return engine_variance(state)

    def resident_bytes(self, state: EngineState) -> int:
        return engine_resident_bytes(state)
