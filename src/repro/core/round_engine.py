"""Flat-buffer FAVAS round engine.

The FAVAS server round is memory-bound: every byte of every resident
client's parameters crosses HBM each round (eq. 3 reweight, line-10
aggregation, line-11/12 selected-client reset). The seed implementation did
that as ~6 separate full-parameter ``tree_map`` passes per round. This
engine instead:

* flattens the parameter pytree ONCE into contiguous flat buffers — a
  ``(Dp,)`` server vector and ``(n, Dp)`` clients / inits matrices per
  dtype bucket, pre-padded to the kernel lane tile so the Pallas path never
  re-pads — and holds them across rounds;
* runs the whole aggregation + reset as ONE streamed pass per tile through
  the multi-output Pallas kernel ``kernels.favas_agg.favas_fused_pallas``
  (TPU; interpret for validation) or its jnp oracle
  ``kernels.ref.favas_fused_ref`` (CPU default — XLA fuses the flat-buffer
  expression into a single loop, which is already the oracle's point);
* unflattens only at the boundaries that need model structure: the vmapped
  local-SGD step (which needs the pytree for the model's loss), evaluation,
  and checkpoint export.

``core.favas.favas_round`` keeps the seed's pytree API by wrapping
``engine_round`` with flatten/unflatten at the call boundary;
``launch.train`` uses ``RoundEngine`` directly so the buffers genuinely
persist across rounds and the jitted round donates them.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import sampler, reweight
from repro.core.quant import quantize_tree
from repro.kernels.favas_agg import CLIENT_TILE, TILE
from repro.kernels.ops import favas_fused_flat
from repro.utils.tree import tree_map


# ---------------------------------------------------------------------------
# FlatSpec: static description of the pytree <-> flat-buffer mapping
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static (hashable, trace-free) layout of a parameter pytree flattened
    into one contiguous buffer per distinct leaf dtype ("bucket").

    Leaves keep their original dtype; mixed-precision trees get one buffer
    per dtype so no storage precision is lost. Buffer length is padded up to
    a multiple of the kernel lane tile; the padded tail is zero-initialized
    and provably stays zero under the fused round update (the masked padded
    "server" tail aggregates only zeros).

    When built with ``n_clients``, the spec is client-aware: stacked buffers
    additionally pad the client (row) axis up to a multiple of the kernel's
    ``client_tile`` once n exceeds one client block, so the tiled kernel
    never re-pads either axis. Padded rows are all-zero with zero selection
    mask and unit alpha — they contribute exactly nothing to the masked
    aggregation and provably stay zero across rounds.
    """
    treedef: Any
    shapes: tuple                 # per leaf, original shape
    dtypes: tuple                 # per leaf, jnp dtype name (str, hashable)
    bucket_of: tuple              # per leaf, bucket index
    offsets: tuple                # per leaf, start offset within its bucket
    bucket_dtypes: tuple          # per bucket, dtype name
    bucket_sizes: tuple           # per bucket, unpadded element count
    bucket_padded: tuple          # per bucket, padded element count
    n_clients: Optional[int] = None   # logical client rows (None: not stacked)
    n_padded: Optional[int] = None    # stored client rows incl. padding
    client_tile: Optional[int] = None  # kernel client-axis tile

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_dtypes)


def make_flat_spec(tree, *, tile: int = TILE, n_clients: Optional[int] = None,
                   client_tile: int = CLIENT_TILE) -> FlatSpec:
    """Build the layout from a pytree of arrays / ShapeDtypeStructs.

    ``n_clients``: make the spec client-aware (see class docstring). Row
    padding only kicks in beyond one client block (n > client_tile), so
    small federations carry no extra rows."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes, dtypes, bucket_of, offsets = [], [], [], []
    bucket_dtypes, cursors = [], []
    for leaf in leaves:
        dt = jnp.dtype(leaf.dtype).name
        if dt not in bucket_dtypes:
            bucket_dtypes.append(dt)
            cursors.append(0)
        b = bucket_dtypes.index(dt)
        size = 1
        for d in leaf.shape:
            size *= int(d)
        shapes.append(tuple(leaf.shape))
        dtypes.append(dt)
        bucket_of.append(b)
        offsets.append(cursors[b])
        cursors[b] += size
    padded = tuple(c + ((-c) % tile) for c in cursors)
    n_padded = None
    if n_clients is not None:
        n_padded = (n_clients if n_clients <= client_tile
                    else n_clients + ((-n_clients) % client_tile))
    return FlatSpec(treedef=treedef, shapes=tuple(shapes), dtypes=tuple(dtypes),
                    bucket_of=tuple(bucket_of), offsets=tuple(offsets),
                    bucket_dtypes=tuple(bucket_dtypes),
                    bucket_sizes=tuple(cursors), bucket_padded=padded,
                    n_clients=n_clients, n_padded=n_padded,
                    client_tile=client_tile if n_clients is not None else None)


def flatten_tree(spec: FlatSpec, tree) -> tuple:
    """Pytree -> tuple of (Dp_b,) flat buffers (one per dtype bucket)."""
    leaves = jax.tree_util.tree_leaves(tree)
    parts = [[] for _ in range(spec.n_buckets)]
    for leaf, b in zip(leaves, spec.bucket_of):
        parts[b].append(jnp.ravel(leaf))
    out = []
    for b in range(spec.n_buckets):
        buf = jnp.concatenate(parts[b]) if len(parts[b]) > 1 else parts[b][0]
        pad = spec.bucket_padded[b] - spec.bucket_sizes[b]
        if pad:
            buf = jnp.pad(buf, (0, pad))
        out.append(buf)
    return tuple(out)


def flatten_stacked(spec: FlatSpec, tree) -> tuple:
    """Client-stacked pytree (leading axis n) -> tuple of (Np_b, Dp_b).

    With a client-aware spec the row axis is zero-padded up to
    ``spec.n_padded`` so the tiled kernel path never re-pads."""
    leaves = jax.tree_util.tree_leaves(tree)
    n = leaves[0].shape[0]
    rpad = 0
    if spec.n_padded is not None:
        # loud failure instead of silently mis-padding: a client-aware spec
        # only describes trees with exactly n_clients rows
        if n != spec.n_clients:
            raise ValueError(
                f"stacked tree has {n} client rows but the spec was built "
                f"for n_clients={spec.n_clients}")
        rpad = spec.n_padded - n
    parts = [[] for _ in range(spec.n_buckets)]
    for leaf, b in zip(leaves, spec.bucket_of):
        parts[b].append(leaf.reshape(n, -1))
    out = []
    for b in range(spec.n_buckets):
        buf = (jnp.concatenate(parts[b], axis=1) if len(parts[b]) > 1
               else parts[b][0])
        pad = spec.bucket_padded[b] - spec.bucket_sizes[b]
        if pad or rpad:
            buf = jnp.pad(buf, ((0, rpad), (0, pad)))
        out.append(buf)
    return tuple(out)


def unflatten_tree(spec: FlatSpec, bufs: Sequence):
    """Tuple of (Dp_b,) buffers -> pytree with the original leaf layout."""
    leaves = []
    for shape, dt, b, off in zip(spec.shapes, spec.dtypes, spec.bucket_of,
                                 spec.offsets):
        size = 1
        for d in shape:
            size *= d
        leaves.append(jax.lax.dynamic_slice_in_dim(bufs[b], off, size)
                      .reshape(shape))
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def unflatten_stacked(spec: FlatSpec, bufs: Sequence):
    """Tuple of (Np_b, Dp_b) buffers -> client-stacked pytree (padded client
    rows, if any, are dropped)."""
    leaves = []
    for shape, dt, b, off in zip(spec.shapes, spec.dtypes, spec.bucket_of,
                                 spec.offsets):
        buf = bufs[b]
        n = buf.shape[0]
        if spec.n_padded is not None:
            if n != spec.n_padded:
                raise ValueError(
                    f"stacked buffer has {n} rows but the spec stores "
                    f"n_padded={spec.n_padded}")
            if spec.n_clients < n:
                n = spec.n_clients
                buf = buf[:n]
        size = 1
        for d in shape:
            size *= d
        leaves.append(
            jax.lax.dynamic_slice_in_dim(buf, off, size, axis=1)
            .reshape((n,) + shape))
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def pad_client_vec(spec: FlatSpec, v, fill: float = 0.0):
    """(n,) per-client vector -> (Np,) padded to the spec's stored rows.
    ``fill``: value for padded rows (0 for masks — padded rows are never
    selected; 1 for alphas — keeps the guarded division trivially exact)."""
    if spec.n_padded is None:
        return v
    if v.shape[0] != spec.n_clients:
        raise ValueError(
            f"per-client vector has {v.shape[0]} rows but the spec was "
            f"built for n_clients={spec.n_clients}")
    rpad = spec.n_padded - spec.n_clients
    if not rpad:
        return v
    return jnp.concatenate([v, jnp.full((rpad,), fill, v.dtype)])


def stack_server_rows(spec: FlatSpec, server_bufs: Sequence, n: int) -> tuple:
    """Server flat buffers -> client/init row stacks: the server row
    broadcast to n clients plus all-zero padded rows up to the spec's stored
    row count. Each result is a DISTINCT buffer (broadcasts are materialized)
    so a donating jit never sees the same buffer twice."""
    if spec.n_clients is not None and n != spec.n_clients:
        raise ValueError(
            f"stacking {n} client rows but the spec was built for "
            f"n_clients={spec.n_clients}")
    rows = spec.n_padded or n
    out = []
    for b in server_bufs:
        buf = jnp.broadcast_to(b[None], (n,) + b.shape)
        buf = (jnp.pad(buf, ((0, rows - n), (0, 0))) if rows > n
               else buf.copy())
        out.append(buf)
    return tuple(out)


# ---------------------------------------------------------------------------
# Engine state (flat buffers held across rounds)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EngineState:
    server: tuple                  # per bucket (Dp_b,)
    clients: tuple                 # per bucket (n, Dp_b)
    inits: tuple                   # per bucket (n, Dp_b)
    counters: jnp.ndarray          # (n,) int32 — q^i, local steps since reset
    stale: jnp.ndarray             # (n,) int32 — rounds since last selection
    key: jnp.ndarray
    t: jnp.ndarray                 # scalar int32

    def tree_flatten(self):
        return ((self.server, self.clients, self.inits, self.counters,
                 self.stale, self.key, self.t), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def engine_init(spec: FlatSpec, params, cfg, key) -> EngineState:
    """All clients start from the server model (Algorithm 1 line 16).
    Client rows beyond ``n`` (the client-tile padding of a client-aware
    spec) are zero and stay zero across rounds."""
    n = cfg.n_clients
    server = flatten_tree(spec, params)
    clients = stack_server_rows(spec, server, n)
    inits = stack_server_rows(spec, server, n)
    return EngineState(
        server=server, clients=clients, inits=inits,
        counters=jnp.zeros((n,), jnp.int32),
        stale=jnp.zeros((n,), jnp.int32),
        key=key, t=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# The round
# ---------------------------------------------------------------------------

def _local_training(loss_fn: Callable, cfg, clients_tree, counters,
                    new_counters, batch):
    """Masked R-step local SGD, vmapped over the client axis.

    Returns (trained_tree, loss_sum (n,), live_steps (n,)) — the raw masked
    loss sum and live-step count per client, so the caller can form a
    live-step-weighted aggregate instead of averaging in idle clients.

    batch: pytree with leading dims (n, R, ...) — one microbatch per client
    per potential local step."""

    def one_client(params, data, q0, q1):
        def step(p, inp):
            k, batch_k = inp
            loss, g = jax.value_and_grad(loss_fn)(p, batch_k)
            live = ((q0 + k) < q1).astype(jnp.float32)
            p = tree_map(lambda pp, gg: pp - cfg.eta * live * gg.astype(pp.dtype),
                         p, g)
            return p, loss * live
        ks = jnp.arange(cfg.R)
        params, losses = jax.lax.scan(step, params, (ks, data))
        return params, jnp.sum(losses), (q1 - q0).astype(jnp.float32)

    return jax.vmap(one_client)(clients_tree, batch, counters, new_counters)


def engine_round(spec: FlatSpec, state: EngineState, batch, *, cfg,
                 loss_fn: Callable, lambdas,
                 det_alpha: Optional[jnp.ndarray] = None,
                 use_kernel: Optional[bool] = None):
    """One FAVAS server round on flat buffers. Pure; jit/pjit this.

    The hot path is: unflatten clients -> vmapped local SGD -> flatten ->
    ONE fused aggregation+reset pass per dtype bucket. No per-leaf tree_map
    touches the aggregation."""
    n, s, K = cfg.n_clients, cfg.s_selected, cfg.local_steps
    key, k_inc, k_sel, k_q = jax.random.split(state.key, 4)

    # 1. heterogeneous progress this round
    d = sampler.sample_increments(k_inc, lambdas)              # (n,)
    new_counters = jnp.minimum(state.counters + d, K)

    # 2. masked local SGD (needs model structure -> tree space)
    clients_tree = unflatten_stacked(spec, state.clients)
    trained_tree, loss_sum, live = _local_training(
        loss_fn, cfg, clients_tree, state.counters, new_counters, batch)

    # 3. eq. (3) reweight coefficients
    if cfg.reweight == "deterministic":
        alpha = det_alpha
    else:
        alpha = reweight.alpha_stochastic(new_counters, p_pos=1.0)

    progress = (None,) * spec.n_buckets
    if cfg.quant_bits > 0:
        # FAVAS[QNN]: quantize the TRANSMITTED progress in tree space
        # (per-leaf LUQ scale, same per-leaf keys as the seed
        # implementation). Quantization is communication-only (Remark 1):
        # the fused pass aggregates Q(progress) but resets unselected
        # clients to their full-precision trained state.
        inits_tree = unflatten_stacked(spec, state.inits)
        prog = quantize_tree(tree_map(jnp.subtract, trained_tree, inits_tree),
                             cfg.quant_bits, k_q)
        progress = flatten_stacked(spec, prog)

    trained = flatten_stacked(spec, trained_tree)

    # 4+5. fused aggregation + selected-client reset: one pass per bucket.
    # alpha/mask ride to the kernel padded alongside the buffers' client
    # rows (unit alpha / zero mask => padded rows aggregate exactly nothing
    # and reset to themselves, i.e. stay zero).
    m = sampler.sample_selection(k_sel, n, s)                  # (n,) float
    alpha_p = pad_client_vec(spec, alpha, 1.0)
    m_p = pad_client_vec(spec, m, 0.0)
    server_new, clients_new, inits_new = [], [], []
    for b in range(spec.n_buckets):
        srv, cli, ini = favas_fused_flat(
            state.server[b], trained[b], state.inits[b], alpha_p, m_p,
            float(s), progress=progress[b], client_tile=spec.client_tile,
            n_logical=n, use_kernel=use_kernel)
        server_new.append(srv)
        clients_new.append(cli)
        inits_new.append(ini)

    counters_new = jnp.where(m > 0, 0, new_counters).astype(jnp.int32)
    stale_new = jnp.where(m > 0, 0, state.stale + 1).astype(jnp.int32)

    new_state = EngineState(server=tuple(server_new),
                            clients=tuple(clients_new),
                            inits=tuple(inits_new),
                            counters=counters_new, stale=stale_new,
                            key=key, t=state.t + 1)
    total_live = jnp.sum(live)
    metrics = {
        # live-step-weighted: clients that ran zero live steps this round
        # contribute nothing instead of dragging the mean toward 0, and a
        # stale straggler's high loss is weighted by its actual step count.
        "loss": jnp.sum(loss_sum) / jnp.maximum(total_live, 1.0),
        "mean_steps": jnp.mean(new_counters.astype(jnp.float32)),
        "selected": jnp.sum(m),
        "stale_rounds": jnp.max(stale_new).astype(jnp.float32),
    }
    return new_state, metrics


def engine_server_params(spec: FlatSpec, state: EngineState):
    """Current server model as the original parameter pytree."""
    return unflatten_tree(spec, state.server)


def engine_variance(state: EngineState) -> jnp.ndarray:
    """sum_i ||w^i - w_t||^2 straight off the flat buffers. Padded lane
    tails are identical between clients and server (zero contribution);
    padded client ROWS are all-zero, not copies of the server, so they are
    sliced off (the counters carry the logical n)."""
    n = state.counters.shape[0]
    tot = jnp.zeros((), jnp.float32)
    for srv, cli in zip(state.server, state.clients):
        diff = cli[:n].astype(jnp.float32) - srv[None].astype(jnp.float32)
        tot = tot + jnp.sum(jnp.square(diff))
    return tot


# ---------------------------------------------------------------------------
# RoundEngine: holds the static spec + a donated jitted round
# ---------------------------------------------------------------------------

class RoundEngine:
    """Convenience wrapper owning the FlatSpec and the jitted, buffer-donating
    round. The state never leaves flat form between rounds."""

    def __init__(self, params_template, cfg, loss_fn: Callable, *,
                 lambdas=None, det_alpha=None, use_kernel: Optional[bool] = None,
                 client_tile: int = CLIENT_TILE):
        from repro.core.favas import client_lambdas  # cycle-free at call time
        self.cfg = cfg
        self.spec = make_flat_spec(params_template, n_clients=cfg.n_clients,
                                   client_tile=client_tile)
        self.loss_fn = loss_fn
        self.lambdas = (jnp.asarray(lambdas) if lambdas is not None
                        else jnp.asarray(client_lambdas(cfg)))
        self.det_alpha = None if det_alpha is None else jnp.asarray(det_alpha)
        self.use_kernel = use_kernel
        self._round = jax.jit(
            functools.partial(engine_round, self.spec, cfg=self.cfg,
                              loss_fn=self.loss_fn, lambdas=self.lambdas,
                              det_alpha=self.det_alpha,
                              use_kernel=self.use_kernel),
            donate_argnums=(0,))

    def init_state(self, params, key) -> EngineState:
        return engine_init(self.spec, params, self.cfg, key)

    def step(self, state: EngineState, batch):
        """Jitted round; donates the previous state's buffers."""
        return self._round(state, batch)

    def server_params(self, state: EngineState):
        return engine_server_params(self.spec, state)

    def variance(self, state: EngineState) -> jnp.ndarray:
        return engine_variance(state)
