"""Flat-buffer FAVAS round engine.

The FAVAS server round is memory-bound: every byte of every resident
client's parameters crosses HBM each round (eq. 3 reweight, line-10
aggregation, line-11/12 selected-client reset). The seed implementation did
that as ~6 separate full-parameter ``tree_map`` passes per round. This
engine instead:

* flattens the parameter pytree ONCE into contiguous flat buffers — a
  ``(Dp,)`` server vector and ``(n, Dp)`` clients / inits matrices per
  dtype bucket, pre-padded to the kernel lane tile so the Pallas path never
  re-pads — and holds them across rounds;
* runs the whole aggregation + reset as ONE streamed pass per tile through
  the multi-output Pallas kernel ``kernels.favas_agg.favas_fused_pallas``
  (TPU; interpret for validation) or its jnp oracle
  ``kernels.ref.favas_fused_ref`` (CPU default — XLA fuses the flat-buffer
  expression into a single loop, which is already the oracle's point);
* unflattens only at the boundaries that need model structure: the vmapped
  local-SGD step (which needs the pytree for the model's loss), evaluation,
  and checkpoint export.

``core.favas.favas_round`` keeps the seed's pytree API by wrapping
``engine_round`` with flatten/unflatten at the call boundary;
``launch.train`` uses ``RoundEngine`` directly so the buffers genuinely
persist across rounds and the jitted round donates them.

Beyond the fused single round, ``engine_multi_round`` /
``RoundEngine.run`` scan a whole CHUNK of rounds on-device — one jitted,
buffer-donating dispatch and one stacked metrics fetch per chunk instead
of per round ("supersteps", docs/architecture.md §7) — which removes the
per-round host dispatch + sync overhead that dominates FAVAS's cheap,
frequent server rounds.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import sampler, reweight
from repro.core.paging import PassthroughCodec, make_codec
from repro.core.quant import quantize_tree
from repro.kernels.favas_agg import CLIENT_TILE, TILE
from repro.kernels.ops import favas_fused_flat, favas_stream_flat
from repro.utils.tree import tree_map


# ---------------------------------------------------------------------------
# FlatSpec: static description of the pytree <-> flat-buffer mapping
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static (hashable, trace-free) layout of a parameter pytree flattened
    into one contiguous buffer per (leaf dtype, sharding group) "bucket".

    Leaves keep their original dtype; mixed-precision trees get one buffer
    per dtype so no storage precision is lost. Buffer length is padded up to
    a multiple of the kernel lane tile; the padded tail is zero-initialized
    and provably stays zero under the fused round update (the masked padded
    "server" tail aggregates only zeros).

    When built with ``n_clients``, the spec is client-aware: stacked buffers
    additionally pad the client (row) axis up to a multiple of the kernel's
    ``client_tile`` once n exceeds one client block, so the tiled kernel
    never re-pads either axis. Padded rows are all-zero with zero selection
    mask and unit alpha — they contribute exactly nothing to the masked
    aggregation and provably stay zero across rounds.

    When built with ``mesh`` (or explicit ``shard_axes``/``model_shards``),
    the spec is additionally *sharding-aware* (docs/architecture.md §6):
    leaves whose resolved PartitionSpec (``sharding/rules.py``) puts a dim
    on the "model" mesh axis land in a separate bucket per dtype, laid out
    SHARD-MAJOR — the flat buffer is the concatenation over the S model
    shards of that shard's slice of every leaf, each per-shard segment
    independently padded to the lane tile. Partitioning the flat axis into S
    equal contiguous blocks (``PartitionSpec("model")``) therefore hands
    each device exactly its own leaf shards: flatten, the fused round, and
    unflatten all stay communication-free on the model axis (no full-buffer
    all-gather; see ``fused_bucket_update``). Invariant:
    ``bucket_padded[b] == bucket_shards[b] * bucket_shard_padded[b]``.
    """
    treedef: Any
    shapes: tuple                 # per leaf, original shape
    dtypes: tuple                 # per leaf, jnp dtype name (str, hashable)
    bucket_of: tuple              # per leaf, bucket index
    offsets: tuple                # per leaf, start offset within its bucket
    #                               (per-shard units for sharded buckets)
    bucket_dtypes: tuple          # per bucket, dtype name
    bucket_sizes: tuple           # per bucket, unpadded element count (total)
    bucket_padded: tuple          # per bucket, padded element count (total)
    n_clients: Optional[int] = None   # logical client rows (None: not stacked)
    n_padded: Optional[int] = None    # stored client rows incl. padding
    client_tile: Optional[int] = None  # kernel client-axis tile
    shard_axes: tuple = ()        # per leaf, model-sharded dim index or None
    bucket_shards: tuple = ()     # per bucket, model shard count (1 = replicated)
    bucket_shard_sizes: tuple = ()   # per bucket, unpadded elements PER SHARD
    bucket_shard_padded: tuple = ()  # per bucket, padded elements PER SHARD
    mesh_axis: Optional[str] = None  # mesh axis sharded buckets live on
    # residency axis (docs/architecture.md §9): "dense" keeps all n client
    # rows in full precision; "paged" keeps a hot working set of s_max rows
    # plus a codec-encoded cold pool covering all n clients
    residency: str = "dense"
    s_max: Optional[int] = None        # hot rows (logical), paged specs only
    s_hot_padded: Optional[int] = None  # hot rows incl. client-tile padding
    cold_codec: Any = None             # hashable codec (core.paging)
    # cold-pool placement (docs/architecture.md §13): "device" keeps the
    # encoded pools in HBM (the §9 layout); "host" keeps them in host
    # memory — device-resident bytes then scale with s_max instead of n,
    # and each chunk streams only its churned pages through a bounded slab
    cold_placement: str = "device"

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_dtypes)

    def shards(self, b: int) -> int:
        """Model shard count of bucket ``b`` (1 for pre-sharding specs)."""
        return self.bucket_shards[b] if self.bucket_shards else 1

    @property
    def paged(self) -> bool:
        return self.residency == "paged"

    @property
    def stacked_logical(self) -> Optional[int]:
        """Logical rows of the client/init stacks the state carries: the hot
        working set for paged specs, all clients for dense ones."""
        return self.s_max if self.paged else self.n_clients

    @property
    def stacked_rows(self) -> Optional[int]:
        """Stored rows of the client/init stacks (incl. client-tile pad)."""
        return self.s_hot_padded if self.paged else self.n_padded


def make_flat_spec(tree, *, tile: int = TILE, n_clients: Optional[int] = None,
                   client_tile: int = CLIENT_TILE, mesh=None,
                   shard_axes: Optional[Sequence] = None,
                   model_shards: Optional[int] = None,
                   residency: str = "dense", s_max: Optional[int] = None,
                   cold_codec=None, cold_placement: str = "device") -> FlatSpec:
    """Build the layout from a pytree of arrays / ShapeDtypeStructs.

    ``n_clients``: make the spec client-aware (see class docstring). Row
    padding only kicks in beyond one client block (n > client_tile), so
    small federations carry no extra rows.

    ``mesh``: make the spec sharding-aware — leaves are classified through
    ``sharding.rules.model_shard_axes`` (the same regex rules pjit uses)
    and model-sharded leaves get their own shard-major bucket per dtype.
    ``shard_axes`` (a per-leaf list of dim indices / None, aligned with
    ``tree_leaves``) overrides the rule lookup; ``model_shards`` overrides
    the shard count (needed when passing ``shard_axes`` without a mesh —
    layout is pure metadata and never touches devices). A leaf whose
    nominated dim does not divide by the shard count falls back to the
    replicated bucket, mirroring ``sharding.rules.check_divisible``.

    ``residency="paged"``: virtualize the client axis (docs/architecture.md
    §9) — the state's stacks hold only ``s_max`` hot rows (padded with the
    same client-tile formula as the dense n), and a ``cold_codec``-encoded
    pool covers all n clients. ``s_max`` defaults to (and is clamped at)
    ``n_clients``; at ``s_max == n_clients`` the hot set is the whole
    id-ordered population and the paged round is bit-exact with the dense
    one. ``cold_codec`` defaults to the passthrough (identity) codec.

    ``cold_placement="host"`` (paged specs only, docs/architecture.md §13)
    moves the encoded cold pools to HOST memory: the state carries a
    ``core.streaming.HostColdPool`` instead of device arrays, every round
    touches cold pages through a churn-bounded device slab planned ahead
    of the chunk, and device-resident bytes scale with ``s_max`` instead
    of ``n``. Values are bit-exact vs ``"device"`` placement — only where
    the encoded bytes live changes."""
    if cold_placement not in ("device", "host"):
        raise ValueError(f"unknown cold_placement {cold_placement!r}")
    if cold_placement == "host" and residency != "paged":
        raise ValueError("cold_placement='host' requires residency='paged'")
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    S0 = model_shards or 1
    if mesh is not None and model_shards is None:
        S0 = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if shard_axes is None:
        if mesh is not None and S0 > 1:
            from repro.sharding.rules import model_shard_axes  # lazy: no cycle
            shard_axes = model_shard_axes(tree, mesh)
        else:
            shard_axes = [None] * len(leaves)
    if len(shard_axes) != len(leaves):
        raise ValueError(
            f"shard_axes has {len(shard_axes)} entries for {len(leaves)} leaves")
    shapes, dtypes, bucket_of, offsets, axes_out = [], [], [], [], []
    keys, bucket_dtypes, shards_l, cursors = [], [], [], []
    for leaf, ax in zip(leaves, shard_axes):
        dt = jnp.dtype(leaf.dtype).name
        size = 1
        for d in leaf.shape:
            size *= int(d)
        if (ax is not None and (S0 <= 1 or ax >= len(leaf.shape)
                                or leaf.shape[ax] % S0 != 0)):
            ax = None                    # non-dividing dim: replicate
        key = (dt, ax is not None)
        if key not in keys:
            keys.append(key)
            bucket_dtypes.append(dt)
            shards_l.append(S0 if ax is not None else 1)
            cursors.append(0)
        b = keys.index(key)
        shapes.append(tuple(leaf.shape))
        dtypes.append(dt)
        bucket_of.append(b)
        offsets.append(cursors[b])
        cursors[b] += size // shards_l[b]
        axes_out.append(ax)
    shard_padded = tuple(c + ((-c) % tile) for c in cursors)
    padded = tuple(sp * s for sp, s in zip(shard_padded, shards_l))
    sizes = tuple(c * s for c, s in zip(cursors, shards_l))
    n_padded = None
    if n_clients is not None:
        n_padded = (n_clients if n_clients <= client_tile
                    else n_clients + ((-n_clients) % client_tile))
    s_hot_padded = None
    if residency == "paged":
        if n_clients is None:
            raise ValueError("residency='paged' requires n_clients")
        s_max = n_clients if s_max is None else min(int(s_max), n_clients)
        if s_max < 1:
            raise ValueError(f"s_max must be >= 1 (got {s_max})")
        # same padding formula as the dense client axis, so at s_max == n
        # the hot stacks have exactly the dense shapes (the parity regime)
        s_hot_padded = (s_max if s_max <= client_tile
                        else s_max + ((-s_max) % client_tile))
        cold_codec = cold_codec if cold_codec is not None else PassthroughCodec()
    else:
        s_max, cold_codec = None, None
    return FlatSpec(treedef=treedef, shapes=tuple(shapes), dtypes=tuple(dtypes),
                    bucket_of=tuple(bucket_of), offsets=tuple(offsets),
                    bucket_dtypes=tuple(bucket_dtypes),
                    bucket_sizes=sizes, bucket_padded=padded,
                    n_clients=n_clients, n_padded=n_padded,
                    client_tile=client_tile if n_clients is not None else None,
                    shard_axes=tuple(axes_out),
                    bucket_shards=tuple(shards_l),
                    bucket_shard_sizes=tuple(cursors),
                    bucket_shard_padded=shard_padded,
                    mesh_axis="model" if any(s > 1 for s in shards_l) else None,
                    residency=residency, s_max=s_max,
                    s_hot_padded=s_hot_padded, cold_codec=cold_codec,
                    cold_placement=(cold_placement if residency == "paged"
                                    else "device"))


def flatten_tree(spec: FlatSpec, tree) -> tuple:
    """Pytree -> tuple of (Dp_b,) flat buffers (one per spec bucket).

    Sharded buckets are laid out shard-major: leaf dims sharded on the model
    axis move to the front and split into S rows before concatenation, so
    every op here is shard-local under GSPMD (transpose + reshape of the
    sharded dim by exactly the shard count — no cross-device data motion)."""
    leaves = jax.tree_util.tree_leaves(tree)
    parts = [[] for _ in range(spec.n_buckets)]
    for leaf, b, ax in zip(leaves, spec.bucket_of, spec.shard_axes):
        S = spec.shards(b)
        if S > 1:
            parts[b].append(jnp.moveaxis(leaf, ax, 0).reshape(S, -1))
        else:
            parts[b].append(jnp.ravel(leaf))
    out = []
    for b in range(spec.n_buckets):
        S = spec.shards(b)
        if S > 1:
            buf = (jnp.concatenate(parts[b], axis=1) if len(parts[b]) > 1
                   else parts[b][0])
            pad = spec.bucket_shard_padded[b] - spec.bucket_shard_sizes[b]
            if pad:
                buf = jnp.pad(buf, ((0, 0), (0, pad)))
            out.append(buf.reshape(-1))
        else:
            buf = jnp.concatenate(parts[b]) if len(parts[b]) > 1 else parts[b][0]
            pad = spec.bucket_padded[b] - spec.bucket_sizes[b]
            if pad:
                buf = jnp.pad(buf, (0, pad))
            out.append(buf)
    return tuple(out)


def flatten_stacked(spec: FlatSpec, tree) -> tuple:
    """Client-stacked pytree (leading axis n) -> tuple of (Np_b, Dp_b).

    With a client-aware spec the row axis is zero-padded up to
    ``spec.n_padded`` so the tiled kernel path never re-pads."""
    leaves = jax.tree_util.tree_leaves(tree)
    n = leaves[0].shape[0]
    rpad = 0
    if spec.stacked_rows is not None:
        # loud failure instead of silently mis-padding: a client-aware spec
        # only describes trees with exactly stacked_logical rows (n_clients
        # dense, the s_max hot working set paged)
        if n != spec.stacked_logical:
            raise ValueError(
                f"stacked tree has {n} client rows but the spec stacks "
                f"{spec.stacked_logical} ({spec.residency})")
        rpad = spec.stacked_rows - n
    parts = [[] for _ in range(spec.n_buckets)]
    for leaf, b, ax in zip(leaves, spec.bucket_of, spec.shard_axes):
        S = spec.shards(b)
        if S > 1:
            parts[b].append(jnp.moveaxis(leaf, 1 + ax, 1).reshape(n, S, -1))
        else:
            parts[b].append(leaf.reshape(n, -1))
    out = []
    for b in range(spec.n_buckets):
        S = spec.shards(b)
        if S > 1:
            buf = (jnp.concatenate(parts[b], axis=2) if len(parts[b]) > 1
                   else parts[b][0])
            pad = spec.bucket_shard_padded[b] - spec.bucket_shard_sizes[b]
            if pad or rpad:
                buf = jnp.pad(buf, ((0, rpad), (0, 0), (0, pad)))
            out.append(buf.reshape(n + rpad, spec.bucket_padded[b]))
        else:
            buf = (jnp.concatenate(parts[b], axis=1) if len(parts[b]) > 1
                   else parts[b][0])
            pad = spec.bucket_padded[b] - spec.bucket_sizes[b]
            if pad or rpad:
                buf = jnp.pad(buf, ((0, rpad), (0, pad)))
            out.append(buf)
    return tuple(out)


def unflatten_tree(spec: FlatSpec, bufs: Sequence):
    """Tuple of (Dp_b,) buffers -> pytree with the original leaf layout.
    Sharded buckets invert the shard-major layout (shard-local under GSPMD,
    exact inverse of ``flatten_tree`` — round-trips are bit-exact)."""
    leaves = []
    for shape, dt, b, off, ax in zip(spec.shapes, spec.dtypes, spec.bucket_of,
                                     spec.offsets, spec.shard_axes):
        size = 1
        for d in shape:
            size *= d
        S = spec.shards(b)
        if S > 1:
            rows = bufs[b].reshape(S, spec.bucket_shard_padded[b])
            rows = jax.lax.dynamic_slice_in_dim(rows, off, size // S, axis=1)
            moved = (shape[ax],) + shape[:ax] + shape[ax + 1:]
            leaves.append(jnp.moveaxis(rows.reshape(moved), 0, ax))
        else:
            leaves.append(jax.lax.dynamic_slice_in_dim(bufs[b], off, size)
                          .reshape(shape))
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def unflatten_stacked(spec: FlatSpec, bufs: Sequence):
    """Tuple of (Np_b, Dp_b) buffers -> client-stacked pytree (padded client
    rows, if any, are dropped)."""
    leaves = []
    for shape, dt, b, off, ax in zip(spec.shapes, spec.dtypes, spec.bucket_of,
                                     spec.offsets, spec.shard_axes):
        buf = bufs[b]
        n = buf.shape[0]
        if spec.stacked_rows is not None:
            if n != spec.stacked_rows:
                raise ValueError(
                    f"stacked buffer has {n} rows but the spec stores "
                    f"{spec.stacked_rows} ({spec.residency})")
            if spec.stacked_logical < n:
                n = spec.stacked_logical
                buf = buf[:n]
        size = 1
        for d in shape:
            size *= d
        S = spec.shards(b)
        if S > 1:
            rows = buf.reshape(n, S, spec.bucket_shard_padded[b])
            rows = jax.lax.dynamic_slice_in_dim(rows, off, size // S, axis=2)
            moved = (n, shape[ax]) + shape[:ax] + shape[ax + 1:]
            leaves.append(jnp.moveaxis(rows.reshape(moved), 1, 1 + ax))
        else:
            leaves.append(
                jax.lax.dynamic_slice_in_dim(buf, off, size, axis=1)
                .reshape((n,) + shape))
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def pad_client_vec(spec: FlatSpec, v, fill: float = 0.0):
    """(n,) per-client vector -> (Np,) padded to the spec's stored rows.
    ``fill``: value for padded rows (0 for masks — padded rows are never
    selected; 1 for alphas — keeps the guarded division trivially exact)."""
    if spec.stacked_rows is None:
        return v
    if v.shape[0] != spec.stacked_logical:
        raise ValueError(
            f"per-client vector has {v.shape[0]} rows but the spec stacks "
            f"{spec.stacked_logical} ({spec.residency})")
    rpad = spec.stacked_rows - spec.stacked_logical
    if not rpad:
        return v
    return jnp.concatenate([v, jnp.full((rpad,), fill, v.dtype)])


def stack_server_rows(spec: FlatSpec, server_bufs: Sequence, n: int) -> tuple:
    """Server flat buffers -> client/init row stacks: the server row
    broadcast to n clients plus all-zero padded rows up to the spec's stored
    row count. Each result is a DISTINCT buffer (broadcasts are materialized)
    so a donating jit never sees the same buffer twice."""
    if spec.stacked_logical is not None and n != spec.stacked_logical:
        raise ValueError(
            f"stacking {n} client rows but the spec stacks "
            f"{spec.stacked_logical} ({spec.residency})")
    rows = spec.stacked_rows or n
    out = []
    for b in server_bufs:
        buf = jnp.broadcast_to(b[None], (n,) + b.shape)
        buf = (jnp.pad(buf, ((0, rows - n), (0, 0))) if rows > n
               else buf.copy())
        out.append(buf)
    return tuple(out)


# ---------------------------------------------------------------------------
# Mesh-aware execution: shardings, constraints, and the per-bucket fused call
# ---------------------------------------------------------------------------

def bucket_partition_specs(spec: FlatSpec, *, stacked: bool) -> tuple:
    """Per-bucket ``PartitionSpec`` for flat buffers: sharded buckets put the
    lane axis on the spec's model mesh axis, replicated buckets on nothing.
    ``stacked``: (n, Dp) client/init matrices (leading client axis is NOT
    model-sharded) vs (Dp,) server vectors."""
    from jax.sharding import PartitionSpec as P
    out = []
    for b in range(spec.n_buckets):
        ax = spec.mesh_axis if spec.shards(b) > 1 else None
        out.append(P(None, ax) if stacked else P(ax))
    return tuple(out)


def engine_sharding(spec: FlatSpec, mesh):
    """``NamedSharding`` pytree for an :class:`EngineState` on ``mesh`` —
    what ``jax.device_put`` of the initial state and the jitted round's
    output constraints use. Sharded buckets live with their lane axis on
    "model"; counters/stale/key/t are replicated."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())
    srv = tuple(NamedSharding(mesh, p)
                for p in bucket_partition_specs(spec, stacked=False))
    stk = tuple(NamedSharding(mesh, p)
                for p in bucket_partition_specs(spec, stacked=True))
    hot_ids, cold = None, None
    if spec.paged:
        hot_ids = rep
    if spec.paged and spec.cold_placement == "device":
        # cold pools shard exactly like the dense stacked buckets (§6): the
        # encoded lane axis (packed codes / per-shard scales) splits on the
        # model axis, the client-id row axis replicates. Host-placed pools
        # are NOT device arrays (core.streaming.HostColdPool) and carry no
        # sharding — their churn slab gets these specs per chunk instead.
        cold = tuple(
            jax.tree_util.tree_map(
                lambda p: NamedSharding(mesh, p),
                spec.cold_codec.partition_specs(
                    spec.shards(b) > 1, spec.mesh_axis or "model"),
                is_leaf=lambda x: isinstance(x, P))
            for b in range(spec.n_buckets))
    return EngineState(server=srv, clients=stk, inits=stk,
                       counters=rep, stale=rep, key=rep, t=rep,
                       hot_ids=hot_ids, cold=cold)


def _constrain_buckets(spec: FlatSpec, mesh, bufs, *, stacked: bool) -> tuple:
    """Pin per-bucket flat buffers to their mesh sharding (None entries pass
    through). Keeps GSPMD from replicating the buffers around the
    flatten/unflatten transposes in the round body."""
    if mesh is None:
        return tuple(bufs)
    from jax.sharding import NamedSharding
    specs = bucket_partition_specs(spec, stacked=stacked)
    return tuple(
        x if x is None or spec.shards(b) <= 1
        else jax.lax.with_sharding_constraint(x, NamedSharding(mesh, specs[b]))
        for b, x in enumerate(bufs))


def _constrain_cold(spec: FlatSpec, mesh, cold) -> tuple:
    """Pin per-bucket encoded cold pools to the §6 layout (lane axis on the
    model mesh axis for sharded buckets). Row-axis gathers/scatters and the
    per-shard encode reductions are then provably shard-local — the paged
    round adds no collectives over the dense engine's."""
    if mesh is None:
        return tuple(cold)
    from jax.sharding import NamedSharding, PartitionSpec as P
    out = []
    for b in range(spec.n_buckets):
        if spec.shards(b) <= 1:
            out.append(cold[b])
            continue
        specs = spec.cold_codec.partition_specs(True, spec.mesh_axis or "model")
        out.append(jax.tree_util.tree_map(
            lambda p, x: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, p)),
            specs, cold[b], is_leaf=lambda t: isinstance(t, P)))
    return tuple(out)


def fused_bucket_update(spec: FlatSpec, b: int, server_b, trained_b, inits_b,
                        alpha_p, mask_p, s: float, *, progress_b=None,
                        progress_codes_b=None, progress_bits: int = 0,
                        n_logical: Optional[int] = None, mesh=None,
                        use_kernel: Optional[bool] = None):
    """One bucket's fused aggregation + selected-client reset, mesh-aware.

    Dispatch (docs/architecture.md §6):

    * no mesh, or a replicated bucket -> plain ``favas_fused_flat`` (kernel
      or oracle; GSPMD replicates it on a mesh);
    * sharded bucket + kernel -> ``shard_map`` over the model axis: each
      device runs the Pallas kernel on its own (n, Dp_b/S) flat slice. The
      slice is lane-tile aligned by construction (per-shard padding), the
      client reduction is shard-local, and the body contains no collectives
      — the round cannot all-gather the buffer;
    * sharded bucket + oracle -> the jnp expression under pjit with explicit
      output ``PartitionSpec`` constraints; GSPMD partitions the elementwise
      lanes and the (unsharded) client-axis reduction locally.

    ``progress_codes_b`` (mutually exclusive with ``progress_b``): the
    transmitted progress as a ``{"codes", "scale"}`` encoding from
    ``kernels.ops.cold_requant_rows`` at ``progress_bits``, encoded with
    ``shards=spec.shards(b)``. The per-shard scale layout makes the codes-in
    shard_map body exactly per-device: each device's codes slice is a
    standalone shards=1 encoding of its own lane segment, so the kernel
    dequantizes shard-locally with no collectives.

    Returns (server_new, clients_new, inits_new) with the inputs' shardings.
    """
    if progress_b is not None and progress_codes_b is not None:
        raise ValueError("progress_b and progress_codes_b are mutually "
                         "exclusive")
    if mesh is None or spec.shards(b) <= 1:
        return favas_fused_flat(server_b, trained_b, inits_b, alpha_p, mask_p,
                                float(s), progress=progress_b,
                                progress_codes=progress_codes_b,
                                progress_bits=progress_bits,
                                progress_shards=max(1, spec.shards(b)),
                                client_tile=spec.client_tile,
                                n_logical=n_logical, use_kernel=use_kernel)
    kernel_active = (use_kernel if use_kernel is not None
                     else jax.default_backend() == "tpu")
    from jax.sharding import PartitionSpec as P
    lane, row, vec = P(spec.mesh_axis), P(None, spec.mesh_axis), P(None)
    if kernel_active:
        from jax.experimental.shard_map import shard_map

        def body(*ops):
            pr = pc = None
            if progress_b is not None:
                srv, cli, ini, pr, al, mk = ops
            elif progress_codes_b is not None:
                srv, cli, ini, cd, sc, al, mk = ops
                pc = {"codes": cd, "scale": sc}
            else:
                srv, cli, ini, al, mk = ops
            # per-device view: the local codes slice is one shard segment
            # with its own (rows, 1) scale column -> progress_shards=1
            return favas_fused_flat(srv, cli, ini, al, mk, float(s),
                                    progress=pr, progress_codes=pc,
                                    progress_bits=progress_bits,
                                    progress_shards=1,
                                    client_tile=spec.client_tile,
                                    n_logical=n_logical, use_kernel=True)

        operands = [server_b, trained_b, inits_b]
        in_specs = [lane, row, row]
        if progress_b is not None:
            operands.append(progress_b)
            in_specs.append(row)
        elif progress_codes_b is not None:
            # codes split on the lane axis like the row buffers; the
            # (rows, S) scale splits its shard column onto its shard
            operands += [progress_codes_b["codes"], progress_codes_b["scale"]]
            in_specs += [row, row]
        operands += [alpha_p, mask_p]
        in_specs += [vec, vec]
        return shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                         out_specs=(lane, row, row),
                         check_rep=False)(*operands)
    from jax.sharding import NamedSharding
    out = favas_fused_flat(server_b, trained_b, inits_b, alpha_p, mask_p,
                           float(s), progress=progress_b,
                           progress_codes=progress_codes_b,
                           progress_bits=progress_bits,
                           progress_shards=spec.shards(b),
                           client_tile=spec.client_tile,
                           n_logical=n_logical, use_kernel=False)
    return tuple(jax.lax.with_sharding_constraint(o, NamedSharding(mesh, p))
                 for o, p in zip(out, (lane, row, row)))


def stream_bucket_update(spec: FlatSpec, b: int, server_b, trained_b, inits_b,
                         alpha_p, mask_p, s: float, *, progress_b=None,
                         progress_codes_b=None, progress_bits: int = 0,
                         n_logical: Optional[int] = None, mesh=None,
                         use_kernel: Optional[bool] = None):
    """One bucket's STREAMED aggregation (docs/architecture.md §13):
    the :func:`fused_bucket_update` dispatch contract (plain call /
    shard_map kernel / pjit oracle), returning ONLY the new server vector.
    The caller applies the selected-client reset as a churn-bounded scatter
    of this row into the donated client/init buffers — unselected rows are
    never rewritten, so per-bucket round traffic drops from ~2R+2W to
    1R (+ O(s * Dp) scatter writes) per resident byte. Bit-identical
    server to ``fused_bucket_update`` per dispatch path."""
    if progress_b is not None and progress_codes_b is not None:
        raise ValueError("progress_b and progress_codes_b are mutually "
                         "exclusive")
    if mesh is None or spec.shards(b) <= 1:
        return favas_stream_flat(server_b, trained_b, inits_b, alpha_p,
                                 mask_p, float(s), progress=progress_b,
                                 progress_codes=progress_codes_b,
                                 progress_bits=progress_bits,
                                 progress_shards=max(1, spec.shards(b)),
                                 client_tile=spec.client_tile,
                                 n_logical=n_logical, use_kernel=use_kernel)
    kernel_active = (use_kernel if use_kernel is not None
                     else jax.default_backend() == "tpu")
    from jax.sharding import PartitionSpec as P
    lane, row, vec = P(spec.mesh_axis), P(None, spec.mesh_axis), P(None)
    if kernel_active:
        from jax.experimental.shard_map import shard_map

        def body(*ops):
            pr = pc = None
            if progress_b is not None:
                srv, cli, ini, pr, al, mk = ops
            elif progress_codes_b is not None:
                srv, cli, ini, cd, sc, al, mk = ops
                pc = {"codes": cd, "scale": sc}
            else:
                srv, cli, ini, al, mk = ops
            return favas_stream_flat(srv, cli, ini, al, mk, float(s),
                                     progress=pr, progress_codes=pc,
                                     progress_bits=progress_bits,
                                     progress_shards=1,
                                     client_tile=spec.client_tile,
                                     n_logical=n_logical, use_kernel=True)

        operands = [server_b, trained_b, inits_b]
        in_specs = [lane, row, row]
        if progress_b is not None:
            operands.append(progress_b)
            in_specs.append(row)
        elif progress_codes_b is not None:
            operands += [progress_codes_b["codes"], progress_codes_b["scale"]]
            in_specs += [row, row]
        operands += [alpha_p, mask_p]
        in_specs += [vec, vec]
        return shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                         out_specs=lane, check_rep=False)(*operands)
    from jax.sharding import NamedSharding
    out = favas_stream_flat(server_b, trained_b, inits_b, alpha_p, mask_p,
                            float(s), progress=progress_b,
                            progress_codes=progress_codes_b,
                            progress_bits=progress_bits,
                            progress_shards=spec.shards(b),
                            client_tile=spec.client_tile,
                            n_logical=n_logical, use_kernel=False)
    return jax.lax.with_sharding_constraint(out, NamedSharding(mesh, lane))


def _streamed_reset(spec: FlatSpec, mesh, bufs, sel_idx, rows):
    """Churn-bounded selected-client reset: scatter each bucket's new server
    row into the ``sel_idx`` positions of the (donated) state buffers.
    ``rows`` is the per-bucket new-server vector list. XLA performs the
    scatter in place on donated inputs, so unselected rows are never
    rewritten (the write-traffic audit in launch/roofline.py pins this).
    Bit-exact vs the fused reset: the mask is exactly the indicator of
    ``sel_idx`` and the fused ``m*s_new + (1-m)*x`` blend is ``x`` (exact
    f32 round-trip) off-selection and ``s_new.astype(dtype)`` — the
    scattered row — on it."""
    out = [buf.at[sel_idx].set(row.astype(buf.dtype))
           for buf, row in zip(bufs, rows)]
    return _constrain_buckets(spec, mesh, out, stacked=True)


def slab_shardings(spec: FlatSpec, mesh):
    """Per-bucket ``NamedSharding`` tree for a host-tier churn slab — the
    same §6 layout as the device-placed cold pools (encoded lane axis on
    the model mesh axis, row axis replicated). None without a mesh."""
    if mesh is None:
        return None
    from jax.sharding import NamedSharding, PartitionSpec as P
    return tuple(
        jax.tree_util.tree_map(
            lambda p: NamedSharding(mesh, p),
            spec.cold_codec.partition_specs(
                spec.shards(b) > 1, spec.mesh_axis or "model"),
            is_leaf=lambda x: isinstance(x, P))
        for b in range(spec.n_buckets))


def _encode_progress(spec: FlatSpec, trained, inits, k_q, bits: int, *,
                     mesh=None, use_kernel: Optional[bool] = None) -> tuple:
    """Per-bucket LUQ encode of the transmitted progress (``quant_fused``
    transport): ``trained[b] - inits[b]`` in f32 -> packed codes +
    per-(row, shard) scales via ``kernels.ops.cold_requant_rows``. Padded
    client rows and lane tails are zero in both operands, so their delta is
    exactly zero, the guarded scale is 1.0 and the codes decode to exact
    zeros — padding stays a no-op through the codec. Keys: ``fold_in(k_q,
    0x7166)`` ('qf') then per-bucket fold — a stream disjoint from both the
    per-leaf ``quantize_tree`` split and the paged eviction fold."""
    from repro.kernels.ops import cold_requant_rows   # lazy: no import cycle
    k_qf = jax.random.fold_in(k_q, 0x7166)
    codes = []
    for b in range(spec.n_buckets):
        delta = (trained[b].astype(jnp.float32)
                 - inits[b].astype(jnp.float32))
        codes.append(cold_requant_rows(
            delta, bits, jax.random.fold_in(k_qf, b),
            shards=max(1, spec.shards(b)), use_kernel=use_kernel))
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        lane = P(None, spec.mesh_axis or "model")
        codes = [pc if spec.shards(b) <= 1 else jax.tree_util.tree_map(
                     lambda x: jax.lax.with_sharding_constraint(
                         x, NamedSharding(mesh, lane)), pc)
                 for b, pc in enumerate(codes)]
    return tuple(codes)


# ---------------------------------------------------------------------------
# Engine state (flat buffers held across rounds)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EngineState:
    server: tuple                  # per bucket (Dp_b,)
    clients: tuple                 # per bucket (rows, Dp_b) — all n rows on a
    #                                dense spec, the s_max hot rows on paged
    inits: tuple                   # per bucket (rows, Dp_b)
    counters: jnp.ndarray          # (n,) int32 — q^i, local steps since reset
    stale: jnp.ndarray             # (n,) int32 — rounds since last selection
    key: jnp.ndarray
    t: jnp.ndarray                 # scalar int32
    # paged residency only (None on dense states, docs/architecture.md §9):
    hot_ids: Any = None            # (s_max,) int32 resident client ids, sorted
    cold: Any = None               # per bucket codec-encoded pools, n rows

    def tree_flatten(self):
        return ((self.server, self.clients, self.inits, self.counters,
                 self.stale, self.key, self.t, self.hot_ids, self.cold), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def engine_init(spec: FlatSpec, params, cfg, key, *,
                use_kernel: Optional[bool] = None) -> EngineState:
    """Build the initial :class:`EngineState` from a parameter pytree.

    All clients start from the server model (Algorithm 1 line 16): the
    server buffer is ``params`` flattened per ``spec``; the client and init
    stacks are that row broadcast to ``cfg.n_clients`` distinct buffers.
    Client rows beyond ``n`` (the client-tile padding of a client-aware
    spec) are zero and stay zero across rounds; per-shard lane tails of a
    sharding-aware spec are likewise zero forever.

    Args:
      spec: layout from :func:`make_flat_spec` (must be client-aware with
        ``n_clients == cfg.n_clients`` if built with ``n_clients``).
      params: parameter pytree matching ``spec.treedef``.
      cfg: :class:`repro.core.favas.FavasConfig` (reads ``n_clients``).
      key: PRNG key stored in the state and split every round.
      use_kernel: cold-pool codec dispatch for the paged seeding encode —
        same contract as the round's (None = TPU auto); the kernel and
        oracle paths are bit-identical under shared uniforms so the choice
        never changes the seeded state's values.

    Returns an :class:`EngineState` on the default device; on a mesh,
    ``jax.device_put`` it with :func:`engine_sharding` (``RoundEngine``
    does both)."""
    n = cfg.n_clients
    server = flatten_tree(spec, params)
    hot_ids, cold = None, None
    if spec.paged:
        if cfg.s_selected > spec.s_max:
            raise ValueError(
                f"s_selected={cfg.s_selected} exceeds the hot working set "
                f"s_max={spec.s_max}: every selected client must fit hot")
        # hot working set: everyone starts equally fresh (stale 0), so the
        # staleness/id order picks the s_max lowest ids — at s_max == n this
        # is arange(n), the dense layout
        hot_ids = jnp.arange(spec.s_max, dtype=jnp.int32)
        clients = stack_server_rows(spec, server, spec.s_max)
        inits = stack_server_rows(spec, server, spec.s_max)
        # cold pools: every client is the server row with zero progress, so
        # ONE row is encoded per bucket and broadcast to all n ids (for the
        # LUQ codec the progress codes are exactly zero; identical per-row
        # uniforms are harmless since the rows are identical). fold_in keeps
        # the state's key chain untouched — bit-identical to the dense init.
        k_cold = jax.random.fold_in(key, 0x636f6c64)
        cold = []
        for b in range(spec.n_buckets):
            row = server[b][None]
            enc1 = spec.cold_codec.encode_pair(
                row, row, jax.random.fold_in(k_cold, b),
                shards=spec.shards(b), use_kernel=use_kernel)
            if spec.cold_placement == "host":
                # host tier (§13): the encode still runs on device (bit-
                # identical bytes to the device placement) but the n-row
                # broadcast materializes in HOST memory — the device never
                # holds an O(n) pool
                import numpy as np
                cold.append(jax.tree_util.tree_map(
                    lambda a: np.broadcast_to(
                        np.asarray(jax.device_get(a)),
                        (n,) + a.shape[1:]).copy(), enc1))
            else:
                cold.append(jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (n,) + a.shape[1:]).copy(),
                    enc1))
        if spec.cold_placement == "host":
            from repro.core.streaming import HostColdPool  # lazy: no cycle
            cold = HostColdPool(tuple(cold))
        else:
            cold = tuple(cold)
    else:
        clients = stack_server_rows(spec, server, n)
        inits = stack_server_rows(spec, server, n)
    # private copy of the key: the jitted round DONATES the state, and a
    # caller-owned key array shared between two states (or reused for a
    # second init) would be deleted by the first state's first dispatch
    return EngineState(
        server=server, clients=clients, inits=inits,
        counters=jnp.zeros((n,), jnp.int32),
        stale=jnp.zeros((n,), jnp.int32),
        key=jnp.array(key, copy=True), t=jnp.zeros((), jnp.int32),
        hot_ids=hot_ids, cold=cold)


# ---------------------------------------------------------------------------
# The round
# ---------------------------------------------------------------------------

def _local_training(loss_fn: Callable, cfg, clients_tree, counters,
                    new_counters, batch):
    """Masked R-step local SGD, vmapped over the client axis.

    Returns (trained_tree, loss_sum (n,), live_steps (n,)) — the raw masked
    loss sum and live-step count per client, so the caller can form a
    live-step-weighted aggregate instead of averaging in idle clients.

    batch: pytree with leading dims (n, R, ...) — one microbatch per client
    per potential local step."""

    def one_client(params, data, q0, q1):
        def step(p, inp):
            k, batch_k = inp
            loss, g = jax.value_and_grad(loss_fn)(p, batch_k)
            live = ((q0 + k) < q1).astype(jnp.float32)
            # update in f32, store back in the leaf dtype: keeps the scan
            # carry type stable for bf16 leaves (f32 leaves are unchanged —
            # the expression is the same f32 arithmetic as before)
            p = tree_map(
                lambda pp, gg: (pp - cfg.eta * live * gg.astype(jnp.float32)
                                ).astype(pp.dtype),
                p, g)
            return p, loss * live
        ks = jnp.arange(cfg.R)
        params, losses = jax.lax.scan(step, params, (ks, data))
        return params, jnp.sum(losses), (q1 - q0).astype(jnp.float32)

    return jax.vmap(one_client)(clients_tree, batch, counters, new_counters)


def engine_round(spec: FlatSpec, state: EngineState, batch=None, *, cfg,
                 loss_fn: Callable, lambdas,
                 det_alpha: Optional[jnp.ndarray] = None,
                 use_kernel: Optional[bool] = None, mesh=None,
                 quant_fused: bool = False, corpus=None, batch_key=None,
                 schedule: str = "streamed", slab=None, plan=None):
    """One FAVAS server round on flat buffers. Pure; jit/pjit this.

    The hot path is: unflatten clients -> vmapped local SGD -> flatten ->
    ONE fused aggregation+reset pass per bucket. No per-leaf tree_map
    touches the aggregation.

    Args:
      spec: the :func:`make_flat_spec` layout the buffers follow.
      state: current :class:`EngineState`; donate it when jitting.
      batch: pytree with leading dims (n, R, ...) — one microbatch per
        client per potential local step.
      cfg: :class:`FavasConfig` (n_clients, s_selected, local_steps, eta,
        reweight, quant_bits).
      loss_fn: ``loss_fn(params_pytree, microbatch) -> scalar``; vmapped
        over the client axis inside.
      lambdas: (n,) per-client heterogeneity rates for the step sampler.
      det_alpha: (n,) deterministic eq. 3 coefficients (used when
        ``cfg.reweight == "deterministic"``).
      use_kernel: None -> Pallas kernel on TPU / jnp oracle elsewhere;
        True/False force the choice (True runs interpret mode off-TPU).
      quant_fused: FAVAS[QNN] transport format. False (default, the seed
        semantics) quantizes the transmitted progress in tree space with
        per-leaf scales and hands the fused pass a dense dequantized
        (n, Dp) buffer. True encodes the progress per BUCKET as bit-packed
        LUQ codes + per-(row, shard) scales (``kernels.ops.
        cold_requant_rows``) and hands the fused pass the CODES — the
        kernel dequantizes per VMEM tile, so no full-precision (n, Dp)
        progress buffer ever materializes (different per-row-vs-per-leaf
        scale granularity and key stream, so an opt-in knob, not a drop-in
        replacement for the seed path).
      mesh: optional device mesh matching a sharding-aware ``spec``. Sharded
        buckets then run their fused pass via :func:`fused_bucket_update`
        (shard_map on the kernel path, pjit constraints on the oracle path)
        so the round never gathers a full buffer onto one device.
      corpus / batch_key: device data plane — instead of ``batch``, a
        resident :class:`repro.data.device_corpus.DeviceCorpus` plus the
        round's batch key; the round samples its own minibatches (and, on a
        paged spec, gathers corpus rows for the hot working set only).
      schedule: "streamed" (default, docs/architecture.md §13) aggregates
        with the single-sweep :func:`stream_bucket_update` and resets the
        s selected rows by a churn-bounded scatter into the donated
        buffers (~1R+1W per resident byte, no pass-through rewrites);
        "two_sweep" keeps the historical fused aggregation+reset kernel
        (~2R+2W). The two schedules are BIT-EXACT — the mask is exactly
        the indicator of the Gumbel top-s index set — so the knob only
        changes traffic, never values.
      slab / plan: host-tier cold paging (paged specs with
        ``cold_placement="host"`` only): the chunk's churned cold pages as
        a device slab plus this round's slab positions — see
        :func:`plan_rounds` and ``core.streaming``. The round then returns
        ``(new_state, new_slab, metrics)``.

    On a ``residency="paged"`` spec the round runs the hot/cold body
    (:func:`_paged_round`): select -> promote/evict the hot working set ->
    gather+dequant -> fused round over the s_max hot rows -> requant+
    scatter-back. With the passthrough codec at ``s_max == n`` it is
    bit-exact with this dense body (tests/test_paged_engine.py).

    Returns ``(new_state, metrics)`` where metrics holds the live-step-
    weighted ``loss``, ``mean_steps``, ``selected`` and ``stale_rounds``."""
    if schedule not in ("streamed", "two_sweep"):
        raise ValueError(f"unknown schedule {schedule!r}")
    if spec.paged:
        return _paged_round(spec, state, batch, cfg=cfg, loss_fn=loss_fn,
                            lambdas=lambdas, det_alpha=det_alpha,
                            use_kernel=use_kernel, mesh=mesh,
                            quant_fused=quant_fused,
                            corpus=corpus, batch_key=batch_key,
                            schedule=schedule, slab=slab, plan=plan)
    if slab is not None or plan is not None:
        raise ValueError("slab/plan are host-tier paging arguments "
                         "(paged specs with cold_placement='host')")
    if corpus is not None:
        batch = corpus.sample_round_batch(batch_key, cfg.R)
    n, s, K = cfg.n_clients, cfg.s_selected, cfg.local_steps
    key, k_inc, k_sel, k_q = jax.random.split(state.key, 4)

    # 1. heterogeneous progress this round
    d = sampler.sample_increments(k_inc, lambdas)              # (n,)
    new_counters = jnp.minimum(state.counters + d, K)

    # 2. masked local SGD (needs model structure -> tree space)
    clients_tree = unflatten_stacked(spec, state.clients)
    trained_tree, loss_sum, live = _local_training(
        loss_fn, cfg, clients_tree, state.counters, new_counters, batch)

    # 3. eq. (3) reweight coefficients
    if cfg.reweight == "deterministic":
        alpha = det_alpha
    else:
        alpha = reweight.alpha_stochastic(new_counters, p_pos=1.0)

    trained = _constrain_buckets(spec, mesh, flatten_stacked(spec, trained_tree),
                                 stacked=True)

    progress = (None,) * spec.n_buckets
    progress_codes = (None,) * spec.n_buckets
    if cfg.quant_bits > 0 and quant_fused:
        # FAVAS[QNN], codes-in transport: LUQ-encode the transmitted
        # progress per BUCKET on the flat buffers (per-(row, shard) scales)
        # and keep it as packed codes all the way into the fused pass — the
        # dense (n, Dp) dequantized progress never materializes. Keys fold
        # off k_q under a dedicated tag so the stream can never collide
        # with the paged path's eviction fold (fold_in(k_q, 1)).
        progress_codes = _encode_progress(spec, trained, state.inits, k_q,
                                          cfg.quant_bits, mesh=mesh,
                                          use_kernel=use_kernel)
    elif cfg.quant_bits > 0:
        # FAVAS[QNN]: quantize the TRANSMITTED progress in tree space
        # (per-leaf LUQ scale, same per-leaf keys as the seed
        # implementation). Quantization is communication-only (Remark 1):
        # the fused pass aggregates Q(progress) but resets unselected
        # clients to their full-precision trained state.
        inits_tree = unflatten_stacked(spec, state.inits)
        prog = quantize_tree(tree_map(jnp.subtract, trained_tree, inits_tree),
                             cfg.quant_bits, k_q)
        progress = _constrain_buckets(spec, mesh, flatten_stacked(spec, prog),
                                      stacked=True)

    # 4+5. aggregation + selected-client reset: one pass per bucket.
    # alpha/mask ride to the kernel padded alongside the buffers' client
    # rows (unit alpha / zero mask => padded rows aggregate exactly nothing
    # and reset to themselves, i.e. stay zero). sample_selection_indices is
    # the SAME rng stream as sample_selection (the mask is derived from the
    # indices), so taking the indices here changes no draw.
    sel_idx, m = sampler.sample_selection_indices(k_sel, n, s)  # (s,), (n,)
    alpha_p = pad_client_vec(spec, alpha, 1.0)
    m_p = pad_client_vec(spec, m, 0.0)
    server_new, clients_new, inits_new = [], [], []
    if schedule == "streamed":
        # §13: single-sweep aggregation, then ONE churn-bounded scatter of
        # the new server row into the s selected rows of the donated
        # trained/init buffers — unselected rows are never rewritten
        for b in range(spec.n_buckets):
            server_new.append(stream_bucket_update(
                spec, b, state.server[b], trained[b], state.inits[b],
                alpha_p, m_p, float(s), progress_b=progress[b],
                progress_codes_b=progress_codes[b],
                progress_bits=cfg.quant_bits, n_logical=n, mesh=mesh,
                use_kernel=use_kernel))
        clients_new = _streamed_reset(spec, mesh, trained, sel_idx,
                                      server_new)
        inits_new = _streamed_reset(spec, mesh, state.inits, sel_idx,
                                    server_new)
    else:
        for b in range(spec.n_buckets):
            srv, cli, ini = fused_bucket_update(
                spec, b, state.server[b], trained[b], state.inits[b],
                alpha_p, m_p, float(s), progress_b=progress[b],
                progress_codes_b=progress_codes[b],
                progress_bits=cfg.quant_bits, n_logical=n, mesh=mesh,
                use_kernel=use_kernel)
            server_new.append(srv)
            clients_new.append(cli)
            inits_new.append(ini)

    counters_new = jnp.where(m > 0, 0, new_counters).astype(jnp.int32)
    stale_new = jnp.where(m > 0, 0, state.stale + 1).astype(jnp.int32)

    new_state = EngineState(server=tuple(server_new),
                            clients=tuple(clients_new),
                            inits=tuple(inits_new),
                            counters=counters_new, stale=stale_new,
                            key=key, t=state.t + 1)
    total_live = jnp.sum(live)
    metrics = {
        # live-step-weighted: clients that ran zero live steps this round
        # contribute nothing instead of dragging the mean toward 0, and a
        # stale straggler's high loss is weighted by its actual step count.
        "loss": jnp.sum(loss_sum) / jnp.maximum(total_live, 1.0),
        "mean_steps": jnp.mean(new_counters.astype(jnp.float32)),
        "selected": jnp.sum(m),
        "stale_rounds": jnp.max(stale_new).astype(jnp.float32),
    }
    return new_state, metrics


def _paged_round(spec: FlatSpec, state: EngineState, batch, *, cfg,
                 loss_fn: Callable, lambdas,
                 det_alpha: Optional[jnp.ndarray] = None,
                 use_kernel: Optional[bool] = None, mesh=None,
                 quant_fused: bool = False, corpus=None, batch_key=None,
                 schedule: str = "streamed", slab=None, plan=None):
    """One FAVAS round on a paged (hot/cold) spec — docs/architecture.md §9.

    Control flow inverts relative to the dense body: Gumbel top-s selection
    runs FIRST, then the hot working set is rebuilt (promote selected cold
    clients by gather+dequant, evict the stalest hot rows by requant+
    scatter-back), and only the ``s_max`` hot rows see local SGD and the
    fused aggregation+reset. Cold clients are frozen — their parameters,
    counters and progress do not move until promotion, which is exactly the
    dense semantics for never-selected clients once ``s_max`` covers every
    client touched between two selections of any given id.

    RNG streams: the round draws ``key, k_inc, k_sel, k_q`` from the SAME
    four-way split as the dense body — selection's key is merely consumed
    earlier — and all codec randomness is folded off ``k_q``, never split
    from the chain. With the passthrough codec at ``s_max == n`` (hot stacks
    = all clients in id order, identical shapes, identical reduction trees)
    the round is therefore bit-exact with the dense ``engine_round``.

    Host-placed cold tier (``spec.cold_placement == 'host'``, docs §13):
    ``state.cold`` is None inside the trace — the full cold pools live in
    host memory (:class:`repro.core.streaming.HostColdPool`) and the round
    reads/writes a device-resident SLAB holding one encoded row per client
    that churns anywhere in the current chunk. ``plan`` carries this
    round's ``{"evict_slab", "promo_slab"}`` (s_churn,) slab positions
    (precomputed by :func:`plan_rounds` + ``streaming.build_chunk_plan``
    from the bookkeeping-only replay of the key chain; invalid churn slots
    point at the all-zero dummy row), and the round returns ``(state, slab,
    metrics)`` so the slab rides the superstep carry. Because each churning
    id owns exactly one slab row, an evict at round t is visible to that
    id's promotion at any later round of the chunk — the same read-after-
    write order the device pools give for free."""
    n, s, K = cfg.n_clients, cfg.s_selected, cfg.local_steps
    s_hot = spec.s_max
    codec = spec.cold_codec
    host_cold = spec.cold_placement == "host"
    if host_cold and (slab is None or plan is None):
        raise ValueError("cold_placement='host' rounds need the slab and "
                         "per-round plan (see RoundEngine/engine_run_stream)")
    if not host_cold and (slab is not None or plan is not None):
        raise ValueError("slab/plan only apply to cold_placement='host'")
    key, k_inc, k_sel, k_q = jax.random.split(state.key, 4)

    # 1. heterogeneous progress + SELECT-FIRST
    d = sampler.sample_increments(k_inc, lambdas)               # (n,)
    _, m = sampler.sample_selection_indices(k_sel, n, s)        # (n,) 0/1
    stale_new = jnp.where(m > 0, 0, state.stale + 1).astype(jnp.int32)

    # 2. new hot membership: the s_max most recently selected clients.
    # Two-key lexsort (staleness, then id) instead of a composite score —
    # stale * n + id overflows int32 at populations this layer targets.
    # Membership stays ascending by id, so s_max == n degenerates to
    # arange(n), the dense row layout. Selected clients (staleness 0)
    # always fit: engine_init enforces s <= s_max.
    order = jnp.lexsort((jnp.arange(n, dtype=jnp.int32), stale_new))
    members = jnp.sort(order[:s_hot]).astype(jnp.int32)
    old_ids = state.hot_ids
    pos_in_old = jnp.clip(jnp.searchsorted(old_ids, members), 0, s_hot - 1)
    was_hot = old_ids[pos_in_old] == members                    # (s_max,)
    pos_in_new = jnp.clip(jnp.searchsorted(members, old_ids), 0, s_hot - 1)
    evicted = members[pos_in_new] != old_ids                    # (s_max,)

    # 3. evict: requant the rows leaving the hot set into the cold pools.
    # Membership churn is bounded by s_selected — only a client selected
    # THIS round can enter the hot set (staleness order among unselected
    # clients is preserved round to round), and the hot set has fixed size,
    # so at most s rows leave and at most s rows are promoted. The codec
    # therefore touches s_churn = min(s, s_max) rows, not the whole working
    # set. nonzero() pads the churn index vectors with out-of-range
    # positions; pad entries are routed to a row that is NOT churning this
    # round and write back its current value, so duplicate scatter indices
    # always carry identical values — deterministic, and a bit-exact no-op
    # in the s_max == n parity regime where nothing ever churns.
    s_churn = min(s, s_hot)

    def _churn_positions(flags):
        pos = jnp.nonzero(flags, size=s_churn, fill_value=s_hot)[0]
        valid = pos < s_hot
        safe = jnp.argmin(flags).astype(pos.dtype)  # first non-churning row
        return jnp.where(valid, jnp.minimum(pos, s_hot - 1), safe), valid

    evict_pos, evict_valid = _churn_positions(evicted)
    promo_pos, promo_valid = _churn_positions(~was_hot)

    # Unique sorted scatter ids + donation => in-place read-modify-write;
    # non-evicted clients' cold bytes are untouched. The encode key is
    # FOLDED off k_q (not split), leaving the dense key chain intact.
    k_evict = jax.random.fold_in(k_q, 1)
    evict_ids = old_ids[evict_pos]
    # host tier: churn ids become slab rows; invalid slots hit the all-zero
    # dummy row and write back its own gathered value (a no-op). The id
    # spaces differ but the ENCODED BYTES are identical — the codec key
    # chain never branches on placement.
    evict_rows = plan["evict_slab"] if host_cold else evict_ids
    pools = slab if host_cold else state.cold
    cold = []
    for b in range(spec.n_buckets):
        enc = codec.encode_pair(
            state.clients[b][evict_pos], state.inits[b][evict_pos],
            jax.random.fold_in(k_evict, b), shards=spec.shards(b),
            use_kernel=use_kernel)

        def scatter(pool, e):
            sel = evict_valid.reshape((-1,) + (1,) * (e.ndim - 1))
            return pool.at[evict_rows].set(
                jnp.where(sel, e.astype(pool.dtype), pool[evict_rows]))

        cold.append(jax.tree_util.tree_map(scatter, pools[b], enc))
    cold = _constrain_cold(spec, mesh, cold)

    # 4. promote: gather + dequant ONLY the rows entering the hot set. Rows
    # that never went cold keep their full-precision buffers — surviving
    # hot clients pay NO requant round-trip.
    rpad = spec.stacked_rows - s_hot
    promo_ids = members[promo_pos]
    promo_rows = plan["promo_slab"] if host_cold else promo_ids
    clients_hot, inits_hot = [], []
    for b in range(spec.n_buckets):
        dt = jnp.dtype(spec.bucket_dtypes[b])
        enc_rows = jax.tree_util.tree_map(lambda p: p[promo_rows], cold[b])
        dec_cli, dec_ini = codec.decode_pair(enc_rows, dt,
                                             shards=spec.shards(b),
                                             use_kernel=use_kernel)
        base_cli = state.clients[b][pos_in_old]
        base_ini = state.inits[b][pos_in_old]
        sel = promo_valid[:, None]
        cli = base_cli.at[promo_pos].set(
            jnp.where(sel, dec_cli, base_cli[promo_pos]))
        ini = base_ini.at[promo_pos].set(
            jnp.where(sel, dec_ini, base_ini[promo_pos]))
        if rpad:
            cli = jnp.pad(cli, ((0, rpad), (0, 0)))
            ini = jnp.pad(ini, ((0, rpad), (0, 0)))
        clients_hot.append(cli)
        inits_hot.append(ini)
    clients_hot = _constrain_buckets(spec, mesh, clients_hot, stacked=True)
    inits_hot = _constrain_buckets(spec, mesh, inits_hot, stacked=True)

    # 5. hot-set bookkeeping + batch rows (the credit clock advances for
    # hot clients only — cold clients are frozen, not merely unselected)
    q0 = state.counters[members]
    q1 = jnp.minimum(q0 + d[members], K)
    m_hot = m[members]
    if corpus is not None:
        batch = corpus.sample_round_batch(batch_key, cfg.R, ids=members)
    else:
        batch = tree_map(lambda x: x[members], batch)

    # 6. masked local SGD over the hot rows only
    clients_tree = unflatten_stacked(spec, clients_hot)
    trained_tree, loss_sum, live = _local_training(
        loss_fn, cfg, clients_tree, q0, q1, batch)

    # 7. eq. (3) coefficients + optional FAVAS[QNN] transmitted progress,
    # all in hot space (at s_max == n these are the dense expressions,
    # k_q included)
    if cfg.reweight == "deterministic":
        alpha = det_alpha[members]
    else:
        alpha = reweight.alpha_stochastic(q1, p_pos=1.0)
    trained = _constrain_buckets(spec, mesh,
                                 flatten_stacked(spec, trained_tree),
                                 stacked=True)
    progress = (None,) * spec.n_buckets
    progress_codes = (None,) * spec.n_buckets
    if cfg.quant_bits > 0 and quant_fused:
        # codes-in transport over the HOT stacks (see engine_round): the
        # 0x7166 tag keeps the fold stream disjoint from k_evict above
        progress_codes = _encode_progress(spec, trained, inits_hot, k_q,
                                          cfg.quant_bits, mesh=mesh,
                                          use_kernel=use_kernel)
    elif cfg.quant_bits > 0:
        inits_tree = unflatten_stacked(spec, inits_hot)
        prog = quantize_tree(tree_map(jnp.subtract, trained_tree, inits_tree),
                             cfg.quant_bits, k_q)
        progress = _constrain_buckets(spec, mesh, flatten_stacked(spec, prog),
                                      stacked=True)

    # 8. aggregation + selected-client reset over the hot stacks
    alpha_p = pad_client_vec(spec, alpha, 1.0)
    m_p = pad_client_vec(spec, m_hot, 0.0)
    server_new, clients_new, inits_new = [], [], []
    if schedule == "streamed":
        # §13: every selected client is hot (engine_init enforces
        # s <= s_max), so m_hot carries exactly s ones and the nonzero
        # fill value is never consumed. Scatter replaces the second sweep.
        sel_pos = jnp.nonzero(m_hot > 0, size=s, fill_value=0)[0]
        for b in range(spec.n_buckets):
            server_new.append(stream_bucket_update(
                spec, b, state.server[b], trained[b], inits_hot[b],
                alpha_p, m_p, float(s), progress_b=progress[b],
                progress_codes_b=progress_codes[b],
                progress_bits=cfg.quant_bits, n_logical=s_hot, mesh=mesh,
                use_kernel=use_kernel))
        clients_new = _streamed_reset(spec, mesh, trained, sel_pos,
                                      server_new)
        inits_new = _streamed_reset(spec, mesh, inits_hot, sel_pos,
                                    server_new)
    else:
        for b in range(spec.n_buckets):
            srv, cli, ini = fused_bucket_update(
                spec, b, state.server[b], trained[b], inits_hot[b], alpha_p,
                m_p, float(s), progress_b=progress[b],
                progress_codes_b=progress_codes[b],
                progress_bits=cfg.quant_bits, n_logical=s_hot,
                mesh=mesh, use_kernel=use_kernel)
            server_new.append(srv)
            clients_new.append(cli)
            inits_new.append(ini)

    # 9. scatter the hot counter updates back into the full-n view
    counters_new = state.counters.at[members].set(
        jnp.where(m_hot > 0, 0, q1).astype(jnp.int32))

    new_state = EngineState(server=tuple(server_new),
                            clients=tuple(clients_new),
                            inits=tuple(inits_new),
                            counters=counters_new, stale=stale_new,
                            key=key, t=state.t + 1,
                            hot_ids=members,
                            cold=None if host_cold else cold)
    total_live = jnp.sum(live)
    metrics = {
        # live-step-weighted over the SELECTED HOT SET only: frozen cold
        # clients run zero live steps and contribute nothing — paging must
        # not reintroduce the zero-live-step masking bug (ROADMAP notes;
        # regression-pinned in tests/test_paged_engine.py)
        "loss": jnp.sum(loss_sum) / jnp.maximum(total_live, 1.0),
        "mean_steps": jnp.mean(q1.astype(jnp.float32)),
        "selected": jnp.sum(m),
        "stale_rounds": jnp.max(stale_new).astype(jnp.float32),
    }
    if host_cold:
        return new_state, tuple(cold), metrics
    return new_state, metrics


def plan_rounds(spec: FlatSpec, cfg, key, stale, hot_ids, *,
                n_rounds: int, device_plane: bool = False):
    """Bookkeeping-only replay of ``n_rounds`` of the paged key chain — the
    host-tier planner (docs §13). Hot-set membership depends only on
    ``(key, stale, hot_ids)``: selection and the staleness lexsort never
    read parameters, so the chunk's churn schedule is known BEFORE the
    chunk runs — that is what lets the page streamer fetch the next
    chunk's cold rows while this chunk computes. Returns ``(carry, plan)``:
    ``carry = (key, stale, hot_ids)`` is the bookkeeping AFTER the chunk
    (feed it back in to plan the next chunk ahead of time) and ``plan`` is
    the stacked ``(n_rounds, s_churn)`` arrays ``{"evict_ids",
    "evict_valid", "promo_ids", "promo_valid"}``; invalid churn slots
    carry id 0 with valid=False (``streaming.build_chunk_plan`` routes
    them to the slab's dummy row).

    The replay draws the SAME splits as :func:`_paged_round` — ``k_inc``
    and ``k_q`` are consumed but unused (a split is key arithmetic, not
    state mutation, so skipping the unused streams changes nothing), and
    ``device_plane=True`` burns the per-round batch key first, exactly
    like the device-plane scan body in :func:`engine_multi_round`."""
    n, s = cfg.n_clients, cfg.s_selected
    s_hot = spec.s_max
    s_churn = min(s, s_hot)

    def body(carry, _):
        key, stale, old_ids = carry
        if device_plane:
            key, _kb = jax.random.split(key)
        key, _k_inc, k_sel, _k_q = jax.random.split(key, 4)
        _, m = sampler.sample_selection_indices(k_sel, n, s)
        stale_new = jnp.where(m > 0, 0, stale + 1).astype(jnp.int32)
        order = jnp.lexsort((jnp.arange(n, dtype=jnp.int32), stale_new))
        members = jnp.sort(order[:s_hot]).astype(jnp.int32)
        pos_in_old = jnp.clip(jnp.searchsorted(old_ids, members),
                              0, s_hot - 1)
        was_hot = old_ids[pos_in_old] == members
        pos_in_new = jnp.clip(jnp.searchsorted(members, old_ids),
                              0, s_hot - 1)
        evicted = members[pos_in_new] != old_ids

        def _churn(flags, ids):
            pos = jnp.nonzero(flags, size=s_churn, fill_value=s_hot)[0]
            valid = pos < s_hot
            safe = jnp.argmin(flags).astype(pos.dtype)
            pos = jnp.where(valid, jnp.minimum(pos, s_hot - 1), safe)
            return jnp.where(valid, ids[pos], 0).astype(jnp.int32), valid

        evict_ids, evict_valid = _churn(evicted, old_ids)
        promo_ids, promo_valid = _churn(~was_hot, members)
        out = {"evict_ids": evict_ids, "evict_valid": evict_valid,
               "promo_ids": promo_ids, "promo_valid": promo_valid}
        return (key, stale_new, members), out

    return jax.lax.scan(body, (key, stale, hot_ids), None, length=n_rounds)


def engine_multi_round(spec: FlatSpec, state: EngineState, batches=None, *,
                       cfg, loss_fn: Callable, lambdas,
                       det_alpha: Optional[jnp.ndarray] = None,
                       use_kernel: Optional[bool] = None, mesh=None,
                       quant_fused: bool = False,
                       corpus=None, n_rounds: Optional[int] = None,
                       schedule: str = "streamed",
                       slab=None, plans=None):
    """A whole chunk of FAVAS rounds as ONE ``jax.lax.scan`` — the
    "superstep" (docs/architecture.md §7). Pure; jit/pjit this and donate
    ``state``: a T-round chunk then costs one dispatch instead of T.

    Two data planes feed the scan (docs/architecture.md §8):

    * **host plane** — ``batches`` is the per-round batch pytree with an
      extra LEADING rounds axis — leaves are (T, n, R, ...); round t
      consumes slice ``batches[t]``;
    * **device plane** — ``corpus`` is a
      :class:`repro.data.device_corpus.DeviceCorpus` and ``n_rounds`` the
      (static) chunk length: the scan body draws each round's per-client
      minibatch indices from the carried PRNG key and gathers the rows on
      device (``corpus.sample_round_batch``), so a compiled chunk does ZERO
      host batch-generation work between dispatches.

    The scan carries the :class:`EngineState` and stacks each round's
    metrics, so the caller fetches one (T,)-shaped metrics pytree per chunk
    instead of blocking on T scalar transfers.

    RNG equivalence: :func:`engine_round` derives everything it draws from
    ``state.key`` (split once per round, the new key rides in the carry), so
    the scanned host-plane stream is IDENTICAL to T sequential
    ``engine_round`` calls — superstep-vs-sequential parity is bit-exact,
    not approximate (tests/test_superstep.py). The device plane splits one
    extra batch key per round off the same chain (see
    tests/test_device_corpus.py for the sequential-parity proof), so it is
    *statistically equivalent* to the host plane, not stream-identical —
    the same contract PR 4 set for on-device selection. Composes with
    ``use_kernel`` and ``mesh`` exactly like ``engine_round``: the
    shard_map / pjit dispatch sits inside the scan body, compiled once for
    the whole chunk.

    Host-placed cold tier (``slab``/``plans`` not None, docs §13): the scan
    carries ``(state, slab)`` and consumes the per-round plan xs, and the
    call returns ``(new_state, new_slab, metrics)`` — the caller (the
    :class:`RoundEngine` host prologue or ``streaming.engine_run_stream``)
    owns the gather/writeback against the host pool around the dispatch.

    Returns ``(new_state, metrics)`` with every metric stacked to (T,)."""
    host_cold = slab is not None
    if host_cold and plans is None:
        raise ValueError("a host-tier superstep needs the per-round plans "
                         "(see plan_rounds / streaming.build_chunk_plan)")
    if corpus is not None:
        if batches is not None:
            raise ValueError("pass either batches (host plane) or corpus "
                             "(device plane), not both")
        if n_rounds is None:
            raise ValueError("the device plane needs a static n_rounds "
                             "(there is no batches axis to infer it from)")

        def body_c(st, plan):
            key, k_batch = jax.random.split(st[0].key if host_cold else st.key)
            # sampling happens INSIDE engine_round (same key, same draws as
            # sampling here): a paged spec must select its hot working set
            # before it knows which corpus rows to gather
            if host_cold:
                st0 = dataclasses.replace(st[0], key=key)
                st1, sl, met = engine_round(
                    spec, st0, None, cfg=cfg, loss_fn=loss_fn,
                    lambdas=lambdas, det_alpha=det_alpha,
                    use_kernel=use_kernel, mesh=mesh,
                    quant_fused=quant_fused, corpus=corpus,
                    batch_key=k_batch, schedule=schedule,
                    slab=st[1], plan=plan)
                return (st1, sl), met
            st = dataclasses.replace(st, key=key)
            return engine_round(spec, st, None, cfg=cfg, loss_fn=loss_fn,
                                lambdas=lambdas, det_alpha=det_alpha,
                                use_kernel=use_kernel, mesh=mesh,
                                quant_fused=quant_fused,
                                corpus=corpus, batch_key=k_batch,
                                schedule=schedule)
        if host_cold:
            (st1, sl1), metrics = jax.lax.scan(body_c, (state, slab), plans,
                                               length=n_rounds)
            return st1, sl1, metrics
        return jax.lax.scan(body_c, state, None, length=n_rounds)

    if host_cold:
        def body_h(carry, xs):
            batch, plan = xs
            st1, sl, met = engine_round(spec, carry[0], batch, cfg=cfg,
                                        loss_fn=loss_fn, lambdas=lambdas,
                                        det_alpha=det_alpha,
                                        use_kernel=use_kernel, mesh=mesh,
                                        quant_fused=quant_fused,
                                        schedule=schedule,
                                        slab=carry[1], plan=plan)
            return (st1, sl), met
        (st1, sl1), metrics = jax.lax.scan(body_h, (state, slab),
                                           (batches, plans))
        return st1, sl1, metrics

    def body(st, batch):
        return engine_round(spec, st, batch, cfg=cfg, loss_fn=loss_fn,
                            lambdas=lambdas, det_alpha=det_alpha,
                            use_kernel=use_kernel, mesh=mesh,
                            quant_fused=quant_fused, schedule=schedule)
    return jax.lax.scan(body, state, batches)


def engine_server_params(spec: FlatSpec, state: EngineState):
    """Current server model as the original parameter pytree."""
    return unflatten_tree(spec, state.server)


def engine_variance(state: EngineState) -> jnp.ndarray:
    """sum_i ||w^i - w_t||^2 straight off the flat buffers. Padded lane
    tails are identical between clients and server (zero contribution);
    padded client ROWS are all-zero, not copies of the server, so they are
    sliced off (the counters carry the logical n).

    On a paged state the sum runs over the HOT WORKING SET only — the rows
    that actually trained. Decoding the cold pool here would charge frozen
    clients' (possibly quantized) drift to a live-progress metric and
    reintroduce the zero-live-step averaging bug at the variance level; at
    ``s_max == n`` the hot set is everyone and this is the dense value."""
    rows = (state.counters.shape[0] if state.hot_ids is None
            else state.hot_ids.shape[0])
    tot = jnp.zeros((), jnp.float32)
    for srv, cli in zip(state.server, state.clients):
        diff = cli[:rows].astype(jnp.float32) - srv[None].astype(jnp.float32)
        tot = tot + jnp.sum(jnp.square(diff))
    return tot


def engine_resident_bytes_by_tier(state: EngineState) -> dict:
    """Per-memory-tier byte accounting of the engine state — what the
    residency benches and the CI resident-bytes gates measure. Host-side
    accounting; not jittable.

    ``device``: hot stacks + server + bookkeeping + (device-placed) cold
    pools — everything that occupies accelerator HBM. ``host``: the
    :class:`repro.core.streaming.HostColdPool` pools of a host-placed cold
    tier (zero otherwise). Host pools must NEVER count against the device
    budget — moving them off-device is the whole point of ``cold_placement
    ='host'`` (docs §13); ``benchmarks.paged_state_bench`` asserts both
    tiers against the live arrays."""
    from repro.core.streaming import HostColdPool   # lazy: no import cycle
    device = host = 0
    leaves = jax.tree_util.tree_leaves(
        state, is_leaf=lambda x: isinstance(x, HostColdPool))
    for leaf in leaves:
        if isinstance(leaf, HostColdPool):
            host += leaf.nbytes
        else:
            device += leaf.size * jnp.dtype(leaf.dtype).itemsize
    return {"device": device, "host": host}


def engine_resident_bytes(state: EngineState) -> int:
    """DEVICE-tier bytes of the state (hot stacks + device-placed cold
    pools + bookkeeping) — see :func:`engine_resident_bytes_by_tier`. For
    device-placed specs this is every array in the state, the historical
    meaning; host-placed cold pools are excluded by construction."""
    return engine_resident_bytes_by_tier(state)["device"]


# ---------------------------------------------------------------------------
# RoundEngine: holds the static spec + a donated jitted round
# ---------------------------------------------------------------------------

class RoundEngine:
    """Convenience wrapper owning the FlatSpec and the jitted, buffer-donating
    round. The state never leaves flat form between rounds.

    ``mesh``: run the engine mesh-native — the spec buckets leaves by
    (dtype, sharding group), ``init_state`` places the buffers with
    :func:`engine_sharding`, and every round keeps sharded buckets on the
    model axis end-to-end (``--mesh`` in ``launch.train`` composes this with
    ``--use-kernel``: kernel -> shard_map per shard, oracle -> pjit)."""

    def __init__(self, params_template, cfg, loss_fn: Callable, *,
                 lambdas=None, det_alpha=None, use_kernel: Optional[bool] = None,
                 client_tile: int = CLIENT_TILE, mesh=None,
                 residency: str = "dense", s_max: Optional[int] = None,
                 cold_bits: int = 0, quant_fused: bool = False,
                 cold_placement: str = "device",
                 schedule: str = "streamed"):
        from repro.core.favas import client_lambdas  # cycle-free at call time
        self.cfg = cfg
        self.mesh = mesh
        codec = make_codec(cold_bits) if residency == "paged" else None
        self.spec = make_flat_spec(params_template, n_clients=cfg.n_clients,
                                   client_tile=client_tile, mesh=mesh,
                                   residency=residency, s_max=s_max,
                                   cold_codec=codec,
                                   cold_placement=cold_placement)
        self.loss_fn = loss_fn
        self.lambdas = (jnp.asarray(lambdas) if lambdas is not None
                        else jnp.asarray(client_lambdas(cfg)))
        self.det_alpha = None if det_alpha is None else jnp.asarray(det_alpha)
        self.use_kernel = use_kernel
        self.quant_fused = quant_fused
        self.schedule = schedule
        self._round = jax.jit(
            functools.partial(engine_round, self.spec, cfg=self.cfg,
                              loss_fn=self.loss_fn, lambdas=self.lambdas,
                              det_alpha=self.det_alpha,
                              use_kernel=self.use_kernel, mesh=self.mesh,
                              quant_fused=self.quant_fused,
                              schedule=self.schedule),
            donate_argnums=(0,))
        self._multi = jax.jit(
            functools.partial(engine_multi_round, self.spec, cfg=self.cfg,
                              loss_fn=self.loss_fn, lambdas=self.lambdas,
                              det_alpha=self.det_alpha,
                              use_kernel=self.use_kernel, mesh=self.mesh,
                              quant_fused=self.quant_fused,
                              schedule=self.schedule),
            donate_argnums=(0,))
        # device data plane: the corpus rides as a pytree ARGUMENT (not a
        # closure) so its buffers are shared inputs, never baked into the
        # executable as constants; n_rounds is static (scan length)
        self._multi_device = jax.jit(
            functools.partial(engine_multi_round, self.spec, cfg=self.cfg,
                              loss_fn=self.loss_fn, lambdas=self.lambdas,
                              det_alpha=self.det_alpha,
                              use_kernel=self.use_kernel, mesh=self.mesh,
                              quant_fused=self.quant_fused,
                              schedule=self.schedule),
            static_argnames=("n_rounds",), donate_argnums=(0,))
        # host-placed cold tier (docs §13): the slab rides positionally so
        # it can be donated alongside the state; state.cold is None inside
        # every trace — the HostColdPool never crosses into jit
        if self.spec.paged and self.spec.cold_placement == "host":
            common = dict(cfg=self.cfg, loss_fn=self.loss_fn,
                          lambdas=self.lambdas, det_alpha=self.det_alpha,
                          use_kernel=self.use_kernel, mesh=self.mesh,
                          quant_fused=self.quant_fused,
                          schedule=self.schedule)
            spec = self.spec

            def _rh(state, batch, slab, plan):
                return engine_round(spec, state, batch, slab=slab,
                                    plan=plan, **common)

            def _mh(state, slab, batches, plans):
                return engine_multi_round(spec, state, batches, slab=slab,
                                          plans=plans, **common)

            def _mdh(state, slab, plans, corpus, n_rounds):
                return engine_multi_round(spec, state, corpus=corpus,
                                          n_rounds=n_rounds, slab=slab,
                                          plans=plans, **common)

            self._round_host = jax.jit(_rh, donate_argnums=(0, 2))
            self._multi_host = jax.jit(_mh, donate_argnums=(0, 1))
            self._multi_device_host = jax.jit(
                _mdh, static_argnames=("n_rounds",), donate_argnums=(0, 1))
            self._plan = jax.jit(
                functools.partial(plan_rounds, self.spec, self.cfg),
                static_argnames=("n_rounds", "device_plane"))
        # dispatches into the jitted round/superstep — the regression guard
        # tests/test_superstep.py uses to pin "one chunk = one dispatch"
        self.dispatch_count = 0

    def init_state(self, params, key) -> EngineState:
        state = engine_init(self.spec, params, self.cfg, key,
                            use_kernel=self.use_kernel)
        if self.mesh is not None:
            # a host-placed cold pool is numpy, not a device array — it
            # must not ride through device_put (engine_sharding's tree has
            # cold=None for host placement, matching the stripped state)
            pool = state.cold if self.spec.cold_placement == "host" else None
            if pool is not None:
                state = dataclasses.replace(state, cold=None)
            state = jax.device_put(state, engine_sharding(self.spec, self.mesh))
            if pool is not None:
                state = dataclasses.replace(state, cold=pool)
        return state

    # -- host-placed cold tier: gather/writeback around each dispatch -----
    def _host_prologue(self, state: EngineState, n_rounds: int,
                       device_plane: bool):
        """Plan the chunk's churn, gather its slab from the host pool, and
        move both to device. Returns ``(state_sans_pool, pool, uids, slab,
        plans)`` — see docs §13 and :mod:`repro.core.streaming`."""
        from repro.core import streaming
        pool = state.cold
        state = dataclasses.replace(state, cold=None)
        _, plan = self._plan(state.key, state.stale, state.hot_ids,
                             n_rounds=n_rounds, device_plane=device_plane)
        plan = jax.device_get(plan)
        slab_rows = streaming.chunk_slab_rows(self.spec, self.cfg, n_rounds)
        uids, slab_plan = streaming.build_chunk_plan(plan,
                                                     slab_rows=slab_rows)
        slab_np = pool.gather(uids, slab_rows)
        shardings = slab_shardings(self.spec, self.mesh)
        slab = (jax.device_put(slab_np, shardings) if shardings is not None
                else jax.device_put(slab_np))
        plans = jax.tree_util.tree_map(jnp.asarray, slab_plan)
        return state, pool, uids, slab, plans

    def _host_epilogue(self, state: EngineState, pool, uids, slab):
        """Write the chunk's final slab rows back into the host pool and
        re-attach it to the state."""
        pool.writeback(uids, jax.device_get(slab))
        return dataclasses.replace(state, cold=pool)

    def step(self, state: EngineState, batch):
        """Jitted round; donates the previous state's buffers."""
        self.dispatch_count += 1
        if self.spec.paged and self.spec.cold_placement == "host":
            state, pool, uids, slab, plans = self._host_prologue(
                state, 1, device_plane=False)
            plan0 = jax.tree_util.tree_map(lambda x: x[0], plans)
            state, slab, metrics = self._round_host(state, batch, slab,
                                                    plan0)
            return self._host_epilogue(state, pool, uids, slab), metrics
        return self._round(state, batch)

    def run(self, state: EngineState, batches,
            n_rounds: Optional[int] = None):
        """A chunk of rounds as one superstep dispatch (see
        :func:`engine_multi_round`); donates the previous state's buffers.

        ``batches``: per-round batch pytree with a leading (T,) rounds axis.
        ``n_rounds``: optional sanity check that T is what the caller thinks
        it is (chunks of different T compile once each — the scan length is
        static). Returns ``(new_state, metrics)`` with (T,)-stacked metrics;
        bit-exact with T sequential :meth:`step` calls."""
        T = jax.tree_util.tree_leaves(batches)[0].shape[0]
        if n_rounds is not None and n_rounds != T:
            raise ValueError(
                f"batches carry {T} rounds but n_rounds={n_rounds}")
        self.dispatch_count += 1
        if self.spec.paged and self.spec.cold_placement == "host":
            state, pool, uids, slab, plans = self._host_prologue(
                state, T, device_plane=False)
            state, slab, metrics = self._multi_host(state, slab, batches,
                                                    plans)
            return self._host_epilogue(state, pool, uids, slab), metrics
        return self._multi(state, batches)

    def run_device(self, state: EngineState, corpus, n_rounds: int):
        """A chunk of rounds on the DEVICE data plane: one superstep
        dispatch whose scan body samples each round's minibatches from the
        resident ``corpus`` (a ``data.device_corpus.DeviceCorpus``) — no
        host batch generation, no H2D batch traffic, no prefetcher.
        Donates the previous state's buffers; ``n_rounds`` is static (one
        compilation per distinct chunk length, like the host plane's batch
        shapes). Returns ``(new_state, metrics)`` with (T,)-stacked
        metrics."""
        self.dispatch_count += 1
        if self.spec.paged and self.spec.cold_placement == "host":
            state, pool, uids, slab, plans = self._host_prologue(
                state, n_rounds, device_plane=True)
            state, slab, metrics = self._multi_device_host(
                state, slab, plans, corpus, n_rounds=n_rounds)
            return self._host_epilogue(state, pool, uids, slab), metrics
        return self._multi_device(state, corpus=corpus, n_rounds=n_rounds)

    def server_params(self, state: EngineState):
        return engine_server_params(self.spec, state)

    def variance(self, state: EngineState) -> jnp.ndarray:
        return engine_variance(state)

    def resident_bytes(self, state: EngineState) -> int:
        return engine_resident_bytes(state)

    def resident_bytes_by_tier(self, state: EngineState) -> dict:
        return engine_resident_bytes_by_tier(state)
