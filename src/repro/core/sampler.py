"""Client heterogeneity and selection sampling (paper App. C.2).

* Per-round local-step increments d_t^i ~ shifted-Geometric(lambda_i)
  (support {1, 2, ...}, mean 1/lambda_i). Fast clients: lambda = 1/16
  (≈16 steps/round); slow: lambda = 1/2 (≈2 steps/round). The paper's text
  labels these by "running time"; we parameterize by steps-per-round so fast
  clients make more progress, which is the behaviour its experiments need.
* Server selection S_t: s of n uniformly without replacement, drawn in-jit
  via Gumbel top-s (exact uniform w/o replacement).

Everything is drawn inside the jitted round from explicit PRNG keys.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def make_lambdas(n: int, slow_fraction: float = 1.0 / 3.0,
                 lam_fast: float = 1.0 / 16.0, lam_slow: float = 0.5,
                 seed: int = 0) -> np.ndarray:
    """Per-client geometric parameters; first ``slow_fraction`` are slow
    (assignment randomized by seed)."""
    rng = np.random.default_rng(seed)
    lam = np.where(np.arange(n) < int(round(slow_fraction * n)), lam_slow, lam_fast)
    return rng.permutation(lam).astype(np.float32)


def sample_increments(key, lambdas) -> jnp.ndarray:
    """d_i ~ 1 + Geom0(lambda_i): support {1,2,...}, E[d] = 1/lambda."""
    u = jax.random.uniform(key, lambdas.shape, minval=1e-7, maxval=1.0)
    d = 1 + jnp.floor(jnp.log(u) / jnp.log1p(-lambdas)).astype(jnp.int32)
    return jnp.maximum(d, 1)


def sample_selection_indices(key, n: int, s: int):
    """Uniform s-of-n without replacement, drawn in-jit via Gumbel top-s
    (exact uniform w/o replacement). Returns ``(idx (s,) int32, mask (n,)
    float32)`` — the on-device replacement for the simulator's old host-side
    ``np.random.choice(n, s, replace=False)``, so client selection can live
    inside a scanned superstep."""
    z = jax.random.gumbel(key, (n,))
    _, idx = jax.lax.top_k(z, s)
    mask = jnp.zeros((n,), jnp.float32).at[idx].set(1.0)
    return idx, mask


def sample_selection(key, n: int, s: int) -> jnp.ndarray:
    """Uniform s-of-n without replacement -> float mask (n,) with sum s."""
    return sample_selection_indices(key, n, s)[1]


def time_ticks(step_time, round_dur: float, max_denominator: int = 10 ** 4):
    """Scale (possibly fractional) step times + round duration to a common
    INTEGER tick grid: each time is read as the rational
    ``Fraction(t).limit_denominator(max_denominator)`` (so the float 0.3
    means the rational 3/10, exactly) and everything is multiplied by the
    lcm of the denominators. Returns ``(step_ticks (n,) int32 numpy,
    round_ticks int)`` for :func:`credit_steps`.

    The tick clock is drift-free integer arithmetic — unlike the previous
    f32 on-device clock, non-representable step times like 0.3 match the
    f64 host reference exactly at every round
    (tests/test_superstep.py::test_credit_steps_ticks_adversarial)."""
    from fractions import Fraction
    from math import gcd
    fr = [Fraction(float(t)).limit_denominator(max_denominator)
          for t in np.asarray(step_time).ravel()]
    fr.append(Fraction(float(round_dur)).limit_denominator(max_denominator))
    den = 1
    for f in fr:
        den = den * f.denominator // gcd(den, f.denominator)
    ticks = [int(f * den) for f in fr]
    if min(ticks) <= 0:
        raise ValueError(
            f"step times {np.asarray(step_time)!r} / round_dur {round_dur} "
            f"contain a value below the 1/{max_denominator} tick resolution "
            f"(it would quantize to zero ticks and divide by zero); use "
            f"larger times or a bigger max_denominator")
    if max(ticks) + ticks[-1] >= 2 ** 31:
        raise ValueError(
            f"step times {np.asarray(step_time)!r} / round_dur {round_dur} "
            f"need > int32 ticks (common denominator {den}); pass simpler "
            f"rational times or a smaller max_denominator")
    return (np.asarray(ticks[:-1], np.int32).reshape(np.shape(step_time)),
            ticks[-1])


def credit_steps(credit, step_ticks, q, K: int, round_ticks: int):
    """Deterministic-rate local-step bookkeeping, on-device (the simulator's
    App. C.2 clock), on INTEGER ticks: every client accrues ``round_ticks``
    ticks, converts whole ``step_ticks`` quanta into available steps
    (keeping the remainder as credit), and runs ``min(available, K - q)``
    of them this round. ``credit``/``step_ticks`` are (n,) int32 (build the
    ticks once with :func:`time_ticks`); ``q`` stays (n,) float32. Returns
    ``(steps_run (n,) float32, new_credit (n,) int32)``.

    Integer division replaces the old f32 ``floor(credit / step_time)``,
    so the clock is exact for ANY rational step time — the f64 host loop
    and this scan body can no longer disagree by a step (the ROADMAP
    f32-clock caveat)."""
    credit = credit + round_ticks
    avail = credit // step_ticks
    credit = credit - avail * step_ticks
    return jnp.minimum(avail.astype(jnp.float32), K - q), credit


# ---------------------------------------------------------------------------
# Analytic moments of E ∧ K (E = steps between consecutive polls)
# ---------------------------------------------------------------------------

def poll_steps_distribution(lam: float, K: int, poll_prob: float,
                            max_rounds: int = 2000) -> np.ndarray:
    """Exact (to truncation) pmf of q_poll = min(K, sum_{j<=M} d_j) where
    d_j ~ shifted-Geom(lam) per round and M ~ Geom(poll_prob) rounds between
    polls. Used for the deterministic reweight alpha = E[E ∧ K] and the
    Theorem-3 constants. Dynamic program over capped step counts."""
    # pmf of one round's increment, capped at K
    j = np.arange(1, K + 1)
    inc = lam * (1.0 - lam) ** (j - 1)
    inc[-1] = (1.0 - lam) ** (K - 1)          # P(d >= K) mass into cap
    # state pmf over {0..K} steps accumulated (capped)
    state = np.zeros(K + 1)
    state[0] = 1.0
    out = np.zeros(K + 1)
    survive = 1.0
    for _ in range(max_rounds):
        # advance one round of local compute
        new = np.zeros(K + 1)
        for q in range(K + 1):
            if state[q] <= 0:
                continue
            if q == K:
                new[K] += state[q]
                continue
            add = np.minimum(q + j, K)
            np.add.at(new, add, state[q] * inc)
        state = new
        # poll happens after this round w.p. poll_prob: P(M=m) = (1-p)^{m-1} p
        out += poll_prob * survive * state
        survive *= (1.0 - poll_prob)
        if survive < 1e-9:
            break
    out /= max(out.sum(), 1e-12)
    return out


def moments_at_poll(lam: float, K: int, poll_prob: float):
    """(P(E>0), E[E∧K], E[(E∧K)^2], E[1(E>0)/(E∧K)]) for the poll-interval
    step count. With shifted-geometric increments E >= 1 a.s."""
    pmf = poll_steps_distribution(lam, K, poll_prob)
    q = np.arange(K + 1)
    p_pos = pmf[1:].sum()
    e1 = float((pmf * q).sum())
    e2 = float((pmf * q * q).sum())
    einv = float((pmf[1:] / q[1:]).sum())
    return float(p_pos), e1, e2, einv
