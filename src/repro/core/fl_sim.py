"""Simulated-time federated-learning harness — reproduces the paper's
experimental protocol (Sec. 5 + App. C.1/C.2) for FAVAS and its baselines
(FedAvg, QuAFL, FedBuff, AsyncSGD) on the small classifier models.

Time model (App. C.2):
  * server waiting time 4, server interaction time 3;
  * deterministic per-step client runtimes: fast = 2, slow = 16 time units
    (1/3 slow unless stated);
  * FAVAS/QuAFL server rounds last wait+interact = 7; clients train
    concurrently, capped at K local steps since their last reset;
  * FedAvg rounds last interact + K * (slowest selected client's step time);
  * FedBuff rounds complete when Z client updates arrive (fast clients feed
    the buffer — the bias FAVAS removes);
  * AsyncSGD applies every arriving single-gradient update immediately.

This level is the *paper-experiment* engine (small models, CPU); the
distributed production trainer for the assigned architectures lives in
``repro.core.favas`` + ``repro.launch.train``.

The FAVAS and QuAFL branches run as **supersteps** (docs/architecture.md
§7): every eval-to-eval window of server rounds is ONE jitted, donated
``jax.lax.scan`` over the flat-buffer engine — client selection
(``sampler.sample_selection``), the deterministic credit/step-time clock
(``sampler.credit_steps``, on exact integer ticks), eq. 3 alphas, and the
q bookkeeping all live on-device inside the scan. The host only syncs at
eval boundaries. Batches come from one of two data planes
(``SimConfig.data_plane``, docs/architecture.md §8): ``"host"`` generates
them in numpy on a background-thread ``BatchPrefetcher`` while the current
window computes; ``"device"`` keeps the corpus RESIDENT
(``data.device_corpus.DeviceCorpus``) and samples each round's minibatch
indices inside the scan body — zero host work per round.
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.classifier import mlp_init, mlp_apply, classifier_loss, accuracy
from repro.core.quant import quantize_tree
from repro.core import round_engine, sampler
from repro.utils.tree import tree_map

SERVER_WAIT = 4.0
SERVER_INTERACT = 3.0

# cap on rounds per scanned superstep dispatch: bounds batch memory (the
# prefetcher holds up to ~3 chunks of (C, n, R, B, ...) arrays) regardless
# of how long an eval-to-eval window gets; recording still happens only at
# window starts, so results are unaffected
MAX_SUPERSTEP_ROUNDS = 32


@dataclasses.dataclass
class SimConfig:
    method: str = "favas"            # favas|quafl|fedbuff|fedavg|asyncsgd
    n_clients: int = 30
    s_selected: int = 6
    K: int = 10
    buffer_z: int = 5                # FedBuff
    eta: float = 0.2
    server_eta: float = 1.0          # FedBuff global LR
    total_time: float = 2000.0
    eval_every: float = 100.0
    batch_size: int = 64
    fast_step_time: float = 2.0
    slow_step_time: float = 16.0
    slow_fraction: float = 1.0 / 3.0
    reweight: str = "stochastic"
    quant_bits: int = 0              # FAVAS[QNN]
    permute_speeds: bool = True      # False: clients [0, n_slow) are the slow
    #                                  ones (for speed/data-correlated setups)
    data_plane: str = "host"         # "host": numpy batches + prefetcher;
    #                                  "device": resident DeviceCorpus, the
    #                                  scan samples minibatches in-body
    #                                  (docs/architecture.md §8)
    seed: int = 0


def _step_times(cfg: SimConfig, rng) -> np.ndarray:
    n_slow = int(round(cfg.slow_fraction * cfg.n_clients))
    t = np.full(cfg.n_clients, cfg.fast_step_time)
    t[:n_slow] = cfg.slow_step_time
    return rng.permutation(t) if cfg.permute_speeds else t


def _local_sgd_batched(loss_fn, eta, R):
    """vmapped masked local SGD: params (n,...), data (n,R,B,...), steps (n,)."""
    def one(params, xs, ys, n_steps):
        def step(p, inp):
            k, x, y = inp
            g = jax.grad(loss_fn)(p, x, y)
            live = (k < n_steps).astype(jnp.float32)
            return tree_map(lambda pp, gg: pp - eta * live * gg, p, g), None
        p, _ = jax.lax.scan(step, params, (jnp.arange(R), xs, ys))
        return p
    return jax.jit(jax.vmap(one))


def _window_schedule(total_time: float, eval_every: float,
                     round_dur: float) -> List[int]:
    """Round counts of each eval-to-eval window, replicating the per-round
    loop's semantics exactly: a record fires before round r iff
    ``r * round_dur >= next_eval`` (then ``next_eval += eval_every``), and
    the loop exits once ``r * round_dur >= total_time``. Every window
    therefore STARTS with a record, and the trailing record after the loop
    is the caller's job."""
    windows, cur = [], 0
    r, next_eval = 0, 0.0
    while r * round_dur < total_time:
        if r * round_dur >= next_eval:
            if cur:
                windows.append(cur)
                cur = 0
            next_eval += eval_every
        cur += 1
        r += 1
    if cur:
        windows.append(cur)
    return windows


def _local_sgd_single(loss_fn, eta):
    def run(params, xs, ys):
        def step(p, inp):
            x, y = inp
            g = jax.grad(loss_fn)(p, x, y)
            return tree_map(lambda pp, gg: pp - eta * gg, p, g), None
        p, _ = jax.lax.scan(step, params, (xs, ys))
        return p
    return jax.jit(run)


def run_simulation(cfg: SimConfig, data, *, d_hidden: int = 128,
                   mesh=None) -> Dict:
    """data = (x_train, y_train, x_test, y_test, parts). Returns curves.

    ``mesh``: optional device mesh with a "model" axis — the FAVAS branch
    then builds a sharding-aware FlatSpec (hidden-dim leaves bucketed into
    model-sharded flat buffers, see sharding/rules.py) and runs the fused
    poll through ``round_engine.fused_bucket_update`` without gathering the
    buffers. CPU default (mesh=None) is unchanged."""
    xtr, ytr, xte, yte, parts = data
    n_classes = int(ytr.max()) + 1
    d_in = xtr.shape[1]
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)

    loss_fn = lambda p, x, y: classifier_loss(p, mlp_apply, x, y, n_classes)
    server = mlp_init(key, d_in, d_hidden, n_classes)
    n = cfg.n_clients
    clients = tree_map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape).copy(),
                       server)
    inits = clients
    step_time = _step_times(cfg, rng)

    from repro.data.pipeline import FederatedBatcher
    batcher = FederatedBatcher(xtr, ytr, parts, cfg.batch_size, cfg.seed)

    eval_fn = jax.jit(lambda p: accuracy(p, mlp_apply, xte, yte))
    var_fn = jax.jit(lambda W, w: sum(jax.tree_util.tree_leaves(tree_map(
        lambda a, b: jnp.sum((a - b[None]) ** 2), W, w))))

    times, accs, variances, server_steps = [], [], [], []
    t_now, next_eval, srv_step = 0.0, 0.0, 0

    def record():
        times.append(t_now)
        accs.append(float(eval_fn(server)))
        variances.append(float(var_fn(clients, server)))
        server_steps.append(srv_step)

    if cfg.method in ("favas", "quafl"):
        round_dur = SERVER_WAIT + SERVER_INTERACT
        R = int(np.ceil(round_dur / step_time.min()))
        sgd = _local_sgd_batched(loss_fn, cfg.eta, R)
        # Flat-buffer engine state, held across rounds for BOTH methods: the
        # FAVAS poll (eq. 3 + line 10 + reset) runs as ONE fused pass per
        # dtype bucket and the QuAFL convex-combination poll as one flat
        # elementwise pass — no per-leaf tree_map sweeps on the hot path.
        # The spec is client-aware (row padding beyond one client tile) and,
        # with a mesh, sharding-aware (model-sharded hidden-dim buckets).
        spec = round_engine.make_flat_spec(server, n_clients=n, mesh=mesh)
        srv_f = round_engine.flatten_tree(spec, server)
        cli_f = round_engine.stack_server_rows(spec, srv_f, n)
        ini_f = round_engine.stack_server_rows(spec, srv_f, n)
        # App. C.2 clock on integer ticks: exact for rational step times
        # (0.3 == 3/10), no f32 drift vs the f64 host reference
        step_ticks_np, round_ticks = sampler.time_ticks(step_time, round_dur)
        step_ticks_j = jnp.asarray(step_ticks_np)
        if cfg.method == "favas" and cfg.reweight == "deterministic":
            det_alpha = jnp.asarray(
                np.maximum(_det_alpha(cfg, step_time, round_dur), 1e-6),
                jnp.float32)
        else:
            det_alpha = None

        def one_round(carry, batch):
            """Scan body: ONE server round, everything on-device — the
            credit clock, masked local SGD, Gumbel top-s selection, eq. 3
            alphas, the fused poll, and the q reset."""
            srv_f, cli_f, ini_f, q, credit, rkey = carry
            xs_t, ys_t = batch
            # SELECT FIRST (docs/architecture.md §9): the round's selection
            # is drawn before any client buffer is touched, mirroring the
            # paged engine's select -> gather -> fused -> scatter order.
            # The split positions are unchanged, so the streams (and every
            # regression baseline) are bit-identical to the old
            # train-then-select body.
            rkey, k_sel, k_q = jax.random.split(rkey, 3)
            mj = sampler.sample_selection(k_sel, n, cfg.s_selected)
            do, credit = sampler.credit_steps(credit, step_ticks_j, q,
                                              cfg.K, round_ticks)
            clients_t = round_engine.unflatten_stacked(spec, cli_f)
            clients_t = sgd(clients_t, xs_t, ys_t, do.astype(jnp.int32))
            q_new = q + do
            cli_f = round_engine._constrain_buckets(
                spec, mesh, round_engine.flatten_stacked(spec, clients_t),
                stacked=True)
            if cfg.method == "favas":
                if cfg.reweight == "deterministic":
                    alpha = det_alpha
                elif cfg.reweight == "none":
                    alpha = jnp.ones((n,), jnp.float32)  # ablation: no eq. 3
                else:
                    alpha = jnp.maximum(q_new, 1.0).astype(jnp.float32)
                prog_f = (None,) * spec.n_buckets
                if cfg.quant_bits > 0:
                    # FAVAS[QNN]: quantize the TRANSMITTED progress only
                    # (per-leaf LUQ scale, as in the seed) — unselected
                    # clients keep their full-precision local state
                    inits_t = round_engine.unflatten_stacked(spec, ini_f)
                    prog = quantize_tree(
                        tree_map(jnp.subtract, clients_t, inits_t),
                        cfg.quant_bits, k_q)
                    prog_f = round_engine._constrain_buckets(
                        spec, mesh, round_engine.flatten_stacked(spec, prog),
                        stacked=True)
                alpha_p = round_engine.pad_client_vec(spec, alpha, 1.0)
                mj_p = round_engine.pad_client_vec(spec, mj, 0.0)
                out = [round_engine.fused_bucket_update(
                           spec, b, w, c, i, alpha_p, mj_p,
                           float(cfg.s_selected), progress_b=p,
                           n_logical=n, mesh=mesh)
                       for b, (w, c, i, p) in enumerate(
                           zip(srv_f, cli_f, ini_f, prog_f))]
                srv_f = tuple(o[0] for o in out)
                cli_f = tuple(o[1] for o in out)
                ini_f = tuple(o[2] for o in out)
            else:  # QuAFL (Zakerinia et al. 2022): convex combos, no
                #    reweight — same flat buffers, one elementwise pass per
                #    bucket (padded rows have zero mask and stay zero)
                mj_p = round_engine.pad_client_vec(spec, mj, 0.0)[:, None]
                sp1 = cfg.s_selected + 1.0
                srv_new, cli_new = [], []
                for w, c in zip(srv_f, cli_f):
                    w2 = (w + jnp.sum(mj_p * c, axis=0)) / sp1
                    cli_new.append(jnp.where(
                        mj_p > 0, (w2[None] + cfg.s_selected * c) / sp1, c))
                    srv_new.append(w2)
                srv_f = round_engine._constrain_buckets(
                    spec, mesh, tuple(srv_new), stacked=False)
                cli_f = round_engine._constrain_buckets(
                    spec, mesh, tuple(cli_new), stacked=True)
            q = jnp.where(mj > 0, 0.0, q_new)
            return (srv_f, cli_f, ini_f, q, credit, rkey), None

        @functools.partial(jax.jit, donate_argnums=(0,))
        def superstep(carry, xs, ys):
            """One eval-to-eval window of rounds as a single donated
            dispatch: scan ``one_round`` over the leading rounds axis."""
            carry, _ = jax.lax.scan(one_round, carry, (xs, ys))
            return carry

        @functools.partial(jax.jit, static_argnums=(2,), donate_argnums=(0,))
        def superstep_device(carry, corpus, C):
            """Device data plane (docs/architecture.md §8): same window
            scan, but each round's minibatches are SAMPLED IN THE SCAN BODY
            from the resident corpus (one batch key split off the carried
            chain per round) — no host batch generation, no prefetcher, no
            per-chunk H2D batch copies. The corpus rides as an argument so
            its buffers stay shared inputs, not baked-in constants."""
            def body(c, _):
                srv, cli, ini, q, credit, rkey = c
                rkey, k_batch = jax.random.split(rkey)
                b = corpus.sample_round_batch(k_batch, R)
                return one_round((srv, cli, ini, q, credit, rkey),
                                 (b["x"], b["y"]))
            carry, _ = jax.lax.scan(body, carry, None, length=C)
            return carry

        # split eval-to-eval windows into <= MAX_SUPERSTEP_ROUNDS sub-chunks
        # (bounded batch memory); only the first sub-chunk of a window
        # records, so the curves are identical to whole-window scans
        chunks = []
        for W in _window_schedule(cfg.total_time, cfg.eval_every, round_dur):
            first = True
            while W > 0:
                c = min(W, MAX_SUPERSTEP_ROUNDS)
                chunks.append((c, first))
                first, W = False, W - c
        use_device_plane = cfg.data_plane == "device"
        if use_device_plane:
            from repro.data.device_corpus import make_classification_corpus
            corpus = make_classification_corpus(xtr, ytr, parts,
                                                cfg.batch_size, mesh=mesh)
            prefetch = None
        else:
            from repro.data.pipeline import BatchPrefetcher
            prefetch = BatchPrefetcher(
                lambda i: batcher.superstep_batch(chunks[i][0], R),
                n_steps=len(chunks))
        carry = (srv_f, cli_f, ini_f,
                 jnp.zeros((n,), jnp.float32),       # q: steps since reset
                 jnp.zeros((n,), jnp.int32),         # time credit (ticks)
                 key)
        try:
            for C, at_record in chunks:
                if at_record:
                    # window starts are exactly where the per-round loop
                    # recorded (see _window_schedule)
                    srv_f, cli_f = carry[0], carry[1]
                    server = round_engine.unflatten_tree(spec, srv_f)
                    clients = round_engine.unflatten_stacked(spec, cli_f)
                    record()
                if use_device_plane:
                    carry = superstep_device(carry, corpus, C)
                else:
                    xs, ys = prefetch.get()
                    carry = superstep(carry, xs, ys)
                t_now += C * round_dur
                srv_step += C
        finally:
            if prefetch is not None:
                prefetch.close()
        server = round_engine.unflatten_tree(spec, carry[0])
        clients = round_engine.unflatten_stacked(spec, carry[1])

    elif cfg.method == "fedavg":
        sgd = _local_sgd_single(loss_fn, cfg.eta)
        while t_now < cfg.total_time:
            if t_now >= next_eval:
                record(); next_eval += cfg.eval_every
            sel = rng.choice(n, cfg.s_selected, replace=False)
            newp = []
            for i in sel:
                xs, ys = zip(*[batcher.client_batch(i) for _ in range(cfg.K)])
                newp.append(sgd(server, jnp.asarray(np.stack(xs)),
                                jnp.asarray(np.stack(ys))))
            server = tree_map(lambda *ps: sum(ps) / len(ps), *newp)
            t_now += SERVER_INTERACT + cfg.K * step_time[sel].max()
            srv_step += 1

    elif cfg.method == "fedbuff":
        sgd = _local_sgd_single(loss_fn, cfg.eta)
        # event queue: (finish_time, client); each job = K local steps
        heap = [(cfg.K * step_time[i] * (1 + 0.01 * rng.random()), i)
                for i in range(n)]
        heapq.heapify(heap)
        client_base = [server] * n
        buffer: List = []
        while t_now < cfg.total_time and heap:
            if t_now >= next_eval:
                record(); next_eval += cfg.eval_every
            t_done, i = heapq.heappop(heap)
            t_now = t_done
            xs, ys = zip(*[batcher.client_batch(i) for _ in range(cfg.K)])
            trained = sgd(client_base[i], jnp.asarray(np.stack(xs)),
                          jnp.asarray(np.stack(ys)))
            delta = tree_map(jnp.subtract, client_base[i], trained)  # = eta*sum g
            buffer.append(delta)
            if len(buffer) >= cfg.buffer_z:
                mean_d = tree_map(lambda *ds: sum(ds) / len(ds), *buffer)
                server = tree_map(lambda w, d: w - cfg.server_eta * d,
                                  server, mean_d)
                buffer = []
                srv_step += 1
                t_now += SERVER_INTERACT
            client_base[i] = server
            heapq.heappush(heap, (t_now + cfg.K * step_time[i], i))

    elif cfg.method == "asyncsgd":
        grad_fn = jax.jit(jax.grad(loss_fn))
        heap = [(step_time[i] * (1 + 0.01 * rng.random()), i) for i in range(n)]
        heapq.heapify(heap)
        client_model = [server] * n
        while t_now < cfg.total_time and heap:
            if t_now >= next_eval:
                record(); next_eval += cfg.eval_every
            t_done, i = heapq.heappop(heap)
            t_now = t_done
            x, y = batcher.client_batch(i)
            g = grad_fn(client_model[i], jnp.asarray(x), jnp.asarray(y))
            server = tree_map(lambda w, gg: w - cfg.eta * gg, server, g)
            client_model[i] = server
            heapq.heappush(heap, (t_now + step_time[i], i))
            srv_step += 1
    else:
        raise ValueError(cfg.method)

    record()
    return {"times": np.array(times), "accuracy": np.array(accs),
            "variance": np.array(variances),
            "server_steps": np.array(server_steps),
            "final_accuracy": accs[-1], "method": cfg.method,
            "server": server}


def _det_alpha(cfg: SimConfig, step_time: np.ndarray, round_dur: float):
    """Deterministic alpha = E[E ∧ K]: with deterministic step times and
    poll probability s/n per round, computed by the sampler's DP using the
    per-round step rate."""
    from repro.core.sampler import moments_at_poll
    out = np.empty(cfg.n_clients, np.float32)
    poll_p = cfg.s_selected / cfg.n_clients
    cache = {}
    for i, st in enumerate(step_time):
        lam = min(max(st / round_dur, 1e-3), 0.999)  # approx 1/steps-per-round
        if lam not in cache:
            cache[lam] = moments_at_poll(lam, cfg.K, poll_p)[1]
        out[i] = cache[lam]
    return out
