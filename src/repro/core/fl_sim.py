"""Simulated-time federated-learning harness — reproduces the paper's
experimental protocol (Sec. 5 + App. C.1/C.2) for FAVAS and its baselines
(FedAvg, QuAFL, FedBuff, AsyncSGD) on the small classifier models.

Time model (App. C.2):
  * server waiting time 4, server interaction time 3;
  * deterministic per-step client runtimes: fast = 2, slow = 16 time units
    (1/3 slow unless stated);
  * FAVAS/QuAFL server rounds last wait+interact = 7; clients train
    concurrently, capped at K local steps since their last reset;
  * FedAvg rounds last interact + K * (slowest selected client's step time);
  * FedBuff rounds complete when Z client updates arrive (fast clients feed
    the buffer — the bias FAVAS removes);
  * AsyncSGD applies every arriving single-gradient update immediately.

This level is the *paper-experiment* engine (small models, CPU); the
distributed production trainer for the assigned architectures lives in
``repro.core.favas`` + ``repro.launch.train``.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.classifier import mlp_init, mlp_apply, classifier_loss, accuracy
from repro.core.quant import quantize_tree
from repro.core import round_engine
from repro.utils.tree import tree_map

SERVER_WAIT = 4.0
SERVER_INTERACT = 3.0


@dataclasses.dataclass
class SimConfig:
    method: str = "favas"            # favas|quafl|fedbuff|fedavg|asyncsgd
    n_clients: int = 30
    s_selected: int = 6
    K: int = 10
    buffer_z: int = 5                # FedBuff
    eta: float = 0.2
    server_eta: float = 1.0          # FedBuff global LR
    total_time: float = 2000.0
    eval_every: float = 100.0
    batch_size: int = 64
    fast_step_time: float = 2.0
    slow_step_time: float = 16.0
    slow_fraction: float = 1.0 / 3.0
    reweight: str = "stochastic"
    quant_bits: int = 0              # FAVAS[QNN]
    permute_speeds: bool = True      # False: clients [0, n_slow) are the slow
    #                                  ones (for speed/data-correlated setups)
    seed: int = 0


def _step_times(cfg: SimConfig, rng) -> np.ndarray:
    n_slow = int(round(cfg.slow_fraction * cfg.n_clients))
    t = np.full(cfg.n_clients, cfg.fast_step_time)
    t[:n_slow] = cfg.slow_step_time
    return rng.permutation(t) if cfg.permute_speeds else t


def _local_sgd_batched(loss_fn, eta, R):
    """vmapped masked local SGD: params (n,...), data (n,R,B,...), steps (n,)."""
    def one(params, xs, ys, n_steps):
        def step(p, inp):
            k, x, y = inp
            g = jax.grad(loss_fn)(p, x, y)
            live = (k < n_steps).astype(jnp.float32)
            return tree_map(lambda pp, gg: pp - eta * live * gg, p, g), None
        p, _ = jax.lax.scan(step, params, (jnp.arange(R), xs, ys))
        return p
    return jax.jit(jax.vmap(one))


def _local_sgd_single(loss_fn, eta):
    def run(params, xs, ys):
        def step(p, inp):
            x, y = inp
            g = jax.grad(loss_fn)(p, x, y)
            return tree_map(lambda pp, gg: pp - eta * gg, p, g), None
        p, _ = jax.lax.scan(step, params, (xs, ys))
        return p
    return jax.jit(run)


def run_simulation(cfg: SimConfig, data, *, d_hidden: int = 128,
                   mesh=None) -> Dict:
    """data = (x_train, y_train, x_test, y_test, parts). Returns curves.

    ``mesh``: optional device mesh with a "model" axis — the FAVAS branch
    then builds a sharding-aware FlatSpec (hidden-dim leaves bucketed into
    model-sharded flat buffers, see sharding/rules.py) and runs the fused
    poll through ``round_engine.fused_bucket_update`` without gathering the
    buffers. CPU default (mesh=None) is unchanged."""
    xtr, ytr, xte, yte, parts = data
    n_classes = int(ytr.max()) + 1
    d_in = xtr.shape[1]
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)

    loss_fn = lambda p, x, y: classifier_loss(p, mlp_apply, x, y, n_classes)
    server = mlp_init(key, d_in, d_hidden, n_classes)
    n = cfg.n_clients
    clients = tree_map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape).copy(),
                       server)
    inits = clients
    step_time = _step_times(cfg, rng)

    from repro.data.pipeline import FederatedBatcher
    batcher = FederatedBatcher(xtr, ytr, parts, cfg.batch_size, cfg.seed)

    eval_fn = jax.jit(lambda p: accuracy(p, mlp_apply, xte, yte))
    var_fn = jax.jit(lambda W, w: sum(jax.tree_util.tree_leaves(tree_map(
        lambda a, b: jnp.sum((a - b[None]) ** 2), W, w))))

    times, accs, variances, server_steps = [], [], [], []
    t_now, next_eval, srv_step = 0.0, 0.0, 0

    def record():
        times.append(t_now)
        accs.append(float(eval_fn(server)))
        variances.append(float(var_fn(clients, server)))
        server_steps.append(srv_step)

    if cfg.method in ("favas", "quafl"):
        round_dur = SERVER_WAIT + SERVER_INTERACT
        R = int(np.ceil(round_dur / step_time.min()))
        sgd = _local_sgd_batched(loss_fn, cfg.eta, R)
        q = np.zeros(n)                   # steps since reset (cap K)
        credit = np.zeros(n)              # fractional time credit
        qkey = key
        flat = cfg.method == "favas"
        if flat:
            # Flat-buffer engine state, held across rounds: the FAVAS poll
            # (eq. 3 + line 10 + reset) runs as ONE fused pass per dtype
            # bucket instead of ~6 tree_map sweeps; trees are materialized
            # only at the sgd and eval boundaries (core/round_engine.py).
            # The spec is client-aware: beyond one client tile the row axis
            # is zero-padded so the tiled kernel never re-pads. With a mesh
            # it is also sharding-aware (model-sharded hidden-dim buckets).
            spec = round_engine.make_flat_spec(server, n_clients=n, mesh=mesh)
            srv_f = round_engine.flatten_tree(spec, server)
            cli_f = round_engine.stack_server_rows(spec, srv_f, n)
            ini_f = cli_f
        while t_now < cfg.total_time:
            if t_now >= next_eval:
                if flat:
                    server = round_engine.unflatten_tree(spec, srv_f)
                    clients = round_engine.unflatten_stacked(spec, cli_f)
                record(); next_eval += cfg.eval_every
            # concurrent local compute during this round
            credit += round_dur
            avail = np.floor(credit / step_time)
            credit -= avail * step_time
            do = np.minimum(avail, cfg.K - q)
            xs, ys = batcher.round_batch(R)
            if flat:
                clients = round_engine.unflatten_stacked(spec, cli_f)
            clients = sgd(clients, jnp.asarray(xs), jnp.asarray(ys),
                          jnp.asarray(do, jnp.int32))
            q = q + do
            # server poll
            sel = rng.choice(n, cfg.s_selected, replace=False)
            mask = np.zeros(n); mask[sel] = 1.0
            mj = jnp.asarray(mask, jnp.float32)
            if cfg.method == "favas":
                if cfg.reweight == "deterministic":
                    alpha_np = np.maximum(_det_alpha(cfg, step_time, round_dur), 1e-6)
                elif cfg.reweight == "none":
                    alpha_np = np.ones(n)        # ablation: biased (no eq. 3)
                else:
                    alpha_np = np.maximum(q, 1.0)
                alpha = jnp.asarray(alpha_np, jnp.float32)
                prog_f = (None,) * spec.n_buckets
                if cfg.quant_bits > 0:
                    # FAVAS[QNN]: quantize the TRANSMITTED progress only
                    # (per-leaf LUQ scale, as in the seed) — unselected
                    # clients keep their full-precision local state
                    qkey, sub = jax.random.split(qkey)
                    inits = round_engine.unflatten_stacked(spec, ini_f)
                    prog = quantize_tree(tree_map(jnp.subtract, clients, inits),
                                         cfg.quant_bits, sub)
                    prog_f = round_engine.flatten_stacked(spec, prog)
                cli_f = round_engine.flatten_stacked(spec, clients)
                alpha_p = round_engine.pad_client_vec(spec, alpha, 1.0)
                mj_p = round_engine.pad_client_vec(spec, mj, 0.0)
                out = [round_engine.fused_bucket_update(
                           spec, b, w, c, i, alpha_p, mj_p,
                           float(cfg.s_selected), progress_b=p,
                           n_logical=n, mesh=mesh)
                       for b, (w, c, i, p) in enumerate(
                           zip(srv_f, cli_f, ini_f, prog_f))]
                srv_f = tuple(o[0] for o in out)
                cli_f = tuple(o[1] for o in out)
                ini_f = tuple(o[2] for o in out)
                q[sel] = 0.0
            else:  # QuAFL (Zakerinia et al. 2022): convex combos, no reweight
                server_new = tree_map(
                    lambda w, W: (w + jnp.sum(
                        mj.reshape((n,) + (1,) * (W.ndim - 1)) * W, 0))
                    / (cfg.s_selected + 1.0), server, clients)
                clients = tree_map(
                    lambda W, w: jnp.where(
                        mj.reshape((n,) + (1,) * (W.ndim - 1)) > 0,
                        (w[None] + cfg.s_selected * W) / (cfg.s_selected + 1.0), W),
                    clients, server_new)
                server = server_new
                q[sel] = 0.0
            t_now += round_dur
            srv_step += 1
        if flat:
            server = round_engine.unflatten_tree(spec, srv_f)
            clients = round_engine.unflatten_stacked(spec, cli_f)

    elif cfg.method == "fedavg":
        sgd = _local_sgd_single(loss_fn, cfg.eta)
        while t_now < cfg.total_time:
            if t_now >= next_eval:
                record(); next_eval += cfg.eval_every
            sel = rng.choice(n, cfg.s_selected, replace=False)
            newp = []
            for i in sel:
                xs, ys = zip(*[batcher.client_batch(i) for _ in range(cfg.K)])
                newp.append(sgd(server, jnp.asarray(np.stack(xs)),
                                jnp.asarray(np.stack(ys))))
            server = tree_map(lambda *ps: sum(ps) / len(ps), *newp)
            t_now += SERVER_INTERACT + cfg.K * step_time[sel].max()
            srv_step += 1

    elif cfg.method == "fedbuff":
        sgd = _local_sgd_single(loss_fn, cfg.eta)
        # event queue: (finish_time, client); each job = K local steps
        heap = [(cfg.K * step_time[i] * (1 + 0.01 * rng.random()), i)
                for i in range(n)]
        heapq.heapify(heap)
        client_base = [server] * n
        buffer: List = []
        while t_now < cfg.total_time and heap:
            if t_now >= next_eval:
                record(); next_eval += cfg.eval_every
            t_done, i = heapq.heappop(heap)
            t_now = t_done
            xs, ys = zip(*[batcher.client_batch(i) for _ in range(cfg.K)])
            trained = sgd(client_base[i], jnp.asarray(np.stack(xs)),
                          jnp.asarray(np.stack(ys)))
            delta = tree_map(jnp.subtract, client_base[i], trained)  # = eta*sum g
            buffer.append(delta)
            if len(buffer) >= cfg.buffer_z:
                mean_d = tree_map(lambda *ds: sum(ds) / len(ds), *buffer)
                server = tree_map(lambda w, d: w - cfg.server_eta * d,
                                  server, mean_d)
                buffer = []
                srv_step += 1
                t_now += SERVER_INTERACT
            client_base[i] = server
            heapq.heappush(heap, (t_now + cfg.K * step_time[i], i))

    elif cfg.method == "asyncsgd":
        grad_fn = jax.jit(jax.grad(loss_fn))
        heap = [(step_time[i] * (1 + 0.01 * rng.random()), i) for i in range(n)]
        heapq.heapify(heap)
        client_model = [server] * n
        while t_now < cfg.total_time and heap:
            if t_now >= next_eval:
                record(); next_eval += cfg.eval_every
            t_done, i = heapq.heappop(heap)
            t_now = t_done
            x, y = batcher.client_batch(i)
            g = grad_fn(client_model[i], jnp.asarray(x), jnp.asarray(y))
            server = tree_map(lambda w, gg: w - cfg.eta * gg, server, g)
            client_model[i] = server
            heapq.heappush(heap, (t_now + step_time[i], i))
            srv_step += 1
    else:
        raise ValueError(cfg.method)

    record()
    return {"times": np.array(times), "accuracy": np.array(accs),
            "variance": np.array(variances),
            "server_steps": np.array(server_steps),
            "final_accuracy": accs[-1], "method": cfg.method,
            "server": server}


def _det_alpha(cfg: SimConfig, step_time: np.ndarray, round_dur: float):
    """Deterministic alpha = E[E ∧ K]: with deterministic step times and
    poll probability s/n per round, computed by the sampler's DP using the
    per-round step rate."""
    from repro.core.sampler import moments_at_poll
    out = np.empty(cfg.n_clients, np.float32)
    poll_p = cfg.s_selected / cfg.n_clients
    cache = {}
    for i, st in enumerate(step_time):
        lam = min(max(st / round_dur, 1e-3), 0.999)  # approx 1/steps-per-round
        if lam not in cache:
            cache[lam] = moments_at_poll(lam, cfg.K, poll_p)[1]
        out[i] = cache[lam]
    return out
