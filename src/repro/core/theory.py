"""Table-1 calculator: units-of-time to reach epsilon accuracy for
FedAvg / FedBuff / AsyncSGD / QuAFL / FAVAS, with the paper's constants.

For FAVAS the client-speed statistics (a^i, b) of Theorem 3 are computed
from the speed distribution via ``sampler.moments_at_poll``:
  stochastic alpha:    a^i = (1/P(E>0)) (P(E>0)/K^2 + E[1(E>0)/(E∧K)]),
                       b   = max_i 1/P(E>0)
  deterministic alpha: a^i = 1/E[E∧K] + E[(E∧K)^2]/(K^2 E[E∧K]),
                       b   = max_i E[(E∧K)^2]/E[E∧K]
Per-method C_ constants are the expected time between consecutive server
steps under the App. C.2 time model.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core.sampler import moments_at_poll

SERVER_WAIT, SERVER_INTERACT = 4.0, 3.0


@dataclasses.dataclass
class TheoryParams:
    n: int = 100
    s: int = 20
    K: int = 20
    buffer_z: int = 10
    L: float = 1.0            # smoothness
    sigma2: float = 1.0       # A3 gradient variance
    G2: float = 1.0           # A4 dissimilarity
    B2: float = 1.0
    F: float = 1.0            # f(w0) - f*
    eps: float = 1e-2
    fast_step_time: float = 2.0
    slow_step_time: float = 16.0
    slow_fraction: float = 1.0 / 3.0
    reweight: str = "stochastic"


def favas_speed_constants(p: TheoryParams):
    """(mean_a, b) of Theorem 3 over the client population."""
    round_dur = SERVER_WAIT + SERVER_INTERACT
    poll_p = p.s / p.n
    a_vals, b_vals = [], []
    for st, frac in ((p.fast_step_time, 1 - p.slow_fraction),
                     (p.slow_step_time, p.slow_fraction)):
        lam = min(max(st / round_dur, 1e-3), 0.999)   # ~ 1/steps-per-round
        p_pos, e1, e2, einv = moments_at_poll(lam, p.K, poll_p)
        if p.reweight == "stochastic":
            a = (1.0 / max(p_pos, 1e-9)) * (p_pos / p.K ** 2 + einv)
            b = 1.0 / max(p_pos, 1e-9)
        else:
            a = 1.0 / max(e1, 1e-9) + e2 / (p.K ** 2 * max(e1, 1e-9))
            b = e2 / max(e1, 1e-9)
        a_vals.append((a, frac))
        b_vals.append(b)
    mean_a = sum(a * f for a, f in a_vals)
    return mean_a, max(b_vals)


def _time_constants(p: TheoryParams) -> Dict[str, float]:
    """Expected time between consecutive server steps per method (C_)."""
    exp_max_slow = 1 - (1 - p.slow_fraction) ** p.s     # P(round has a slow client)
    fedavg_round = SERVER_INTERACT + p.K * (
        exp_max_slow * p.slow_step_time + (1 - exp_max_slow) * p.fast_step_time)
    # FedBuff: Z updates; arrival rate = sum_i 1/(K tau_i)
    rate = (p.n * (1 - p.slow_fraction) / (p.K * p.fast_step_time)
            + p.n * p.slow_fraction / (p.K * p.slow_step_time))
    fedbuff_round = SERVER_INTERACT + p.buffer_z / rate
    async_rate = (p.n * (1 - p.slow_fraction) / p.fast_step_time
                  + p.n * p.slow_fraction / p.slow_step_time)
    return {
        "FedAvg": fedavg_round,
        "FedBuff": fedbuff_round,
        "AsyncSGD": 1.0 / async_rate,
        "QuAFL": SERVER_WAIT + SERVER_INTERACT,
        "FAVAS": SERVER_WAIT + SERVER_INTERACT,
    }


def tau_max_estimate(p: TheoryParams) -> float:
    """Delay bound entering FedBuff/AsyncSGD analyses: ratio of slowest to
    fastest update production (the paper's 1 vs 1000 workers discussion)."""
    return p.slow_step_time / p.fast_step_time * p.n


def units_of_time(p: TheoryParams) -> Dict[str, float]:
    """Evaluate every row of Table 1 (constants dropped, as in the paper)."""
    L, s2, G2, B2, F, K, n, s, eps = (p.L, p.sigma2, p.G2, p.B2, p.F, p.K,
                                      p.n, p.s, p.eps)
    C = _time_constants(p)
    tmax = tau_max_estimate(p)
    tavg = tmax / 4.0
    E_mean = (1 - p.slow_fraction) * min(K, (SERVER_WAIT + SERVER_INTERACT)
                                         / p.fast_step_time * n / s) \
        + p.slow_fraction * min(K, (SERVER_WAIT + SERVER_INTERACT)
                                / p.slow_step_time * n / s)
    a_mean, b = favas_speed_constants(p)

    T = {}
    T["FedAvg"] = ((F * L * s2 + (1 - s / n) * K * G2) / (s * K) * eps ** -2
                   + F * L ** 0.5 * G2 ** 0.5 * eps ** -1.5
                   + L * F * B2 / eps) * C["FedAvg"]
    T["FedBuff"] = (F * L * (s2 + G2) * eps ** -2
                    + F * L * ((tmax ** 2 / s ** 2 + 1) * (s2 + n * G2)) ** 0.5
                    * eps ** -1.5 + F * L / eps) * C["FedBuff"]
    T["AsyncSGD"] = (F * L * (3 * s2 + 4 * G2) * eps ** -2
                     + F * L * G2 ** 0.5 * (s * tavg) ** 0.5 * eps ** -1.5
                     + (s * tmax * F) ** 0.5 / eps) * C["AsyncSGD"]
    T["QuAFL"] = (F * L * K * (s2 + 2 * K * G2) / E_mean ** 2 * eps ** -2
                  + n ** 1.5 / (E_mean * (E_mean * s) ** 0.5) * F * K * L
                  * (s2 + 2 * K * G2) ** 0.5 * eps ** -1.5
                  + n ** 1.5 / (E_mean * s ** 0.5) * F * B2 ** 0.5 * K ** 2 * L
                  / eps) * C["QuAFL"]
    T["FAVAS"] = (F * L * (s2 * a_mean + 8 * G2 * b) * eps ** -2
                  + (n / s) * F * L ** 2 * (K ** 2 * s2 + L ** 2 * K ** 2 * G2
                                            + s ** 2 * s2 * a_mean
                                            + s ** 2 * G2 * b) ** 0.5 * eps ** -1.5
                  + n * F * B2 * K * L * b / eps) * C["FAVAS"]
    return T
