"""Jit'd public wrappers over the Pallas kernels, with automatic fallback:
the kernels run natively on TPU and in interpret mode on CPU; ``use_kernel=
False`` selects the pure-jnp oracle path (used by the default pjit trainer,
where XLA fusion already handles the arithmetic — the kernel path is the
single-host / kernel-benchmark configuration).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.favas_agg import (favas_agg_pallas, favas_fused_pallas,
                                     favas_stream_pallas)
from repro.kernels.luq import (luq_decode_pallas, luq_encode_pallas,
                               luq_pallas)


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def favas_fused_flat(server, clients, inits, alpha, mask, s: float,
                     *, progress=None, progress_codes=None,
                     progress_bits: int = 0, progress_shards: int = 1,
                     client_tile=None, n_logical=None, use_kernel=None):
    """Fused full-round aggregation + reset over flat buffers; see
    kernels/favas_agg.py. Returns (server_new, clients_new, inits_new).

    Args:
      server: (D,) flat server vector; clients / inits: (n, D) stacks.
      alpha / mask: (n,) eq. 3 coefficients and 0/1 selection mask, already
        padded alongside any client-row padding (unit alpha / zero mask on
        padded rows keeps them exact no-ops).
      s: |S_t|; the aggregation divides by ``s + 1``.
      progress: optional explicit (n, D) transmitted progress (e.g. the
        LUQ-quantized client deltas); None means ``clients - inits``,
        computed inside. Resets always use full-precision ``clients`` —
        quantization is communication-only (paper Remark 1).
      progress_codes: the CODES-IN variant of ``progress`` (mutually
        exclusive with it): a ``{"codes": (n, D*bits/8) uint8, "scale":
        (n, shards) f32}`` encoding from ``cold_requant_rows``. The kernel
        path dequantizes per VMEM tile (``msg_i = init_i + dequant(code_i)
        / alpha_i``) so the dense (n, D) f32 progress never materializes;
        the oracle path decodes with ``core.paging.luq_decode_rows`` and
        runs the dense reference — element-identical by construction.
      progress_bits / progress_shards: LUQ width and per-row scale count
        of ``progress_codes``.
      client_tile: client-axis tile for the kernel path (the jnp oracle is
        shape-agnostic and ignores it).
      n_logical: real client rows when the buffers carry client-tile
        padding; the oracle path computes on the logical rows and
        re-attaches the padding as exact zeros, so reducing over a padded
        row count never reorders the fp32 client sum (keeps the engine
        bit-identical to ``favas_round_reference`` at any n).
      use_kernel: None (auto) picks the Pallas kernel on TPU and the jnp
        oracle on CPU (interpret mode is a validation tool, not a fast
        path); True forces the kernel (interpret off-TPU), False forces the
        oracle.

    On a device mesh, call this through
    ``core.round_engine.fused_bucket_update`` — it wraps the kernel path in
    ``shard_map`` over per-shard flat slices and pins the oracle path's
    output shardings, so sharded buckets never gather."""
    if progress is not None and progress_codes is not None:
        raise ValueError("progress and progress_codes are mutually exclusive")
    if use_kernel is None:
        use_kernel = _is_tpu()
    if use_kernel:
        return favas_fused_pallas(server, clients, inits, alpha, mask, s,
                                  progress=progress,
                                  progress_codes=progress_codes,
                                  progress_bits=progress_bits,
                                  progress_shards=progress_shards,
                                  client_tile=client_tile,
                                  interpret=not _is_tpu())
    if progress_codes is not None:
        # oracle: decode to dense f32 and run the reference — decode is
        # row-elementwise, so slice-then-decode == decode-then-slice and
        # the n_logical handling below stays exact
        from repro.core.paging import luq_decode_rows   # lazy: no cycle
        progress = luq_decode_rows(progress_codes, progress_bits,
                                   jnp.float32, shards=progress_shards)
    rows = clients.shape[0]
    nl = rows if n_logical is None else n_logical
    if nl < rows:
        srv, cli, ini = ref.favas_fused_ref(
            server, clients[:nl], inits[:nl], alpha[:nl], mask[:nl], s,
            progress=None if progress is None else progress[:nl])
        # padded rows are zero with zero mask: their reset is exactly zero
        rpad = ((0, rows - nl), (0, 0))
        return srv, jnp.pad(cli, rpad), jnp.pad(ini, rpad)
    return ref.favas_fused_ref(server, clients, inits, alpha, mask, s,
                               progress=progress)


def favas_stream_flat(server, clients, inits, alpha, mask, s: float,
                      *, progress=None, progress_codes=None,
                      progress_bits: int = 0, progress_shards: int = 1,
                      client_tile=None, n_logical=None, use_kernel=None):
    """Aggregation-only half of the STREAMED round schedule (docs §13):
    the ``favas_fused_flat`` contract, returning ONLY the (D,) new server
    vector. The caller applies the selected-client reset as a churn-
    bounded scatter of this row into the donated state buffers
    (``core.round_engine.stream_bucket_update``), so unselected rows are
    never rewritten. Same ``use_kernel`` dispatch and the same fp32
    expressions as the fused path — the server it returns is bit-identical
    to ``favas_fused_flat``'s per dispatch path."""
    if progress is not None and progress_codes is not None:
        raise ValueError("progress and progress_codes are mutually exclusive")
    if use_kernel is None:
        use_kernel = _is_tpu()
    if use_kernel:
        return favas_stream_pallas(server, clients, inits, alpha, mask, s,
                                   progress=progress,
                                   progress_codes=progress_codes,
                                   progress_bits=progress_bits,
                                   progress_shards=progress_shards,
                                   client_tile=client_tile,
                                   interpret=not _is_tpu())
    if progress_codes is not None:
        from repro.core.paging import luq_decode_rows   # lazy: no cycle
        progress = luq_decode_rows(progress_codes, progress_bits,
                                   jnp.float32, shards=progress_shards)
    rows = clients.shape[0]
    nl = rows if n_logical is None else n_logical
    if nl < rows:
        # padded rows are zero with zero mask: exact no-ops under the sum
        return ref.favas_stream_ref(
            server, clients[:nl], inits[:nl], alpha[:nl], mask[:nl], s,
            progress=None if progress is None else progress[:nl])
    return ref.favas_stream_ref(server, clients, inits, alpha, mask, s,
                                progress=progress)


def favas_aggregate_flat(server, clients, inits, alpha, mask, s: float,
                         *, client_tile=None, use_kernel: bool = True):
    """Flat-buffer FAVAS aggregation; see kernels/favas_agg.py."""
    if use_kernel:
        return favas_agg_pallas(server, clients, inits, alpha, mask, s,
                                client_tile=client_tile,
                                interpret=not _is_tpu())
    return ref.favas_agg_ref(server, clients, inits, alpha, mask, s)


def favas_aggregate_tree(server_tree, clients_tree, inits_tree, alpha, mask,
                         s: float, *, use_kernel: bool = True):
    """Leafwise fused aggregation over parameter pytrees (leaves flattened
    to (n, D) / (D,) buffers)."""
    def one(w, C, I):
        D = w.size
        out = favas_aggregate_flat(w.reshape(-1), C.reshape(C.shape[0], -1),
                                   I.reshape(I.shape[0], -1), alpha, mask, s,
                                   use_kernel=use_kernel)
        return out.reshape(w.shape)
    return jax.tree_util.tree_map(one, server_tree, clients_tree, inits_tree)


def cold_requant_rows(x, bits: int, key, *, shards: int = 1,
                      use_kernel=None):
    """Paged-engine EVICTION path: LUQ-encode (rows, D) hot rows into
    bit-packed cold-pool codes + per-(row, shard) scales (see
    ``core.paging.luq_encode_rows`` for the math — the same stochastic
    prune/round as ``luq_pallas``/``luq_ref``, emitting codes instead of
    dequantized floats).

    ``use_kernel`` follows the ``favas_fused_flat`` dispatch contract:
    None picks the code-emitting Pallas kernel (``kernels.luq.
    luq_encode_pallas``) on TPU and the jnp oracle elsewhere; True forces
    the kernel (interpret mode off-TPU — a validation tool, not a fast
    path); False forces the oracle. Both paths draw the SAME (rows, D)
    uniform fields from ``key`` and are bit-identical (pinned by
    tests/test_quant_fused.py — this dispatch used to be a silent no-op)."""
    if use_kernel is None:
        use_kernel = _is_tpu()
    if use_kernel:
        k1, k2 = jax.random.split(key)
        rows, D = x.shape
        up = jax.random.uniform(k1, (rows, D))
        ur = jax.random.uniform(k2, (rows, D))
        return luq_encode_pallas(x, up, ur, bits, shards=shards,
                                 interpret=not _is_tpu())
    from repro.core.paging import luq_encode_rows   # lazy: no import cycle
    return luq_encode_rows(x, bits, key, shards=shards)


def cold_dequant_rows(enc, bits: int, dtype, *, shards: int = 1,
                      use_kernel=None):
    """Paged-engine PROMOTION path: decode cold-pool rows gathered for the
    new hot working set back to (rows, D) in ``dtype``. Inverse of
    :func:`cold_requant_rows`, same ``use_kernel`` contract (the Pallas
    path is ``kernels.luq.luq_decode_pallas``)."""
    if use_kernel is None:
        use_kernel = _is_tpu()
    if use_kernel:
        return luq_decode_pallas(enc, bits, dtype, shards=shards,
                                 interpret=not _is_tpu())
    from repro.core.paging import luq_decode_rows   # lazy: no import cycle
    return luq_decode_rows(enc, bits, dtype, shards=shards)


def luq_quantize(x, bits: int, key, *, use_kernel: bool = True):
    """LUQ quantization with explicit PRNG key (kernel or oracle path)."""
    # lazy: core.__init__ transitively imports this module
    from repro.core.quant import luq_scale
    k1, k2 = jax.random.split(key)
    up = jax.random.uniform(k1, x.shape)
    ur = jax.random.uniform(k2, x.shape)
    if use_kernel:
        return luq_pallas(x, up, ur, bits, interpret=not _is_tpu())
    # the guarded scale (all-zero inputs -> 1.0) is shared with
    # core.quant.luq_quantize and the kernel path — one helper, no drift
    return ref.luq_ref(x, up, ur, luq_scale(x), bits)
