"""Pallas TPU kernel: LUQ logarithmic unbiased quantization (FAVAS[QNN],
paper Remark 1 / Chmiel et al. 2021).

Fuses threshold + stochastic prune + log2 + stochastic exponent rounding +
dequant in one VMEM pass over (8*R, 128*C)-aligned tiles. The global scale
(max |x|) is a cheap separate reduction; the uniform random fields are
passed in as inputs so CPU interpret-mode tests are bit-identical to the
jnp oracle (a production TPU build would draw them on-chip with
``pltpu.prng_random_bits`` — noted in DESIGN.md §7).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS, COLS = 256, 1024  # (sublane, lane) tile — multiples of (8, 128)


def _luq_kernel(x_ref, up_ref, ur_ref, scale_ref, out_ref, *, levels: int):
    x = x_ref[...].astype(jnp.float32)
    up = up_ref[...].astype(jnp.float32)
    ur = ur_ref[...].astype(jnp.float32)
    scale = scale_ref[0, 0].astype(jnp.float32)
    scale = jnp.where(scale > 0, scale, 1.0)
    sign = jnp.sign(x)
    m = jnp.abs(x) / scale
    min_level = 2.0 ** (-(levels - 1))
    below = m < min_level
    keep = up < (m / min_level)
    m_pruned = jnp.where(below, jnp.where(keep, min_level, 0.0), m)
    e = jnp.floor(jnp.log2(jnp.maximum(m_pruned, min_level)))
    f = m_pruned / jnp.exp2(e)
    e_hat = e + (ur < (f - 1.0)).astype(jnp.float32)
    q = jnp.where(m_pruned == 0.0, 0.0,
                  jnp.exp2(jnp.clip(e_hat, -(levels - 1), 0.0)))
    out_ref[...] = (sign * scale * q).astype(out_ref.dtype)


def luq_pallas(x, u_prune, u_round, bits: int, *, interpret: bool = True):
    """Elementwise over any shape; flattened to (R, COLS) tiles."""
    # lazy: core.__init__ transitively imports this module, so a top-level
    # import of core.quant would be circular from some entry points
    from repro.core.quant import luq_scale
    levels = 2 ** (bits - 1) - 1
    orig_shape, dtype = x.shape, x.dtype
    scale = luq_scale(x).reshape(1, 1)
    flat = x.reshape(-1)
    D = flat.shape[0]
    width = ROWS * COLS
    pad = (-D) % width
    if pad:
        flat = jnp.pad(flat, (0, pad))
        u_prune = jnp.pad(u_prune.reshape(-1), (0, pad))
        u_round = jnp.pad(u_round.reshape(-1), (0, pad))
    else:
        u_prune = u_prune.reshape(-1)
        u_round = u_round.reshape(-1)
    rows = flat.shape[0] // COLS
    x2 = flat.reshape(rows, COLS)
    up2 = u_prune.reshape(rows, COLS)
    ur2 = u_round.reshape(rows, COLS)
    grid = (rows // ROWS,)
    out = pl.pallas_call(
        functools.partial(_luq_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, COLS), dtype),
        interpret=interpret,
    )(x2, up2, ur2, scale)
    return out.reshape(-1)[:D].reshape(orig_shape)
