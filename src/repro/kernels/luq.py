"""Pallas TPU kernels: LUQ logarithmic unbiased quantization (FAVAS[QNN],
paper Remark 1 / Chmiel et al. 2021).

Three kernels share the LUQ math (threshold + stochastic prune + log2 +
stochastic exponent rounding) in one VMEM pass over (8, 128)-aligned tiles:

* ``luq_pallas`` — the original dequantized-value variant (x -> Q(x)),
  used by ``ops.luq_quantize`` for the transmitted-progress path.
* ``luq_encode_pallas`` — code-EMITTING variant: x + uniforms -> bit-packed
  uint8 codes + per-(row, shard) f32 scales, bit-identical to
  ``core.paging.luq_encode_rows`` under the same uniforms. The pack runs
  in-kernel (strided lane slices + shifts) so the stored representation
  never leaves VMEM wider than ``bits/8`` bytes per element.
* ``luq_decode_pallas`` — code-CONSUMING inverse, bit-identical to
  ``core.paging.luq_decode_rows``.

Scales are cheap separate reductions; the uniform random fields are passed
in as inputs so CPU interpret-mode tests are bit-identical to the jnp
oracle (a production TPU build would draw them on-chip with
``pltpu.prng_random_bits`` — noted in DESIGN.md §7). The scale guard is
shared with ``core.quant.luq_scale``: all-zero segments map to 1.0, a NaN
max PROPAGATES (decode of such a row is loudly non-finite, never silently
finite — pinned by tests/test_quant_codec.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS, COLS = 256, 1024  # (sublane, lane) tile — multiples of (8, 128)
ENC_ROWS = 8            # codec kernels: sublane rows per block
ENC_TILE = 512          # codec kernels: lane tile; 512*bits/8 >= 128 packed


def guard_scale(scale):
    """Shared LUQ scale guard: zero -> 1.0 (exact-zero segments decode to
    exact zeros), positive/Inf pass through, NaN PROPAGATES (a poisoned
    segment must decode loudly non-finite, not quantize against 1.0)."""
    return jnp.where(jnp.isnan(scale), scale,
                     jnp.where(scale > 0, scale, 1.0))


def pack_block(codes, bits: int):
    """In-kernel bit pack: (R, C) int32 codes < 2**bits -> (R, C*bits/8)
    uint8, LSB-first — the layout of ``core.paging.pack_codes``. Strided
    lane slices + shifts only; C must divide by 8//bits."""
    k = 8 // bits
    if k == 1:
        return codes.astype(jnp.uint8)
    packed = codes[:, 0::k]
    for i in range(1, k):
        packed = packed | (codes[:, i::k] << (i * bits))
    return packed.astype(jnp.uint8)


def unpack_block(packed, bits: int):
    """In-kernel inverse of :func:`pack_block`: (R, P) uint8 -> (R, P*8/
    bits) int32 codes, via a k-fold lane repeat + per-lane shift (iota)."""
    k = 8 // bits
    c = packed.astype(jnp.int32)
    if k == 1:
        return c
    rep = jnp.repeat(c, k, axis=1)
    sub = jax.lax.broadcasted_iota(jnp.int32, rep.shape, 1) % k
    return (rep >> (sub * bits)) & ((1 << bits) - 1)


def dequant_block(packed, scale, bits: int):
    """In-kernel LUQ dequant of a packed uint8 block against (R, 1) f32
    scales -> (R, P*8/bits) f32 values. The same expressions (and float-op
    order) as ``core.paging.luq_decode_rows``, so interpret-mode output is
    bit-identical to the jnp oracle."""
    levels = 2 ** (bits - 1) - 1
    codes = unpack_block(packed, bits)
    midx = codes & ((1 << (bits - 1)) - 1)
    sign = (codes >> (bits - 1)).astype(jnp.float32)
    q = jnp.where(midx == 0, 0.0,
                  jnp.exp2(midx.astype(jnp.float32) - levels))
    return ((1.0 - 2.0 * sign) * q) * scale


def _luq_kernel(x_ref, up_ref, ur_ref, scale_ref, out_ref, *, levels: int):
    x = x_ref[...].astype(jnp.float32)
    up = up_ref[...].astype(jnp.float32)
    ur = ur_ref[...].astype(jnp.float32)
    scale = guard_scale(scale_ref[0, 0].astype(jnp.float32))
    sign = jnp.sign(x)
    m = jnp.abs(x) / scale
    min_level = 2.0 ** (-(levels - 1))
    below = m < min_level
    keep = up < (m / min_level)
    m_pruned = jnp.where(below, jnp.where(keep, min_level, 0.0), m)
    e = jnp.floor(jnp.log2(jnp.maximum(m_pruned, min_level)))
    f = m_pruned / jnp.exp2(e)
    e_hat = e + (ur < (f - 1.0)).astype(jnp.float32)
    q = jnp.where(m_pruned == 0.0, 0.0,
                  jnp.exp2(jnp.clip(e_hat, -(levels - 1), 0.0)))
    out_ref[...] = (sign * scale * q).astype(out_ref.dtype)


def luq_pallas(x, u_prune, u_round, bits: int, *, interpret: bool = True):
    """Elementwise over any shape; flattened to (R, COLS) tiles."""
    # lazy: core.__init__ transitively imports this module, so a top-level
    # import of core.quant would be circular from some entry points
    from repro.core.quant import luq_scale
    levels = 2 ** (bits - 1) - 1
    orig_shape, dtype = x.shape, x.dtype
    scale = luq_scale(x).reshape(1, 1)
    flat = x.reshape(-1)
    D = flat.shape[0]
    width = ROWS * COLS
    pad = (-D) % width
    if pad:
        flat = jnp.pad(flat, (0, pad))
        u_prune = jnp.pad(u_prune.reshape(-1), (0, pad))
        u_round = jnp.pad(u_round.reshape(-1), (0, pad))
    else:
        u_prune = u_prune.reshape(-1)
        u_round = u_round.reshape(-1)
    rows = flat.shape[0] // COLS
    x2 = flat.reshape(rows, COLS)
    up2 = u_prune.reshape(rows, COLS)
    ur2 = u_round.reshape(rows, COLS)
    grid = (rows // ROWS,)
    out = pl.pallas_call(
        functools.partial(_luq_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, COLS), dtype),
        interpret=interpret,
    )(x2, up2, ur2, scale)
    return out.reshape(-1)[:D].reshape(orig_shape)


# ---------------------------------------------------------------------------
# Code-emitting / code-consuming codec kernels (paged cold path + the
# codes-in fused round). Math mirrors core.paging.luq_encode_rows /
# luq_decode_rows expression-for-expression: under shared uniforms the
# interpret-mode output is BIT-IDENTICAL to the jnp oracle (pinned by
# tests/test_quant_codec.py / tests/test_quant_fused.py).
# ---------------------------------------------------------------------------

def _codec_tile(seg: int, k: int):
    """Lane tile for the codec grid: ``ENC_TILE`` when the per-shard
    segment is tile-aligned (always true on the engine path, where shard
    segments are multiples of the 2048-lane kernel tile), else the whole
    segment — an interpret-mode validation shape, not a TPU layout."""
    if seg % k:
        raise ValueError(f"segment width {seg} does not divide into "
                         f"{8 // k}-bit groups of {k}")
    return ENC_TILE if seg % ENC_TILE == 0 else seg


def _luq_encode_kernel(x_ref, up_ref, ur_ref, scale_ref, out_ref,
                       *, levels: int, bits: int):
    x = x_ref[...].astype(jnp.float32)
    up = up_ref[...].astype(jnp.float32)
    ur = ur_ref[...].astype(jnp.float32)
    scale = scale_ref[...].astype(jnp.float32)        # (R, 1), pre-guarded
    m = jnp.abs(x) / scale
    min_level = 2.0 ** (-(levels - 1))
    below = m < min_level
    keep = up < (m / min_level)
    m_pruned = jnp.where(below, jnp.where(keep, min_level, 0.0), m)
    e = jnp.floor(jnp.log2(jnp.maximum(m_pruned, min_level)))
    f = m_pruned / jnp.exp2(e)
    e_hat = jnp.clip(e + (ur < (f - 1.0)).astype(jnp.float32),
                     -(levels - 1), 0.0)
    midx = jnp.where(m_pruned == 0.0, 0, (e_hat + levels).astype(jnp.int32))
    sign = (x < 0).astype(jnp.int32)
    out_ref[...] = pack_block((sign << (bits - 1)) | midx, bits)


def _luq_decode_kernel(codes_ref, scale_ref, out_ref, *, bits: int):
    scale = scale_ref[...].astype(jnp.float32)        # (R, 1)
    v = dequant_block(codes_ref[...], scale, bits)
    out_ref[...] = v.astype(out_ref.dtype)


def luq_encode_pallas(x, u_prune, u_round, bits: int, *, shards: int = 1,
                      interpret: bool = True):
    """LUQ-encode (rows, D) to bit-packed codes + per-(row, shard) scales.

    The kernel-path twin of ``core.paging.luq_encode_rows``: given the SAME
    (rows, D) uniform fields it emits bit-identical packed codes and
    scales. The per-(row, shard) max-|x| scale is a cheap jnp reduction
    (identical to the oracle's); all elementwise math and the bit pack run
    in one VMEM pass per (8, tile) block, with the scale riding a (8, 1)
    block indexed by ``lane_tile // tiles_per_shard``."""
    levels = 2 ** (bits - 1) - 1
    rows, D = x.shape
    if D % shards:
        raise ValueError(f"D={D} does not divide into {shards} shards")
    seg = D // shards
    tile = _codec_tile(seg, 8 // bits)
    seg_tiles = seg // tile
    xf = x.astype(jnp.float32)
    scale = guard_scale(jnp.max(jnp.abs(xf.reshape(rows, shards, seg)),
                                axis=2))
    rpad = (-rows) % ENC_ROWS
    up = u_prune.astype(jnp.float32)
    ur = u_round.astype(jnp.float32)
    scale_p = scale
    if rpad:
        xf = jnp.pad(xf, ((0, rpad), (0, 0)))
        up = jnp.pad(up, ((0, rpad), (0, 0)))
        ur = jnp.pad(ur, ((0, rpad), (0, 0)))
        scale_p = jnp.pad(scale, ((0, rpad), (0, 0)), constant_values=1.0)
    rp = rows + rpad
    packed = pl.pallas_call(
        functools.partial(_luq_encode_kernel, levels=levels, bits=bits),
        grid=(rp // ENC_ROWS, D // tile),
        in_specs=[
            pl.BlockSpec((ENC_ROWS, tile), lambda i, c: (i, c)),
            pl.BlockSpec((ENC_ROWS, tile), lambda i, c: (i, c)),
            pl.BlockSpec((ENC_ROWS, tile), lambda i, c: (i, c)),
            pl.BlockSpec((ENC_ROWS, 1), lambda i, c: (i, c // seg_tiles)),
        ],
        out_specs=pl.BlockSpec((ENC_ROWS, tile * bits // 8),
                               lambda i, c: (i, c)),
        out_shape=jax.ShapeDtypeStruct((rp, D * bits // 8), jnp.uint8),
        interpret=interpret,
    )(xf, up, ur, scale_p)
    return {"codes": packed[:rows], "scale": scale}


def luq_decode_pallas(enc, bits: int, dtype, *, shards: int = 1,
                      interpret: bool = True):
    """Inverse of :func:`luq_encode_pallas` -> (rows, D) in ``dtype``;
    bit-identical to ``core.paging.luq_decode_rows`` on the same encoding.
    The unpack + dequant run in one VMEM pass per packed block."""
    codes, scale = enc["codes"], enc["scale"]
    rows, W = codes.shape
    k = 8 // bits
    D = W * k
    if D % shards:
        raise ValueError(f"D={D} does not divide into {shards} shards")
    seg = D // shards
    tile = _codec_tile(seg, k)
    seg_tiles = seg // tile
    rpad = (-rows) % ENC_ROWS
    scale_p = scale
    if rpad:
        codes = jnp.pad(codes, ((0, rpad), (0, 0)))
        scale_p = jnp.pad(scale, ((0, rpad), (0, 0)), constant_values=1.0)
    rp = rows + rpad
    out = pl.pallas_call(
        functools.partial(_luq_decode_kernel, bits=bits),
        grid=(rp // ENC_ROWS, D // tile),
        in_specs=[
            pl.BlockSpec((ENC_ROWS, tile * bits // 8), lambda i, c: (i, c)),
            pl.BlockSpec((ENC_ROWS, 1), lambda i, c: (i, c // seg_tiles)),
        ],
        out_specs=pl.BlockSpec((ENC_ROWS, tile), lambda i, c: (i, c)),
        out_shape=jax.ShapeDtypeStruct((rp, D), jnp.dtype(dtype)),
        interpret=interpret,
    )(codes, scale_p)
    return out[:rows]
