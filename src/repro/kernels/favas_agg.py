"""Pallas TPU kernel: fused FAVAS server aggregation (Algorithm 1 line 10 +
eq. 3 reweighting) over flattened parameter buffers.

Why a kernel: the aggregation touches every byte of every resident client's
parameters each server round and is purely memory-bound. Unfused HLO does
4+ passes per leaf (sub, div, add, mul-mask, reduce); this kernel streams
each (n, TILE) block through VMEM once: one HBM read per operand, one write.

VMEM budget @ TILE=2048, n<=64: 3 operand blocks * 64*2048*4B = 1.5 MiB +
out 8 KiB — comfortably inside ~16 MiB VMEM. The lane dim (TILE) is a
multiple of 128 for clean (8,128) vreg tiling; the client dim rides the
sublane axis.

Two entry points:

* ``favas_agg_pallas`` — the original single-output aggregation (line 10 only);
  kept for the leafwise ``ops.favas_aggregate_tree`` path and its tests.
* ``favas_fused_pallas`` — the full-round multi-output kernel used by the
  flat-buffer round engine (``core/round_engine.py``): one streamed pass per
  (n, TILE) block produces the new server tile AND the reset clients/inits
  tiles (Algorithm 1 lines 10–12), so the round does exactly one HBM read and
  one HBM write per resident byte instead of re-reading everything for the
  two reset passes.

VMEM budget for the fused kernel @ TILE=2048, n<=64, fp32: in blocks
(2n+1)*TILE*4B ≈ 1.06 MiB + out blocks ≈ 1.06 MiB — well inside ~16 MiB.

Validated with interpret=True on CPU against ``ref.favas_agg_ref`` /
``ref.favas_fused_ref``: the kernel body uses the same jnp expressions
(including true division) as the oracle, so fp32 parity holds to 1 ULP —
the only daylight is XLA compiling the two separately (FMA contraction,
blocked reductions).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 2048  # lane-dim tile; multiple of 128


def _agg_kernel(server_ref, clients_ref, inits_ref, coef_ref, mask_ref, out_ref,
                *, inv_s1: float):
    """One (n, TILE) block.
    coef = mask/alpha (n,1); mask (n,1); server/out (1, TILE)."""
    c = clients_ref[...].astype(jnp.float32)          # (n, T)
    i = inits_ref[...].astype(jnp.float32)            # (n, T)
    coef = coef_ref[...].astype(jnp.float32)          # (n, 1)
    m = mask_ref[...].astype(jnp.float32)             # (n, 1)
    # sum_i [ mask*init + (mask/alpha)*(client-init) ]
    total = jnp.sum(m * i + coef * (c - i), axis=0, keepdims=True)
    s = server_ref[...].astype(jnp.float32)           # (1, T)
    out_ref[...] = ((s + total) * inv_s1).astype(out_ref.dtype)


def favas_agg_pallas(server, clients, inits, alpha, mask, s: float,
                     *, interpret: bool = True):
    """server: (D,) f32/bf16; clients/inits: (n, D); alpha/mask: (n,)."""
    n, D = clients.shape
    pad = (-D) % TILE
    if pad:
        server = jnp.pad(server, (0, pad))
        clients = jnp.pad(clients, ((0, 0), (0, pad)))
        inits = jnp.pad(inits, ((0, 0), (0, pad)))
    Dp = D + pad
    coef = (mask / jnp.maximum(alpha, 1e-9)).astype(jnp.float32).reshape(n, 1)
    maskc = mask.astype(jnp.float32).reshape(n, 1)
    grid = (Dp // TILE,)
    out = pl.pallas_call(
        functools.partial(_agg_kernel, inv_s1=1.0 / (s + 1.0)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, TILE), lambda i: (0, i)),    # server (as (1,D))
            pl.BlockSpec((n, TILE), lambda i: (0, i)),    # clients
            pl.BlockSpec((n, TILE), lambda i: (0, i)),    # inits
            pl.BlockSpec((n, 1), lambda i: (0, 0)),       # coef
            pl.BlockSpec((n, 1), lambda i: (0, 0)),       # mask
        ],
        out_specs=pl.BlockSpec((1, TILE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Dp), server.dtype),
        interpret=interpret,
    )(server.reshape(1, Dp), clients, inits, coef, maskc)
    return out.reshape(Dp)[:D]


def _fused_kernel(server_ref, clients_ref, inits_ref, alpha_ref, mask_ref,
                  srv_out_ref, cli_out_ref, ini_out_ref, *, s1: float):
    """One (n, TILE) block of the full round update:
      msg_i   = init_i + (client_i - init_i) / alpha_i          (eq. 3)
      server' = (server + sum_i mask_i * msg_i) / (s+1)         (line 10)
      client' = mask_i ? server' : client_i                     (line 11)
      init'   = mask_i ? server' : init_i                       (line 12)
    alpha/mask (n, 1); server (1, TILE); clients/inits (n, TILE).
    All arithmetic in fp32; expressions mirror ref.favas_fused_ref exactly
    (true division, same reduction axis) so fp32 parity holds to 1 ULP."""
    c = clients_ref[...].astype(jnp.float32)          # (n, T)
    i = inits_ref[...].astype(jnp.float32)            # (n, T)
    a = alpha_ref[...].astype(jnp.float32)            # (n, 1)
    m = mask_ref[...].astype(jnp.float32)             # (n, 1)
    msg = i + (c - i) / a
    total = jnp.sum(m * msg, axis=0, keepdims=True)   # (1, T)
    s_new = (server_ref[...].astype(jnp.float32) + total) / s1
    srv_out_ref[...] = s_new.astype(srv_out_ref.dtype)
    cli_out_ref[...] = (m * s_new + (1.0 - m) * c).astype(cli_out_ref.dtype)
    ini_out_ref[...] = (m * s_new + (1.0 - m) * i).astype(ini_out_ref.dtype)


def _fused_kernel_prog(server_ref, clients_ref, inits_ref, prog_ref, alpha_ref,
                       mask_ref, srv_out_ref, cli_out_ref, ini_out_ref,
                       *, s1: float):
    """FAVAS[QNN] variant: the transmitted progress is supplied explicitly
    (already quantized), msg_i = init_i + prog_i / alpha_i, while the client
    reset keeps the client's own full-precision state — quantization is
    communication-only (paper Remark 1)."""
    c = clients_ref[...].astype(jnp.float32)          # (n, T)
    i = inits_ref[...].astype(jnp.float32)            # (n, T)
    p = prog_ref[...].astype(jnp.float32)             # (n, T)
    a = alpha_ref[...].astype(jnp.float32)            # (n, 1)
    m = mask_ref[...].astype(jnp.float32)             # (n, 1)
    msg = i + p / a
    total = jnp.sum(m * msg, axis=0, keepdims=True)   # (1, T)
    s_new = (server_ref[...].astype(jnp.float32) + total) / s1
    srv_out_ref[...] = s_new.astype(srv_out_ref.dtype)
    cli_out_ref[...] = (m * s_new + (1.0 - m) * c).astype(cli_out_ref.dtype)
    ini_out_ref[...] = (m * s_new + (1.0 - m) * i).astype(ini_out_ref.dtype)


def favas_fused_pallas(server, clients, inits, alpha, mask, s: float,
                       *, progress=None, interpret: bool = True):
    """Fused aggregation + selected-client reset over flat buffers.

    server: (D,) f32/bf16; clients/inits: (n, D); alpha/mask: (n,).
    ``progress``: optional (n, D) explicit transmitted progress (e.g. LUQ-
    quantized client deltas); None means progress = clients - inits,
    computed in-kernel. Client resets always use ``clients`` (full
    precision) — ``progress`` affects only the transmitted message.
    Returns (server_new (D,), clients_new (n, D), inits_new (n, D))."""
    n, D = clients.shape
    pad = (-D) % TILE
    if pad:
        server = jnp.pad(server, (0, pad))
        clients = jnp.pad(clients, ((0, 0), (0, pad)))
        inits = jnp.pad(inits, ((0, 0), (0, pad)))
        if progress is not None:
            progress = jnp.pad(progress, ((0, 0), (0, pad)))
    Dp = D + pad
    alphac = jnp.maximum(alpha.astype(jnp.float32), 1e-9).reshape(n, 1)
    maskc = mask.astype(jnp.float32).reshape(n, 1)
    grid = (Dp // TILE,)
    row_spec = pl.BlockSpec((n, TILE), lambda i: (0, i))
    scalar_spec = pl.BlockSpec((n, 1), lambda i: (0, 0))
    srv_spec = pl.BlockSpec((1, TILE), lambda i: (0, i))
    if progress is None:
        kernel = functools.partial(_fused_kernel, s1=float(s) + 1.0)
        in_specs = [srv_spec, row_spec, row_spec, scalar_spec, scalar_spec]
        operands = (server.reshape(1, Dp), clients, inits, alphac, maskc)
    else:
        kernel = functools.partial(_fused_kernel_prog, s1=float(s) + 1.0)
        in_specs = [srv_spec, row_spec, row_spec, row_spec, scalar_spec,
                    scalar_spec]
        operands = (server.reshape(1, Dp), clients, inits, progress, alphac,
                    maskc)
    srv, cli, ini = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            srv_spec,
            row_spec,
            row_spec,
        ),
        out_shape=(
            jax.ShapeDtypeStruct((1, Dp), server.dtype),
            jax.ShapeDtypeStruct((n, Dp), clients.dtype),
            jax.ShapeDtypeStruct((n, Dp), inits.dtype),
        ),
        interpret=interpret,
    )(*operands)
    return srv.reshape(Dp)[:D], cli[:, :D], ini[:, :D]
