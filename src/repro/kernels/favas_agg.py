"""Pallas TPU kernel: fused FAVAS server aggregation (Algorithm 1 line 10 +
eq. 3 reweighting) over flattened parameter buffers.

Why a kernel: the aggregation touches every byte of every resident client's
parameters each server round and is purely memory-bound. Unfused HLO does
4+ passes per leaf (sub, div, add, mul-mask, reduce); this kernel streams
each (CLIENT_TILE, TILE) block through VMEM once per sweep: one HBM read
per operand per sweep, one write.

Tiling. The lane dim is tiled at ``TILE`` (multiple of 128 for clean
(8, 128) vreg tiling). The client dim rides the sublane axis and is tiled
at ``CLIENT_TILE`` rows: a second grid dimension streams client-row blocks
through a VMEM scratch accumulator, so the number of resident clients ``n``
can scale to thousands while VMEM stays bounded at O(CLIENT_TILE * TILE).
For ``n <= CLIENT_TILE`` the whole client axis fits one block and the
single-sweep resident kernels below are used unchanged.

VMEM budget for the tiled fused kernel @ TILE=2048, CLIENT_TILE=32, fp32,
independent of n and D: in blocks (2*CT+1)*TILE*4B + (CT,1) scalars, out
blocks (2*CT+1)*TILE*4B, two (1, TILE) f32 scratch rows — about 1.03 MiB
total (1.29 MiB with the explicit-progress operand), comfortably inside
~16 MiB VMEM even with double buffering. ``fused_block_vmem_bytes`` computes
this number from the declared block shapes; tests pin it under 2 MiB for
the production shape (n=1024, D=2^20). The resident small-n kernels keep the
PR-1 budget: (2n+1)*TILE*4B in + out ≈ 2.1 MiB at n=64.

Grid schedule of the tiled fused kernel, for each lane tile i (outer grid
dim, "arbitrary" sequential semantics):

* phase 0 (inner grid steps j = 0..nb-1): client block j streams through
  VMEM; its masked message partial sum accumulates into a (1, TILE) f32
  scratch row; the clients/inits out tiles pass the inputs through (already
  final for unselected rows). A ``@pl.when`` epilogue on the last client
  block folds in the server row and stores the new server tile to a second
  scratch row and to the server output.
* phase 1 (j = nb..2*nb-1): client block j-nb streams through again and the
  per-block client/init reset tiles are emitted from the scratch server row
  (line 11-12 selects between the new server and the untouched state).

So the round moves 2 HBM reads + 2 writes per resident client byte at any
n — versus the seed's ~6 passes, and versus 1+1 for the resident small-n
kernel (which remains the dispatch below CLIENT_TILE).

``favas_agg_pallas`` (the original single-output aggregation, kept for the
leafwise ``ops.favas_aggregate_tree`` path) needs no reset phase, so its
tiled variant is a single sweep: accumulate, then one ``@pl.when`` epilogue
emits the server tile once the last client block has streamed through.

The client axis is padded to a CLIENT_TILE multiple with zero rows, zero
mask and unit alpha, so padded rows contribute exactly 0.0 to the masked
sum (adding 0.0 is exact in fp32 — no parity impact). The flat-buffer
engine (``core/round_engine.py``) pre-pads both axes so the kernel path
never re-pads.

Validated with interpret=True on CPU against ``ref.favas_agg_ref`` /
``ref.favas_fused_ref``: the kernel body uses the same jnp expressions
(including true division) as the oracle. The resident kernels reduce over
the same (n, TILE) block as the oracle, so fp32 parity holds to 1 ULP; the
tiled kernels accumulate per-block partial sums sequentially, which
reorders the client reduction — parity then holds to ~1 ULP *of the
accumulator magnitude* (tests bound |kernel - oracle| by ULPs of
|server| + sum_i |mask_i * msg_i| per lane, before the 1/(s+1) division).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.luq import dequant_block

TILE = 2048        # lane-dim tile; multiple of 128
CLIENT_TILE = 32   # sublane-dim tile over client rows; multiple of 8


def _pad_clients(n: int, client_tile: int, arrays, alpha, mask):
    """Zero-pad the client axis to a CLIENT_TILE multiple: zero rows, zero
    mask, unit alpha — exact no-ops under the masked sum."""
    rpad = (-n) % client_tile
    if rpad:
        arrays = [a if a is None else jnp.pad(a, ((0, rpad), (0, 0)))
                  for a in arrays]
        alpha = jnp.pad(alpha, (0, rpad), constant_values=1.0)
        mask = jnp.pad(mask, (0, rpad))
    return n + rpad, arrays, alpha, mask


def fused_block_vmem_bytes(n: int, dtype, *, progress: bool = False,
                           codec_bits: int = 0, tile: int = TILE,
                           client_tile: int = CLIENT_TILE,
                           schedule: str = "two_sweep",
                           double_buffered: bool = False) -> int:
    """Per-grid-step VMEM footprint of ``favas_fused_pallas`` computed from
    the declared BlockSpec shapes (inputs + outputs + scratch). For the
    tiled path (n > client_tile) this is independent of both n and D —
    the property that lets the engine scale to thousands of clients.

    ``codec_bits`` > 0 accounts the CODES-IN progress operand instead of a
    dense row block: a bit-packed (rows, tile*bits/8) uint8 codes block
    plus a (rows, 1) f32 scale block — the codec term of docs/
    architecture.md §10. At n=1024/fp32/bits=8 the total stays ~1.1 MiB
    (vs 1.29 MiB for the dense-progress operand), pinned < 2 MiB by
    tests/test_quant_fused.py.

    ``schedule="streamed"`` accounts the single-sweep aggregation-only
    kernel (``favas_stream_pallas``, docs/architecture.md §13): no
    client/init out blocks (the churn-bounded reset happens outside the
    kernel) and a single f32 accumulator scratch row. ``double_buffered``
    makes the pipeline's double buffering EXPLICIT in the budget: the grid
    pipeline keeps two copies of every in/out block resident (fetching
    block j+1 while block j computes), so the honest peak footprint is
    2x the block bytes (scratch rows are not pipelined and stay single).
    The default (two_sweep, single-buffer) keeps the historical number
    that tests pin."""
    if progress and codec_bits:
        raise ValueError("progress and codec_bits are mutually exclusive")
    if schedule not in ("two_sweep", "streamed"):
        raise ValueError(f"unknown schedule {schedule!r}")
    itemsize = jnp.dtype(dtype).itemsize
    rows = min(n, client_tile)
    row_block = rows * tile * itemsize          # clients / inits / progress
    srv_block = tile * itemsize                 # (1, TILE) server row
    scalar_block = rows * 4                     # (rows, 1) f32 alpha / mask
    n_row_in = 3 if progress else 2
    inputs = srv_block + n_row_in * row_block + 2 * scalar_block
    if codec_bits:
        inputs += rows * tile * codec_bits // 8  # packed progress codes
        inputs += rows * 4                       # (rows, 1) f32 scale block
    if schedule == "streamed":
        outputs = srv_block                      # server row only
        scratch = tile * 4 if n > client_tile else 0      # f32 acc
    else:
        outputs = srv_block + 2 * row_block      # server + client/init tiles
        scratch = 2 * tile * 4 if n > client_tile else 0  # acc + new-server
    if double_buffered:
        inputs, outputs = 2 * inputs, 2 * outputs
    return inputs + outputs + scratch


# ---------------------------------------------------------------------------
# Single-output aggregation (ops.favas_aggregate_tree path)
# ---------------------------------------------------------------------------

def _agg_kernel(server_ref, clients_ref, inits_ref, coef_ref, mask_ref, out_ref,
                *, inv_s1: float):
    """One resident (n, TILE) block.
    coef = mask/alpha (n,1); mask (n,1); server/out (1, TILE)."""
    c = clients_ref[...].astype(jnp.float32)          # (n, T)
    i = inits_ref[...].astype(jnp.float32)            # (n, T)
    coef = coef_ref[...].astype(jnp.float32)          # (n, 1)
    m = mask_ref[...].astype(jnp.float32)             # (n, 1)
    # sum_i [ mask*init + (mask/alpha)*(client-init) ]
    total = jnp.sum(m * i + coef * (c - i), axis=0, keepdims=True)
    s = server_ref[...].astype(jnp.float32)           # (1, T)
    out_ref[...] = ((s + total) * inv_s1).astype(out_ref.dtype)


def _agg_kernel_tiled(server_ref, clients_ref, inits_ref, coef_ref, mask_ref,
                      out_ref, acc_ref, *, inv_s1: float, n_blocks: int):
    """One (CLIENT_TILE, TILE) client block; partial sums accumulate in the
    f32 scratch row, the epilogue emits the server tile after the last
    client block has streamed through."""
    j = pl.program_id(1)
    c = clients_ref[...].astype(jnp.float32)          # (CT, T)
    i = inits_ref[...].astype(jnp.float32)            # (CT, T)
    coef = coef_ref[...].astype(jnp.float32)          # (CT, 1)
    m = mask_ref[...].astype(jnp.float32)             # (CT, 1)
    part = jnp.sum(m * i + coef * (c - i), axis=0, keepdims=True)

    @pl.when(j == 0)
    def _():
        acc_ref[...] = part

    @pl.when(j > 0)
    def _():
        acc_ref[...] = acc_ref[...] + part

    @pl.when(j == n_blocks - 1)
    def _():
        s = server_ref[...].astype(jnp.float32)       # (1, T)
        out_ref[...] = ((s + acc_ref[...]) * inv_s1).astype(out_ref.dtype)


def favas_agg_pallas(server, clients, inits, alpha, mask, s: float,
                     *, client_tile: int | None = None,
                     interpret: bool = True):
    """Single-output FAVAS aggregation kernel (Algorithm 1 line 10 + eq. 3).

    Args:
      server: (D,) f32/bf16 current server vector.
      clients / inits: (n, D) stacked client / last-reset buffers.
      alpha: (n,) eq. 3 reweight coefficients (clamped at 1e-9).
      mask: (n,) 0/1 selection mask for this round's polled set.
      s: |S_t| — the aggregation divides by ``s + 1``.
      client_tile: sublane rows per client block (default ``CLIENT_TILE``);
        ``n <= client_tile`` keeps the whole client axis resident in one
        block, larger n streams blocks through the VMEM accumulator.
      interpret: run the kernel in Pallas interpret mode (CPU validation);
        pass False on TPU for the compiled kernel.

    Returns the (D,) new server vector in the server's dtype. Lane padding
    to ``TILE`` happens here if D is unaligned (the flat-buffer engine
    pre-pads so this is a no-op on the engine path)."""
    n, D = clients.shape
    ct = client_tile or CLIENT_TILE
    pad = (-D) % TILE
    if pad:
        server = jnp.pad(server, (0, pad))
        clients = jnp.pad(clients, ((0, 0), (0, pad)))
        inits = jnp.pad(inits, ((0, 0), (0, pad)))
    Dp = D + pad
    if n <= ct:                                   # whole client axis resident
        coef = (mask / jnp.maximum(alpha, 1e-9)).astype(jnp.float32).reshape(n, 1)
        maskc = mask.astype(jnp.float32).reshape(n, 1)
        out = pl.pallas_call(
            functools.partial(_agg_kernel, inv_s1=1.0 / (s + 1.0)),
            grid=(Dp // TILE,),
            in_specs=[
                pl.BlockSpec((1, TILE), lambda i: (0, i)),    # server (as (1,D))
                pl.BlockSpec((n, TILE), lambda i: (0, i)),    # clients
                pl.BlockSpec((n, TILE), lambda i: (0, i)),    # inits
                pl.BlockSpec((n, 1), lambda i: (0, 0)),       # coef
                pl.BlockSpec((n, 1), lambda i: (0, 0)),       # mask
            ],
            out_specs=pl.BlockSpec((1, TILE), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((1, Dp), server.dtype),
            interpret=interpret,
        )(server.reshape(1, Dp), clients, inits, coef, maskc)
        return out.reshape(Dp)[:D]

    npad, (clients, inits), alpha, mask = _pad_clients(
        n, ct, (clients, inits), alpha, mask)
    nb = npad // ct
    coef = (mask / jnp.maximum(alpha, 1e-9)).astype(jnp.float32).reshape(npad, 1)
    maskc = mask.astype(jnp.float32).reshape(npad, 1)
    out = pl.pallas_call(
        functools.partial(_agg_kernel_tiled, inv_s1=1.0 / (s + 1.0),
                          n_blocks=nb),
        grid=(Dp // TILE, nb),
        in_specs=[
            pl.BlockSpec((1, TILE), lambda i, j: (0, i)),     # server
            pl.BlockSpec((ct, TILE), lambda i, j: (j, i)),    # clients
            pl.BlockSpec((ct, TILE), lambda i, j: (j, i)),    # inits
            pl.BlockSpec((ct, 1), lambda i, j: (j, 0)),       # coef
            pl.BlockSpec((ct, 1), lambda i, j: (j, 0)),       # mask
        ],
        out_specs=pl.BlockSpec((1, TILE), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Dp), server.dtype),
        scratch_shapes=[pltpu.VMEM((1, TILE), jnp.float32)],
        interpret=interpret,
    )(server.reshape(1, Dp), clients, inits, coef, maskc)
    return out.reshape(Dp)[:D]


# ---------------------------------------------------------------------------
# Fused full-round kernels (aggregation + selected-client reset)
# ---------------------------------------------------------------------------

def _fused_kernel(server_ref, clients_ref, inits_ref, alpha_ref, mask_ref,
                  srv_out_ref, cli_out_ref, ini_out_ref, *, s1: float):
    """One resident (n, TILE) block of the full round update:
      msg_i   = init_i + (client_i - init_i) / alpha_i          (eq. 3)
      server' = (server + sum_i mask_i * msg_i) / (s+1)         (line 10)
      client' = mask_i ? server' : client_i                     (line 11)
      init'   = mask_i ? server' : init_i                       (line 12)
    alpha/mask (n, 1); server (1, TILE); clients/inits (n, TILE).
    All arithmetic in fp32; expressions mirror ref.favas_fused_ref exactly
    (true division, same reduction axis) so fp32 parity holds to 1 ULP."""
    c = clients_ref[...].astype(jnp.float32)          # (n, T)
    i = inits_ref[...].astype(jnp.float32)            # (n, T)
    a = alpha_ref[...].astype(jnp.float32)            # (n, 1)
    m = mask_ref[...].astype(jnp.float32)             # (n, 1)
    msg = i + (c - i) / a
    total = jnp.sum(m * msg, axis=0, keepdims=True)   # (1, T)
    s_new = (server_ref[...].astype(jnp.float32) + total) / s1
    srv_out_ref[...] = s_new.astype(srv_out_ref.dtype)
    cli_out_ref[...] = (m * s_new + (1.0 - m) * c).astype(cli_out_ref.dtype)
    ini_out_ref[...] = (m * s_new + (1.0 - m) * i).astype(ini_out_ref.dtype)


def _fused_kernel_prog(server_ref, clients_ref, inits_ref, prog_ref, alpha_ref,
                       mask_ref, srv_out_ref, cli_out_ref, ini_out_ref,
                       *, s1: float):
    """FAVAS[QNN] variant: the transmitted progress is supplied explicitly
    (already quantized), msg_i = init_i + prog_i / alpha_i, while the client
    reset keeps the client's own full-precision state — quantization is
    communication-only (paper Remark 1)."""
    c = clients_ref[...].astype(jnp.float32)          # (n, T)
    i = inits_ref[...].astype(jnp.float32)            # (n, T)
    p = prog_ref[...].astype(jnp.float32)             # (n, T)
    a = alpha_ref[...].astype(jnp.float32)            # (n, 1)
    m = mask_ref[...].astype(jnp.float32)             # (n, 1)
    msg = i + p / a
    total = jnp.sum(m * msg, axis=0, keepdims=True)   # (1, T)
    s_new = (server_ref[...].astype(jnp.float32) + total) / s1
    srv_out_ref[...] = s_new.astype(srv_out_ref.dtype)
    cli_out_ref[...] = (m * s_new + (1.0 - m) * c).astype(cli_out_ref.dtype)
    ini_out_ref[...] = (m * s_new + (1.0 - m) * i).astype(ini_out_ref.dtype)


def _fused_kernel_codes(server_ref, clients_ref, inits_ref, codes_ref,
                        pscale_ref, alpha_ref, mask_ref, srv_out_ref,
                        cli_out_ref, ini_out_ref, *, s1: float, bits: int):
    """CODES-IN FAVAS[QNN] variant: the transmitted progress arrives as a
    bit-packed (n, T*bits/8) uint8 block + (n, 1) f32 scales and is
    dequantized HERE, inside the VMEM pass — ``msg_i = init_i +
    dequant(code_i) / alpha_i`` — so the dense (n, D) f32 progress buffer
    never exists. Resets keep the client's own full-precision state
    (quantization is communication-only, paper Remark 1)."""
    c = clients_ref[...].astype(jnp.float32)          # (n, T)
    i = inits_ref[...].astype(jnp.float32)            # (n, T)
    a = alpha_ref[...].astype(jnp.float32)            # (n, 1)
    m = mask_ref[...].astype(jnp.float32)             # (n, 1)
    p = dequant_block(codes_ref[...],
                      pscale_ref[...].astype(jnp.float32), bits)
    msg = i + p / a
    total = jnp.sum(m * msg, axis=0, keepdims=True)   # (1, T)
    s_new = (server_ref[...].astype(jnp.float32) + total) / s1
    srv_out_ref[...] = s_new.astype(srv_out_ref.dtype)
    cli_out_ref[...] = (m * s_new + (1.0 - m) * c).astype(cli_out_ref.dtype)
    ini_out_ref[...] = (m * s_new + (1.0 - m) * i).astype(ini_out_ref.dtype)


def _fused_kernel_tiled(server_ref, clients_ref, inits_ref, alpha_ref,
                        mask_ref, srv_out_ref, cli_out_ref, ini_out_ref,
                        acc_ref, snew_ref, *, s1: float, n_blocks: int,
                        has_progress: bool, prog_ref=None,
                        codes_ref=None, pscale_ref=None, bits: int = 0):
    """Two-phase sweep over (CLIENT_TILE, TILE) client blocks — see the
    module docstring for the schedule. ``prog_ref`` is bound (via the
    dispatcher's wrapper kernel) only for the dense FAVAS[QNN] variant;
    ``codes_ref``/``pscale_ref`` only for the codes-in variant, which
    dequantizes the packed progress block in-VMEM during phase 0."""
    j = pl.program_id(1)
    c = clients_ref[...].astype(jnp.float32)          # (CT, T)
    i = inits_ref[...].astype(jnp.float32)            # (CT, T)
    m = mask_ref[...].astype(jnp.float32)             # (CT, 1)

    @pl.when(j < n_blocks)
    def _accumulate():
        a = alpha_ref[...].astype(jnp.float32)        # (CT, 1)
        if has_progress:
            p = prog_ref[...].astype(jnp.float32)
        elif codes_ref is not None:
            p = dequant_block(codes_ref[...],
                              pscale_ref[...].astype(jnp.float32), bits)
        else:
            p = c - i
        msg = i + p / a
        part = jnp.sum(m * msg, axis=0, keepdims=True)

        @pl.when(j == 0)
        def _():
            acc_ref[...] = part

        @pl.when(j > 0)
        def _():
            acc_ref[...] = acc_ref[...] + part

        # pass the state through so every flushed out tile holds valid data
        # (already final for rows this phase doesn't reset)
        cli_out_ref[...] = c.astype(cli_out_ref.dtype)
        ini_out_ref[...] = i.astype(ini_out_ref.dtype)

        @pl.when(j == n_blocks - 1)
        def _epilogue():
            s_new = (server_ref[...].astype(jnp.float32) + acc_ref[...]) / s1
            snew_ref[...] = s_new
            srv_out_ref[...] = s_new.astype(srv_out_ref.dtype)

    @pl.when(j >= n_blocks)
    def _reset():
        s_new = snew_ref[...]                         # (1, T) f32
        cli_out_ref[...] = (m * s_new + (1.0 - m) * c).astype(cli_out_ref.dtype)
        ini_out_ref[...] = (m * s_new + (1.0 - m) * i).astype(ini_out_ref.dtype)


def favas_fused_pallas(server, clients, inits, alpha, mask, s: float,
                       *, progress=None, progress_codes=None,
                       progress_bits: int = 0, progress_shards: int = 1,
                       client_tile: int | None = None,
                       interpret: bool = True):
    """Fused aggregation + selected-client reset over flat buffers.

    server: (D,) f32/bf16; clients/inits: (n, D); alpha/mask: (n,).
    ``progress``: optional (n, D) explicit transmitted progress (e.g. LUQ-
    quantized client deltas); None means progress = clients - inits,
    computed in-kernel. ``progress_codes`` (mutually exclusive): the
    transmitted progress as ``{"codes": (n, D*bits/8) uint8, "scale":
    (n, shards) f32}`` — dequantized INSIDE the per-tile VMEM pass, so the
    dense (n, D) f32 progress never materializes; ``progress_bits`` is the
    LUQ width, ``progress_shards`` the per-row scale count (shard segments
    must be TILE-aligned when > 1 — guaranteed on the engine path by the
    per-shard lane padding). Client resets always use ``clients`` (full
    precision) — both progress forms affect only the transmitted message.
    ``client_tile``: sublane rows per client block (default CLIENT_TILE);
    n <= client_tile keeps the whole client axis resident in one block.
    Returns (server_new (D,), clients_new (n, D), inits_new (n, D))."""
    n, D = clients.shape
    ct = client_tile or CLIENT_TILE
    pad = (-D) % TILE
    codes = pscale = None
    bits = progress_bits
    if progress_codes is not None:
        if progress is not None:
            raise ValueError("progress and progress_codes are mutually "
                             "exclusive")
        if bits not in (2, 4, 8):
            raise ValueError(f"progress_bits must be 2, 4 or 8 (got {bits})")
        if D % progress_shards:
            raise ValueError(f"D={D} does not divide into "
                             f"{progress_shards} shards")
        if progress_shards > 1 and (D // progress_shards) % TILE:
            raise ValueError(
                f"codes-in progress needs TILE-aligned shard segments "
                f"(D={D}, shards={progress_shards}, tile={TILE})")
        codes, pscale = progress_codes["codes"], progress_codes["scale"]
    if pad:
        server = jnp.pad(server, (0, pad))
        clients = jnp.pad(clients, ((0, 0), (0, pad)))
        inits = jnp.pad(inits, ((0, 0), (0, pad)))
        if progress is not None:
            progress = jnp.pad(progress, ((0, 0), (0, pad)))
        if codes is not None:
            # zero codes decode to exact zeros — the padded lanes transmit
            # nothing, matching the zero-padded dense operands
            codes = jnp.pad(codes, ((0, 0), (0, pad * bits // 8)))
    Dp = D + pad
    # lane tiles per shard segment: the (rows, 1) scale block for lane tile
    # i sits at column i // seg_tiles (shards == 1 makes this column 0)
    seg_tiles = (Dp // progress_shards) // TILE if codes is not None else 1

    if n <= ct:                                   # whole client axis resident
        alphac = jnp.maximum(alpha.astype(jnp.float32), 1e-9).reshape(n, 1)
        maskc = mask.astype(jnp.float32).reshape(n, 1)
        row_spec = pl.BlockSpec((n, TILE), lambda i: (0, i))
        scalar_spec = pl.BlockSpec((n, 1), lambda i: (0, 0))
        srv_spec = pl.BlockSpec((1, TILE), lambda i: (0, i))
        if codes is not None:
            kernel = functools.partial(_fused_kernel_codes,
                                       s1=float(s) + 1.0, bits=bits)
            in_specs = [srv_spec, row_spec, row_spec,
                        pl.BlockSpec((n, TILE * bits // 8),
                                     lambda i: (0, i)),
                        pl.BlockSpec((n, 1),
                                     lambda i: (0, i // seg_tiles)),
                        scalar_spec, scalar_spec]
            operands = (server.reshape(1, Dp), clients, inits, codes,
                        pscale, alphac, maskc)
        elif progress is None:
            kernel = functools.partial(_fused_kernel, s1=float(s) + 1.0)
            in_specs = [srv_spec, row_spec, row_spec, scalar_spec, scalar_spec]
            operands = (server.reshape(1, Dp), clients, inits, alphac, maskc)
        else:
            kernel = functools.partial(_fused_kernel_prog, s1=float(s) + 1.0)
            in_specs = [srv_spec, row_spec, row_spec, row_spec, scalar_spec,
                        scalar_spec]
            operands = (server.reshape(1, Dp), clients, inits, progress,
                        alphac, maskc)
        srv, cli, ini = pl.pallas_call(
            kernel,
            grid=(Dp // TILE,),
            in_specs=in_specs,
            out_specs=(srv_spec, row_spec, row_spec),
            out_shape=(
                jax.ShapeDtypeStruct((1, Dp), server.dtype),
                jax.ShapeDtypeStruct((n, Dp), clients.dtype),
                jax.ShapeDtypeStruct((n, Dp), inits.dtype),
            ),
            interpret=interpret,
        )(*operands)
        return srv.reshape(Dp)[:D], cli[:, :D], ini[:, :D]

    npad, (clients, inits, progress, codes, pscale), alpha, mask = \
        _pad_clients(n, ct, (clients, inits, progress, codes, pscale),
                     alpha, mask)
    nb = npad // ct
    alphac = jnp.maximum(alpha.astype(jnp.float32), 1e-9).reshape(npad, 1)
    maskc = mask.astype(jnp.float32).reshape(npad, 1)
    # two-phase inner grid dim: j in [0, nb) accumulates, [nb, 2nb) resets
    row_spec = pl.BlockSpec((ct, TILE), lambda i, j: (j % nb, i))
    scalar_spec = pl.BlockSpec((ct, 1), lambda i, j: (j % nb, 0))
    srv_spec = pl.BlockSpec((1, TILE), lambda i, j: (0, i))
    if codes is not None:
        # bind codes/scale as trailing positional refs via a wrapper (same
        # pattern as the dense-progress variant below)
        def kernel(server_ref, clients_ref, inits_ref, codes_ref, pscale_ref,
                   alpha_ref, mask_ref, srv_out_ref, cli_out_ref, ini_out_ref,
                   acc_ref, snew_ref):
            return _fused_kernel_tiled(
                server_ref, clients_ref, inits_ref, alpha_ref, mask_ref,
                srv_out_ref, cli_out_ref, ini_out_ref, acc_ref, snew_ref,
                s1=float(s) + 1.0, n_blocks=nb, has_progress=False,
                codes_ref=codes_ref, pscale_ref=pscale_ref, bits=bits)
        # codes are only read in phase 0 — clamp the block index at the last
        # phase-0 block so phase 1 never re-fetches them (see prog_spec)
        codes_spec = pl.BlockSpec(
            (ct, TILE * bits // 8),
            lambda i, j: (jnp.minimum(j, nb - 1), i))
        pscale_spec = pl.BlockSpec(
            (ct, 1), lambda i, j: (jnp.minimum(j, nb - 1), i // seg_tiles))
        in_specs = [srv_spec, row_spec, row_spec, codes_spec, pscale_spec,
                    scalar_spec, scalar_spec]
        operands = (server.reshape(1, Dp), clients, inits, codes, pscale,
                    alphac, maskc)
    elif progress is None:
        kernel = functools.partial(_fused_kernel_tiled, s1=float(s) + 1.0,
                                   n_blocks=nb, has_progress=False)
        in_specs = [srv_spec, row_spec, row_spec, scalar_spec, scalar_spec]
        operands = (server.reshape(1, Dp), clients, inits, alphac, maskc)
    else:
        # bind prog_ref as the trailing positional ref via a wrapper so the
        # no-progress variant keeps a progress-free operand list
        def kernel(server_ref, clients_ref, inits_ref, prog_ref, alpha_ref,
                   mask_ref, srv_out_ref, cli_out_ref, ini_out_ref,
                   acc_ref, snew_ref):
            return _fused_kernel_tiled(
                server_ref, clients_ref, inits_ref, alpha_ref, mask_ref,
                srv_out_ref, cli_out_ref, ini_out_ref, acc_ref, snew_ref,
                s1=float(s) + 1.0, n_blocks=nb, has_progress=True,
                prog_ref=prog_ref)
        # progress is only read in phase 0: clamp its block index at the
        # last phase-0 block so the window never changes during phase 1 and
        # the pipeline skips the (otherwise redundant) re-fetch of every
        # progress block
        prog_spec = pl.BlockSpec((ct, TILE),
                                 lambda i, j: (jnp.minimum(j, nb - 1), i))
        in_specs = [srv_spec, row_spec, row_spec, prog_spec, scalar_spec,
                    scalar_spec]
        operands = (server.reshape(1, Dp), clients, inits, progress, alphac,
                    maskc)
    srv, cli, ini = pl.pallas_call(
        kernel,
        grid=(Dp // TILE, 2 * nb),
        in_specs=in_specs,
        out_specs=(
            srv_spec,
            pl.BlockSpec((ct, TILE), lambda i, j: (j % nb, i)),
            pl.BlockSpec((ct, TILE), lambda i, j: (j % nb, i)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((1, Dp), server.dtype),
            jax.ShapeDtypeStruct((npad, Dp), clients.dtype),
            jax.ShapeDtypeStruct((npad, Dp), inits.dtype),
        ),
        scratch_shapes=[pltpu.VMEM((1, TILE), jnp.float32),
                        pltpu.VMEM((1, TILE), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return srv.reshape(Dp)[:D], cli[:n, :D], ini[:n, :D]


# ---------------------------------------------------------------------------
# Streamed single-sweep aggregation (docs/architecture.md §13)
# ---------------------------------------------------------------------------
# The two-sweep fused kernel above reads every client block TWICE (phase 0
# accumulate, phase 1 reset) and rewrites every pass-through tile unchanged:
# ~2R+2W per resident client byte. The streamed schedule splits the round:
# this kernel does ONE pipelined sweep (the grid pipeline double-buffers the
# HBM->VMEM block stream, prefetching client block j+1 while block j's
# partial sum computes) and emits ONLY the new server row; the selected-
# client reset happens OUTSIDE as a churn-bounded scatter of that row into
# the s selected positions of the donated (aliased) client/init buffers —
# unselected rows are never read for the reset nor rewritten. Steady-state
# traffic drops to 1R per resident byte + O(s*D) scatter writes.
#
# Bit-exactness contract (why the split loses nothing): the selection mask
# is exactly the 0/1 indicator of the Gumbel top-s index set, so the fused
# reset `m*s_new + (1-m)*x` is `x` to the bit for unselected rows and
# `s_new.astype(dtype)` — exactly the row this kernel returns — for
# selected ones. The accumulation order matches `_fused_kernel_tiled`
# phase 0 block-for-block, so streamed-vs-two-sweep server parity is exact
# per dispatch path and kernel-vs-oracle parity bounds are unchanged.

def _stream_kernel(server_ref, clients_ref, inits_ref, alpha_ref, mask_ref,
                   srv_out_ref, *, s1: float, prog_ref=None, codes_ref=None,
                   pscale_ref=None, bits: int = 0):
    """One resident (n, TILE) block, aggregation only — the `msg`/`total`/
    `s_new` expressions of ``_fused_kernel`` (same reduction axis, true
    division), without the reset outputs."""
    c = clients_ref[...].astype(jnp.float32)          # (n, T)
    i = inits_ref[...].astype(jnp.float32)            # (n, T)
    a = alpha_ref[...].astype(jnp.float32)            # (n, 1)
    m = mask_ref[...].astype(jnp.float32)             # (n, 1)
    if prog_ref is not None:
        p = prog_ref[...].astype(jnp.float32)
    elif codes_ref is not None:
        p = dequant_block(codes_ref[...],
                          pscale_ref[...].astype(jnp.float32), bits)
    else:
        p = c - i
    msg = i + p / a
    total = jnp.sum(m * msg, axis=0, keepdims=True)   # (1, T)
    s_new = (server_ref[...].astype(jnp.float32) + total) / s1
    srv_out_ref[...] = s_new.astype(srv_out_ref.dtype)


def _stream_kernel_tiled(server_ref, clients_ref, inits_ref, alpha_ref,
                         mask_ref, srv_out_ref, acc_ref, *, s1: float,
                         n_blocks: int, prog_ref=None, codes_ref=None,
                         pscale_ref=None, bits: int = 0):
    """Single pipelined sweep over (CLIENT_TILE, TILE) client blocks: each
    block's masked message partial sum accumulates into the f32 scratch
    row (identical accumulation order to ``_fused_kernel_tiled`` phase 0),
    and the epilogue on the last block folds in the server row. No client/
    init outputs exist, so no pass-through tile is ever written back."""
    j = pl.program_id(1)
    c = clients_ref[...].astype(jnp.float32)          # (CT, T)
    i = inits_ref[...].astype(jnp.float32)            # (CT, T)
    a = alpha_ref[...].astype(jnp.float32)            # (CT, 1)
    m = mask_ref[...].astype(jnp.float32)             # (CT, 1)
    if prog_ref is not None:
        p = prog_ref[...].astype(jnp.float32)
    elif codes_ref is not None:
        p = dequant_block(codes_ref[...],
                          pscale_ref[...].astype(jnp.float32), bits)
    else:
        p = c - i
    msg = i + p / a
    part = jnp.sum(m * msg, axis=0, keepdims=True)

    @pl.when(j == 0)
    def _():
        acc_ref[...] = part

    @pl.when(j > 0)
    def _():
        acc_ref[...] = acc_ref[...] + part

    @pl.when(j == n_blocks - 1)
    def _epilogue():
        s_new = (server_ref[...].astype(jnp.float32) + acc_ref[...]) / s1
        srv_out_ref[...] = s_new.astype(srv_out_ref.dtype)


def favas_stream_pallas(server, clients, inits, alpha, mask, s: float,
                        *, progress=None, progress_codes=None,
                        progress_bits: int = 0, progress_shards: int = 1,
                        client_tile: int | None = None,
                        interpret: bool = True):
    """Aggregation-only half of the STREAMED round schedule.

    Same operand contract as ``favas_fused_pallas`` (server (D,), clients/
    inits (n, D), alpha/mask (n,), optional dense ``progress`` or packed
    ``progress_codes`` + ``progress_bits``/``progress_shards``), but
    returns ONLY the (D,) new server vector: the caller applies the
    selected-client reset as a churn-bounded scatter of this row into the
    donated state buffers (``core.round_engine.stream_bucket_update``).
    One HBM read per resident client byte, ~zero client-buffer writes."""
    n, D = clients.shape
    ct = client_tile or CLIENT_TILE
    pad = (-D) % TILE
    codes = pscale = None
    bits = progress_bits
    if progress_codes is not None:
        if progress is not None:
            raise ValueError("progress and progress_codes are mutually "
                             "exclusive")
        if bits not in (2, 4, 8):
            raise ValueError(f"progress_bits must be 2, 4 or 8 (got {bits})")
        if D % progress_shards:
            raise ValueError(f"D={D} does not divide into "
                             f"{progress_shards} shards")
        if progress_shards > 1 and (D // progress_shards) % TILE:
            raise ValueError(
                f"codes-in progress needs TILE-aligned shard segments "
                f"(D={D}, shards={progress_shards}, tile={TILE})")
        codes, pscale = progress_codes["codes"], progress_codes["scale"]
    if pad:
        server = jnp.pad(server, (0, pad))
        clients = jnp.pad(clients, ((0, 0), (0, pad)))
        inits = jnp.pad(inits, ((0, 0), (0, pad)))
        if progress is not None:
            progress = jnp.pad(progress, ((0, 0), (0, pad)))
        if codes is not None:
            codes = jnp.pad(codes, ((0, 0), (0, pad * bits // 8)))
    Dp = D + pad
    seg_tiles = (Dp // progress_shards) // TILE if codes is not None else 1

    if n <= ct:                                   # whole client axis resident
        alphac = jnp.maximum(alpha.astype(jnp.float32), 1e-9).reshape(n, 1)
        maskc = mask.astype(jnp.float32).reshape(n, 1)
        row_spec = pl.BlockSpec((n, TILE), lambda i: (0, i))
        scalar_spec = pl.BlockSpec((n, 1), lambda i: (0, 0))
        srv_spec = pl.BlockSpec((1, TILE), lambda i: (0, i))
        if codes is not None:
            def kernel(server_ref, clients_ref, inits_ref, codes_ref,
                       pscale_ref, alpha_ref, mask_ref, srv_out_ref):
                return _stream_kernel(
                    server_ref, clients_ref, inits_ref, alpha_ref, mask_ref,
                    srv_out_ref, s1=float(s) + 1.0,
                    codes_ref=codes_ref, pscale_ref=pscale_ref, bits=bits)
            in_specs = [srv_spec, row_spec, row_spec,
                        pl.BlockSpec((n, TILE * bits // 8),
                                     lambda i: (0, i)),
                        pl.BlockSpec((n, 1),
                                     lambda i: (0, i // seg_tiles)),
                        scalar_spec, scalar_spec]
            operands = (server.reshape(1, Dp), clients, inits, codes,
                        pscale, alphac, maskc)
        elif progress is None:
            kernel = functools.partial(_stream_kernel, s1=float(s) + 1.0)
            in_specs = [srv_spec, row_spec, row_spec, scalar_spec,
                        scalar_spec]
            operands = (server.reshape(1, Dp), clients, inits, alphac, maskc)
        else:
            def kernel(server_ref, clients_ref, inits_ref, prog_ref,
                       alpha_ref, mask_ref, srv_out_ref):
                return _stream_kernel(
                    server_ref, clients_ref, inits_ref, alpha_ref, mask_ref,
                    srv_out_ref, s1=float(s) + 1.0, prog_ref=prog_ref)
            in_specs = [srv_spec, row_spec, row_spec, row_spec, scalar_spec,
                        scalar_spec]
            operands = (server.reshape(1, Dp), clients, inits, progress,
                        alphac, maskc)
        srv = pl.pallas_call(
            kernel,
            grid=(Dp // TILE,),
            in_specs=in_specs,
            out_specs=srv_spec,
            out_shape=jax.ShapeDtypeStruct((1, Dp), server.dtype),
            interpret=interpret,
        )(*operands)
        return srv.reshape(Dp)[:D]

    npad, (clients, inits, progress, codes, pscale), alpha, mask = \
        _pad_clients(n, ct, (clients, inits, progress, codes, pscale),
                     alpha, mask)
    nb = npad // ct
    alphac = jnp.maximum(alpha.astype(jnp.float32), 1e-9).reshape(npad, 1)
    maskc = mask.astype(jnp.float32).reshape(npad, 1)
    # single-phase inner grid dim: j in [0, nb) — every block exactly once,
    # double-buffered by the grid pipeline (block j+1 prefetches during j)
    row_spec = pl.BlockSpec((ct, TILE), lambda i, j: (j, i))
    scalar_spec = pl.BlockSpec((ct, 1), lambda i, j: (j, 0))
    srv_spec = pl.BlockSpec((1, TILE), lambda i, j: (0, i))
    if codes is not None:
        def kernel(server_ref, clients_ref, inits_ref, codes_ref, pscale_ref,
                   alpha_ref, mask_ref, srv_out_ref, acc_ref):
            return _stream_kernel_tiled(
                server_ref, clients_ref, inits_ref, alpha_ref, mask_ref,
                srv_out_ref, acc_ref, s1=float(s) + 1.0, n_blocks=nb,
                codes_ref=codes_ref, pscale_ref=pscale_ref, bits=bits)
        in_specs = [srv_spec, row_spec, row_spec,
                    pl.BlockSpec((ct, TILE * bits // 8),
                                 lambda i, j: (j, i)),
                    pl.BlockSpec((ct, 1), lambda i, j: (j, i // seg_tiles)),
                    scalar_spec, scalar_spec]
        operands = (server.reshape(1, Dp), clients, inits, codes, pscale,
                    alphac, maskc)
    elif progress is None:
        kernel = functools.partial(_stream_kernel_tiled, s1=float(s) + 1.0,
                                   n_blocks=nb)
        in_specs = [srv_spec, row_spec, row_spec, scalar_spec, scalar_spec]
        operands = (server.reshape(1, Dp), clients, inits, alphac, maskc)
    else:
        def kernel(server_ref, clients_ref, inits_ref, prog_ref, alpha_ref,
                   mask_ref, srv_out_ref, acc_ref):
            return _stream_kernel_tiled(
                server_ref, clients_ref, inits_ref, alpha_ref, mask_ref,
                srv_out_ref, acc_ref, s1=float(s) + 1.0, n_blocks=nb,
                prog_ref=prog_ref)
        in_specs = [srv_spec, row_spec, row_spec, row_spec, scalar_spec,
                    scalar_spec]
        operands = (server.reshape(1, Dp), clients, inits, progress, alphac,
                    maskc)
    srv = pl.pallas_call(
        kernel,
        grid=(Dp // TILE, nb),
        in_specs=in_specs,
        out_specs=srv_spec,
        out_shape=jax.ShapeDtypeStruct((1, Dp), server.dtype),
        scratch_shapes=[pltpu.VMEM((1, TILE), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return srv.reshape(Dp)[:D]
