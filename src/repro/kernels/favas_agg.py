"""Pallas TPU kernel: fused FAVAS server aggregation (Algorithm 1 line 10 +
eq. 3 reweighting) over flattened parameter buffers.

Why a kernel: the aggregation touches every byte of every resident client's
parameters each server round and is purely memory-bound. Unfused HLO does
4+ passes per leaf (sub, div, add, mul-mask, reduce); this kernel streams
each (n, TILE) block through VMEM once: one HBM read per operand, one write.

VMEM budget @ TILE=2048, n<=64: 3 operand blocks * 64*2048*4B = 1.5 MiB +
out 8 KiB — comfortably inside ~16 MiB VMEM. The lane dim (TILE) is a
multiple of 128 for clean (8,128) vreg tiling; the client dim rides the
sublane axis.

Validated with interpret=True on CPU against ``ref.favas_agg_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 2048  # lane-dim tile; multiple of 128


def _agg_kernel(server_ref, clients_ref, inits_ref, coef_ref, mask_ref, out_ref,
                *, inv_s1: float):
    """One (n, TILE) block.
    coef = mask/alpha (n,1); mask (n,1); server/out (1, TILE)."""
    c = clients_ref[...].astype(jnp.float32)          # (n, T)
    i = inits_ref[...].astype(jnp.float32)            # (n, T)
    coef = coef_ref[...].astype(jnp.float32)          # (n, 1)
    m = mask_ref[...].astype(jnp.float32)             # (n, 1)
    # sum_i [ mask*init + (mask/alpha)*(client-init) ]
    total = jnp.sum(m * i + coef * (c - i), axis=0, keepdims=True)
    s = server_ref[...].astype(jnp.float32)           # (1, T)
    out_ref[...] = ((s + total) * inv_s1).astype(out_ref.dtype)


def favas_agg_pallas(server, clients, inits, alpha, mask, s: float,
                     *, interpret: bool = True):
    """server: (D,) f32/bf16; clients/inits: (n, D); alpha/mask: (n,)."""
    n, D = clients.shape
    pad = (-D) % TILE
    if pad:
        server = jnp.pad(server, (0, pad))
        clients = jnp.pad(clients, ((0, 0), (0, pad)))
        inits = jnp.pad(inits, ((0, 0), (0, pad)))
    Dp = D + pad
    coef = (mask / jnp.maximum(alpha, 1e-9)).astype(jnp.float32).reshape(n, 1)
    maskc = mask.astype(jnp.float32).reshape(n, 1)
    grid = (Dp // TILE,)
    out = pl.pallas_call(
        functools.partial(_agg_kernel, inv_s1=1.0 / (s + 1.0)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, TILE), lambda i: (0, i)),    # server (as (1,D))
            pl.BlockSpec((n, TILE), lambda i: (0, i)),    # clients
            pl.BlockSpec((n, TILE), lambda i: (0, i)),    # inits
            pl.BlockSpec((n, 1), lambda i: (0, 0)),       # coef
            pl.BlockSpec((n, 1), lambda i: (0, 0)),       # mask
        ],
        out_specs=pl.BlockSpec((1, TILE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Dp), server.dtype),
        interpret=interpret,
    )(server.reshape(1, Dp), clients, inits, coef, maskc)
    return out.reshape(Dp)[:D]
