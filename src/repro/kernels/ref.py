"""Pure-jnp oracles for the Pallas kernels. Tests assert_allclose the
kernels (interpret=True on CPU) against these across shape/dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def favas_agg_ref(server, clients, inits, alpha, mask, s: float):
    """Fused FAVAS server aggregation over flattened parameter buffers.

    server: (D,), clients/inits: (n, D), alpha/mask: (n,).
    out = (server + sum_i mask_i * (init_i + (client_i - init_i)/alpha_i)) / (s+1)
    """
    a = alpha[:, None].astype(jnp.float32)
    m = mask[:, None].astype(jnp.float32)
    msg = inits.astype(jnp.float32) + (clients.astype(jnp.float32)
                                       - inits.astype(jnp.float32)) / a
    total = jnp.sum(m * msg, axis=0)
    return ((server.astype(jnp.float32) + total) / (s + 1.0)).astype(server.dtype)


def favas_fused_ref(server, clients, inits, alpha, mask, s: float,
                    *, progress=None):
    """Full-round fused oracle: aggregation (line 10) + selected-client reset
    (lines 11–12) over flat buffers. Mirrors ``favas_agg._fused_kernel``
    expression-for-expression, so kernel parity holds to 1 fp32 ULP.

    server: (D,), clients/inits: (n, D), alpha/mask: (n,). ``progress``:
    optional explicit (quantized) transmitted progress; None means
    clients - inits. Resets always use full-precision ``clients``.
    Returns (server_new, clients_new, inits_new)."""
    c = clients.astype(jnp.float32)
    i = inits.astype(jnp.float32)
    a = jnp.maximum(alpha.astype(jnp.float32), 1e-9)[:, None]
    m = mask.astype(jnp.float32)[:, None]
    p = (c - i) if progress is None else progress.astype(jnp.float32)
    msg = i + p / a
    total = jnp.sum(m * msg, axis=0, keepdims=True)
    s_new = (server.astype(jnp.float32)[None] + total) / (float(s) + 1.0)
    server_new = s_new[0].astype(server.dtype)
    clients_new = (m * s_new + (1.0 - m) * c).astype(clients.dtype)
    inits_new = (m * s_new + (1.0 - m) * i).astype(inits.dtype)
    return server_new, clients_new, inits_new


def favas_stream_ref(server, clients, inits, alpha, mask, s: float,
                     *, progress=None):
    """Aggregation-only oracle of the STREAMED schedule (docs §13): the
    exact ``favas_fused_ref`` server expressions, emitting ONLY the new
    server row. The selected-client reset happens outside the kernel as a
    churn-bounded scatter of this row into the s selected positions.

    Bit-exactness with the fused reset: ``mask`` is exactly the 0/1
    indicator of the selected index set (Gumbel top-s), so for every
    unselected row ``m*s_new + (1-m)*x == x`` to the bit (the f32
    round-trip of a finite value is identity for f32/bf16 states) and for
    every selected row it equals ``s_new.astype(dtype)`` — the row this
    oracle returns."""
    c = clients.astype(jnp.float32)
    i = inits.astype(jnp.float32)
    a = jnp.maximum(alpha.astype(jnp.float32), 1e-9)[:, None]
    m = mask.astype(jnp.float32)[:, None]
    p = (c - i) if progress is None else progress.astype(jnp.float32)
    msg = i + p / a
    total = jnp.sum(m * msg, axis=0, keepdims=True)
    s_new = (server.astype(jnp.float32)[None] + total) / (float(s) + 1.0)
    return s_new[0].astype(server.dtype)


def luq_ref(x, u_prune, u_round, scale, bits: int):
    """LUQ log-domain unbiased quantization (see core/quant.py), with the
    randomness and the global scale passed in (kernel parity)."""
    levels = 2 ** (bits - 1) - 1
    xf = x.astype(jnp.float32)
    sign = jnp.sign(xf)
    mag = jnp.abs(xf)
    # shared guard (core.quant.luq_scale semantics): zero -> 1.0, NaN
    # propagates — see kernels/luq.py::guard_scale
    from repro.kernels.luq import guard_scale
    scale = guard_scale(scale).astype(jnp.float32)
    m = mag / scale
    min_level = 2.0 ** (-(levels - 1))
    below = m < min_level
    keep = u_prune < (m / min_level)
    m_pruned = jnp.where(below, jnp.where(keep, min_level, 0.0), m)
    e = jnp.floor(jnp.log2(jnp.maximum(m_pruned, min_level)))
    f = m_pruned / jnp.exp2(e)
    e_hat = e + (u_round < (f - 1.0)).astype(jnp.float32)
    q = jnp.where(m_pruned == 0.0, 0.0,
                  jnp.exp2(jnp.clip(e_hat, -(levels - 1), 0.0)))
    return (sign * scale * q).astype(x.dtype)
