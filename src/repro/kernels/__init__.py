# Pallas TPU kernels for the paper's memory-bound hot spots:
#   favas_agg — fused masked reweighted client aggregation (Alg. 1 line 10 + eq. 3)
#               and the multi-output full-round variant (agg + client/init reset)
#               driving core/round_engine.py
#   luq       — LUQ logarithmic unbiased quantization (FAVAS[QNN], Remark 1)
# ops.py = jit wrappers (kernel on TPU, interpret=True on CPU);
# ref.py = pure-jnp oracles; tests sweep shapes/dtypes with assert_allclose.
from repro.kernels.ops import (favas_aggregate_flat, favas_aggregate_tree,
                               favas_fused_flat, luq_quantize)
