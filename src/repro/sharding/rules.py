"""Parameter sharding: regex path -> PartitionSpec rule engine.

Tensor-parallel layout over the "model" mesh axis (Megatron f/g pattern):
column-shard the in-projections (qkv, mlp up/gate, recurrent in-proj),
row-shard the out-projections (wo, mlp down, recurrent out), shard the
embedding table on (padded) vocab. MoE experts are tensor-sharded on the
per-expert ff dim (see docs/architecture.md §6 for why expert-parallelism
is rejected for the assigned expert counts).

Every candidate axis is validated for divisibility against the mesh; a
non-dividing axis falls back to replication (logged via `check_divisible`),
which keeps odd head-counts (granite 24H, starcoder 36H) compiling.
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

# (path regex, per-dim axis template). Applied top-down, first match wins.
_RULES: Tuple[Tuple[str, Tuple], ...] = (
    (r"embed/table$",                   ("model", None)),
    (r"encoder/pos$|dec_pos$",          (None, None)),
    (r"lm_head/w$",                     (None, "model")),
    # attention
    (r"(wq|wk|wv)/w$",                  (None, "model")),
    (r"(wq|wk|wv)/b$",                  ("model",)),
    (r"wo/w$",                          ("model", None)),
    (r"wo/b$",                          (None,)),
    (r"(q_norm|k_norm)/scale$",         (None,)),
    # MoE (stacked expert tensors are raw arrays, not {w})
    (r"router/w$",                      (None, None)),
    (r"mlp/(gate|up)$",                 (None, None, "model")),
    (r"mlp/down$",                      (None, "model", None)),
    # dense MLP
    (r"mlp/(gate|up)/w$",               (None, "model")),
    (r"mlp/(gate|up)/b$",               ("model",)),
    (r"mlp/down/w$",                    ("model", None)),
    (r"mlp/down/b$",                    (None,)),
    # mamba2 branches
    (r"(in_z|in_x|in_dt)/w$",           (None, "model")),
    (r"(in_z|in_x|in_dt)/b$",           ("model",)),
    (r"(in_B|in_C)/",                   None),            # replicated (small)
    (r"conv_x/w$",                      (None, "model")),
    (r"conv_x/b$",                      ("model",)),
    (r"(conv_B|conv_C)/",               None),
    (r"(A_log|D|dt_bias)$",             ("model",)),
    (r"ssm/norm/scale$",                ("model",)),
    (r"out_proj/w$",                    ("model", None)),
    (r"out_proj/b$",                    (None,)),
    # RG-LRU
    (r"rnn/(in_x|in_gate)/w$",          (None, "model")),
    (r"rnn/(in_x|in_gate)/b$",          ("model",)),
    (r"rnn/conv_w$",                    (None, "model")),
    (r"rnn/conv_b$",                    ("model",)),
    (r"rnn/(gate_r|gate_i)/w$",         ("model", None)),
    (r"rnn/lam$",                       (None,)),
    (r"rnn/out/w$",                     ("model", None)),
    (r"rnn/out/b$",                     (None,)),
    # shallow classifier MLP (fl_sim / paper-experiment engine): hidden-dim
    # tensor parallelism; the final (d_hidden, n_classes) layer replicates
    # automatically via the divisibility check (n_classes = 10)
    (r"l\d+/w$",                        (None, "model")),
    (r"l\d+/b$",                        ("model",)),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def check_divisible(shape, spec_dims, axis_sizes) -> Tuple:
    """Replace axes that don't divide their dim by None (replicate)."""
    fixed = []
    for dim, ax in zip(shape, spec_dims):
        if ax is None:
            fixed.append(None)
            continue
        names = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for nm in names:
            size *= axis_sizes.get(nm, 1)
        fixed.append(ax if dim % size == 0 else None)
    return tuple(fixed)


def spec_for(path_str: str, shape, axis_sizes, *, prefix: Sequence = ()) -> P:
    """Resolve one leaf. ``prefix`` = specs for leading stacked dims
    (layer-scan axis -> None, client axis -> ("pod","data"))."""
    ndim = len(shape)
    body_shape = shape[len(prefix):]
    for pat, tmpl in _RULES:
        if re.search(pat, path_str):
            if tmpl is None:
                dims = (None,) * len(body_shape)
            else:
                if len(tmpl) != len(body_shape):
                    dims = (None,) * len(body_shape)   # rank mismatch: replicate
                else:
                    dims = tmpl
            dims = check_divisible(body_shape, dims, axis_sizes)
            full = check_divisible(shape[:len(prefix)], tuple(prefix), axis_sizes) + dims
            return P(*full)
    full = check_divisible(shape[:len(prefix)], tuple(prefix), axis_sizes) \
        + (None,) * len(body_shape)
    return P(*full)


def model_shard_axes(tree, mesh, *, axis: str = "model") -> list:
    """Per-leaf index of the dim sharded on the ``axis`` mesh axis, or None.

    Resolved through the same regex rules as ``param_specs`` (divisibility
    fallbacks included), so a flat buffer laid out from this classification
    agrees with how pjit would shard the unflattened leaves. This is what
    ``core.round_engine.make_flat_spec(mesh=...)`` uses to bucket leaves by
    (dtype, sharding group) — docs/architecture.md §6.

    Returns a list aligned with ``jax.tree_util.tree_leaves(tree)``."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    leaves_with_path = jax.tree_util.tree_flatten_with_path(tree)[0]
    if axis_sizes.get(axis, 1) <= 1:
        return [None] * len(leaves_with_path)
    out = []
    for path, leaf in leaves_with_path:
        spec = spec_for(_path_str(path), leaf.shape, axis_sizes)
        found = None
        for k, dim_ax in enumerate(spec):
            names = dim_ax if isinstance(dim_ax, tuple) else (dim_ax,)
            if dim_ax is not None and axis in names:
                found = k
                break
        out.append(found)
    return out


def param_specs(params, mesh, cfg=None, *, client_axis=None):
    """PartitionSpec tree matching ``params``.

    ``client_axis``: mesh axis (or tuple) for a stacked leading client dim.
    Scan-stacked "layers" subtrees get a leading None automatically when the
    model cfg scans layers.
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    scan_layers = bool(cfg is not None and cfg.uniform_stack())

    def one(path, leaf):
        ps = _path_str(path)
        prefix = []
        if client_axis is not None:
            prefix.append(client_axis)
        if scan_layers and re.match(r"^layers/", ps):
            prefix.append(None)
        return spec_for(ps, leaf.shape, axis_sizes, prefix=prefix)

    return jax.tree_util.tree_map_with_path(one, params)


def favas_state_specs(state, mesh, cfg, *, client_axis=("pod", "data")):
    """Specs for a FavasState: server model-sharded & replicated over
    pod/data; client stacks sharded on the client axis."""
    # normalize client axis to the axes present in this mesh
    names = set(mesh.axis_names)
    ca = tuple(a for a in (client_axis if isinstance(client_axis, tuple)
                           else (client_axis,)) if a in names)
    ca = ca if len(ca) > 1 else (ca[0] if ca else None)
    from jax.sharding import PartitionSpec as P
    import repro.core.favas as F
    return F.FavasState(
        server=param_specs(state.server, mesh, cfg),
        clients=param_specs(state.clients, mesh, cfg, client_axis=ca),
        inits=param_specs(state.inits, mesh, cfg, client_axis=ca),
        counters=P(ca),
        stale=P(ca),
        key=P(),
        t=P(),
    )
