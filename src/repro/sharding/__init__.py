from repro.sharding.rules import param_specs, favas_state_specs, check_divisible
