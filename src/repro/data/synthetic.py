"""Seeded synthetic datasets (the container is offline; real MNIST/CIFAR/
TinyImageNet are not fetchable). Dimensionalities and class counts match the
paper's tasks; EXPERIMENTS.md validates *relative* method claims on these.

* ``make_classification`` — K-class Gaussian mixture with class-dependent
  means and within-class structure; "mnist-like" (784 dims / 10 classes),
  "cifar-like" (3072 / 10), "tiny-like" (1024 / 200) presets.
* ``make_lm_corpus`` — token stream from a seeded order-2 Markov chain with
  per-domain transition matrices (gives clients *domain skew* for non-IID
  LM training of the assigned architectures).
"""
from __future__ import annotations

import numpy as np

# sep values chosen so the scaled-down CPU models train into a meaningful
# accuracy band within the simulated-time budget (method ordering — not
# absolute accuracy — is what the paper validation compares).
PRESETS = {
    "mnist-like": dict(dim=784, n_classes=10, sep=2.2),
    "cifar-like": dict(dim=3072, n_classes=10, sep=2.0),
    "tiny-like": dict(dim=1024, n_classes=200, sep=8.0),
}


def make_classification(preset: str = "mnist-like", n_train: int = 20000,
                        n_test: int = 4000, seed: int = 0):
    """Returns (x_train, y_train, x_test, y_test) float32/int32 numpy."""
    p = PRESETS[preset]
    dim, C, sep = p["dim"], p["n_classes"], p["sep"]
    rng = np.random.default_rng(seed)
    means = rng.normal(0, sep / np.sqrt(dim), (C, dim)).astype(np.float32)
    # shared low-rank within-class covariance structure
    basis = rng.normal(0, 1.0 / np.sqrt(dim), (16, dim)).astype(np.float32)

    def draw(n):
        y = rng.integers(0, C, n).astype(np.int32)
        z = rng.normal(0, 1, (n, 16)).astype(np.float32)
        eps = rng.normal(0, 0.5, (n, dim)).astype(np.float32)
        x = means[y] + z @ basis + eps
        return x, y

    xtr, ytr = draw(n_train)
    xte, yte = draw(n_test)
    return xtr, ytr, xte, yte


def make_lm_corpus(vocab: int, n_tokens: int, n_domains: int = 8, seed: int = 0):
    """(tokens, domain_ids) — per-domain unigram mixtures, cheap and seeded.
    Domains give the non-IID client split for LM FAVAS training."""
    rng = np.random.default_rng(seed)
    per = n_tokens // n_domains
    toks, doms = [], []
    for d in range(n_domains):
        logits = rng.normal(0, 2.0, vocab)
        probs = np.exp(logits - logits.max())
        probs /= probs.sum()
        toks.append(rng.choice(vocab, per, p=probs).astype(np.int32))
        doms.append(np.full(per, d, np.int32))
    return np.concatenate(toks), np.concatenate(doms)
