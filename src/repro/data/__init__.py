from repro.data.synthetic import make_classification, make_lm_corpus
from repro.data.partition import partition_iid, partition_label_skew
from repro.data.pipeline import (BatchPrefetcher, FederatedBatcher,
                                 lm_round_batch, lm_superstep_batch)
from repro.data.device_corpus import (DeviceCorpus, make_classification_corpus,
                                      make_lm_device_corpus)
