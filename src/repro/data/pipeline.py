"""Batch pipelines.

* ``FederatedBatcher`` — per-client minibatch streams for the FL simulator
  and the distributed trainer: each call yields a (n_clients, R, B, ...)
  stack (one microbatch per client per potential local step).
* ``lm_round_batch`` — token batches for the assigned-architecture trainer:
  clients are mapped to corpus domains (non-IID domain skew).
"""
from __future__ import annotations

import numpy as np


class FederatedBatcher:
    def __init__(self, x, y, parts, batch_size: int, seed: int = 0):
        self.x, self.y = x, y
        self.parts = parts
        self.B = batch_size
        self.rng = np.random.default_rng(seed)

    def client_batch(self, i: int):
        idx = self.parts[i]
        take = self.rng.choice(idx, self.B, replace=len(idx) < self.B)
        return self.x[take], self.y[take]

    def round_batch(self, n_steps: int):
        """(n, R, B, d) x, (n, R, B) y for one server round."""
        n = len(self.parts)
        xs = np.empty((n, n_steps, self.B) + self.x.shape[1:], self.x.dtype)
        ys = np.empty((n, n_steps, self.B), self.y.dtype)
        for i in range(n):
            for k in range(n_steps):
                xs[i, k], ys[i, k] = self.client_batch(i)
        return xs, ys


def lm_round_batch(tokens: np.ndarray, domains: np.ndarray, n_clients: int,
                   n_steps: int, batch: int, seq: int, rng: np.random.Generator):
    """(n, R, B, S) int32 token batch; client i samples from domain
    i % n_domains (domain-skew non-IID)."""
    n_domains = int(domains.max()) + 1
    out = np.empty((n_clients, n_steps, batch, seq), np.int32)
    dom_index = [np.where(domains == d)[0] for d in range(n_domains)]
    for i in range(n_clients):
        pool = dom_index[i % n_domains]
        lo, hi = pool.min(), pool.max() - seq - 1
        starts = rng.integers(lo, max(hi, lo + 1), (n_steps, batch))
        for k in range(n_steps):
            for b in range(batch):
                s = int(starts[k, b])
                out[i, k, b] = tokens[s:s + seq]
    return out
