"""Batch pipelines.

* ``FederatedBatcher`` — per-client minibatch streams for the FL simulator
  and the distributed trainer: each call yields a (n_clients, R, B, ...)
  stack (one microbatch per client per potential local step).
  ``superstep_batch`` stacks T of those along a leading rounds axis for the
  on-device superstep scan (core/round_engine.py::engine_multi_round).
* ``lm_round_batch`` / ``lm_superstep_batch`` — token batches for the
  assigned-architecture trainer: clients are mapped to corpus domains
  (non-IID domain skew).
* ``BatchPrefetcher`` — double-buffered background-thread prefetcher: host
  batch generation (and the H2D ``jax.device_put``) overlaps device
  compute, so the superstep host loop never blocks on numpy sampling.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Optional

import numpy as np


class FederatedBatcher:
    def __init__(self, x, y, parts, batch_size: int, seed: int = 0):
        self.x, self.y = x, y
        self.parts = parts
        self.B = batch_size
        self.rng = np.random.default_rng(seed)

    def client_batch(self, i: int):
        idx = self.parts[i]
        take = self.rng.choice(idx, self.B, replace=len(idx) < self.B)
        return self.x[take], self.y[take]

    def round_batch(self, n_steps: int):
        """(n, R, B, d) x, (n, R, B) y for one server round."""
        n = len(self.parts)
        xs = np.empty((n, n_steps, self.B) + self.x.shape[1:], self.x.dtype)
        ys = np.empty((n, n_steps, self.B), self.y.dtype)
        for i in range(n):
            for k in range(n_steps):
                xs[i, k], ys[i, k] = self.client_batch(i)
        return xs, ys

    def superstep_batch(self, n_rounds: int, n_steps: int):
        """(T, n, R, B, d) x, (T, n, R, B) y — ``n_rounds`` round batches
        stacked on a leading rounds axis, drawn in round order so the rng
        stream is identical to ``n_rounds`` sequential ``round_batch``
        calls."""
        n = len(self.parts)
        xs = np.empty((n_rounds, n, n_steps, self.B) + self.x.shape[1:],
                      self.x.dtype)
        ys = np.empty((n_rounds, n, n_steps, self.B), self.y.dtype)
        for t in range(n_rounds):
            xs[t], ys[t] = self.round_batch(n_steps)
        return xs, ys


def lm_round_batch(tokens: np.ndarray, domains: np.ndarray, n_clients: int,
                   n_steps: int, batch: int, seq: int, rng: np.random.Generator):
    """(n, R, B, S) int32 token batch; client i samples from domain
    i % n_domains (domain-skew non-IID)."""
    n_domains = int(domains.max()) + 1
    out = np.empty((n_clients, n_steps, batch, seq), np.int32)
    dom_index = [np.where(domains == d)[0] for d in range(n_domains)]
    for i in range(n_clients):
        pool = dom_index[i % n_domains]
        lo, hi = pool.min(), pool.max() - seq - 1
        starts = rng.integers(lo, max(hi, lo + 1), (n_steps, batch))
        for k in range(n_steps):
            for b in range(batch):
                s = int(starts[k, b])
                out[i, k, b] = tokens[s:s + seq]
    return out


def lm_superstep_batch(tokens: np.ndarray, domains: np.ndarray,
                       n_rounds: int, n_clients: int, n_steps: int,
                       batch: int, seq: int, rng: np.random.Generator):
    """(T, n, R, B, S) int32 — ``n_rounds`` LM round batches stacked on a
    leading rounds axis, same rng stream as sequential ``lm_round_batch``
    calls."""
    return np.stack([lm_round_batch(tokens, domains, n_clients, n_steps,
                                    batch, seq, rng)
                     for _ in range(n_rounds)])


class BatchPrefetcher:
    """Double-buffered background-thread batch prefetcher.

    ``make_batch(i)`` runs on ONE background thread for i = 0, 1, ... and
    its results queue up to ``depth`` chunks ahead of the consumer;
    :meth:`get` pops the next one. While the device runs superstep i, the
    host is already generating (and, with ``to_device``, ``jax.device_put``-
    copying) superstep i+1 — batch generation leaves the critical path.

    Contract (docs/architecture.md §7):

    * **order & determinism** — generation happens strictly in index order
      on a single thread, so a seeded ``np.random.Generator`` owned by
      ``make_batch`` produces exactly the stream the synchronous loop would;
    * **bounded lookahead** — at most ``depth`` chunks are ever buffered
      (``depth=2`` is classic double buffering: one in flight to the
      device, one being built), so host memory stays bounded;
    * **errors surface at get()** — an exception in ``make_batch`` is
      re-raised on the consumer thread at its position in the stream
      (batches built before the failure are still served first), never
      swallowed;
    * ``n_steps=None`` streams forever; otherwise :meth:`get` raises
      ``StopIteration`` after ``n_steps`` chunks. :meth:`close` stops the
      producer promptly (it may still finish the chunk it is building).

    ``to_device`` applies ``jax.device_put`` on the producer thread, which
    overlaps the host->device copy with compute as well (JAX transfers are
    thread-safe and async).
    """

    def __init__(self, make_batch: Callable[[int], Any],
                 n_steps: Optional[int] = None, depth: int = 2,
                 to_device: bool = True):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._n = n_steps
        self._served = 0
        self._done = object()           # sentinel: producer exhausted
        self._make = make_batch
        self._to_device = to_device
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self):
        try:
            i = 0
            while not self._stop.is_set() and (self._n is None or i < self._n):
                b = self._make(i)
                if self._to_device:
                    import jax
                    b = jax.device_put(b)
                # bounded put that still honors close(): poll the stop flag
                while not self._stop.is_set():
                    try:
                        self._q.put(b, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                i += 1
        except BaseException as e:  # noqa: BLE001 — re-raised at get()
            self._err = e
        finally:
            try:
                self._q.put(self._done, timeout=0.1)
            except queue.Full:
                pass

    def get(self):
        """Next batch, blocking until the producer has one ready. Batches
        built before a producer failure are still served (FIFO); the error
        surfaces at its position in the stream."""
        while True:
            if self._n is not None and self._served >= self._n:
                raise StopIteration
            try:
                b = self._q.get(timeout=0.1)
            except queue.Empty:
                if self._err is not None:
                    err, self._err = self._err, None
                    raise err
                if not self._thread.is_alive():
                    raise StopIteration from None
                continue
            if b is self._done:
                if self._err is not None:
                    err, self._err = self._err, None
                    raise err
                raise StopIteration
            self._served += 1
            return b

    def __iter__(self):
        while True:
            try:
                yield self.get()
            except StopIteration:
                return

    def close(self):
        """Stop the producer and drop buffered chunks."""
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
