"""Batch pipelines.

* ``FederatedBatcher`` — per-client minibatch streams for the FL simulator
  and the distributed trainer: each call yields a (n_clients, R, B, ...)
  stack (one microbatch per client per potential local step).
  ``superstep_batch`` stacks T of those along a leading rounds axis for the
  on-device superstep scan (core/round_engine.py::engine_multi_round).
* ``lm_round_batch`` / ``lm_superstep_batch`` — token batches for the
  assigned-architecture trainer: clients are mapped to corpus domains
  (non-IID domain skew).
* ``BatchPrefetcher`` — double-buffered background-thread prefetcher: host
  batch generation (and the H2D ``jax.device_put``) overlaps device
  compute, so the superstep host loop never blocks on numpy sampling.
"""
from __future__ import annotations

import queue
import threading
import time
import warnings
from typing import Any, Callable, Optional

import numpy as np


class FederatedBatcher:
    """Per-client minibatch streams.

    ``stream`` versions the rng stream (docs/architecture.md §8):

    * ``"v1"`` (default) — the original per-(client, step)
      ``rng.choice`` loop: without-replacement minibatches whenever the
      partition is large enough, one generator call per cell. Kept as the
      reference stream — results of every pre-existing seed reproduce.
    * ``"v2"`` — fully vectorized: ONE uniform draw per round mapped
      through the padded partition table with the same index math the
      device plane uses (``data.device_corpus.uniform_to_indices``), so a
      round costs one numpy gather instead of ``n * R`` generator calls.
      Samples WITH replacement (like the device plane); the stream differs
      from v1, hence the explicit opt-in.
    """

    def __init__(self, x, y, parts, batch_size: int, seed: int = 0,
                 stream: str = "v1"):
        if stream not in ("v1", "v2"):
            raise ValueError(f"unknown stream version {stream!r}")
        self.x, self.y = x, y
        self.parts = parts
        self.B = batch_size
        self.rng = np.random.default_rng(seed)
        self.stream = stream
        self._lens = np.array([len(p) for p in parts], np.int64)
        if stream == "v2":
            lmax = int(self._lens.max())
            self._table = np.zeros((len(parts), lmax), np.int64)
            for i, p in enumerate(parts):
                self._table[i, :len(p)] = p

    def client_batch(self, i: int):
        idx = self.parts[i]
        take = self.rng.choice(idx, self.B, replace=len(idx) < self.B)
        return self.x[take], self.y[take]

    def round_batch(self, n_steps: int):
        """(n, R, B, d) x, (n, R, B) y for one server round."""
        n = len(self.parts)
        if self.stream == "v2":
            # one generator call + one gather per round: the numpy run of
            # the device plane's index math (j = min(int(u * L), L - 1))
            u = self.rng.random((n, n_steps, self.B))
            j = np.minimum((u * self._lens[:, None, None]).astype(np.int64),
                           self._lens[:, None, None] - 1)
            take = self._table[np.arange(n)[:, None, None], j]
            return self.x[take], self.y[take]
        xs = np.empty((n, n_steps, self.B) + self.x.shape[1:], self.x.dtype)
        ys = np.empty((n, n_steps, self.B), self.y.dtype)
        for i in range(n):
            for k in range(n_steps):
                xs[i, k], ys[i, k] = self.client_batch(i)
        return xs, ys

    def superstep_batch(self, n_rounds: int, n_steps: int):
        """(T, n, R, B, d) x, (T, n, R, B) y — ``n_rounds`` round batches
        stacked on a leading rounds axis, drawn in round order so the rng
        stream is identical to ``n_rounds`` sequential ``round_batch``
        calls."""
        n = len(self.parts)
        xs = np.empty((n_rounds, n, n_steps, self.B) + self.x.shape[1:],
                      self.x.dtype)
        ys = np.empty((n_rounds, n, n_steps, self.B), self.y.dtype)
        for t in range(n_rounds):
            xs[t], ys[t] = self.round_batch(n_steps)
        return xs, ys


def _lm_start_bounds(domains: np.ndarray, n_clients: int, seq: int):
    """Per-client window-start (lo, span): client i samples starts uniformly
    from [lo_i, lo_i + span_i) over domain i % n_domains (domain-skew
    non-IID). The ONE copy of the window-bound formula — shared by both
    host stream versions AND ``data.device_corpus.make_lm_device_corpus``,
    so the two data planes draw from identical pools by construction."""
    n_domains = int(domains.max()) + 1
    dom_index = [np.where(domains == d)[0] for d in range(n_domains)]
    lo = np.empty((n_clients,), np.int64)
    span = np.empty((n_clients,), np.int64)
    for i in range(n_clients):
        pool = dom_index[i % n_domains]
        a, b = int(pool.min()), int(pool.max()) - seq - 1
        lo[i], span[i] = a, max(b, a + 1) - a
    return lo, span


def lm_round_batch(tokens: np.ndarray, domains: np.ndarray, n_clients: int,
                   n_steps: int, batch: int, seq: int,
                   rng: np.random.Generator, stream: str = "v1"):
    """(n, R, B, S) int32 token batch; client i samples from domain
    i % n_domains (domain-skew non-IID).

    ``stream="v1"`` (default) keeps the original per-client
    ``rng.integers`` draws — the stream is IDENTICAL to the seed's triple
    Python loop; only the window gather is vectorized (pure indexing, no
    generator calls). ``"v2"`` draws one uniform block for all clients and
    maps it with the device plane's index math — one generator call per
    round, stream intentionally different."""
    lo, span = _lm_start_bounds(domains, n_clients, seq)
    if stream == "v2":
        u = rng.random((n_clients, n_steps, batch))
        starts = lo[:, None, None] + np.minimum(
            (u * span[:, None, None]).astype(np.int64),
            span[:, None, None] - 1)
    elif stream == "v1":
        starts = np.empty((n_clients, n_steps, batch), np.int64)
        for i in range(n_clients):
            # one rng.integers call per client, exactly as the old loop made
            starts[i] = rng.integers(lo[i], lo[i] + span[i],
                                     (n_steps, batch))
    else:
        raise ValueError(f"unknown stream version {stream!r}")
    return tokens[starts[..., None] + np.arange(seq)].astype(np.int32)


def lm_superstep_batch(tokens: np.ndarray, domains: np.ndarray,
                       n_rounds: int, n_clients: int, n_steps: int,
                       batch: int, seq: int, rng: np.random.Generator,
                       stream: str = "v1"):
    """(T, n, R, B, S) int32 — ``n_rounds`` LM round batches stacked on a
    leading rounds axis, same rng stream as sequential ``lm_round_batch``
    calls."""
    return np.stack([lm_round_batch(tokens, domains, n_clients, n_steps,
                                    batch, seq, rng, stream=stream)
                     for _ in range(n_rounds)])


class BatchPrefetcher:
    """Double-buffered background-thread batch prefetcher.

    ``make_batch(i)`` runs on ONE background thread for i = 0, 1, ... and
    its results queue up to ``depth`` chunks ahead of the consumer;
    :meth:`get` pops the next one. While the device runs superstep i, the
    host is already generating (and, with ``to_device``, ``jax.device_put``-
    copying) superstep i+1 — batch generation leaves the critical path.

    Contract (docs/architecture.md §7):

    * **order & determinism** — generation happens strictly in index order
      on a single thread, so a seeded ``np.random.Generator`` owned by
      ``make_batch`` produces exactly the stream the synchronous loop would;
    * **bounded lookahead** — at most ``depth`` chunks are ever buffered
      (``depth=2`` is classic double buffering: one in flight to the
      device, one being built), so host memory stays bounded;
    * **errors surface at get()** — an exception in ``make_batch`` is
      re-raised on the consumer thread at its position in the stream
      (batches built before the failure are still served first), never
      swallowed;
    * ``n_steps=None`` streams forever; otherwise :meth:`get` raises
      ``StopIteration`` after ``n_steps`` chunks. :meth:`close` stops the
      producer promptly (it may still finish the chunk it is building).

    ``to_device`` applies ``jax.device_put`` on the producer thread, which
    overlaps the host->device copy with compute as well (JAX transfers are
    thread-safe and async).
    """

    def __init__(self, make_batch: Callable[[int], Any],
                 n_steps: Optional[int] = None, depth: int = 2,
                 to_device: bool = True):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._n = n_steps
        self._served = 0
        self._done = object()           # sentinel: producer exhausted
        self._make = make_batch
        self._to_device = to_device
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self):
        try:
            i = 0
            while not self._stop.is_set() and (self._n is None or i < self._n):
                b = self._make(i)
                if self._to_device:
                    import jax
                    b = jax.device_put(b)
                # bounded put that still honors close(): poll the stop flag
                while not self._stop.is_set():
                    try:
                        self._q.put(b, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                i += 1
        except BaseException as e:  # noqa: BLE001 — re-raised at get()
            self._err = e
        finally:
            try:
                self._q.put(self._done, timeout=0.1)
            except queue.Full:
                pass

    def get(self):
        """Next batch, blocking until the producer has one ready. Batches
        built before a producer failure are still served (FIFO); the error
        surfaces at its position in the stream."""
        while True:
            if self._n is not None and self._served >= self._n:
                raise StopIteration
            try:
                b = self._q.get(timeout=0.1)
            except queue.Empty:
                if self._err is not None:
                    err, self._err = self._err, None
                    raise err
                if not self._thread.is_alive():
                    raise StopIteration from None
                continue
            if b is self._done:
                if self._err is not None:
                    err, self._err = self._err, None
                    raise err
                raise StopIteration
            self._served += 1
            return b

    def __iter__(self):
        while True:
            try:
                yield self.get()
            except StopIteration:
                return

    def close(self, timeout: float = 30.0) -> bool:
        """Stop the producer and drop buffered chunks. Returns True once
        the producer thread has actually exited.

        Deadlock-safe even when the producer is blocked on a FULL queue:
        the stop flag is set first (the producer's ``put`` polls it every
        0.1 s), then drain-and-join repeats until the thread exits — a
        single drain could race a producer that was mid-``put`` and leave
        it parked behind a re-filled queue. The deadline is measured on
        ``time.monotonic`` (NOT join-call counts, which under-measure when
        a drain or a slow ``device_put`` eats wall time between joins); on
        expiry a ``RuntimeWarning`` is emitted and False returned, so a
        leaked producer is observable instead of silently orphaned
        (tests/test_async_server.py asserts the no-leak contract). A
        pending producer error is NOT cleared here; :meth:`__exit__`
        re-raises it so failures can't vanish when the consumer stops
        early."""
        self._stop.set()
        deadline = time.monotonic() + timeout
        while self._thread.is_alive():
            while True:
                try:
                    self._q.get_nowait()
                except queue.Empty:
                    break
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            self._thread.join(timeout=min(0.25, remaining))
        # drop anything the producer managed to enqueue while exiting
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        if self._thread.is_alive():
            warnings.warn(
                f"BatchPrefetcher.close(): producer thread still alive "
                f"after {timeout:.1f}s (slow make_batch/device_put?)",
                RuntimeWarning, stacklevel=2)
            return False
        return True

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        """Close, then PROPAGATE a pending producer error (one that was
        raised on the producer thread but never surfaced through ``get``)
        — unless the body is already unwinding with its own exception."""
        self.close()
        if self._err is not None and exc_type is None:
            err, self._err = self._err, None
            raise err
        return False
