"""On-device data plane: a resident corpus the superstep scan samples.

The host data plane (``data/pipeline.py``) generates every microbatch in
numpy and ships ``(T, n, R, B, ...)`` arrays to the device — after PR 4
that generation is the LAST host-side work per superstep chunk, and at
large ``n_clients`` it lags the device (ROADMAP "next lever"). This module
replaces it for the device plane (``--data-plane device``,
docs/architecture.md §8):

* the corpus (feature/label rows or the token stream) and the per-client
  partition **index tables** are uploaded ONCE (``jax.device_put``;
  replicated over the mesh when one is given, matching how
  ``round_engine.engine_sharding`` treats per-client auxiliaries);
* ragged partitions are padded to one rectangular ``(n, Lmax)`` int32
  table with a per-client ``lengths`` vector — padded entries are never
  sampled because every drawn local index ``j`` satisfies
  ``j < lengths[i]`` by construction (tests/test_device_corpus.py);
* :meth:`DeviceCorpus.sample_round_batch` draws the per-client minibatch
  indices INSIDE the jitted scan body from an explicit PRNG key and
  gathers the rows on device — zero host work per round.

Index-sampling math (the contract the numpy mirrors pin down bit-exactly):
one ``jax.random.uniform`` draw ``u`` of shape ``(n, R, B)`` maps to local
indices ``j = min(int(u * L_i), L_i - 1)`` — f32 multiply + truncation,
identical IEEE ops in jnp and numpy, so :func:`mirror_partition_indices` /
:func:`mirror_lm_starts` reproduce the device indices element-exactly from
the same uniforms. The stream is the jax PRNG (not numpy's), so the device
plane is *statistically equivalent* to the host plane, not
stream-identical — same contract PR 4 set for on-device selection. The
host batcher's ``stream="v2"`` path (``data/pipeline.py``) runs the exact
same index math on numpy's generator.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Index-sampling math + numpy mirrors
# ---------------------------------------------------------------------------

def uniform_to_indices(u, lengths):
    """Map uniforms ``u`` in [0, 1) to local indices ``j < lengths[i]``.

    ``u``: (n, ...) f32; ``lengths``: (n,) int32 (must be >= 1). The math is
    ``j = min(int(u * L), L - 1)`` — pure f32 multiply + int truncation, so
    the numpy mirror is element-exact for identical ``u``."""
    L = lengths.reshape(lengths.shape + (1,) * (u.ndim - 1))
    j = (u * L.astype(jnp.float32)).astype(jnp.int32)
    return jnp.minimum(j, L - 1)


def mirror_partition_indices(u: np.ndarray, lengths: np.ndarray) -> np.ndarray:
    """Numpy mirror of :func:`uniform_to_indices` — element-exact against
    the device sampler for the same (f32) uniforms."""
    u = np.asarray(u, np.float32)
    L = np.asarray(lengths, np.int32).reshape(
        (len(lengths),) + (1,) * (u.ndim - 1))
    j = (u * L.astype(np.float32)).astype(np.int32)
    return np.minimum(j, L - 1)


def sample_partition_indices(key, lengths, n_steps: int, batch: int):
    """(n, n_steps, batch) int32 local indices, one uniform draw per slot."""
    u = jax.random.uniform(key, (lengths.shape[0], n_steps, batch))
    return uniform_to_indices(u, lengths)


def mirror_lm_starts(u: np.ndarray, lo: np.ndarray,
                     span: np.ndarray) -> np.ndarray:
    """Numpy mirror of the LM start sampling: ``lo + min(int(u*span),
    span-1)`` — element-exact against the device draw for the same u."""
    return (np.asarray(lo, np.int32).reshape(
        (len(lo),) + (1,) * (u.ndim - 1))
        + mirror_partition_indices(u, span))


# ---------------------------------------------------------------------------
# The corpus
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceCorpus:
    """Device-resident corpus + per-client sampling tables.

    Two kinds share one type (the static ``kind`` picks the sample path):

    * ``"classification"`` — ``x (N, d)``, ``y (N,)``, padded partition
      index table ``idx (n, Lmax)`` int32 and ``lengths (n,)`` int32
      (ragged partitions right-padded with 0; the pad is masked by the
      ``j < lengths`` invariant, never by branching);
    * ``"lm"`` — ``tokens (N,)`` int32 plus per-client window-start bounds
      ``lo (n,)`` / ``span (n,)`` int32 (client i samples starts uniformly
      from ``[lo_i, lo_i + span_i)``, the same domain-skew pools the host
      ``lm_round_batch`` uses).

    A ``DeviceCorpus`` is a pytree (arrays are leaves, ``kind``/``batch``/
    ``seq`` are static aux data), so it passes straight through ``jax.jit``
    / ``lax.scan`` closures without retracing per call.
    """
    kind: str                      # "classification" | "lm"  (static)
    batch: int                     # B, per-client per-step     (static)
    seq: int                       # S, LM window length        (static)
    x: Optional[jnp.ndarray] = None        # (N, d) features
    y: Optional[jnp.ndarray] = None        # (N,) labels
    idx: Optional[jnp.ndarray] = None      # (n, Lmax) int32 partition table
    lengths: Optional[jnp.ndarray] = None  # (n,) int32 partition sizes
    tokens: Optional[jnp.ndarray] = None   # (N,) int32 token stream
    lo: Optional[jnp.ndarray] = None       # (n,) int32 window-start lows
    span: Optional[jnp.ndarray] = None     # (n,) int32 window-start ranges

    def tree_flatten(self):
        children = (self.x, self.y, self.idx, self.lengths,
                    self.tokens, self.lo, self.span)
        return children, (self.kind, self.batch, self.seq)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux[0], aux[1], aux[2], *children)

    @property
    def n_clients(self) -> int:
        v = self.lengths if self.kind == "classification" else self.lo
        return v.shape[0]

    def sample_round_batch(self, key, n_steps: int, ids=None) -> Dict:
        """Draw one round's per-client microbatches ON DEVICE (jit/scan
        safe). Returns the same batch pytree the host plane ships:
        ``{"x": (n, R, B, d), "y": (n, R, B)}`` for classification,
        ``{"tokens": (n, R, B, S)}`` for LM.

        ``ids``: optional (s,) int32 client ids — return only those
        clients' rows (leading axis s), for the paged engine's hot working
        set. The index draw always covers ALL n clients so the PRNG stream
        is identical to the dense call; only the corpus DATA gather is
        restricted to ``ids`` (with ``ids == arange(n)`` the result is the
        full batch, value-for-value)."""
        if self.kind == "classification":
            j = sample_partition_indices(key, self.lengths, n_steps,
                                         self.batch)
            n = self.lengths.shape[0]
            cids = jnp.arange(n) if ids is None else ids
            rows = self.idx[cids[:, None, None], j[cids]]
            return {"x": self.x[rows], "y": self.y[rows]}
        u = jax.random.uniform(key, (self.lo.shape[0], n_steps, self.batch))
        lo, span = self.lo, self.span
        if ids is not None:
            u, lo, span = u[ids], lo[ids], span[ids]
        starts = lo[:, None, None] + uniform_to_indices(u, span)
        return {"tokens": self.tokens[starts[..., None]
                                      + jnp.arange(self.seq)]}

    def nbytes(self) -> int:
        """Total device bytes of the corpus arrays (the all-gather audit
        bound in tests/test_sharded_engine.py)."""
        tot = 0
        for leaf in self.tree_flatten()[0]:
            if leaf is not None:
                tot += leaf.size * jnp.dtype(leaf.dtype).itemsize
        return tot


def _put(arrays: Dict[str, np.ndarray], mesh) -> Dict[str, jnp.ndarray]:
    """Upload once; replicated over the mesh when one is given (the corpus
    is read-only side input — every model shard gathers locally)."""
    if mesh is None:
        return {k: jax.device_put(jnp.asarray(v)) for k, v in arrays.items()}
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())
    return {k: jax.device_put(jnp.asarray(v), rep) for k, v in arrays.items()}


def make_classification_corpus(x, y, parts: Sequence, batch: int,
                               *, mesh=None) -> DeviceCorpus:
    """Upload a classification corpus + ragged per-client partitions.

    ``parts``: list of per-client index arrays into ``x``/``y`` (ragged).
    Padded table entries are 0 but provably never sampled (``j <
    lengths[i]``)."""
    n = len(parts)
    if n == 0 or any(len(p) == 0 for p in parts):
        raise ValueError("every client partition must be non-empty")
    lmax = max(len(p) for p in parts)
    idx = np.zeros((n, lmax), np.int32)
    lengths = np.empty((n,), np.int32)
    for i, p in enumerate(parts):
        idx[i, :len(p)] = np.asarray(p, np.int32)
        lengths[i] = len(p)
    put = _put({"x": np.asarray(x), "y": np.asarray(y),
                "idx": idx, "lengths": lengths}, mesh)
    return DeviceCorpus(kind="classification", batch=batch, seq=0, **put)


def make_lm_device_corpus(tokens: np.ndarray, domains: np.ndarray,
                          n_clients: int, batch: int, seq: int,
                          *, mesh=None) -> DeviceCorpus:
    """Upload a token stream + per-client window-start bounds.

    Client i samples from domain ``i % n_domains`` over the SAME
    ``[lo, lo + span)`` start range as the host ``lm_round_batch``
    (``pipeline._lm_start_bounds`` — one shared formula, so the two planes
    draw from identical pools by construction)."""
    from repro.data.pipeline import _lm_start_bounds  # no import cycle
    lo, span = _lm_start_bounds(domains, n_clients, seq)
    put = _put({"tokens": np.asarray(tokens, np.int32),
                "lo": np.asarray(lo, np.int32),
                "span": np.asarray(span, np.int32)}, mesh)
    return DeviceCorpus(kind="lm", batch=batch, seq=seq, **put)
