"""Federated partitioners. ``partition_label_skew`` reproduces the paper's
non-IID split: "each client takes two classes (out of the ten possible)
without replacement" (Sec. 5)."""
from __future__ import annotations

import numpy as np


def partition_iid(n_samples: int, n_clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n_samples)
    return np.array_split(idx, n_clients)


def partition_label_skew(labels: np.ndarray, n_clients: int,
                         classes_per_client: int = 2, seed: int = 0):
    """Each client draws ``classes_per_client`` classes; samples of each class
    are split evenly among the clients holding it."""
    rng = np.random.default_rng(seed)
    C = int(labels.max()) + 1
    # assign classes to clients, cycling so every class is covered
    class_choices = []
    deck = []
    for i in range(n_clients):
        if len(deck) < classes_per_client:
            deck = list(rng.permutation(C))
        class_choices.append([deck.pop() for _ in range(classes_per_client)])
    holders = {c: [] for c in range(C)}
    for i, cs in enumerate(class_choices):
        for c in cs:
            holders[c].append(i)
    parts = [[] for _ in range(n_clients)]
    for c in range(C):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        who = holders[c] or [int(rng.integers(0, n_clients))]
        for j, chunk in enumerate(np.array_split(idx, len(who))):
            parts[who[j]].extend(chunk.tolist())
    return [np.array(sorted(p), dtype=np.int64) for p in parts]
