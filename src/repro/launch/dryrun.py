import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: .lower().compile() every (architecture x input-shape x
mesh) combination with ShapeDtypeStruct inputs (no allocation), record
memory_analysis / cost_analysis / parsed collective schedule, and emit the
roofline artifact JSON that EXPERIMENTS.md §Dry-run and §Roofline read.

NOTE: the XLA_FLAGS line above MUST stay the first statement — jax locks the
device count at first init. The flag lives only in this module (and the
subprocesses benchmarks spawn); tests and benches see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED, get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as STEPS
from repro.launch.roofline import parse_hlo_collectives, build_report

SHAPES = list(STEPS.INPUT_SHAPES)


def normalize_cost_analysis(cost) -> dict:
    """``compiled.cost_analysis()`` returns a dict on older JAX, a LIST of
    per-computation dicts on newer JAX (one per executable computation), or
    None. Normalize to one flat dict, summing numeric keys across
    computations, so ``cost.get("flops")`` works everywhere."""
    if cost is None:
        return {}
    if isinstance(cost, dict):
        return dict(cost)
    merged = {}
    for entry in cost:
        for k, v in (entry or {}).items():
            if isinstance(v, (int, float)):
                merged[k] = merged.get(k, 0) + v
            else:
                merged.setdefault(k, v)
    return merged


def run_one(arch: str, shape_name: str, mesh_name: str, *, out_dir=None,
            verbose=True, hlo_dir=None, variant="base"):
    cfg = get_config(arch)
    if not STEPS.supports(cfg, shape_name):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "variant": variant,
               "reason": "requires sub-quadratic attention (DESIGN.md §4)"}
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            suffix = "" if variant == "base" else f"_{variant}"
            path = os.path.join(
                out_dir, f"{arch}_{shape_name}_{mesh_name}{suffix}.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_chips = mesh.devices.size
    model_shards = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "n_chips": n_chips, "status": "ok", "variant": variant}
    try:
        built = STEPS.build_step(arch, shape_name, mesh, variant=variant)
        jitted, sds_args, cfg, kind = built
        rec["step_kind"] = kind
        with mesh:
            lowered = jitted.lower(*sds_args)
            t_low = time.time()
            compiled = lowered.compile()
            t_comp = time.time()
        ma = compiled.memory_analysis()
        cost = normalize_cost_analysis(compiled.cost_analysis())
        hlo = compiled.as_text()
        coll = parse_hlo_collectives(
            hlo, bf16_dot_comms=(cfg.compute_dtype == "bfloat16"))
        if hlo_dir:
            os.makedirs(hlo_dir, exist_ok=True)
            with open(os.path.join(
                    hlo_dir, f"{arch}_{shape_name}_{mesh_name}.hlo"), "w") as f:
                f.write(hlo)
        rec.update(
            lower_s=round(t_low - t0, 2), compile_s=round(t_comp - t_low, 2),
            memory={
                "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                "output_bytes": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                "code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
            },
            cost={"flops": cost.get("flops"),
                  "bytes_accessed": cost.get("bytes accessed")},
            collectives=coll,
        )
        fcfg = STEPS.default_favas_config(mesh)
        report = build_report(
            arch, shape_name, mesh_name, cfg, STEPS.INPUT_SHAPES[shape_name],
            n_chips, model_shards, cost, coll,
            local_steps=fcfg.R if kind == "train" else 0,
            param_bytes=4 if kind == "train" else 2)
        rec["roofline"] = {
            "compute_s": report.compute_s, "memory_s": report.memory_s,
            "collective_s": report.collective_s, "dominant": report.dominant,
            "model_flops": report.model_flops,
            "useful_ratio": report.useful_ratio,
            "raw_cost_flops": report.raw_cost_flops,
        }
        if verbose:
            print(f"[ok] {arch} x {shape_name} x {mesh_name}: "
                  f"lower {rec['lower_s']}s compile {rec['compile_s']}s | "
                  f"temp {rec['memory']['temp_bytes']} B | "
                  f"coll {coll['total_bytes']:.3e} B | dom {report.dominant}")
            print("     memory_analysis:", ma)
            print("     cost_analysis: flops=%s bytes=%s" %
                  (cost.get("flops"), cost.get("bytes accessed")))
    except Exception as e:  # noqa: BLE001 — record the failure, don't die
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[ERR] {arch} x {shape_name} x {mesh_name}: {rec['error']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "" if variant == "base" else f"_{variant}"
        path = os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (see configs)")
    ap.add_argument("--shape", default=None, choices=SHAPES)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="all assigned archs x all shapes")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--hlo-dir", default=None)
    ap.add_argument("--variant", default="base", choices=["base", "opt"])
    args = ap.parse_args()

    archs = ASSIGNED if args.all or not args.arch else [args.arch]
    shapes = SHAPES if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = []
    for mesh_name in meshes:
        for arch in archs:
            for shape in shapes:
                results.append(run_one(arch, shape, mesh_name, out_dir=args.out,
                                       hlo_dir=args.hlo_dir,
                                       variant=args.variant))
    ok = sum(r["status"] == "ok" for r in results)
    skip = sum(r["status"] == "skipped" for r in results)
    err = sum(r["status"] == "error" for r in results)
    print(f"\n=== dry-run summary: {ok} ok / {skip} skipped / {err} errors "
          f"of {len(results)} ===")
    for r in results:
        if r["status"] == "error":
            print("  FAILED:", r["arch"], r["shape"], r["mesh"], "->", r["error"])
    return 0 if err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
