"""Step builders + ShapeDtypeStruct input specs for every
(architecture x input-shape) combination — the dry-run and the real
launchers share these.

Input shapes (task assignment):
  train_4k     seq 4096,   global_batch 256   -> FAVAS train_step (one round)
  prefill_32k  seq 32768,  global_batch 32    -> serve_prefill
  decode_32k   seq 32768,  global_batch 128   -> serve_step (1 token + cache)
  long_500k    seq 524288, global_batch 1     -> serve_step, sub-quadratic
                                                 archs only (DESIGN.md §4)
"""
from __future__ import annotations

import dataclasses
import functools
import re
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.favas import FavasConfig, favas_init, favas_round, \
    favas_multi_round, client_lambdas
from repro.launch.mesh import data_axes, n_client_slots
from repro.models.model import ModelConfig, init_params, loss_fn, forward, \
    init_cache, decode_step
from repro.sharding.rules import param_specs, favas_state_specs, check_divisible

INPUT_SHAPES = {
    "train_4k": dict(seq=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq=524288, global_batch=1, kind="decode"),
}

N_PATCHES = 256       # stubbed vision tokens (qwen2-vl)


def supports(cfg: ModelConfig, shape_name: str) -> bool:
    """long_500k needs sub-quadratic attention: SSM, hybrid (RG-LRU + local
    window), or a sliding-window dense variant."""
    if shape_name != "long_500k":
        return True
    return cfg.arch_type in ("ssm", "hybrid") or cfg.window > 0


def serve_config(cfg: ModelConfig) -> ModelConfig:
    """Serving runs bf16 weights, no remat."""
    return dataclasses.replace(cfg, param_dtype="bfloat16", remat=False)


def apply_variant(cfg: ModelConfig, variant: str, seq: int,
                  model_shards: int) -> ModelConfig:
    """"base" = paper-faithful baseline lowering; "opt" = beyond-paper perf
    config (§Perf): residual-stream sequence sharding over "model" when the
    shape divides."""
    if (variant == "opt" and seq % model_shards == 0 and seq > 1
            and cfg.seq_shard_friendly):
        return dataclasses.replace(cfg, act_seq_axis="model")
    return cfg


# ---------------------------------------------------------------------------
# Input ShapeDtypeStructs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, fcfg: FavasConfig, seq: int,
                      global_batch: int) -> Dict:
    n, R = fcfg.n_clients, fcfg.R
    B_loc = max(global_batch // n, 1)
    batch = {"tokens": _sds((n, R, B_loc, seq), jnp.int32)}
    if cfg.arch_type == "audio":
        batch["enc_frames"] = _sds((n, R, B_loc, cfg.enc_seq, cfg.d_model),
                                   jnp.bfloat16)
    if cfg.arch_type == "vlm":
        batch["patch_embeds"] = _sds((n, R, B_loc, N_PATCHES, cfg.d_model),
                                     jnp.bfloat16)
    return batch


def prefill_batch_specs(cfg: ModelConfig, seq: int, global_batch: int) -> Dict:
    batch = {"tokens": _sds((global_batch, seq), jnp.int32)}
    if cfg.arch_type == "audio":
        batch["enc_frames"] = _sds((global_batch, cfg.enc_seq, cfg.d_model),
                                   jnp.bfloat16)
    if cfg.arch_type == "vlm":
        batch["patch_embeds"] = _sds((global_batch, N_PATCHES, cfg.d_model),
                                     jnp.bfloat16)
    return batch


def lm_corpus_specs(fcfg: FavasConfig, seq: int, global_batch: int,
                    n_tokens: int = 400_000):
    """ShapeDtypeStruct stand-in for a device-resident LM corpus
    (``data.device_corpus.DeviceCorpus``): the token stream + per-client
    window-start tables the device data plane samples in-scan
    (docs/architecture.md §8). Shardable (replicated), no allocation."""
    from repro.data.device_corpus import DeviceCorpus
    n = fcfg.n_clients
    B_loc = max(global_batch // n, 1)
    return DeviceCorpus(kind="lm", batch=B_loc, seq=seq,
                        tokens=_sds((n_tokens,), jnp.int32),
                        lo=_sds((n,), jnp.int32),
                        span=_sds((n,), jnp.int32))


def input_specs(arch: str, shape_name: str,
                fcfg: Optional[FavasConfig] = None, mesh=None) -> Dict:
    """Public entry: ShapeDtypeStruct stand-ins for every model input of the
    given (arch, shape) — weak-type-correct, shardable, no allocation."""
    cfg = get_config(arch)
    info = INPUT_SHAPES[shape_name]
    if info["kind"] == "train":
        fcfg = fcfg or default_favas_config(mesh)
        return train_batch_specs(cfg, fcfg, info["seq"], info["global_batch"])
    if info["kind"] == "prefill":
        return prefill_batch_specs(serve_config(cfg), info["seq"],
                                   info["global_batch"])
    B = info["global_batch"]
    return {"token": _sds((B, 1), jnp.int32), "pos": _sds((), jnp.int32)}


def default_favas_config(mesh=None, **overrides) -> FavasConfig:
    n = n_client_slots(mesh) if mesh is not None else 16
    kw = dict(n_clients=n, s_selected=max(n // 4, 1), local_steps=8, eta=1e-3)
    kw.update(overrides)
    return FavasConfig(**kw)


# ---------------------------------------------------------------------------
# Sharding for batches and caches
# ---------------------------------------------------------------------------

def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _dp(mesh):
    da = data_axes(mesh)
    return da if len(da) > 1 else da[0]


def batch_shardings(batch_sds, mesh, *, leading_client_axis: bool,
                    leading_rounds_axis: bool = False):
    """``leading_rounds_axis``: the batch carries a superstep (T,) rounds
    axis in front — the scan axis is never device-sharded, so the data axes
    move to dim 1."""
    dp = _dp(mesh)
    sizes = _axis_sizes(mesh)
    lead = 1 if leading_rounds_axis else 0

    def one(sds):
        dims = [None] * len(sds.shape)
        dims[lead] = dp
        spec = P(*check_divisible(sds.shape, tuple(dims), sizes))
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map(one, batch_sds)


def cache_specs(cache_sds, mesh, cfg: ModelConfig):
    """PartitionSpec tree for a decode cache: batch over data axes, KV-cache
    sequence over "model" (distributed flash-decode), SSM/RNN inner channels
    over "model"."""
    dp = _dp(mesh)
    sizes = _axis_sizes(mesh)
    stacked = cfg.uniform_stack()

    def one(path, sds):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        ps = "/".join(names)
        rank = len(sds.shape)
        prefix = (None,) if (stacked and "layers" in names) else ()
        body_rank = rank - len(prefix)
        if re.search(r"/(k|v)$", ps) or names[-1] in ("k", "v"):
            dims = (dp, "model", None, None)
        elif names[-1] in ("k_scale", "v_scale"):
            dims = (dp, "model", None)
        elif names[-1] == "state":
            dims = (dp, "model", None, None)
        elif names[-1] == "conv_x":
            dims = (dp, None, "model")
        elif names[-1] in ("conv_B", "conv_C"):
            dims = (dp, None, None)
        elif names[-1] == "h":
            dims = (dp, "model")
        elif names[-1] == "conv":
            dims = (dp, None, "model")
        elif "cross_kv" in names:
            dims = (dp, None, "model", None)
        else:
            dims = (dp,) + (None,) * (body_rank - 1)
        dims = dims[:body_rank] + (None,) * max(body_rank - len(dims), 0)
        full = prefix + tuple(dims)
        return P(*check_divisible(sds.shape, full, sizes))

    return jax.tree_util.tree_map_with_path(one, cache_sds)


# ---------------------------------------------------------------------------
# Step builders (shared by dryrun.py, train.py, serve.py)
# ---------------------------------------------------------------------------

def build_train_step(arch: str, mesh, fcfg: Optional[FavasConfig] = None,
                     *, use_agg_kernel: bool = False, variant: str = "opt",
                     rounds_per_step: int = 1, data_plane: str = "host"):
    """Returns (jitted_step, state_sds, batch_sds). train_step = one FAVAS
    server round over the resident clients — or, with ``rounds_per_step`` >
    1, one SUPERSTEP: that many rounds scanned on-device in a single
    dispatch (``favas_multi_round``; batch gains a leading (T,) rounds axis
    and metrics come back (T,)-stacked).

    ``data_plane="device"`` (docs/architecture.md §8): the step's second
    operand becomes a replicated ``DeviceCorpus`` stand-in instead of a
    batch — the superstep samples every round's minibatches in-scan, so
    the host ships no batch bytes at all. Token-corpus archs only (audio /
    VLM side inputs have no corpus sampler yet)."""
    cfg = get_config(arch)
    ms = _axis_sizes(mesh)["model"]
    cfg = apply_variant(cfg, variant, INPUT_SHAPES["train_4k"]["seq"], ms)
    fcfg = fcfg or default_favas_config(mesh)
    lambdas = jnp.asarray(client_lambdas(fcfg))

    def lfn(p, b):
        return loss_fn(p, cfg, b)

    if data_plane not in ("host", "device"):
        raise ValueError(f"unknown data_plane {data_plane!r}")
    if data_plane == "device" and cfg.arch_type in ("audio", "vlm"):
        raise ValueError(
            f"--data-plane device needs a pure token corpus; {arch} "
            f"({cfg.arch_type}) feeds extra side inputs per batch")

    def step(state, batch):
        # use_agg_kernel=False keeps the jnp oracle under pjit (XLA fuses the
        # flat-buffer expression); True forces the Pallas fused kernel.
        if data_plane == "device":
            # batch IS the resident corpus; minibatches are sampled in-scan
            return favas_multi_round(state, corpus=batch,
                                     n_rounds=max(rounds_per_step, 1),
                                     cfg=fcfg, loss_fn=lfn, lambdas=lambdas,
                                     use_kernel=use_agg_kernel)
        if rounds_per_step > 1:
            return favas_multi_round(state, batch, cfg=fcfg, loss_fn=lfn,
                                     lambdas=lambdas,
                                     use_kernel=use_agg_kernel)
        return favas_round(state, batch, cfg=fcfg, loss_fn=lfn,
                           lambdas=lambdas, use_kernel=use_agg_kernel)

    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_sds = jax.eval_shape(functools.partial(init_params, cfg=cfg), key_sds)
    state_sds = jax.eval_shape(
        functools.partial(favas_init, cfg=fcfg), params_sds, key=key_sds)

    sspec = favas_state_specs(state_sds, mesh, cfg)
    state_sh = jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), sspec,
        is_leaf=lambda x: isinstance(x, P))
    info = INPUT_SHAPES["train_4k"]
    if data_plane == "device":
        batch_sds = lm_corpus_specs(fcfg, info["seq"], info["global_batch"])
        # the corpus is a replicated side input: every shard gathers locally
        batch_sh = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, P()), batch_sds)
    else:
        batch_sds = train_batch_specs(cfg, fcfg, info["seq"],
                                      info["global_batch"])
        if rounds_per_step > 1:
            batch_sds = jax.tree_util.tree_map(
                lambda s: _sds((rounds_per_step,) + s.shape, s.dtype),
                batch_sds)
        batch_sh = batch_shardings(batch_sds, mesh, leading_client_axis=True,
                                   leading_rounds_axis=rounds_per_step > 1)
    metrics_sh = {k: NamedSharding(mesh, P()) for k in
                  ("loss", "mean_steps", "selected", "stale_rounds")}
    jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, metrics_sh), donate_argnums=(0,))
    return jitted, (state_sds, batch_sds), cfg


def build_prefill_step(arch: str, mesh, shape_name: str = "prefill_32k",
                       *, variant: str = "opt"):
    cfg = serve_config(get_config(arch))
    info = INPUT_SHAPES[shape_name]
    cfg = apply_variant(cfg, variant, info["seq"], _axis_sizes(mesh)["model"])

    def step(params, batch):
        logits, _ = forward(params, cfg, batch)
        return logits

    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_sds = jax.eval_shape(functools.partial(init_params, cfg=cfg), key_sds)
    pspec = param_specs(params_sds, mesh, cfg)
    params_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspec,
                                       is_leaf=lambda x: isinstance(x, P))
    batch_sds = prefill_batch_specs(cfg, info["seq"], info["global_batch"])
    batch_sh = batch_shardings(batch_sds, mesh, leading_client_axis=False)
    logits_sh = NamedSharding(mesh, P(_dp(mesh), None, "model"))
    jitted = jax.jit(step, in_shardings=(params_sh, batch_sh),
                     out_shardings=logits_sh)
    return jitted, (params_sds, batch_sds), cfg


def build_serve_step(arch: str, mesh, shape_name: str, *, variant: str = "opt"):
    """One-token decode with a seq_len KV cache."""
    cfg = serve_config(get_config(arch))
    if variant == "opt":
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    info = INPUT_SHAPES[shape_name]
    B, S = info["global_batch"], info["seq"]

    def step(params, cache, token, pos):
        return decode_step(params, cfg, cache, token, pos)

    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_sds = jax.eval_shape(functools.partial(init_params, cfg=cfg), key_sds)
    pspec = param_specs(params_sds, mesh, cfg)
    params_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspec,
                                       is_leaf=lambda x: isinstance(x, P))
    cache_sds = jax.eval_shape(
        functools.partial(init_cache, cfg, B, S, dtype=jnp.bfloat16))
    if cfg.arch_type == "audio":
        # cross-KV filled by prefill; materialize specs for it too
        hd = cfg.head_dim
        xkv = [(_sds((B, cfg.enc_seq, cfg.n_kv_heads, hd), jnp.bfloat16),) * 2
               for _ in range(cfg.n_layers)]
        cache_sds = dict(cache_sds)
        cache_sds["cross_kv"] = [tuple(t) for t in xkv]
    cspec = cache_specs(cache_sds, mesh, cfg)
    cache_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), cspec,
                                      is_leaf=lambda x: isinstance(x, P))
    dp = _dp(mesh)
    sizes = _axis_sizes(mesh)
    tok_spec = P(*check_divisible((B, 1), (dp, None), sizes))
    token_sh = NamedSharding(mesh, tok_spec)
    pos_sh = NamedSharding(mesh, P())
    logits_sh = NamedSharding(mesh, P(*check_divisible(
        (B, 1, cfg.vocab_size), (dp, None, "model"), sizes)))
    jitted = jax.jit(step, in_shardings=(params_sh, cache_sh, token_sh, pos_sh),
                     out_shardings=(logits_sh, cache_sh), donate_argnums=(1,))
    token_sds = _sds((B, 1), jnp.int32)
    pos_sds = _sds((), jnp.int32)
    return jitted, (params_sds, cache_sds, token_sds, pos_sds), cfg


def build_step(arch: str, shape_name: str, mesh,
               fcfg: Optional[FavasConfig] = None, variant: str = "opt"):
    kind = INPUT_SHAPES[shape_name]["kind"]
    if kind == "train":
        return build_train_step(arch, mesh, fcfg, variant=variant) + ("train",)
    if kind == "prefill":
        return build_prefill_step(arch, mesh, shape_name,
                                  variant=variant) + ("prefill",)
    return build_serve_step(arch, mesh, shape_name, variant=variant) + ("decode",)
