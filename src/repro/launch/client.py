"""LocalSGDClient: the FAVAS local-SGD worker as a transport actor
(docs/architecture.md §11).

The client owns THREE pieces of state the simulator kept server-side:

* the **credit clock** — pure Python integers on the exact tick grid of
  ``sampler.time_ticks`` (credit += round_ticks; whole ``step_ticks``
  quanta become available steps; run ``min(available, K - q)``; the
  sub-step remainder persists across resets, excess whole steps above
  ``K - q`` are discarded). Because the arithmetic is integral, the
  per-round step stream is BIT-IDENTICAL to the simulator's on-device
  ``sampler.credit_steps`` — the "credit stream exact" half of the
  equivalence contract (tests/test_async_server.py replays both).
* its **parameters** — trained by a jitted scan over this round's
  minibatches, drawn from the client's own seeded numpy stream (losses are
  therefore statistically comparable to fl_sim, not bit-equal: the
  simulator consumes one global batcher).
* the **push ledger** — every polled update is retried on the
  :class:`repro.comms.retry.BackoffPolicy` schedule until the server acks
  it (``stale`` acks stop the retries too: the round closed without us,
  our progress simply keeps accumulating like an unselected client's).
  Every NEW push carries a monotone ``seq`` stamp that retransmits reuse
  — the server's exactly-once dedup ledger keys on ``(client, round,
  seq)``, so a retry of an update that was durably admitted before a
  server crash is acked-but-ignored after recovery.

Crash-and-rejoin: the transport blackholes a crashed client and fires
``on_rejoin``; the client then sends ``join`` and resynchronizes from the
server's ``sync`` reply (params adopted, q -> 0), rejoining the population
exactly like a fresh reset.

Server recovery (docs/architecture.md §12): a restarted server announces
a ``recover`` hello (its new epoch + current round) and re-broadcasts the
open round's ticks and the last close's resets. The client is idempotent
against all of that: ticks and resets are deduplicated BY ROUND
(``_last_tick_round`` / ``_last_reset_round``), so a re-broadcast never
double-advances the credit clock or re-zeroes ``q``, and the ``recover``
hello makes the client retransmit its still-unacked pushes immediately
instead of waiting out the backoff schedule.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comms.retry import BackoffPolicy
from repro.comms.transport import Actor, TransportAPI
from repro.core import round_engine
from repro.models.classifier import classifier_loss, mlp_apply
from repro.utils.tree import tree_map

SERVER = "server"


def _sgd_runner(loss_fn, eta):
    """Jitted ``params, xs (T,B,d), ys (T,B) -> params`` scan. Retraces per
    distinct T, which is bounded by K+1 values."""
    @jax.jit
    def run(params, xs, ys):
        def step(p, inp):
            x, y = inp
            g = jax.grad(loss_fn)(p, x, y)
            return tree_map(lambda pp, gg: pp - eta * gg, p, g), None
        p, _ = jax.lax.scan(step, params, (xs, ys))
        return p
    return run


class LocalSGDClient(Actor):
    """One worker. ``step_ticks`` / ``round_ticks`` come from
    ``sampler.time_ticks`` on the deployment's step-time vector; ``x, y``
    is this client's data shard; ``n_clients`` sizes the shared FlatSpec so
    pushed buckets match the server's row layout."""

    def __init__(self, node_id: str, params0, x, y, *, n_clients: int,
                 batch_size: int, eta: float, K: int, step_ticks: int,
                 round_ticks: int, n_classes: int, seed: int = 0,
                 backoff: Optional[BackoffPolicy] = None):
        self.node_id = node_id
        self.spec = round_engine.make_flat_spec(params0, n_clients=n_clients)
        self.params = params0
        self.x = np.asarray(x)
        self.y = np.asarray(y)
        self.batch_size = int(batch_size)
        self.K = int(K)
        self.step_ticks = int(step_ticks)
        self.round_ticks = int(round_ticks)
        self.q = 0
        self.credit = 0
        self._rng = np.random.default_rng(seed)
        self._sgd = _sgd_runner(
            lambda p, bx, by: classifier_loss(p, mlp_apply, bx, by,
                                              n_classes), eta)
        self.backoff = backoff or BackoffPolicy()
        self._inflight = {}             # round -> {"msg", "attempt"}
        self._seq = 0                   # exactly-once stamp for NEW pushes
        self._last_tick_round = -1      # idempotency vs recovery re-sends
        self._last_reset_round = -1
        self.server_epoch = 0           # learned from the recover hello
        self.log: List[dict] = []       # per-round credit/step records
        self.stats = {"rounds": 0, "pushes": 0, "retries": 0, "gave_up": 0,
                      "stale_acks": 0, "resets": 0, "rejoins": 0,
                      "recovers_seen": 0}

    # -- local compute -------------------------------------------------------

    def _credit_clock(self) -> int:
        """One round of the integer credit clock (sampler.credit_steps on
        host ints)."""
        self.credit += self.round_ticks
        avail = self.credit // self.step_ticks
        self.credit -= avail * self.step_ticks
        return min(avail, self.K - self.q)

    def _train(self, steps: int) -> None:
        if steps <= 0:
            return
        B = self.batch_size
        ix = self._rng.integers(0, len(self.x), size=(steps, B))
        self.params = self._sgd(self.params,
                                jnp.asarray(self.x[ix]),
                                jnp.asarray(self.y[ix]))

    def warmup(self, steps=(1,)) -> None:
        """Pre-trace the jitted SGD scan for the given step counts — on the
        wall-clock transport the first-use compile would otherwise land
        inside round 0's harvest window and turn it into a spurious
        straggler round. State is untouched (the traced result is
        discarded)."""
        B = self.batch_size
        feat = tuple(self.x.shape[1:])
        for t in sorted({int(t) for t in steps if int(t) > 0}):
            xs = jnp.zeros((t, B) + feat, self.x.dtype)
            ys = jnp.zeros((t, B), self.y.dtype)
            jax.block_until_ready(self._sgd(self.params, xs, ys))

    # -- actor contract ------------------------------------------------------

    def on_start(self, api: TransportAPI) -> None:
        api.send(SERVER, {"kind": "hello"})

    def on_message(self, src: str, msg, api: TransportAPI) -> None:
        kind = msg.get("kind")
        if kind == "tick":
            self._on_tick(msg, api)
        elif kind == "ack":
            self._on_ack(msg, api)
        elif kind in ("reset", "sync"):
            if kind == "reset":
                r = int(msg.get("round", -1))
                if r <= self._last_reset_round:
                    return               # recovery re-send: already applied
                self._last_reset_round = r
            bufs = [jnp.asarray(b) for b in msg["params"]]
            self.params = round_engine.unflatten_tree(self.spec, bufs)
            self.q = 0
            self.stats["resets" if kind == "reset" else "rejoins"] += 1
        elif kind == "recover":
            self._on_recover(msg, api)
        elif kind == "stop":
            api.send(SERVER, {"kind": "bye", "log": list(self.log)})
            api.stop()

    def on_rejoin(self, api: TransportAPI) -> None:
        # drop any pre-crash push state and ask the server to resync us
        for r in list(self._inflight):
            api.cancel_timer(f"push:{r}")
        self._inflight = {}
        api.send(SERVER, {"kind": "join"})

    # -- push path -----------------------------------------------------------

    def _on_tick(self, msg, api: TransportAPI) -> None:
        r = int(msg["round"])
        if r <= self._last_tick_round:
            return                       # recovery re-broadcast: no-op
        self._last_tick_round = r
        do = self._credit_clock()
        self._train(do)
        self.q += do
        self.stats["rounds"] += 1
        self.log.append({"round": r, "do": do, "q": self.q,
                         "polled": bool(msg.get("polled"))})
        if msg.get("polled"):
            bufs = [np.asarray(b) for b in
                    round_engine.flatten_tree(self.spec, self.params)]
            push = {"kind": "update", "round": r, "client": self.node_id,
                    "q": self.q, "seq": self._seq, "params": bufs}
            self._seq += 1               # retransmits reuse the stamp
            self._inflight[r] = {"msg": push, "attempt": 0}
            api.send(SERVER, push)
            self.stats["pushes"] += 1
            api.set_timer(f"push:{r}", self.backoff.delay(0))

    def _on_recover(self, msg, api: TransportAPI) -> None:
        """Server came back: adopt its epoch and retransmit every unacked
        push NOW (fresh backoff) — the dedup ledger makes this safe even
        when the original was admitted just before the crash."""
        self.server_epoch = int(msg.get("epoch", self.server_epoch))
        self.stats["recovers_seen"] += 1
        for r, ent in self._inflight.items():
            ent["attempt"] = 0
            api.send(SERVER, ent["msg"])
            self.stats["retries"] += 1
            api.set_timer(f"push:{r}", self.backoff.delay(0))

    def _on_ack(self, msg, api: TransportAPI) -> None:
        r = msg.get("round")
        if r in self._inflight:
            api.cancel_timer(f"push:{r}")
            del self._inflight[r]
        if msg.get("stale"):
            self.stats["stale_acks"] += 1

    def on_timer(self, name: str, api: TransportAPI) -> None:
        if not name.startswith("push:"):
            return
        r = int(name.split(":", 1)[1])
        ent = self._inflight.get(r)
        if ent is None:
            return
        ent["attempt"] += 1
        if self.backoff.exhausted(ent["attempt"]):
            del self._inflight[r]
            self.stats["gave_up"] += 1
            return
        api.send(SERVER, ent["msg"])
        self.stats["retries"] += 1
        api.set_timer(name, self.backoff.delay(ent["attempt"]))
