"""Batched serving loop: prefill a prompt batch, then step the decode cache
token-by-token with temperature sampling. Runs reduced configs on CPU; the
production shapes are exercised by the dry-run (launch/dryrun.py).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.models.model import (init_params, forward, init_cache, decode_step,
                                prefill_audio)
from repro.launch.steps import serve_config


def generate(params, cfg, prompts, gen_len: int, key, *, temperature=1.0,
             extras=None):
    """prompts: (B, P) int32. Returns (B, P+gen_len) tokens."""
    B, P = prompts.shape
    max_seq = P + gen_len
    cache = init_cache(cfg, B, max_seq, dtype=jnp.bfloat16)
    if cfg.arch_type == "audio":
        cache = prefill_audio(params, cfg, cache, extras["enc_frames"])

    step = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))

    toks = prompts
    logits = None
    # prefill by stepping (cache-exact; a fused prefill kernel is the
    # production path, exercised by prefill_32k in the dry-run)
    for i in range(P):
        logits, cache = step(params, cache, toks[:, i:i + 1], jnp.int32(i))
    out = [toks]
    cur = None
    for g in range(gen_len):
        key, sub = jax.random.split(key)
        logit = logits[:, -1] / max(temperature, 1e-4)
        # mask padded vocab tail
        logit = logit.at[:, cfg.vocab_size_raw:].set(-1e30)
        cur = jax.random.categorical(sub, logit)[:, None].astype(jnp.int32)
        out.append(cur)
        logits, cache = step(params, cache, cur, jnp.int32(P + g))
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    cfg = serve_config(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size_raw, dtype=jnp.int32)
    extras = None
    if cfg.arch_type == "audio":
        extras = {"enc_frames": jax.random.normal(
            key, (args.batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)}
    t0 = time.time()
    out = generate(params, cfg, prompts, args.gen, key,
                   temperature=args.temperature, extras=extras)
    dt = time.time() - t0
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    print(np.asarray(out[:2, -10:]))


if __name__ == "__main__":
    main()
