"""End-to-end FAVAS trainer CLI.

Runs on whatever devices exist: a 1-device CPU box (reduced configs, smoke/
example use) or the production mesh (full configs). One train step = one
FAVAS server round over the resident clients, driven by the flat-buffer
``core.round_engine.RoundEngine``: parameters live in contiguous flat
buffers across rounds, the jitted round donates them, and the fused
aggregation+reset runs as one pass (Pallas kernel on TPU, jnp oracle on
CPU; override with --use-kernel). With --mesh the engine is sharded: flat
buffers stay partitioned over the "model" mesh axis end-to-end
(docs/architecture.md §6) and the round never gathers them.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 50 --n-clients 4 --s 2 --seq 128 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing import save_checkpoint, latest_checkpoint, load_checkpoint
from repro.configs import get_config, get_reduced_config
from repro.core import FavasConfig, RoundEngine, client_lambdas
from repro.data import make_lm_corpus
from repro.data.pipeline import lm_round_batch
from repro.models.model import init_params, loss_fn
from repro.utils.metrics import MetricsLogger


def build_cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--n-clients", type=int, default=4)
    ap.add_argument("--s", type=int, default=2)
    ap.add_argument("--K", type=int, default=4)
    ap.add_argument("--eta", type=float, default=0.05)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4, help="per-client per-step")
    ap.add_argument("--reweight", default="stochastic",
                    choices=["stochastic", "deterministic"])
    ap.add_argument("--quant-bits", type=int, default=0)
    ap.add_argument("--use-kernel", default="auto",
                    choices=["auto", "on", "off"],
                    help="fused Pallas aggregation kernel: auto = TPU only "
                         "(CPU gets the jnp oracle), on = force (interpret "
                         "mode off-TPU), off = always the oracle")
    ap.add_argument("--mesh", default="none",
                    help="device mesh for the sharded flat-buffer engine: "
                         "none (default, single-device), model / model=K "
                         "(1-D tensor-parallel mesh over local devices), "
                         "single, multi (production TPU meshes). Composes "
                         "with --use-kernel: the kernel runs per model "
                         "shard via shard_map, the oracle under pjit — "
                         "either way no full-buffer gather per round")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics", default=None, help="JSONL metrics path")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def run(args):
    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    fcfg = FavasConfig(n_clients=args.n_clients, s_selected=args.s,
                       local_steps=args.K, eta=args.eta,
                       reweight=args.reweight, quant_bits=args.quant_bits,
                       seed=args.seed)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    lambdas = jnp.asarray(client_lambdas(fcfg))
    det_alpha = None
    if args.reweight == "deterministic":
        from repro.core import deterministic_alphas
        det_alpha = jnp.asarray(deterministic_alphas(fcfg))

    def lfn(p, b):
        return loss_fn(p, cfg, b)

    use_kernel = {"auto": None, "on": True, "off": False}[args.use_kernel]
    from repro.launch.mesh import mesh_from_arg, model_axis_size
    mesh = mesh_from_arg(args.mesh)
    if mesh is not None:
        print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
              f"({model_axis_size(mesh)}-way model sharding of the engine)")
    engine = RoundEngine(params, fcfg, lfn, lambdas=lambdas,
                         det_alpha=det_alpha, use_kernel=use_kernel,
                         mesh=mesh)
    state = engine.init_state(params, key)
    del params  # the flat buffers are now the authoritative copy

    if args.ckpt_dir:
        ck = latest_checkpoint(args.ckpt_dir)
        if ck:
            print(f"restoring {ck}")
            try:
                state = load_checkpoint(ck, state)
            except (KeyError, ValueError) as e:
                raise SystemExit(
                    f"checkpoint {ck} does not match the flat-buffer "
                    f"EngineState layout ({e}). Checkpoints written before "
                    f"the round-engine change (pytree FavasState) or with a "
                    f"different parameter layout cannot be restored — start "
                    f"from a fresh --ckpt-dir.") from e

    step_fn = engine.step

    tokens, domains = make_lm_corpus(cfg.vocab_size_raw, 400_000,
                                     n_domains=max(args.n_clients, 2),
                                     seed=args.seed)
    rng = np.random.default_rng(args.seed)
    logger = MetricsLogger(args.metrics)
    losses = []
    t0 = time.time()
    for t in range(args.steps):
        batch_np = lm_round_batch(tokens, domains, fcfg.n_clients, fcfg.R,
                                  args.batch, args.seq, rng)
        state, metrics = step_fn(state, {"tokens": jnp.asarray(batch_np)})
        losses.append(float(metrics["loss"]))
        logger.log(t + 1, loss=metrics["loss"], mean_steps=metrics["mean_steps"],
                   stale_rounds=metrics["stale_rounds"])
        if (t + 1) % args.log_every == 0:
            var = float(engine.variance(state))
            logger.log(t + 1, client_variance=var)
            print(f"round {t+1:5d} | loss {np.mean(losses[-args.log_every:]):.4f}"
                  f" | client-var {var:.3e} | {(t+1)/(time.time()-t0):.2f} it/s")
        if args.ckpt_dir and (t + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, t + 1, state)
    print(f"done: first-10 loss {np.mean(losses[:10]):.4f} -> "
          f"last-10 {np.mean(losses[-10:]):.4f}")
    return state, losses


def main():
    args = build_cli().parse_args()
    run(args)


if __name__ == "__main__":
    main()
