"""End-to-end FAVAS trainer CLI.

Runs on whatever devices exist: a 1-device CPU box (reduced configs, smoke/
example use) or the production mesh (full configs). One train step = one
FAVAS server round over the resident clients, driven by the flat-buffer
``core.round_engine.RoundEngine``: parameters live in contiguous flat
buffers across rounds, the jitted round donates them, and the fused
aggregation+reset runs as one pass (Pallas kernel on TPU, jnp oracle on
CPU; override with --use-kernel). With --mesh the engine is sharded: flat
buffers stay partitioned over the "model" mesh axis end-to-end
(docs/architecture.md §6) and the round never gathers them.

The host loop is pipelined (docs/architecture.md §7): with
``--rounds-per-step T`` every chunk of T rounds runs as ONE on-device
superstep dispatch (``RoundEngine.run``, bit-exact with T sequential
rounds), batch generation runs ahead on a background thread
(``data.pipeline.BatchPrefetcher``, H2D copies overlapped), and metrics
stay on device until a ``--log-every`` boundary — the loop never blocks
on a per-round ``float(loss)``. With ``--data-plane device`` batch
generation leaves the host entirely (docs/architecture.md §8): the token
corpus is uploaded once (``data.device_corpus``) and the superstep scan
samples every round's minibatch indices in-body (``RoundEngine.
run_device``) — no prefetcher, no per-chunk H2D batch copies.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 50 --n-clients 4 --s 2 --seq 128 --batch 4 --rounds-per-step 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing import (save_engine_checkpoint, latest_checkpoint,
                                 load_engine_checkpoint)
from repro.configs import get_config, get_reduced_config
from repro.core import FavasConfig, RoundEngine, client_lambdas
from repro.data import make_lm_corpus
from repro.data.pipeline import BatchPrefetcher, lm_round_batch, \
    lm_superstep_batch
from repro.models.model import init_params, loss_fn
from repro.utils.metrics import MetricsLogger


def build_cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--n-clients", type=int, default=4)
    ap.add_argument("--s", type=int, default=2)
    ap.add_argument("--K", type=int, default=4)
    ap.add_argument("--eta", type=float, default=0.05)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4, help="per-client per-step")
    ap.add_argument("--reweight", default="stochastic",
                    choices=["stochastic", "deterministic"])
    ap.add_argument("--quant-bits", type=int, default=0)
    ap.add_argument("--quant-fused", action="store_true",
                    help="with --quant-bits > 0: transport the FAVAS[QNN] "
                         "progress as bit-packed LUQ codes + per-(row, "
                         "shard) scales all the way into the fused round "
                         "(dequantized per VMEM tile, no dense (n, D) f32 "
                         "progress buffer — docs/architecture.md §10); "
                         "default quantizes per leaf and hands the kernel "
                         "a dense dequantized buffer")
    ap.add_argument("--rounds-per-step", type=int, default=1,
                    help="rounds per superstep dispatch: T > 1 scans T "
                         "server rounds on-device in ONE jitted call "
                         "(bit-exact with T sequential rounds) and fetches "
                         "metrics once per chunk — removes per-round host "
                         "dispatch/sync overhead")
    ap.add_argument("--data-plane", default="host",
                    choices=["host", "device"],
                    help="host (default): numpy batch generation on the "
                         "background prefetch thread, batches shipped per "
                         "chunk; device: the token corpus is uploaded ONCE "
                         "and every round's minibatch indices are sampled "
                         "inside the on-device scan — zero host batch work "
                         "per round (docs/architecture.md §8; jax-PRNG "
                         "stream, statistically equivalent to host)")
    ap.add_argument("--residency", default="dense",
                    choices=["dense", "paged"],
                    help="client-state residency (docs/architecture.md §9): "
                         "dense keeps all n clients' full-precision (n, D) "
                         "buffers resident; paged keeps a hot working set "
                         "of --s-max rows plus a --cold-bits-encoded cold "
                         "pool covering all n clients — resident bytes drop "
                         "from O(n*D*4) to O(n*D*bits/8 + s_max*D*4)")
    ap.add_argument("--s-max", type=int, default=None,
                    help="hot working-set size for --residency paged "
                         "(default: n-clients, which is bit-exact with "
                         "dense when --cold-bits 0). Must be >= --s")
    ap.add_argument("--cold-bits", type=int, default=0,
                    choices=[0, 2, 4, 8],
                    help="cold-pool LUQ width for --residency paged: 0 = "
                         "passthrough (full precision, bit-exact parity "
                         "tool), 2/4/8 = bit-packed LUQ codes + per-(row, "
                         "shard) scales (kernels/luq.py math)")
    ap.add_argument("--cold-placement", default="device",
                    choices=["device", "host"],
                    help="where --residency paged keeps the cold pools "
                         "(docs/architecture.md §13): device (default) "
                         "holds them in HBM; host offloads them to host "
                         "memory and streams each superstep's churn-bounded "
                         "slab in/out around the dispatch — device bytes "
                         "scale with --s-max instead of --n-clients, "
                         "bit-exact with device placement")
    ap.add_argument("--use-kernel", default="auto",
                    choices=["auto", "on", "off"],
                    help="fused Pallas aggregation kernel: auto = TPU only "
                         "(CPU gets the jnp oracle), on = force (interpret "
                         "mode off-TPU), off = always the oracle")
    ap.add_argument("--mesh", default="none",
                    help="device mesh for the sharded flat-buffer engine: "
                         "none (default, single-device), model / model=K "
                         "(1-D tensor-parallel mesh over local devices), "
                         "single, multi (production TPU meshes). Composes "
                         "with --use-kernel: the kernel runs per model "
                         "shard via shard_map, the oracle under pjit — "
                         "either way no full-buffer gather per round")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics", default=None, help="JSONL metrics path")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def run(args):
    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    fcfg = FavasConfig(n_clients=args.n_clients, s_selected=args.s,
                       local_steps=args.K, eta=args.eta,
                       reweight=args.reweight, quant_bits=args.quant_bits,
                       seed=args.seed)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    lambdas = jnp.asarray(client_lambdas(fcfg))
    det_alpha = None
    if args.reweight == "deterministic":
        from repro.core import deterministic_alphas
        det_alpha = jnp.asarray(deterministic_alphas(fcfg))

    def lfn(p, b):
        return loss_fn(p, cfg, b)

    use_kernel = {"auto": None, "on": True, "off": False}[args.use_kernel]
    from repro.launch.mesh import mesh_from_arg, model_axis_size
    mesh = mesh_from_arg(args.mesh)
    if mesh is not None:
        print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
              f"({model_axis_size(mesh)}-way model sharding of the engine)")
    engine = RoundEngine(params, fcfg, lfn, lambdas=lambdas,
                         det_alpha=det_alpha, use_kernel=use_kernel,
                         mesh=mesh, residency=args.residency,
                         s_max=args.s_max, cold_bits=args.cold_bits,
                         cold_placement=args.cold_placement,
                         quant_fused=args.quant_fused)
    if args.residency == "paged":
        print(f"residency: paged (s_max={engine.spec.s_max} hot rows, "
              f"cold codec {engine.spec.cold_codec}, "
              f"cold tier on {engine.spec.cold_placement})")
    state = engine.init_state(params, key)
    if args.residency == "paged":
        tiers = engine.resident_bytes_by_tier(state)
        print(f"resident bytes: device {tiers['device']:,} | "
              f"host {tiers['host']:,}")
    del params  # the flat buffers are now the authoritative copy

    if args.ckpt_dir:
        ck = latest_checkpoint(args.ckpt_dir)
        if ck:
            print(f"restoring {ck}")
            try:
                state = load_engine_checkpoint(ck, state)
            except (KeyError, ValueError) as e:
                raise SystemExit(
                    f"checkpoint {ck} does not match the flat-buffer "
                    f"EngineState layout ({e}). Checkpoints written before "
                    f"the round-engine change (pytree FavasState) or with a "
                    f"different parameter layout cannot be restored — start "
                    f"from a fresh --ckpt-dir.") from e

    tokens, domains = make_lm_corpus(cfg.vocab_size_raw, 400_000,
                                     n_domains=max(args.n_clients, 2),
                                     seed=args.seed)
    rng = np.random.default_rng(args.seed)
    logger = MetricsLogger(args.metrics)

    # chunk schedule: T-round supersteps plus a short remainder chunk
    T = max(args.rounds_per_step, 1)
    schedule = [T] * (args.steps // T)
    if args.steps % T:
        schedule.append(args.steps % T)

    device_plane = args.data_plane == "device"
    corpus = None
    if device_plane:
        # upload the corpus + per-client sampling tables ONCE; every chunk
        # is then a single dispatch with zero host batch-generation work
        from repro.data.device_corpus import make_lm_device_corpus
        corpus = make_lm_device_corpus(tokens, domains, fcfg.n_clients,
                                       args.batch, args.seq, mesh=mesh)

    def make_chunk(i):
        """Host batch generation for chunk i — runs on the prefetch thread,
        concurrently with the device's current superstep; the prefetcher
        also overlaps the H2D copy (device_put on that thread)."""
        W = schedule[i]
        if T == 1:
            b = lm_round_batch(tokens, domains, fcfg.n_clients, fcfg.R,
                               args.batch, args.seq, rng)
        else:
            b = lm_superstep_batch(tokens, domains, W, fcfg.n_clients,
                                   fcfg.R, args.batch, args.seq, rng)
        return {"tokens": b}

    losses = []
    pending = []      # (first_round_idx, W, device metrics) — NOT fetched yet
    rounds_done, next_log = 0, args.log_every
    next_ckpt = args.ckpt_every

    def flush():
        """Materialize pending chunk metrics (ONE host sync per flush) and
        emit the per-round JSONL records the per-round loop used to write."""
        nonlocal pending
        for start, W, m in pending:
            host = {k: np.atleast_1d(np.asarray(v)) for k, v in m.items()}
            for j in range(W):
                losses.append(float(host["loss"][j]))
                logger.log(start + j + 1, loss=host["loss"][j],
                           mean_steps=host["mean_steps"][j],
                           stale_rounds=host["stale_rounds"][j])
        pending = []

    prefetch = (None if device_plane
                else BatchPrefetcher(make_chunk, n_steps=len(schedule)))
    t0 = time.time()
    try:
        for W in schedule:
            if device_plane:
                state, metrics = engine.run_device(state, corpus, W)
            elif T == 1:
                batch = prefetch.get()
                state, metrics = engine.step(state, batch)
            else:
                batch = prefetch.get()
                state, metrics = engine.run(state, batch, n_rounds=W)
            pending.append((rounds_done, W, metrics))
            rounds_done += W
            # host syncs only at --log-every / --ckpt-every boundaries: the
            # loop above never blocks on a per-round float(loss). A chunk
            # can cross several boundaries at once; each gets its own
            # window mean. Client variance is measured once per crossing
            # chunk from the chunk-end state (the only state the host has)
            # and is labeled with THAT round number.
            need_var = rounds_done >= next_log
            rate = f"{rounds_done/(time.time()-t0):.2f} it/s"
            while rounds_done >= next_log:
                flush()
                window = losses[next_log - args.log_every:next_log]
                line = f"round {next_log:5d} | loss {np.mean(window):.4f}"
                if next_log == rounds_done:
                    # variance and throughput are measured at the chunk-end
                    # state/round — only printed on the line they belong to
                    var = float(engine.variance(state))
                    logger.log(rounds_done, client_variance=var)
                    line += f" | client-var {var:.3e} | {rate}"
                    need_var = False
                print(line)
                next_log += args.log_every
            if need_var:      # boundaries crossed mid-chunk only
                var = float(engine.variance(state))
                logger.log(rounds_done, client_variance=var)
                print(f"round {rounds_done:5d} | client-var {var:.3e} | {rate}")
            if args.ckpt_dir and rounds_done >= next_ckpt:
                # one snapshot per chunk (mid-chunk state never exists on
                # the host); keep the cadence anchored to --ckpt-every
                # multiples even when a chunk crosses several boundaries
                save_engine_checkpoint(args.ckpt_dir, rounds_done, state)
                while next_ckpt <= rounds_done:
                    next_ckpt += args.ckpt_every
    finally:
        if prefetch is not None:
            prefetch.close()
    flush()
    print(f"done: first-10 loss {np.mean(losses[:10]):.4f} -> "
          f"last-10 {np.mean(losses[-10:]):.4f}")
    return state, losses


def main():
    args = build_cli().parse_args()
    run(args)


if __name__ == "__main__":
    main()
