"""FavasAsyncServer: the FAVAS aggregator as a transport actor
(docs/architecture.md §11).

This is the simulated-clock round loop of ``core/fl_sim.py`` re-expressed
as an event-driven server, with the engine's fused flat-buffer update
(``round_engine.fused_bucket_update``) as the aggregation core. Protocol,
per round ``r`` (cadence ``round_dur``):

1. ``tick``  server -> every client: carry the round index and a
   ``polled`` flag. Clients advance their integer-tick credit clock and run
   that many local SGD steps; polled clients then push their update.
2. ``update`` client -> server (the UNRELIABLE class — may be dropped or
   duplicated by the fault layer; clients retry with exponential backoff
   until ``ack``-ed): flat parameter buckets + the client's local-step
   count ``q`` (the eq. 3 alpha numerator).
3. Harvest: when all polled updates are admitted, or at
   ``harvest_frac * round_dur``, the server aggregates the admitted set
   with eq. 3 de-biasing (alpha_i = max(q_i, 1), stochastic reweight) and
   FAVAS line 10, normalizing by ``(n_admitted + 1)`` — equal to the
   simulator's ``(s + 1)`` whenever every polled client delivers, and a
   graceful contraction toward the current iterate when faults thin the
   poll. ``reset`` goes to each ADMITTED client (new params, q -> 0);
   un-admitted stragglers keep training uninterrupted, exactly like the
   simulator's unselected clients.

Key-chain equivalence: the server draws the round selection from the SAME
chain as ``fl_sim`` — ``rkey, k_sel, k_q = jax.random.split(rkey, 3)``
with ``rkey = PRNGKey(seed)``, selection via
``sampler.sample_selection_indices(k_sel, n, s)`` — so the selection
stream is bit-identical to the simulated baseline (asserted in
tests/test_async_server.py). ``k_q`` is split even when unused, keeping
the chain aligned; with ``quant_bits > 0`` it keys the per-client LUQ
encode of PENDING updates (below).

Quantized admission (``quant_bits > 0``): an admitted update is
immediately re-encoded with ``kernels.ops.cold_requant_rows`` under
``fold_in(k_q, client)`` and held BETWEEN admission and harvest as codes +
scales — so in-flight progress never sits at full precision in server
memory, and the pending set is part of the checkpointable state:
``checkpoint_state()`` / ``save()`` round-trip the flat buckets, the rng
key chain, and every pending entry's codes + scales through
``checkpointing.ckpt.save_engine_checkpoint`` bit-exactly (the PR 7
checkpointing gap, tests/test_async_server.py::test_server_checkpoint_*).

Durability (``wal_dir`` set; docs/architecture.md §12): every round start,
every admitted update (its wire-exact entry — codes + scales when
``quant_bits > 0``), and every round close is appended to a crash-safe
write-ahead log (``checkpointing/wal.py``) BEFORE the effect is
acknowledged, and every ``ckpt_every`` closed rounds the full server state
snapshots atomically (tmp + fsync + rename) and the WAL rotates. A killed
server recovers as snapshot + WAL replay (:func:`recover_server`) —
selection re-derives from the logged key chain, the pending set rebuilds
bit-exactly from the admit records, and closes re-run the deterministic
aggregation. Admission is EXACTLY-ONCE across restarts: clients stamp
``(round, seq)`` on every push, the dedup ledger rides in the WAL/snapshot
with the admits, and a retransmit of an already-logged update after
recovery is acked-but-ignored. On restart the server announces a
``recover`` hello (epoch + current round) and re-broadcasts the open
round's ticks; clients treat ticks/resets idempotently by round, so the
recovered trajectory's buckets are bit-exact vs an uninterrupted run
(tests/test_chaos_recovery.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpointing import wal
from repro.comms.transport import Actor, TransportAPI
from repro.core import round_engine, sampler
from repro.kernels import ops as kops

SERVER_ID = "server"

#: LUQ code widths the pending-update codec supports (0 = raw admission)
SUPPORTED_QUANT_BITS = (0, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Deployment config. Defaults mirror ``fl_sim.SimConfig`` semantics;
    ``round_dur`` is virtual seconds under InProcTransport and wall seconds
    under ProcEndpoint (the protocol only ever sees the ratio of latencies
    to ``round_dur``, which is why one config drives both)."""
    n_clients: int = 8
    s_selected: int = 2
    K: int = 10
    eta: float = 0.2
    batch_size: int = 32
    rounds: int = 20
    round_dur: float = 7.0           # fl_sim SERVER_WAIT + SERVER_INTERACT
    harvest_frac: float = 0.9        # harvest deadline, fraction of round
    eval_every_rounds: int = 0       # 0: record only the final model
    quant_bits: int = 0              # LUQ-encode pending updates (0: raw)
    barrier_timeout: float = 120.0   # max wait for client hellos at startup
    fast_step_time: float = 2.0
    slow_step_time: float = 16.0
    slow_fraction: float = 1.0 / 3.0
    permute_speeds: bool = True
    seed: int = 0

    def __post_init__(self):
        if not 0 < self.harvest_frac <= 1.0:
            raise ValueError(f"harvest_frac must be in (0, 1], got "
                             f"{self.harvest_frac}")
        if self.round_dur <= 0:
            raise ValueError(f"round_dur must be > 0, got {self.round_dur}")
        if self.n_clients < 1:
            raise ValueError(f"n_clients must be >= 1, got {self.n_clients}")
        if self.s_selected > self.n_clients:
            raise ValueError("s_selected > n_clients")
        if self.quant_bits not in SUPPORTED_QUANT_BITS:
            raise ValueError(
                f"quant_bits must be one of {SUPPORTED_QUANT_BITS} (the LUQ "
                f"codec's supported widths), got {self.quant_bits}")

    def step_times(self) -> np.ndarray:
        """Per-client step times, IDENTICAL to fl_sim's ``_step_times``
        draw (same rng consumption) so tick streams line up."""
        from repro.core.fl_sim import _step_times
        return _step_times(self, np.random.default_rng(self.seed))


class FavasAsyncServer(Actor):
    """The aggregator actor. Runs unmodified on InProcTransport (virtual
    clock — the deterministic test substrate) and ProcEndpoint (real
    processes). ``eval_fn(params_tree) -> float`` is optional."""

    node_id = SERVER_ID

    def __init__(self, cfg: AsyncConfig, params0,
                 eval_fn: Optional[Callable] = None,
                 client_ids: Optional[List[str]] = None, *,
                 wal_dir: Optional[str] = None, ckpt_every: int = 0,
                 wal_fsync: bool = True, chaos=None):
        self.cfg = cfg
        n = cfg.n_clients
        self.client_ids = list(client_ids) if client_ids is not None \
            else [f"client{i}" for i in range(n)]
        if len(self.client_ids) != n:
            raise ValueError("client_ids length != n_clients")
        self._row = {c: i for i, c in enumerate(self.client_ids)}
        self.spec = round_engine.make_flat_spec(params0, n_clients=n)
        self.srv_f = round_engine.flatten_tree(self.spec, params0)
        self.cli_f = round_engine.stack_server_rows(self.spec, self.srv_f, n)
        self.ini_f = round_engine.stack_server_rows(self.spec, self.srv_f, n)
        self.rkey = jax.random.PRNGKey(cfg.seed)
        self.eval_fn = eval_fn
        self.round = -1                  # index of the OPEN round
        self._open = False
        self._k_q = None                 # this round's quant key
        self._polled: List[str] = []
        self.pending: Dict[str, dict] = {}
        # exactly-once dedup ledger: client -> (round, seq) of its LAST
        # admitted (durably logged) update — WAL/snapshot-recorded, so a
        # retransmit after recovery is acked-but-ignored
        self.ledger: Dict[str, Tuple[int, int]] = {}
        self.epoch = 0                   # number of server incarnations
        # equivalence logs + operational stats (tests read these)
        self.selection_log: List[tuple] = []
        self.alpha_log: List[dict] = []
        self.staleness: List[int] = []   # q of each ADMITTED update
        self.curves = {"round": [], "accuracy": []}
        self.client_logs: Dict[str, list] = {}
        self.stats = {"rounds": 0, "admitted": 0, "late": 0, "short_polls": 0,
                      "resets": 0, "rejoins": 0, "byes": 0, "dedup": 0,
                      "recoveries": 0}
        self._stopping = False
        self._ready: set = set()
        self._started = False
        # durability layer (docs/architecture.md §12)
        self.wal_dir = wal_dir
        self.ckpt_every = int(ckpt_every)
        self._wal = wal.WalWriter(wal_dir, fsync=wal_fsync) \
            if wal_dir else None
        self._chaos = chaos              # comms.faults.ServerCrashSwitch
        self._recovered = False
        self._last_close: Optional[dict] = None

    # -- actor contract ------------------------------------------------------

    def on_start(self, api: TransportAPI) -> None:
        if self._recovered:
            self._resume(api)
            return
        # hello barrier: clients check in before round 0 — on the proc
        # transport a child spends seconds importing jax and warming up its
        # SGD jit, and starting the cadence early would turn the first
        # rounds into spurious short polls. The fallback timer bounds the
        # wait so a never-arriving client can't wedge startup.
        api.set_timer("barrier", self.cfg.barrier_timeout)

    def _begin(self, api: TransportAPI) -> None:
        if self._started:
            return
        self._started = True
        api.cancel_timer("barrier")
        api.set_timer("round", 0.0)

    def on_timer(self, name: str, api: TransportAPI) -> None:
        if name == "barrier":
            self._begin(api)
        elif name == "round":
            if self._open:               # safety: harvest timer not yet fired
                self._close_round(api)
            if self.round + 1 >= self.cfg.rounds:
                self._shutdown(api)
            else:
                self._start_round(api)
                api.set_timer("round", self.cfg.round_dur)
        elif name == "harvest":
            if self._open:
                self._close_round(api)
        elif name == "drain":
            api.stop()

    def on_message(self, src: str, msg, api: TransportAPI) -> None:
        kind = msg.get("kind")
        if kind == "hello":
            self._ready.add(src)
            if len(self._ready) >= len(self.client_ids):
                self._begin(api)
        elif kind == "update":
            self._on_update(src, msg, api)
        elif kind == "join":
            self.stats["rejoins"] += 1
            api.send(src, {"kind": "sync", "round": self.round,
                           "params": self._server_payload()})
        elif kind == "bye":
            self.client_logs[src] = msg.get("log", [])
            self.stats["byes"] += 1
            if self._stopping and self.stats["byes"] >= len(self.client_ids):
                api.stop()

    # -- round machinery -----------------------------------------------------

    def _start_round(self, api: TransportAPI) -> None:
        self.round += 1
        r = self.round
        if (self.eval_fn is not None and self.cfg.eval_every_rounds > 0
                and r % self.cfg.eval_every_rounds == 0):
            self._record(r)
        # fl_sim's exact per-round chain: k_q is split even when unused
        self.rkey, k_sel, self._k_q = jax.random.split(self.rkey, 3)
        idx, _ = sampler.sample_selection_indices(
            k_sel, self.cfg.n_clients, self.cfg.s_selected)
        sel = set(int(i) for i in np.asarray(idx))
        self.selection_log.append(tuple(sorted(sel)))
        self._polled = [c for c in self.client_ids if self._row[c] in sel]
        self._open = True
        self.pending = {}
        # the record carries only the round index: selection re-derives
        # from the logged key chain on replay, so it cannot diverge
        self._durable("round_start", {"kind": "round_start", "round": r})
        for c in self.client_ids:
            api.send(c, {"kind": "tick", "round": r,
                         "polled": c in self._polled})
        api.set_timer("harvest", self.cfg.harvest_frac * self.cfg.round_dur)

    def _on_update(self, src: str, msg, api: TransportAPI) -> None:
        r = int(msg.get("round"))
        seq = int(msg.get("seq", -1))
        led = self.ledger.get(src)
        if seq >= 0 and led is not None and (r, seq) <= led:
            # already durably admitted (possibly by a pre-crash
            # incarnation): exactly-once means ack-but-ignore
            self.stats["dedup"] += 1
            api.send(src, {"kind": "ack", "round": r,
                           "stale": not (self._open and r == self.round)})
            return
        # ack everything (duplicates included) so client retries stop;
        # stale=True tells the client the round already closed without it
        if not self._open or r != self.round or src not in self._polled:
            self.stats["late"] += 1
            api.send(src, {"kind": "ack", "round": r, "stale": True})
            return
        if src in self.pending:          # duplicate without a seq stamp
            api.send(src, {"kind": "ack", "round": r, "stale": False})
            return
        ent = self._admit(src, msg)
        if seq >= 0:
            self.ledger[src] = (r, seq)
        # write-ahead THEN ack: once the client sees this ack, the update
        # is durable — a restart can never lose an acknowledged admission
        self._durable("admit", {"kind": "admit", "round": r, "client": src,
                                "seq": seq, "entry": dict(ent)})
        api.send(src, {"kind": "ack", "round": r, "stale": False})
        self.pending[src] = ent
        self.stats["admitted"] += 1
        self.staleness.append(int(msg["q"]))
        if len(self.pending) == len(self._polled):
            api.cancel_timer("harvest")
            self._close_round(api)

    def _admit(self, src: str, msg) -> dict:
        """Build the pending entry. With quant_bits > 0 the update is held
        as LUQ codes + scales keyed by fold_in(k_q, row) — the
        checkpointable between-round representation."""
        bufs = [np.asarray(b, np.float32) for b in msg["params"]]
        ent = {"q": np.int32(msg["q"])}
        if self.cfg.quant_bits > 0:
            key = jax.random.fold_in(self._k_q, self._row[src])
            for b, buf in enumerate(bufs):
                enc = kops.cold_requant_rows(buf[None, :],
                                             self.cfg.quant_bits, key)
                ent[f"codes{b}"] = np.asarray(enc["codes"])
                ent[f"scale{b}"] = np.asarray(enc["scale"])
        else:
            for b, buf in enumerate(bufs):
                ent[f"raw{b}"] = buf
        return ent

    def _pending_row(self, ent: dict, b: int, dtype) -> np.ndarray:
        if self.cfg.quant_bits > 0:
            dec = kops.cold_dequant_rows(
                {"codes": ent[f"codes{b}"], "scale": ent[f"scale{b}"]},
                self.cfg.quant_bits, dtype)
            return np.asarray(dec)[0]
        return ent[f"raw{b}"]

    def _close_round(self, api: TransportAPI) -> None:
        self._open = False
        self.stats["rounds"] += 1
        admitted = sorted(self.pending, key=self._row.get)
        if len(admitted) < len(self._polled):
            self.stats["short_polls"] += 1
        # redo log, not a value log: the record names the admitted set and
        # replay re-runs the deterministic aggregation over the (already
        # logged) admit entries — closes cost O(#admitted) WAL bytes
        self._durable("close", {"kind": "close", "round": self.round,
                                "admitted": list(admitted)})
        self._apply_close(admitted)
        self._last_close = {"round": self.round, "admitted": list(admitted)}
        self.pending = {}
        if admitted:
            payload = self._server_payload()
            for c in admitted:
                api.send(c, {"kind": "reset", "round": self.round,
                             "params": payload})
                self.stats["resets"] += 1
        self._maybe_checkpoint()

    def _apply_close(self, admitted: List[str]) -> None:
        """The deterministic aggregation for one close, over entries in
        ``self.pending`` — shared verbatim by the live path and WAL
        replay, which is what makes recovered buckets bit-exact."""
        if not admitted:
            return                       # nobody delivered: w_{t+1} = w_t
        n = self.cfg.n_clients
        alpha = np.ones((n,), np.float32)
        mask = np.zeros((n,), np.float32)
        cli_f = [np.array(b) for b in self.cli_f]   # writable host copies
        for c in admitted:
            ent = self.pending[c]
            row = self._row[c]
            alpha[row] = max(float(ent["q"]), 1.0)   # eq. 3, stochastic
            mask[row] = 1.0
            for b in range(self.spec.n_buckets):
                cli_f[b][row] = self._pending_row(ent, b, cli_f[b].dtype)
        self.alpha_log.append({c: float(alpha[self._row[c]])
                               for c in admitted})
        alpha_p = round_engine.pad_client_vec(self.spec, alpha, 1.0)
        mask_p = round_engine.pad_client_vec(self.spec, mask, 0.0)
        out = [round_engine.fused_bucket_update(
                   self.spec, b, self.srv_f[b], jax.numpy.asarray(cli_f[b]),
                   self.ini_f[b], alpha_p, mask_p, float(len(admitted)),
                   n_logical=n)
               for b in range(self.spec.n_buckets)]
        self.srv_f = tuple(o[0] for o in out)
        self.cli_f = tuple(o[1] for o in out)
        self.ini_f = tuple(o[2] for o in out)

    def _shutdown(self, api: TransportAPI) -> None:
        self._record(self.cfg.rounds)
        self._stopping = True
        if self._wal is not None:
            self._wal.close()
        for c in self.client_ids:
            api.send(c, {"kind": "stop"})
        # fallback: stop even if some byes never arrive (crashed clients)
        api.set_timer("drain", 2.0 * self.cfg.round_dur)

    # -- durability: WAL, snapshots, recovery (docs/architecture.md §12) -----

    def _durable(self, point: str, rec: dict) -> None:
        """Append a WAL record, then give the chaos switch its shot. The
        kill point sits BETWEEN the durable write and every effect that
        acknowledges it (acks, resets, ticks) — exactly the interleaving
        recovery has to get right."""
        if self._wal is not None:
            self._wal.append(rec)
        if self._chaos is not None:
            self._chaos.hit(point, wal=self._wal)

    def _maybe_checkpoint(self) -> None:
        """Every ``ckpt_every`` closed rounds: rotate the WAL, snapshot
        the full state atomically, prune segments the snapshot covers."""
        if (self._wal is None or self.ckpt_every <= 0
                or self.stats["rounds"] % self.ckpt_every != 0):
            return
        seg = self._wal.rotate()         # snapshot covers everything < seg
        state = self._snapshot_state()
        state["seg"] = seg
        wal.save_snapshot(self.wal_dir, self.stats["rounds"], state)
        wal.prune_segments(self.wal_dir, seg)
        wal.prune_snapshots(self.wal_dir, keep=2)

    def _snapshot_state(self) -> dict:
        """Everything a restarted server needs BESIDES the tail of the
        WAL. Only taken at a close boundary, so ``pending`` is always
        empty here — in-flight admissions live in the log, never in the
        snapshot."""
        return {
            "server": [np.asarray(b) for b in self.srv_f],
            "clients": [np.asarray(b) for b in self.cli_f],
            "inits": [np.asarray(b) for b in self.ini_f],
            "rkey": np.asarray(self.rkey),
            "round": int(self.round),
            "ledger": dict(self.ledger),
            "epoch": int(self.epoch),
            "stats": dict(self.stats),
            "selection": list(self.selection_log),
            "alpha": list(self.alpha_log),
            "staleness": list(self.staleness),
            "curves": {k: list(v) for k, v in self.curves.items()},
            "last_close": self._last_close,
        }

    def _restore_snapshot(self, state: dict) -> int:
        self.srv_f = tuple(jax.numpy.asarray(b) for b in state["server"])
        self.cli_f = tuple(jax.numpy.asarray(b) for b in state["clients"])
        self.ini_f = tuple(jax.numpy.asarray(b) for b in state["inits"])
        self.rkey = jax.numpy.asarray(state["rkey"])
        self.round = int(state["round"])
        self.ledger = {c: tuple(v) for c, v in state["ledger"].items()}
        self.epoch = int(state["epoch"])
        self.stats.update(state["stats"])
        self.selection_log = list(state["selection"])
        self.alpha_log = list(state["alpha"])
        self.staleness = list(state["staleness"])
        self.curves = {k: list(v) for k, v in state["curves"].items()}
        self._last_close = state["last_close"]
        return int(state["seg"])

    def _recover(self) -> None:
        """Rebuild state as latest-valid-snapshot + WAL replay. Runs on a
        FRESH server object before it joins a transport; the subsequent
        ``on_start`` then executes the resume protocol instead of the
        cold-start barrier."""
        start_seg = 0
        snap = wal.latest_snapshot(self.wal_dir)
        if snap is not None:
            start_seg = self._restore_snapshot(wal.load_snapshot(snap))
        records, meta = wal.replay(self.wal_dir, start_seg)
        for rec in records:
            self._replay_record(rec)
        self.epoch += 1
        self.stats["recoveries"] += 1
        # a dead process cannot log its own death — the new incarnation
        # logs its BIRTH instead, so epoch/recovery counts survive further
        # crashes (replay of this record re-counts it)
        if self._wal is not None:
            self._wal.append({"kind": "recovered", "epoch": self.epoch})
        self._recovered = True
        self.replay_meta = dict(meta, records=len(records))

    def _replay_record(self, rec: dict) -> None:
        """Re-apply one logged transition. Appends happen strictly in
        protocol order and a tear only ever truncates the suffix, so a
        readable ``close`` always finds its admits already replayed."""
        kind = rec["kind"]
        if kind == "round_start":
            self.round = int(rec["round"])
            # same chain walk as _start_round — selection re-derives
            self.rkey, k_sel, self._k_q = jax.random.split(self.rkey, 3)
            idx, _ = sampler.sample_selection_indices(
                k_sel, self.cfg.n_clients, self.cfg.s_selected)
            sel = set(int(i) for i in np.asarray(idx))
            self.selection_log.append(tuple(sorted(sel)))
            self._polled = [c for c in self.client_ids
                            if self._row[c] in sel]
            self._open = True
            self.pending = {}
        elif kind == "admit":
            src = rec["client"]
            self.pending[src] = dict(rec["entry"])
            if rec["seq"] >= 0:
                self.ledger[src] = (int(rec["round"]), int(rec["seq"]))
            self.stats["admitted"] += 1
            self.staleness.append(int(rec["entry"]["q"]))
        elif kind == "close":
            admitted = list(rec["admitted"])
            self._open = False
            self.stats["rounds"] += 1
            if len(admitted) < len(self._polled):
                self.stats["short_polls"] += 1
            self._apply_close(admitted)
            self._last_close = {"round": self.round,
                                "admitted": admitted}
            self.pending = {}
        elif kind == "recovered":        # a prior incarnation's birth
            self.epoch = max(self.epoch, int(rec["epoch"]))
            self.stats["recoveries"] = self.epoch
        else:                            # forward-compat: ignore unknown
            pass

    def _resume(self, api: TransportAPI) -> None:
        """First ``on_start`` after recovery. Re-sends anything whose
        delivery the crash may have swallowed — clients treat resets and
        ticks idempotently by round, so over-sending is safe — and
        restarts the round cadence. Stretching the interrupted round's
        wall time is invisible to the aggregate: buckets depend on the
        selection chain, the admitted sets, and the logged entries, none
        of which see the clock."""
        self._started = True
        for c in self.client_ids:
            api.send(c, {"kind": "recover", "epoch": self.epoch,
                         "round": self.round})
        if self._last_close is not None and self._last_close["admitted"]:
            # the last close's resets may have died with the old process
            payload = self._server_payload()
            for c in self._last_close["admitted"]:
                api.send(c, {"kind": "reset",
                             "round": self._last_close["round"],
                             "params": payload})
        if self._open:
            # re-broadcast the open round's ticks and restart its clock
            for c in self.client_ids:
                api.send(c, {"kind": "tick", "round": self.round,
                             "polled": c in self._polled})
            api.set_timer("round", self.cfg.round_dur)
            if len(self.pending) == len(self._polled):
                self._close_round(api)   # everyone delivered pre-crash
            else:
                api.set_timer("harvest",
                              self.cfg.harvest_frac * self.cfg.round_dur)
        elif self.round + 1 >= self.cfg.rounds:
            self._shutdown(api)
        else:
            api.set_timer("round", 0.0)

    # -- views / checkpointing ----------------------------------------------

    def _server_payload(self) -> list:
        return [np.asarray(b) for b in self.srv_f]

    def server_params(self):
        return round_engine.unflatten_tree(self.spec, self.srv_f)

    def _record(self, r: int) -> None:
        if self.eval_fn is not None:
            self.curves["round"].append(r)
            self.curves["accuracy"].append(float(self.eval_fn(
                self.server_params())))

    def result(self) -> dict:
        return {"rounds": self.stats["rounds"],
                "final_accuracy": (self.curves["accuracy"][-1]
                                   if self.curves["accuracy"] else None),
                "curves": {k: list(v) for k, v in self.curves.items()},
                "selection": list(self.selection_log),
                "alpha": list(self.alpha_log),
                "staleness": list(self.staleness),
                "stats": dict(self.stats)}

    def checkpoint_state(self) -> dict:
        """The full restartable aggregator state as one pytree: flat
        buckets, the rng key chain, the round counter, and every pending
        admitted update (codes + scales under quant_bits > 0, raw rows
        otherwise). Feed to ``ckpt.save_engine_checkpoint`` /
        ``load_engine_checkpoint``."""
        return {
            "server": tuple(self.srv_f),
            "clients": tuple(self.cli_f),
            "inits": tuple(self.ini_f),
            "rkey": self.rkey,
            "round": np.int32(self.round),
            "pending": {c: dict(ent) for c, ent in self.pending.items()},
        }

    def save(self, directory: str, step: Optional[int] = None) -> str:
        from repro.checkpointing.ckpt import save_engine_checkpoint
        return save_engine_checkpoint(
            directory, self.stats["rounds"] if step is None else step,
            self.checkpoint_state())

    def restore_state(self, state: dict) -> None:
        self.srv_f = tuple(jax.numpy.asarray(b) for b in state["server"])
        self.cli_f = tuple(jax.numpy.asarray(b) for b in state["clients"])
        self.ini_f = tuple(jax.numpy.asarray(b) for b in state["inits"])
        self.rkey = jax.numpy.asarray(state["rkey"])
        self.round = int(state["round"])
        self.pending = {c: dict(ent)
                        for c, ent in state.get("pending", {}).items()}

    def load(self, path: str) -> None:
        from repro.checkpointing.ckpt import load_engine_checkpoint
        self.restore_state(load_engine_checkpoint(path,
                                                  self.checkpoint_state()))


def recover_server(cfg: AsyncConfig, params0, wal_dir: str, *,
                   eval_fn: Optional[Callable] = None,
                   client_ids: Optional[List[str]] = None,
                   ckpt_every: int = 0, wal_fsync: bool = True,
                   chaos=None) -> FavasAsyncServer:
    """The restart path: build a NEW server whose state is the latest
    valid snapshot plus a replay of the WAL records after it. The
    returned server's first ``on_start`` runs the resume protocol
    (``recover`` hello with the new epoch, idempotent re-sends, cadence
    restart) instead of the cold-start barrier. ``cfg`` / ``params0`` /
    ``client_ids`` must match the crashed deployment — they define the
    initial state the log is a delta against."""
    srv = FavasAsyncServer(cfg, params0, eval_fn=eval_fn,
                           client_ids=client_ids, wal_dir=wal_dir,
                           ckpt_every=ckpt_every, wal_fsync=wal_fsync,
                           chaos=chaos)
    srv._recover()
    return srv
