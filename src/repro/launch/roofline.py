"""Roofline analysis from compiled dry-run artifacts (CPU container — terms
are *derived*, not timed; TPU v5e is the target).

Terms per (arch, shape, mesh), all in seconds:
  compute    = FLOPs_per_chip / 197e12          (bf16 peak)
  memory     = HBM_bytes_per_chip / 819e9
  collective = collective_bytes_per_chip / 50e9 (per-link ICI)

Sources:
* collective bytes — parsed from ``compiled.as_text()``; XLA:CPU while loops
  carry ``backend_config={"known_trip_count":{"n":N}}``, so collectives inside
  scan bodies are multiplied by their (possibly nested) trip counts. This
  fixes the body-counted-once problem exactly for comms.
* ``compiled.cost_analysis()`` flops/bytes are recorded raw but — caveat —
  XLA's HloCostAnalysis counts while bodies ONCE; for scanned layers/steps
  the raw number underestimates by ~L*K. The roofline compute/memory terms
  therefore use the ANALYTIC estimators below (6*N*D etc.), and the raw
  numbers are kept as a cross-check column.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip, TPU v5e
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->.*\{\s*$")
_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*(\w+)\[([\d,]*)\][^ ]*\s+(" + "|".join(COLLECTIVES) + r")\(")
_WHILE_RE = re.compile(r"while\(.*?body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\(?\s*(\w+)\[([\d,]*)\]")
_DOT_RE = re.compile(
    r"=\s*(\w+)\[([\d,]*)\][^ ]*\s+dot\(\s*%?([\w.\-]+)\s*,")
_DOT_LHS_CONTR_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*\(?(\w+)\[([\d,]*)\]")


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)          # [n_groups, group_size]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2                                     # conservative default


def _wire_factor(kind: str, g: int) -> float:
    """Per-device ICI wire bytes as a multiple of the op's OUTPUT bytes.
    S = gathered (full) size: AG out = S, RS out = S/g, AR out = S."""
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g
    if kind == "all-gather":
        return (g - 1) / g
    if kind == "reduce-scatter":
        return float(g - 1)                      # input = g * output
    if kind == "all-to-all":
        return (g - 1) / g
    return 1.0                                   # collective-permute


# JAX einsum subscripts whose outputs are compute-dtype (bf16) on TPU.
# XLA:CPU float-normalizes bf16 dots to f32, so the CPU-compiled HLO shows
# f32 collectives where the TPU program moves bf16 — collectives whose
# op_name metadata stems from these einsums are counted at half width.
BF16_DOT_TAGS = ("...d,df->...f", "ecd,edf->ecf", "ecf,efd->ecd")


def collective_ops(hlo_text: str) -> List[Tuple[str, int]]:
    """Flat (kind, output_bytes) list of every collective op in an HLO text,
    ignoring trip counts — the raw census the sharded-engine acceptance
    check reads (tests assert no all-gather at full-flat-buffer size; see
    docs/architecture.md §6 and tests/test_sharded_engine.py)."""
    out = []
    for ln in hlo_text.splitlines():
        m = _COLL_RE.search(ln)
        if m:
            dtype, dims, kind = m.groups()
            out.append((kind, _shape_bytes(dtype, dims)))
    return out


def dense_materializations(hlo_text: str, *, rows: int, min_cols: int = 128,
                           dtypes: Tuple[str, ...] = ("f32", "bf16")
                           ) -> List[Tuple[str, str, Tuple[int, ...]]]:
    """Census of full-precision (rows, >=min_cols, ...) arrays DEFINED
    anywhere in an HLO text — the quantized-transport acceptance gate
    (docs/architecture.md §10, tests/test_quant_fused.py).

    A compiled codes-in round must never materialize the transmitted
    progress (or a cold pool) as a dense float array over the full client
    population: every op whose output is ``f32/bf16[rows, C>=min_cols,
    ...]`` is returned as ``(op_name, dtype, dims)``. ``rows`` is the
    population being gated (n for the whole round, s_max for the isolated
    cold promote/evict cycle); ``min_cols`` filters out (rows,)-shaped
    bookkeeping vectors and (rows, 1) scale columns, which are legitimate
    full-precision residents. uint8 code buffers at any shape pass — they
    ARE the storage format."""
    out = []
    for ln in hlo_text.splitlines():
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, dtype, dims = m.groups()
        if dtype not in dtypes or not dims.strip():
            continue
        d = tuple(int(x) for x in dims.split(","))
        if len(d) >= 2 and d[0] == rows and max(d[1:]) >= min_cols:
            out.append((name, dtype, d))
    return out


# entry-output defining opcodes that do NOT rewrite the full buffer: the
# output either aliases a donated input directly or is produced by an
# in-place churn-bounded update (scatter / dynamic-update-slice; XLA:CPU
# expands a row scatter to a while loop whose result surfaces through
# get-tuple-element). Everything else writes the whole buffer.
_IN_PLACE_OPS = frozenset({
    "parameter", "get-tuple-element", "dynamic-update-slice", "scatter",
    "bitcast", "copy-start", "copy-done", "optimization-barrier", "tuple",
})
_OPCODE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(?:\([^)]*\)|[\w\[\]{},]+)\s+([\w\-]+)\(")
_ROOT_OPERAND_RE = re.compile(r"(\w+)\[([\d,]*)\][^\s]*\s+%?([\w.\-]+)")


def pass_through_copies(hlo_text: str, *, rows: int, min_cols: int = 128,
                        dtypes: Tuple[str, ...] = ("f32", "bf16")
                        ) -> List[Tuple[str, str, Tuple[int, ...]]]:
    """Write-traffic audit of a compiled round (docs/architecture.md §13):
    entry outputs of shape ``(rows, >=min_cols, ...)`` in a full-precision
    dtype whose defining op REWRITES the whole buffer.

    The streamed schedule's contract is that the donated client/init
    stacks are only ever touched by churn-bounded in-place updates
    (scatter / dynamic-update-slice on the aliased input), so unselected
    rows are never rewritten — under the two-sweep schedule the same
    outputs are full ``(n, D)`` elementwise fusions (the ``m*s_new +
    (1-m)*x`` blend), ~1 extra read + 1 extra write per resident byte.
    Returns ``(output_name, defining_opcode, dims)`` per violation; a
    compiled streamed round must return ``[]`` (pinned in
    tests/test_streaming.py beside the ``dense_materializations`` gate
    this mirrors). ``rows`` is the client-stack row count (n padded, or
    s_max-stack rows for a paged round)."""
    lines = hlo_text.splitlines()
    opcodes: Dict[str, str] = {}
    for ln in lines:
        m = _OPCODE_RE.match(ln)
        if m:
            opcodes[m.group(1)] = m.group(2)
    # the ENTRY computation's ROOT line carries the typed operand list
    root = None
    in_entry = False
    for ln in lines:
        s = ln.strip()
        if s.startswith("ENTRY"):
            in_entry = True
        elif in_entry and s.startswith("ROOT"):
            root = s
            break
        elif in_entry and s == "}":
            in_entry = False
    if root is None:
        return []
    args = root.split("(", 2)[-1]
    out = []
    for dtype, dims, name in _ROOT_OPERAND_RE.findall(args):
        if dtype not in dtypes or not dims.strip():
            continue
        d = tuple(int(x) for x in dims.split(","))
        if len(d) < 2 or d[0] != rows or max(d[1:]) < min_cols:
            continue
        op = opcodes.get(name, "?")
        if op not in _IN_PLACE_OPS:
            out.append((name, op, d))
    return out


def round_traffic_report(compiled, *, rows: int, min_cols: int = 128) -> Dict:
    """HBM bytes-accessed-per-round audit of a compiled round executable:
    total "bytes accessed" from ``compiled.cost_analysis()`` (normalized —
    the ONE accessor, per ROADMAP) plus the :func:`pass_through_copies`
    write census. The streamed-vs-two-sweep traffic-reduction gate in
    tests/test_streaming.py and ``benchmarks.streaming_bench`` read this."""
    from repro.launch.dryrun import normalize_cost_analysis
    cost = normalize_cost_analysis(compiled.cost_analysis())
    return {
        "bytes_accessed": float(cost.get("bytes accessed", 0.0) or 0.0),
        "pass_through_copies": pass_through_copies(
            compiled.as_text(), rows=rows, min_cols=min_cols),
    }


def parse_hlo_collectives(hlo_text: str, *, bf16_dot_comms: bool = False) -> Dict:
    """Trip-count-aware collective byte accounting (per-device program).

    Returns {kind: bytes} plus per-kind op counts and the top shapes.
    ``bf16_dot_comms``: apply the TPU-dtype correction above (set when the
    model's compute dtype is bf16).
    """
    # 1. split into computations: header = "<name> (sig) -> ... {",
    #    body runs until a lone "}" (HLO computations are flat).
    comps: Dict[str, List[str]] = {}
    entry = None
    cur = None
    name_re = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
    for line in hlo_text.splitlines():
        s = line.strip()
        if cur is None:
            if s.endswith("{") and "->" in s:
                m = name_re.match(s)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
                    if s.startswith("ENTRY"):
                        entry = cur
        else:
            if s == "}":
                cur = None
            else:
                comps[cur].append(line)

    # 2. per-computation collectives, dots, and calls
    comp_coll: Dict[str, List[Tuple[str, int, float]]] = {}
    comp_flops: Dict[str, float] = {}
    comp_calls: Dict[str, List[Tuple[str, int]]] = {}   # (callee, multiplier)
    for name, lines in comps.items():
        colls, calls = [], []
        flops = 0.0
        # local symbol table: op/param name -> (dtype, dims) for dot operands
        symtab: Dict[str, Tuple[str, str]] = {}
        for ln in lines:
            dm = _DEF_RE.match(ln)
            if dm:
                symtab[dm.group(1)] = (dm.group(2), dm.group(3))
        for ln in lines:
            cm = _COLL_RE.search(ln)
            if cm:
                dtype, dims, kind = cm.groups()
                out_bytes = _shape_bytes(dtype, dims)
                if (bf16_dot_comms and dtype == "f32"
                        and any(t in ln for t in BF16_DOT_TAGS)):
                    out_bytes //= 2              # bf16 on the TPU target
                wire = out_bytes * _wire_factor(kind, _group_size(ln))
                colls.append((kind, out_bytes, wire))
            dot = _DOT_RE.search(ln)
            if dot:
                _, out_dims, lhs_name = dot.groups()
                out_elems = 1
                for d in out_dims.split(","):
                    if d:
                        out_elems *= int(d)
                contr = 1
                lhs = symtab.get(lhs_name)
                cdm = _DOT_LHS_CONTR_RE.search(ln)
                if lhs and cdm and cdm.group(1):
                    lhs_dims = [int(d) for d in lhs[1].split(",") if d]
                    for ci in cdm.group(1).split(","):
                        ci = int(ci)
                        if ci < len(lhs_dims):
                            contr *= lhs_dims[ci]
                flops += 2.0 * out_elems * contr
            if " while(" in ln:
                wm = _WHILE_RE.search(ln)
                tm = _TRIP_RE.search(ln)
                if wm:
                    calls.append((wm.group(1), int(tm.group(1)) if tm else 1))
            else:
                for callee in _CALL_RE.findall(ln):
                    calls.append((callee, 1))
        comp_coll[name] = colls
        comp_calls[name] = calls
        comp_flops[name] = flops

    # 3. walk from ENTRY with multipliers
    totals: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    wire_totals: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    shapes: Dict[str, float] = {}
    seen_stack = []
    dot_flops = [0.0]

    def walk(name: str, mult: float):
        if name not in comps or name in seen_stack:
            return
        seen_stack.append(name)
        for kind, out_bytes, wire in comp_coll.get(name, ()):
            totals[kind] += mult * out_bytes
            wire_totals[kind] += mult * wire
            counts[kind] += int(mult)
            key = f"{kind}:{out_bytes}"
            shapes[key] = shapes.get(key, 0) + mult * wire
        dot_flops[0] += mult * comp_flops.get(name, 0.0)
        for callee, m in comp_calls.get(name, ()):
            walk(callee, mult * m)
        seen_stack.pop()

    if entry is None and comps:
        entry = list(comps)[-1]
    walk(entry, 1.0)
    top = sorted(shapes.items(), key=lambda kv: -kv[1])[:8]
    return {"bytes_by_kind": totals, "op_counts": counts,
            "wire_bytes_by_kind": wire_totals,
            "total_bytes": sum(wire_totals.values()),
            "output_bytes": sum(totals.values()),
            "dot_flops": dot_flops[0],
            "top_contributors": top}


# ---------------------------------------------------------------------------
# Analytic FLOPs / bytes estimators
# ---------------------------------------------------------------------------

def model_param_counts(cfg) -> Dict[str, int]:
    """Exact param counts via eval_shape (no allocation)."""
    import jax
    import functools
    from repro.models.model import init_params
    from repro.utils.tree import tree_param_count
    key = jax.ShapeDtypeStruct((2,), "uint32")
    sds = jax.eval_shape(functools.partial(init_params, cfg=cfg), key)
    total = tree_param_count(sds)
    embed = tree_param_count(sds["embed"])
    expert = 0
    if cfg.arch_type == "moe":
        def moe_leaves(t):
            out = 0
            layers = t["layers"]
            mlp = layers["mlp"] if isinstance(layers, dict) else None
            if mlp is not None:
                for k in ("gate", "up", "down"):
                    out += mlp[k].size if hasattr(mlp[k], "size") else 0
            return out
        expert = moe_leaves(sds)
    active = total - expert + (expert * cfg.top_k // max(cfg.n_experts, 1)
                               if expert else 0)
    return {"total": total, "embed": embed, "expert": expert, "active": active}


def analytic_flops(cfg, shape_info: dict, n_chips: int, local_steps: int = 0,
                   window_override: Optional[int] = None) -> Dict[str, float]:
    """MODEL_FLOPS per the task spec + attention extras, whole-program."""
    counts = model_param_counts(cfg)
    N = counts["active"] if cfg.arch_type == "moe" else counts["total"]
    S, B = shape_info["seq"], shape_info["global_batch"]
    kind = shape_info["kind"]
    hd = cfg.head_dim or 0
    Hq = cfg.n_heads
    L_attn = sum(1 for k in cfg.layer_kinds() if k in ("attn", "local_attn"))
    win = window_override if window_override is not None else cfg.window

    if kind == "train":
        tokens = B * S * max(local_steps, 1)
        flops = 6.0 * N * tokens
        kv_span = min(win, S) if win else S
        flops += 3 * 2 * 2 * B * max(local_steps, 1) * Hq * hd * S * kv_span \
            / 2 * L_attn
    elif kind == "prefill":
        tokens = B * S
        flops = 2.0 * N * tokens
        kv_span = min(win, S) if win else S
        flops += 2 * 2 * B * Hq * hd * S * kv_span / 2 * L_attn
    else:  # decode: one token, cache of length S
        tokens = B
        flops = 2.0 * N * tokens
        span = min(win, S) if win else S
        if cfg.arch_type == "hybrid":
            span = min(2048, S)
        flops += 2 * 2 * B * Hq * hd * span * L_attn
    return {"model_flops": flops, "per_chip": flops / n_chips,
            "params": counts}


def analytic_bytes(cfg, shape_info: dict, n_chips: int, model_shards: int,
                   local_steps: int = 0, param_bytes: int = 4) -> float:
    """Dominant HBM traffic per chip: weight traffic (+cache for decode)."""
    counts = model_param_counts(cfg)
    N = counts["total"]
    kind = shape_info["kind"]
    S, B = shape_info["seq"], shape_info["global_batch"]
    w_per_chip = N * param_bytes / model_shards
    if kind == "train":
        # fwd read + bwd read + grad write + update r/w, per local step,
        # x3 resident copies touched at aggregation
        return (4 * w_per_chip * max(local_steps, 1) + 3 * w_per_chip)
    if kind == "prefill":
        act = B * S * cfg.d_model * 2 * max(cfg.n_layers, 1) * 4 / n_chips
        return w_per_chip + act
    # decode
    kv_layers = sum(1 for k in cfg.layer_kinds() if k in ("attn", "local_attn"))
    span = min(cfg.window, S) if cfg.window else S
    if cfg.arch_type == "hybrid":
        span = min(2048, S)
    kv_elt = 1 if cfg.kv_cache_dtype == "int8" else 2
    cache = B * span * cfg.n_kv_heads * ((cfg.head_dim or 0) * kv_elt
                                         + (2 if kv_elt == 1 else 0)) \
        * 2 * kv_layers
    if cfg.arch_type == "ssm":
        cache = B * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4 \
            * cfg.n_layers * 2
    return w_per_chip * 2 / param_bytes + cache / n_chips  # bf16 weights read


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    raw_cost_flops: float
    raw_cost_bytes: float
    collective_bytes: float
    dominant: str
    useful_ratio: float

    def row(self) -> str:
        return (f"{self.arch},{self.shape},{self.mesh},{self.compute_s:.3e},"
                f"{self.memory_s:.3e},{self.collective_s:.3e},{self.dominant},"
                f"{self.model_flops:.3e},{self.useful_ratio:.3f}")


def build_report(arch: str, shape_name: str, mesh_name: str, cfg, shape_info,
                 n_chips: int, model_shards: int, cost: dict, coll: dict,
                 local_steps: int = 0, param_bytes: int = 4) -> RooflineReport:
    fl = analytic_flops(cfg, shape_info, n_chips, local_steps)
    by = analytic_bytes(cfg, shape_info, n_chips, model_shards, local_steps,
                        param_bytes)
    # compute term: prefer the trip-adjusted per-device dot FLOPs parsed from
    # the compiled HLO (counts remat recompute!); analytic as floor/fallback.
    hlo_flops_chip = float(coll.get("dot_flops", 0.0) or 0.0)
    compute_s = max(hlo_flops_chip, fl["per_chip"]) / PEAK_FLOPS
    memory_s = by / HBM_BW
    coll_s = coll["total_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    raw_flops = float(cost.get("flops", 0.0) or 0.0)
    useful = fl["model_flops"] / max(hlo_flops_chip * n_chips, 1.0)
    return RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, n_chips=n_chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        model_flops=fl["model_flops"], raw_cost_flops=raw_flops,
        raw_cost_bytes=float(cost.get("bytes accessed", 0.0) or 0.0),
        collective_bytes=coll["total_bytes"], dominant=dominant,
        useful_ratio=min(useful, 1e6),
    )
