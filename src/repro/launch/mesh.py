"""Production meshes (TPU v5e target).

single pod : (16, 16)      axes ("data", "model")          = 256 chips
multi pod  : (2, 16, 16)   axes ("pod", "data", "model")   = 512 chips

FAVAS clients live on the ("pod", "data") product axis — one resident client
per data-parallel coordinate; "model" is tensor parallelism. Defined as a
FUNCTION so importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The mesh axes that carry clients/batch (everything but "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_client_slots(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = 1
    for a in data_axes(mesh):
        out *= sizes[a]
    return out
