"""Production meshes (TPU v5e target).

single pod : (16, 16)      axes ("data", "model")          = 256 chips
multi pod  : (2, 16, 16)   axes ("pod", "data", "model")   = 512 chips

FAVAS clients live on the ("pod", "data") product axis — one resident client
per data-parallel coordinate; "model" is tensor parallelism. Defined as a
FUNCTION so importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_model_mesh(n_model: int | None = None):
    """1-D ("model",) mesh — the tensor-parallel slice of the production
    meshes, and what the forced-8-CPU-device sharded tests / benchmarks run
    on. ``n_model=None`` uses every visible device."""
    n = n_model or len(jax.devices())
    return jax.make_mesh((n,), ("model",))


def mesh_from_arg(arg: str | None):
    """Parse a ``--mesh`` CLI value into a mesh (or None).

    "none"/"" -> None (single-device engine, the CPU default);
    "model"   -> all visible devices on a 1-D ("model",) mesh;
    "model=K" -> K devices on a 1-D ("model",) mesh;
    "single"  -> the 256-chip (16, 16) ("data", "model") production mesh;
    "multi"   -> the 512-chip (2, 16, 16) ("pod", "data", "model") mesh."""
    if arg in (None, "none", ""):
        return None
    if arg == "single":
        return make_production_mesh()
    if arg == "multi":
        return make_production_mesh(multi_pod=True)
    if arg == "model":
        return make_model_mesh()
    if arg.startswith("model="):
        return make_model_mesh(int(arg.split("=", 1)[1]))
    raise ValueError(f"unknown --mesh value: {arg!r}")


def model_axis_size(mesh) -> int:
    if mesh is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)


def data_axes(mesh) -> tuple:
    """The mesh axes that carry clients/batch (everything but "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_client_slots(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = 1
    for a in data_axes(mesh):
        out *= sizes[a]
    return out
