"""Cluster orchestrator for the async FAVAS deployment (ROADMAP open
item 2's deliverable; docs/architecture.md §11).

Two runners over the SAME server/client actors:

* :func:`run_inproc` — everything on one :class:`InProcTransport` event
  loop: virtual clock, seeded faults, fully deterministic. The test
  substrate (tests/test_async_server.py) and the simulated baseline of the
  async benchmark.
* :func:`run_proc` — the server in THIS process, each client a real
  spawned OS process, wired in a star of duplex pipes with
  :class:`ProcEndpoint` pumps on both ends. Wall-clock latencies are
  injected by the shared :class:`FaultPlan`; teardown is
  stop-broadcast -> bye harvest -> join-with-deadline -> terminate
  stragglers, and the result reports per-child exit codes so CI can gate
  on a clean shutdown.

CLI (the CI 2-client smoke and the bench's workhorse)::

  PYTHONPATH=src python -m repro.launch.cluster --transport proc \
      --clients 2 --rounds 20 --latency 0.02 --out cluster_summary.json

exits non-zero unless every round completed and every child exited 0.
"""
from __future__ import annotations

import argparse
import json
import multiprocessing as mp
import os
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comms import BackoffPolicy, FaultPlan, InProcTransport, ProcEndpoint
from repro.core import sampler
from repro.launch.client import LocalSGDClient
from repro.launch.server import (SERVER_ID, AsyncConfig, FavasAsyncServer,
                                 recover_server)
from repro.models.classifier import accuracy, mlp_apply, mlp_init


def default_backoff(cfg: AsyncConfig) -> BackoffPolicy:
    """Push-retry schedule scaled to the round: first retry at
    round_dur/4 (comfortably above a sane RTT, so an in-flight ack usually
    cancels it), doubling, capped at one round — several attempts still fit
    inside the harvest window on either clock."""
    return BackoffPolicy(base=max(cfg.round_dur / 4.0, 1e-3),
                         factor=2.0, max_delay=cfg.round_dur,
                         max_attempts=6)


def _client_seed(cfg: AsyncConfig, i: int) -> int:
    # distinct per-client batch streams, disjoint from the server chain
    return (cfg.seed * 1009 + 17 * i + 13) % (2 ** 31)


def build_deployment(cfg: AsyncConfig, data, *, d_hidden: int = 32,
                     backoff: Optional[BackoffPolicy] = None,
                     wal_dir: Optional[str] = None, ckpt_every: int = 0,
                     wal_fsync: bool = True, chaos=None):
    """Shared setup for both runners: the model init and server rng ride
    the exact fl_sim chain (``PRNGKey(cfg.seed)`` for both), the step-time
    vector is fl_sim's ``_step_times`` draw, and the integer tick grid
    comes from ``sampler.time_ticks`` — the preconditions of the
    equivalence contract. ``wal_dir`` arms the server's durability layer
    (docs/architecture.md §12). Returns ``(server, clients)``."""
    xtr, ytr, xte, yte, parts = data
    n_classes = int(ytr.max()) + 1
    params0 = mlp_init(jax.random.PRNGKey(cfg.seed), xtr.shape[1],
                       d_hidden, n_classes)
    step_time = cfg.step_times()
    step_ticks, round_ticks = sampler.time_ticks(step_time, cfg.round_dur)
    xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)
    eval_fn = jax.jit(lambda p: accuracy(p, mlp_apply, xte_j, yte_j))
    server = FavasAsyncServer(cfg, params0, eval_fn=eval_fn,
                              wal_dir=wal_dir, ckpt_every=ckpt_every,
                              wal_fsync=wal_fsync, chaos=chaos)
    backoff = backoff or default_backoff(cfg)
    clients = [
        LocalSGDClient(server.client_ids[i], params0,
                       xtr[parts[i]], ytr[parts[i]],
                       n_clients=cfg.n_clients, batch_size=cfg.batch_size,
                       eta=cfg.eta, K=cfg.K,
                       step_ticks=int(step_ticks[i]),
                       round_ticks=round_ticks, n_classes=n_classes,
                       seed=_client_seed(cfg, i), backoff=backoff)
        for i in range(cfg.n_clients)]
    return server, clients


# ---------------------------------------------------------------------------
# deterministic in-process runner
# ---------------------------------------------------------------------------

def run_inproc(cfg: AsyncConfig, data, *, d_hidden: int = 32,
               plan: Optional[FaultPlan] = None, seed: int = 0,
               max_events: int = 2_000_000,
               wal_dir: Optional[str] = None, ckpt_every: int = 0,
               wal_fsync: bool = True) -> dict:
    """One deterministic virtual-clock run. Returns the server result plus
    per-client logs/stats and the transport counters; ``virtual_time`` is
    where the clock stopped."""
    server, clients = build_deployment(cfg, data, d_hidden=d_hidden,
                                       wal_dir=wal_dir,
                                       ckpt_every=ckpt_every,
                                       wal_fsync=wal_fsync)
    t = InProcTransport(plan, seed=seed)
    t.add_actor(server)
    for c in clients:
        t.add_actor(c)
    t.run(max_events=max_events)
    return {"server": server.result(),
            "client_logs": {c.node_id: list(c.log) for c in clients},
            "client_stats": {c.node_id: dict(c.stats) for c in clients},
            "transport": dict(t.stats),
            "virtual_time": t._now,
            "server_actor": server}


def recovered_server(cfg: AsyncConfig, data, *, d_hidden: int = 32,
                     wal_dir: str, ckpt_every: int = 0,
                     wal_fsync: bool = True, chaos=None) -> FavasAsyncServer:
    """Rebuild the server after a crash: re-derive the same ``params0`` /
    eval_fn as :func:`build_deployment` and recover state from the WAL
    directory (snapshot + replay)."""
    xtr, ytr, xte, yte, _ = data
    n_classes = int(ytr.max()) + 1
    params0 = mlp_init(jax.random.PRNGKey(cfg.seed), xtr.shape[1],
                       d_hidden, n_classes)
    xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)
    eval_fn = jax.jit(lambda p: accuracy(p, mlp_apply, xte_j, yte_j))
    return recover_server(cfg, params0, wal_dir, eval_fn=eval_fn,
                          ckpt_every=ckpt_every, wal_fsync=wal_fsync,
                          chaos=chaos)


def run_inproc_chaos(cfg: AsyncConfig, data, *, d_hidden: int = 32,
                     wal_dir: str, ckpt_every: int = 0,
                     kills=(), plan: Optional[FaultPlan] = None,
                     seed: int = 0, max_events: int = 2_000_000) -> dict:
    """Deterministic kill/restart harness on the virtual clock.

    ``kills`` is a sequence of :class:`repro.comms.ServerCrashSwitch`es,
    armed one at a time: the run steps the clock in small slices; when the
    armed switch has fired (the server died at its durability point) the
    supervisor builds a :func:`recovered_server`, swaps it in via
    ``InProcTransport.revive``, and arms the next switch. Slices are a
    quarter round — shorter than the first push-retry backoff — so no
    client exhausts its retries against a dead server. The recovered
    trajectory's buckets are BIT-EXACT vs an uninterrupted run on the same
    seed (tests/test_chaos_recovery.py)."""
    switches = list(kills)
    chaos = switches.pop(0) if switches else None
    server, clients = build_deployment(cfg, data, d_hidden=d_hidden,
                                       wal_dir=wal_dir,
                                       ckpt_every=ckpt_every, chaos=chaos)
    t = InProcTransport(plan, seed=seed)
    t.add_actor(server)
    for c in clients:
        t.add_actor(c)
    step = cfg.round_dur / 4.0
    horizon = 0.0
    wedge = 100.0 * (cfg.rounds + 2) * cfg.round_dur
    recoveries = 0
    while True:
        horizon += step
        if horizon > wedge:
            raise RuntimeError("chaos run exceeded its virtual-time bound")
        t.run(until=horizon, max_events=max_events)
        if SERVER_ID in t.killed_nodes():
            chaos = switches.pop(0) if switches else None
            server = recovered_server(cfg, data, d_hidden=d_hidden,
                                      wal_dir=wal_dir,
                                      ckpt_every=ckpt_every, chaos=chaos)
            t.revive(server)
            recoveries += 1
        elif t.done():
            break
    return {"server": server.result(),
            "client_logs": {c.node_id: list(c.log) for c in clients},
            "client_stats": {c.node_id: dict(c.stats) for c in clients},
            "transport": dict(t.stats),
            "virtual_time": t._now,
            "recoveries": recoveries,
            "server_actor": server}


# ---------------------------------------------------------------------------
# real multi-process runner
# ---------------------------------------------------------------------------

def _client_main(conn, payload, plan, seed, until):
    """Spawned-child entry: rebuild the worker from the picklable payload
    (the model init is re-derived from the seed, not shipped) and pump its
    endpoint until stop/timeout."""
    cfg = payload["cfg"]
    params0 = mlp_init(jax.random.PRNGKey(cfg.seed), payload["d_in"],
                       payload["d_hidden"], payload["n_classes"])
    client = LocalSGDClient(payload["node_id"], params0,
                            payload["x"], payload["y"],
                            n_clients=cfg.n_clients,
                            batch_size=cfg.batch_size, eta=cfg.eta,
                            K=cfg.K, step_ticks=payload["step_ticks"],
                            round_ticks=payload["round_ticks"],
                            n_classes=payload["n_classes"],
                            seed=payload["seed"],
                            backoff=payload["backoff"])
    client.warmup(range(1, cfg.K + 1))
    ep = ProcEndpoint(payload["node_id"], {SERVER_ID: conn}, plan=plan,
                      seed=seed)
    try:
        ep.run(client, until=until)
    finally:
        ep.close()


def run_proc(cfg: AsyncConfig, data, *, d_hidden: int = 32,
             plan: Optional[FaultPlan] = None, seed: int = 0,
             timeout: Optional[float] = None,
             wal_dir: Optional[str] = None, ckpt_every: int = 0) -> dict:
    """Spawn ``cfg.n_clients`` worker processes, run the server endpoint in
    this process, harvest, and tear down. ``timeout`` bounds the server
    pump (default: the nominal schedule plus generous slack) so a wedged
    transport fails fast instead of hanging the caller."""
    xtr, ytr, _, _, parts = data
    n_classes = int(ytr.max()) + 1
    step_time = cfg.step_times()
    step_ticks, round_ticks = sampler.time_ticks(step_time, cfg.round_dur)
    backoff = default_backoff(cfg)
    if timeout is None:
        timeout = cfg.rounds * cfg.round_dur + 60.0
    server, _ = build_deployment(cfg, data, d_hidden=d_hidden,
                                 wal_dir=wal_dir, ckpt_every=ckpt_every)

    ctx = mp.get_context("spawn")    # fork is unsafe once jax is live
    conns, procs = {}, {}
    for i, cid in enumerate(server.client_ids):
        parent_c, child_c = ctx.Pipe(duplex=True)
        payload = {"cfg": cfg, "node_id": cid, "d_in": xtr.shape[1],
                   "d_hidden": d_hidden, "n_classes": n_classes,
                   "x": np.asarray(xtr[parts[i]]),
                   "y": np.asarray(ytr[parts[i]]),
                   "step_ticks": int(step_ticks[i]),
                   "round_ticks": round_ticks,
                   "seed": _client_seed(cfg, i), "backoff": backoff}
        p = ctx.Process(target=_client_main,
                        args=(child_c, payload, plan, seed, timeout + 30.0),
                        daemon=True)
        p.start()
        child_c.close()
        conns[cid], procs[cid] = parent_c, p

    ep = ProcEndpoint(SERVER_ID, conns, plan=plan, seed=seed)
    t0 = time.monotonic()
    try:
        ep.run(server, until=timeout)
    finally:
        wall = time.monotonic() - t0
        ep.close()
    exitcodes = {}
    deadline = time.monotonic() + 15.0
    for cid, p in procs.items():
        p.join(timeout=max(deadline - time.monotonic(), 0.1))
        if p.is_alive():
            p.terminate()
            p.join(timeout=5.0)
        exitcodes[cid] = p.exitcode
    res = server.result()
    return {"server": res,
            "client_logs": dict(server.client_logs),
            "transport": dict(ep.stats),
            "wall_time": wall,
            "rounds_per_sec": res["rounds"] / max(wall, 1e-9),
            "exitcodes": exitcodes,
            "clean": all(ec == 0 for ec in exitcodes.values()),
            "server_actor": server}


# ---------------------------------------------------------------------------
# supervised real-process runner: killable, restartable server child
# ---------------------------------------------------------------------------

def _server_main(conns, payload, plan, seed, until, recover, result_conn):
    """Spawned SERVER entry for the supervised runner. ``recover=True``
    rebuilds state from the WAL directory; the final (uninterrupted)
    incarnation ships the result dict back over ``result_conn``. Earlier
    incarnations are SIGKILLed by the supervisor and ship nothing — which
    is the point."""
    cfg = payload["cfg"]
    params0 = mlp_init(jax.random.PRNGKey(cfg.seed), payload["d_in"],
                       payload["d_hidden"], payload["n_classes"])
    if recover:
        server = recover_server(cfg, params0, payload["wal_dir"],
                                ckpt_every=payload["ckpt_every"])
    else:
        server = FavasAsyncServer(cfg, params0,
                                  wal_dir=payload["wal_dir"],
                                  ckpt_every=payload["ckpt_every"])
    ep = ProcEndpoint(SERVER_ID, conns, plan=plan, seed=seed)
    try:
        ep.run(server, until=until)
    finally:
        ep.close()
    result_conn.send({"server": server.result(),
                      "client_logs": dict(server.client_logs),
                      "transport": dict(ep.stats)})
    result_conn.close()


def run_proc_supervised(cfg: AsyncConfig, data, *, d_hidden: int = 32,
                        plan: Optional[FaultPlan] = None, seed: int = 0,
                        timeout: Optional[float] = None,
                        wal_dir: str, ckpt_every: int = 0,
                        kill_at=()) -> dict:
    """Real-asynchrony chaos runner: the server lives in its OWN child
    process behind per-client pipe proxies held by this (supervisor)
    process, so SIGKILLing it at each offset in ``kill_at`` (wall seconds
    from start) leaves every client's connection intact. The supervisor
    respawns the server with ``recover=True`` (WAL snapshot + replay) and
    re-wires the server-side pipes; client pushes that died with the old
    process are simply retried into the new one, where the exactly-once
    ledger sorts them out. Returns the final incarnation's result plus
    ``crashes`` — CI gates on it being ``len(kill_at)``."""
    from multiprocessing import connection as mpc
    xtr, ytr, _, _, parts = data
    n_classes = int(ytr.max()) + 1
    step_time = cfg.step_times()
    step_ticks, round_ticks = sampler.time_ticks(step_time, cfg.round_dur)
    backoff = default_backoff(cfg)
    if timeout is None:
        timeout = cfg.rounds * cfg.round_dur + 60.0 \
            + 2.0 * cfg.round_dur * len(tuple(kill_at))
    ctx = mp.get_context("spawn")    # fork is unsafe once jax is live
    client_ids = [f"client{i}" for i in range(cfg.n_clients)]

    # A-side: client child <-> supervisor (survives server restarts)
    proxy_a, client_procs = {}, {}
    for i, cid in enumerate(client_ids):
        parent_c, child_c = ctx.Pipe(duplex=True)
        payload = {"cfg": cfg, "node_id": cid, "d_in": xtr.shape[1],
                   "d_hidden": d_hidden, "n_classes": n_classes,
                   "x": np.asarray(xtr[parts[i]]),
                   "y": np.asarray(ytr[parts[i]]),
                   "step_ticks": int(step_ticks[i]),
                   "round_ticks": round_ticks,
                   "seed": _client_seed(cfg, i), "backoff": backoff}
        p = ctx.Process(target=_client_main,
                        args=(child_c, payload, plan, seed, timeout + 30.0),
                        daemon=True)
        p.start()
        child_c.close()
        proxy_a[cid], client_procs[cid] = parent_c, p

    spayload = {"cfg": cfg, "d_in": xtr.shape[1], "d_hidden": d_hidden,
                "n_classes": n_classes, "wal_dir": wal_dir,
                "ckpt_every": ckpt_every}

    def spawn_server(recover: bool):
        # B-side: supervisor <-> server child (rebuilt on every respawn)
        proxy_b, child_conns = {}, {}
        for cid in client_ids:
            pb, sb = ctx.Pipe(duplex=True)
            proxy_b[cid], child_conns[cid] = pb, sb
        res_parent, res_child = ctx.Pipe(duplex=False)
        p = ctx.Process(target=_server_main,
                        args=(child_conns, spayload, plan, seed,
                              timeout, recover, res_child),
                        daemon=True)
        p.start()
        for c in child_conns.values():
            c.close()
        res_child.close()
        return p, proxy_b, res_parent

    srv_proc, proxy_b, res_conn = spawn_server(False)
    kills = sorted(float(k) for k in kill_at)
    t0 = time.monotonic()
    crashes = 0
    result = None
    while result is None and time.monotonic() - t0 < timeout:
        now = time.monotonic() - t0
        if kills and now >= kills[0]:
            kills.pop(0)
            srv_proc.kill()
            srv_proc.join(timeout=10.0)
            crashes += 1
            for c in proxy_b.values():
                c.close()
            res_conn.close()
            srv_proc, proxy_b, res_conn = spawn_server(True)
            continue
        wait_for = min(kills[0] - now if kills else 0.1, 0.1)
        try:
            ready = mpc.wait(list(proxy_a.values()) + list(proxy_b.values())
                             + [res_conn], timeout=max(wait_for, 0.0))
        except OSError:
            ready = []
        a_of = {id(v): k for k, v in proxy_a.items()}
        b_of = {id(v): k for k, v in proxy_b.items()}
        for conn in ready:
            try:
                if conn is res_conn:
                    result = conn.recv()
                elif id(conn) in a_of:       # client -> server
                    env = conn.recv()
                    dst = proxy_b.get(a_of[id(conn)])
                    if dst is not None and srv_proc.is_alive():
                        dst.send(env)        # dead server: drop, retries cope
                elif id(conn) in b_of:       # server -> client
                    proxy_a[b_of[id(conn)]].send(conn.recv())
            except (EOFError, OSError, BrokenPipeError):
                continue                     # a side died mid-transfer
    wall = time.monotonic() - t0
    srv_proc.join(timeout=10.0)
    if srv_proc.is_alive():
        srv_proc.terminate()
        srv_proc.join(timeout=5.0)
    for c in list(proxy_a.values()) + list(proxy_b.values()):
        try:
            c.close()
        except OSError:
            pass
    exitcodes = {}
    deadline = time.monotonic() + 15.0
    for cid, p in client_procs.items():
        p.join(timeout=max(deadline - time.monotonic(), 0.1))
        if p.is_alive():
            p.terminate()
            p.join(timeout=5.0)
        exitcodes[cid] = p.exitcode
    if result is None:
        return {"server": None, "crashes": crashes, "clean": False,
                "exitcodes": exitcodes, "wall_time": wall}
    res = result["server"]
    return {"server": res,
            "client_logs": result["client_logs"],
            "transport": result["transport"],
            "wall_time": wall,
            "rounds_per_sec": res["rounds"] / max(wall, 1e-9),
            "exitcodes": exitcodes,
            "crashes": crashes,
            "clean": all(ec == 0 for ec in exitcodes.values()),
            "server_actor": None}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _smoke_data(n_clients: int, seed: int, n_train: int = 400,
                n_test: int = 200):
    from repro.data.partition import partition_iid
    from repro.data.synthetic import make_classification
    x, y, xt, yt = make_classification("mnist-like", n_train=n_train,
                                       n_test=n_test, seed=seed)
    parts = partition_iid(len(y), n_clients, seed=seed)
    return x, y, xt, yt, parts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--transport", choices=("inproc", "proc"),
                    default="proc")
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--selected", type=int, default=0,
                    help="s per round (default: ceil(clients/2))")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--round-dur", type=float, default=0.5,
                    help="round cadence (wall s for proc, virtual for "
                         "inproc)")
    ap.add_argument("--latency", type=float, default=0.02,
                    help="injected base one-way latency")
    ap.add_argument("--jitter", type=float, default=0.0)
    ap.add_argument("--drop", type=float, default=0.0)
    ap.add_argument("--straggler", type=float, default=1.0,
                    help="latency multiplier for client0")
    ap.add_argument("--k-steps", type=int, default=4)
    ap.add_argument("--d-hidden", type=int, default=16)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--timeout", type=float, default=0.0,
                    help="server pump bound in s (0: auto)")
    ap.add_argument("--wal-dir", default="",
                    help="arm the server's write-ahead log in this dir")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="snapshot + rotate the WAL every N closed rounds")
    ap.add_argument("--chaos", default="",
                    help="comma-separated wall-clock offsets (s) at which "
                         "the supervisor SIGKILLs and restarts the server "
                         "child (proc transport only; requires --wal-dir)")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    kill_at = tuple(float(x) for x in args.chaos.split(",") if x.strip())
    if kill_at and (args.transport != "proc" or not args.wal_dir):
        ap.error("--chaos needs --transport proc and --wal-dir")

    s = args.selected or max(1, (args.clients + 1) // 2)
    cfg = AsyncConfig(n_clients=args.clients, s_selected=s, K=args.k_steps,
                      batch_size=args.batch, rounds=args.rounds,
                      round_dur=args.round_dur,
                      fast_step_time=args.round_dur / max(args.k_steps, 1),
                      slow_step_time=args.round_dur / 2.0,
                      seed=args.seed)
    plan = FaultPlan(latency=args.latency, jitter=args.jitter,
                     drop=args.drop,
                     straggler=({"client0": args.straggler}
                                if args.straggler != 1.0 else {}))
    data = _smoke_data(args.clients, args.seed)
    if kill_at:
        out = run_proc_supervised(cfg, data, d_hidden=args.d_hidden,
                                  plan=plan, seed=args.seed,
                                  timeout=args.timeout or None,
                                  wal_dir=args.wal_dir,
                                  ckpt_every=args.ckpt_every,
                                  kill_at=kill_at)
        if out["server"] is None:
            print(json.dumps({"clean": False, "crashes": out["crashes"],
                              "exitcodes": out["exitcodes"]}, default=float))
            return 1
    elif args.transport == "proc":
        out = run_proc(cfg, data, d_hidden=args.d_hidden, plan=plan,
                       seed=args.seed,
                       timeout=args.timeout or None,
                       wal_dir=args.wal_dir or None,
                       ckpt_every=args.ckpt_every)
    else:
        out = run_inproc(cfg, data, d_hidden=args.d_hidden, plan=plan,
                         seed=args.seed,
                         wal_dir=args.wal_dir or None,
                         ckpt_every=args.ckpt_every)
        out["clean"] = True
    res = out["server"]
    summary = {
        "transport": args.transport,
        "config": {"clients": args.clients, "selected": s,
                   "rounds": args.rounds, "round_dur": args.round_dur,
                   "latency": args.latency, "drop": args.drop,
                   "straggler": args.straggler, "seed": args.seed},
        "rounds_completed": res["rounds"],
        "final_accuracy": res["final_accuracy"],
        "staleness": res["staleness"],
        "server_stats": res["stats"],
        "transport_stats": out["transport"],
        "wall_time": out.get("wall_time"),
        "rounds_per_sec": out.get("rounds_per_sec"),
        "exitcodes": out.get("exitcodes"),
        "crashes": out.get("crashes", 0),
        "clean": out["clean"],
    }
    line = json.dumps(summary, indent=2, default=float)
    print(line)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(line + "\n")
    ok = (out["clean"] and res["rounds"] >= args.rounds
          and out.get("crashes", 0) == len(kill_at))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
