"""Cluster orchestrator for the async FAVAS deployment (ROADMAP open
item 2's deliverable; docs/architecture.md §11).

Two runners over the SAME server/client actors:

* :func:`run_inproc` — everything on one :class:`InProcTransport` event
  loop: virtual clock, seeded faults, fully deterministic. The test
  substrate (tests/test_async_server.py) and the simulated baseline of the
  async benchmark.
* :func:`run_proc` — the server in THIS process, each client a real
  spawned OS process, wired in a star of duplex pipes with
  :class:`ProcEndpoint` pumps on both ends. Wall-clock latencies are
  injected by the shared :class:`FaultPlan`; teardown is
  stop-broadcast -> bye harvest -> join-with-deadline -> terminate
  stragglers, and the result reports per-child exit codes so CI can gate
  on a clean shutdown.

CLI (the CI 2-client smoke and the bench's workhorse)::

  PYTHONPATH=src python -m repro.launch.cluster --transport proc \
      --clients 2 --rounds 20 --latency 0.02 --out cluster_summary.json

exits non-zero unless every round completed and every child exited 0.
"""
from __future__ import annotations

import argparse
import json
import multiprocessing as mp
import os
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comms import BackoffPolicy, FaultPlan, InProcTransport, ProcEndpoint
from repro.core import sampler
from repro.launch.client import LocalSGDClient
from repro.launch.server import SERVER_ID, AsyncConfig, FavasAsyncServer
from repro.models.classifier import accuracy, mlp_apply, mlp_init


def default_backoff(cfg: AsyncConfig) -> BackoffPolicy:
    """Push-retry schedule scaled to the round: first retry at
    round_dur/4 (comfortably above a sane RTT, so an in-flight ack usually
    cancels it), doubling, capped at one round — several attempts still fit
    inside the harvest window on either clock."""
    return BackoffPolicy(base=max(cfg.round_dur / 4.0, 1e-3),
                         factor=2.0, max_delay=cfg.round_dur,
                         max_attempts=6)


def _client_seed(cfg: AsyncConfig, i: int) -> int:
    # distinct per-client batch streams, disjoint from the server chain
    return (cfg.seed * 1009 + 17 * i + 13) % (2 ** 31)


def build_deployment(cfg: AsyncConfig, data, *, d_hidden: int = 32,
                     backoff: Optional[BackoffPolicy] = None):
    """Shared setup for both runners: the model init and server rng ride
    the exact fl_sim chain (``PRNGKey(cfg.seed)`` for both), the step-time
    vector is fl_sim's ``_step_times`` draw, and the integer tick grid
    comes from ``sampler.time_ticks`` — the preconditions of the
    equivalence contract. Returns ``(server, clients)``."""
    xtr, ytr, xte, yte, parts = data
    n_classes = int(ytr.max()) + 1
    params0 = mlp_init(jax.random.PRNGKey(cfg.seed), xtr.shape[1],
                       d_hidden, n_classes)
    step_time = cfg.step_times()
    step_ticks, round_ticks = sampler.time_ticks(step_time, cfg.round_dur)
    xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)
    eval_fn = jax.jit(lambda p: accuracy(p, mlp_apply, xte_j, yte_j))
    server = FavasAsyncServer(cfg, params0, eval_fn=eval_fn)
    backoff = backoff or default_backoff(cfg)
    clients = [
        LocalSGDClient(server.client_ids[i], params0,
                       xtr[parts[i]], ytr[parts[i]],
                       n_clients=cfg.n_clients, batch_size=cfg.batch_size,
                       eta=cfg.eta, K=cfg.K,
                       step_ticks=int(step_ticks[i]),
                       round_ticks=round_ticks, n_classes=n_classes,
                       seed=_client_seed(cfg, i), backoff=backoff)
        for i in range(cfg.n_clients)]
    return server, clients


# ---------------------------------------------------------------------------
# deterministic in-process runner
# ---------------------------------------------------------------------------

def run_inproc(cfg: AsyncConfig, data, *, d_hidden: int = 32,
               plan: Optional[FaultPlan] = None, seed: int = 0,
               max_events: int = 2_000_000) -> dict:
    """One deterministic virtual-clock run. Returns the server result plus
    per-client logs/stats and the transport counters; ``virtual_time`` is
    where the clock stopped."""
    server, clients = build_deployment(cfg, data, d_hidden=d_hidden)
    t = InProcTransport(plan, seed=seed)
    t.add_actor(server)
    for c in clients:
        t.add_actor(c)
    t.run(max_events=max_events)
    return {"server": server.result(),
            "client_logs": {c.node_id: list(c.log) for c in clients},
            "client_stats": {c.node_id: dict(c.stats) for c in clients},
            "transport": dict(t.stats),
            "virtual_time": t._now,
            "server_actor": server}


# ---------------------------------------------------------------------------
# real multi-process runner
# ---------------------------------------------------------------------------

def _client_main(conn, payload, plan, seed, until):
    """Spawned-child entry: rebuild the worker from the picklable payload
    (the model init is re-derived from the seed, not shipped) and pump its
    endpoint until stop/timeout."""
    cfg = payload["cfg"]
    params0 = mlp_init(jax.random.PRNGKey(cfg.seed), payload["d_in"],
                       payload["d_hidden"], payload["n_classes"])
    client = LocalSGDClient(payload["node_id"], params0,
                            payload["x"], payload["y"],
                            n_clients=cfg.n_clients,
                            batch_size=cfg.batch_size, eta=cfg.eta,
                            K=cfg.K, step_ticks=payload["step_ticks"],
                            round_ticks=payload["round_ticks"],
                            n_classes=payload["n_classes"],
                            seed=payload["seed"],
                            backoff=payload["backoff"])
    client.warmup(range(1, cfg.K + 1))
    ep = ProcEndpoint(payload["node_id"], {SERVER_ID: conn}, plan=plan,
                      seed=seed)
    try:
        ep.run(client, until=until)
    finally:
        ep.close()


def run_proc(cfg: AsyncConfig, data, *, d_hidden: int = 32,
             plan: Optional[FaultPlan] = None, seed: int = 0,
             timeout: Optional[float] = None) -> dict:
    """Spawn ``cfg.n_clients`` worker processes, run the server endpoint in
    this process, harvest, and tear down. ``timeout`` bounds the server
    pump (default: the nominal schedule plus generous slack) so a wedged
    transport fails fast instead of hanging the caller."""
    xtr, ytr, _, _, parts = data
    n_classes = int(ytr.max()) + 1
    step_time = cfg.step_times()
    step_ticks, round_ticks = sampler.time_ticks(step_time, cfg.round_dur)
    backoff = default_backoff(cfg)
    if timeout is None:
        timeout = cfg.rounds * cfg.round_dur + 60.0
    server, _ = build_deployment(cfg, data, d_hidden=d_hidden)

    ctx = mp.get_context("spawn")    # fork is unsafe once jax is live
    conns, procs = {}, {}
    for i, cid in enumerate(server.client_ids):
        parent_c, child_c = ctx.Pipe(duplex=True)
        payload = {"cfg": cfg, "node_id": cid, "d_in": xtr.shape[1],
                   "d_hidden": d_hidden, "n_classes": n_classes,
                   "x": np.asarray(xtr[parts[i]]),
                   "y": np.asarray(ytr[parts[i]]),
                   "step_ticks": int(step_ticks[i]),
                   "round_ticks": round_ticks,
                   "seed": _client_seed(cfg, i), "backoff": backoff}
        p = ctx.Process(target=_client_main,
                        args=(child_c, payload, plan, seed, timeout + 30.0),
                        daemon=True)
        p.start()
        child_c.close()
        conns[cid], procs[cid] = parent_c, p

    ep = ProcEndpoint(SERVER_ID, conns, plan=plan, seed=seed)
    t0 = time.monotonic()
    try:
        ep.run(server, until=timeout)
    finally:
        wall = time.monotonic() - t0
        ep.close()
    exitcodes = {}
    deadline = time.monotonic() + 15.0
    for cid, p in procs.items():
        p.join(timeout=max(deadline - time.monotonic(), 0.1))
        if p.is_alive():
            p.terminate()
            p.join(timeout=5.0)
        exitcodes[cid] = p.exitcode
    res = server.result()
    return {"server": res,
            "client_logs": dict(server.client_logs),
            "transport": dict(ep.stats),
            "wall_time": wall,
            "rounds_per_sec": res["rounds"] / max(wall, 1e-9),
            "exitcodes": exitcodes,
            "clean": all(ec == 0 for ec in exitcodes.values()),
            "server_actor": server}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _smoke_data(n_clients: int, seed: int, n_train: int = 400,
                n_test: int = 200):
    from repro.data.partition import partition_iid
    from repro.data.synthetic import make_classification
    x, y, xt, yt = make_classification("mnist-like", n_train=n_train,
                                       n_test=n_test, seed=seed)
    parts = partition_iid(len(y), n_clients, seed=seed)
    return x, y, xt, yt, parts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--transport", choices=("inproc", "proc"),
                    default="proc")
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--selected", type=int, default=0,
                    help="s per round (default: ceil(clients/2))")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--round-dur", type=float, default=0.5,
                    help="round cadence (wall s for proc, virtual for "
                         "inproc)")
    ap.add_argument("--latency", type=float, default=0.02,
                    help="injected base one-way latency")
    ap.add_argument("--jitter", type=float, default=0.0)
    ap.add_argument("--drop", type=float, default=0.0)
    ap.add_argument("--straggler", type=float, default=1.0,
                    help="latency multiplier for client0")
    ap.add_argument("--k-steps", type=int, default=4)
    ap.add_argument("--d-hidden", type=int, default=16)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--timeout", type=float, default=0.0,
                    help="server pump bound in s (0: auto)")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    s = args.selected or max(1, (args.clients + 1) // 2)
    cfg = AsyncConfig(n_clients=args.clients, s_selected=s, K=args.k_steps,
                      batch_size=args.batch, rounds=args.rounds,
                      round_dur=args.round_dur,
                      fast_step_time=args.round_dur / max(args.k_steps, 1),
                      slow_step_time=args.round_dur / 2.0,
                      seed=args.seed)
    plan = FaultPlan(latency=args.latency, jitter=args.jitter,
                     drop=args.drop,
                     straggler=({"client0": args.straggler}
                                if args.straggler != 1.0 else {}))
    data = _smoke_data(args.clients, args.seed)
    if args.transport == "proc":
        out = run_proc(cfg, data, d_hidden=args.d_hidden, plan=plan,
                       seed=args.seed,
                       timeout=args.timeout or None)
    else:
        out = run_inproc(cfg, data, d_hidden=args.d_hidden, plan=plan,
                         seed=args.seed)
        out["clean"] = True
    res = out["server"]
    summary = {
        "transport": args.transport,
        "config": {"clients": args.clients, "selected": s,
                   "rounds": args.rounds, "round_dur": args.round_dur,
                   "latency": args.latency, "drop": args.drop,
                   "straggler": args.straggler, "seed": args.seed},
        "rounds_completed": res["rounds"],
        "final_accuracy": res["final_accuracy"],
        "staleness": res["staleness"],
        "server_stats": res["stats"],
        "transport_stats": out["transport"],
        "wall_time": out.get("wall_time"),
        "rounds_per_sec": out.get("rounds_per_sec"),
        "exitcodes": out.get("exitcodes"),
        "clean": out["clean"],
    }
    line = json.dumps(summary, indent=2, default=float)
    print(line)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(line + "\n")
    ok = out["clean"] and res["rounds"] >= args.rounds
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
