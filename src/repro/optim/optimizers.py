"""Minimal optimizer substrate (no optax offline): (init, update) pairs over
pytrees. FAVAS local steps use plain SGD per the paper; AdamW/momentum are
provided for the general trainer and beyond-paper server-side optimization
(FedOpt-style), with per-client stacked states supported by construction
(every op is leafwise, so a leading client axis broadcasts through).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_map


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], tuple]  # (g, state, params, step)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, step):
        new = tree_map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        return new, state
    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return tree_map(jnp.zeros_like, params)

    def update(grads, state, params, step):
        new_m = tree_map(lambda m, g: beta * m + g.astype(m.dtype), state, grads)
        new_p = tree_map(lambda p, m: p - lr * m, params, new_m)
        return new_p, new_m
    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    """lr may be a float or a schedule fn(step) -> float."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = tree_map(jnp.zeros_like, params)
        return {"m": z, "v": z}

    def update(grads, state, params, step):
        stepf = step.astype(jnp.float32) + 1.0
        m = tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(m_.dtype),
                     state["m"], grads)
        v = tree_map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(v_.dtype)),
                     state["v"], grads)
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** stepf
        c2 = 1.0 - b2 ** stepf

        def upd(p, m_, v_):
            mh = m_ / c1
            vh = v_ / c2
            return (p - lr_t * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)
                    ).astype(p.dtype)
        return tree_map(upd, params, m, v), {"m": m, "v": v}
    return Optimizer(init, update)


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return fn
