from repro.optim.optimizers import sgd, momentum, adamw, cosine_schedule, Optimizer
