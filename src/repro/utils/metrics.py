"""Tiny metrics substrate: JSONL writer + rolling aggregator for the
trainer/server CLIs (no tensorboard offline)."""
from __future__ import annotations

import json
import os
import time
from collections import deque
from typing import Dict, Optional


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, window: int = 20):
        self.path = path
        self._fh = None
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fh = open(path, "a", buffering=1)
        self.window: Dict[str, deque] = {}
        self._wsize = window
        self.t0 = time.time()

    def log(self, step: int, **scalars):
        rec = {"step": step, "wall_s": round(time.time() - self.t0, 3)}
        for k, v in scalars.items():
            v = float(v)
            rec[k] = v
            self.window.setdefault(k, deque(maxlen=self._wsize)).append(v)
        if self._fh:
            self._fh.write(json.dumps(rec) + "\n")
        return rec

    def mean(self, key: str) -> float:
        w = self.window.get(key)
        return sum(w) / len(w) if w else float("nan")

    def close(self):
        if self._fh:
            self._fh.close()
