"""Pytree utilities shared across the framework.

Every FAVAS state object is a pytree of jnp arrays; these helpers implement
the vector-space operations the protocol needs (client messages, server
averaging, potential diagnostics) without flattening to a single buffer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

tree_map = jax.tree_util.tree_map


def tree_zeros_like(t):
    return tree_map(jnp.zeros_like, t)


def tree_add(a, b):
    return tree_map(jnp.add, a, b)


def tree_sub(a, b):
    return tree_map(jnp.subtract, a, b)


def tree_scale(t, c):
    return tree_map(lambda x: x * c, t)


def tree_axpy(a, x, y):
    """a * x + y, leafwise."""
    return tree_map(lambda xi, yi: a * xi + yi, x, y)


def tree_where(pred, a, b):
    """Leafwise select; ``pred`` may be a scalar bool or per-leaf-broadcastable."""
    return tree_map(lambda ai, bi: jnp.where(pred, ai, bi), a, b)


def tree_param_count(t) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(t))


def tree_flatten_concat(t) -> jnp.ndarray:
    """Flatten a pytree into one 1-D vector (diagnostics only)."""
    leaves = jax.tree_util.tree_leaves(t)
    return jnp.concatenate([jnp.ravel(x).astype(jnp.float32) for x in leaves])


def tree_global_norm(t) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(t)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_sq_dist(a, b) -> jnp.ndarray:
    """|| a - b ||^2 summed over every leaf (used for the paper's potential Phi)."""
    d = tree_map(
        lambda x, y: jnp.sum(jnp.square(x.astype(jnp.float32) - y.astype(jnp.float32))),
        a,
        b,
    )
    return sum(jax.tree_util.tree_leaves(d))


def tree_stack(trees):
    """Stack a list of identical pytrees along a new leading axis."""
    return tree_map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_index(t, i):
    """Select index ``i`` along the leading axis of every leaf."""
    return tree_map(lambda x: x[i], t)


def tree_cast(t, dtype):
    return tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, t
    )
