from repro.utils.tree import (
    tree_map,
    tree_zeros_like,
    tree_add,
    tree_scale,
    tree_where,
    tree_param_count,
    tree_flatten_concat,
    tree_global_norm,
    tree_stack,
    tree_index,
)
