"""Async transport substrate: the actor contract, the deterministic
virtual-clock transport, the real multi-process endpoint, fault injection,
and the client-push retry policy (docs/architecture.md §11)."""
from repro.comms.faults import (Decision, FaultPlan, ServerCrashSwitch,
                                SimulatedCrash, UPDATE_KINDS,
                                symmetric_latency_table)
from repro.comms.retry import BackoffPolicy
from repro.comms.transport import (Actor, InProcTransport, ProcEndpoint,
                                   TransportAPI)

__all__ = [
    "Actor", "BackoffPolicy", "Decision", "FaultPlan", "InProcTransport",
    "ProcEndpoint", "ServerCrashSwitch", "SimulatedCrash", "TransportAPI",
    "UPDATE_KINDS", "symmetric_latency_table",
]
