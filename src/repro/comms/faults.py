"""Fault-injection layer for the async transports (docs/architecture.md
§11).

A :class:`FaultPlan` is a *declarative* description of everything hostile
the network may do to a FAVAS deployment:

* **latency** — a base one-way latency, an optional per-``(src, dst)``
  latency table, and a seeded uniform jitter, applied to EVERY message;
* **stragglers** — per-node multipliers on every message the node sends or
  receives (a ×10 straggler's poll responses arrive an order of magnitude
  late — the heterogeneous-client regime of arxiv 2402.11198);
* **drop / duplicate / reorder** — applied to *update-class* messages only
  (the client→server push path, per the fault model of ISSUE 8): control
  messages (tick/poll/reset) ride a reliable channel, data pushes do not,
  which is exactly what the client-side retry/backoff path exists to
  survive;
* **crash-and-rejoin** — per-node outage windows ``[t_down, t_up)`` (one
  window or a list of them): the transport blackholes every message to or
  from the node inside a window and delivers ``on_crash`` / ``on_rejoin``
  control events at the boundaries (InProc transport; real processes
  crash for real);
* **server kill points** — :class:`ServerCrashSwitch` arms a named
  DURABILITY point inside the server (``admit``, ``close``,
  ``round_start``): the k-th hit raises :class:`SimulatedCrash`
  (optionally tearing the WAL tail first, the torn-write crash model) and
  ``InProcTransport`` marks the node killed until a supervisor swaps in a
  recovered actor via ``revive()``. This is how the chaos suite kills the
  server BETWEEN a log write and its acknowledgement — an interleaving a
  time-based crash window cannot express.

Every stochastic decision is drawn from an ``np.random.Generator`` owned by
the transport, consumed in deterministic event order — under
``InProcTransport`` the same (plan, seed) always yields the same run, which
is what makes the fault suite assertable in tier-1 CI.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Tuple

import numpy as np

#: message kinds subject to drop/duplicate/reorder (the unreliable
#: data-plane classes; everything else is control-plane and only sees
#: latency/straggler/crash effects)
UPDATE_KINDS = ("update",)


class SimulatedCrash(RuntimeError):
    """Raised inside an actor handler to model the process dying at a
    durability point. ``InProcTransport`` catches it, marks the node
    killed (blackholed, timers invalidated), and lets a supervisor
    ``revive()`` a recovered replacement actor."""


@dataclasses.dataclass
class ServerCrashSwitch:
    """Deterministic kill switch for the chaos suite: counts hits of
    named durability points and raises :class:`SimulatedCrash` on the
    ``at``-th hit of ``point`` (1-based). With ``tear_bytes > 0`` the
    WAL's open segment is truncated by that many bytes first — the crash
    happens MID-write and replay must tolerate the torn record."""
    point: str
    at: int = 1
    tear_bytes: int = 0
    fired: bool = False
    counts: dict = dataclasses.field(default_factory=dict)

    def hit(self, point: str, wal=None) -> None:
        if self.fired:
            return
        c = self.counts.get(point, 0) + 1
        self.counts[point] = c
        if point == self.point and c == self.at:
            self.fired = True
            if self.tear_bytes > 0 and wal is not None:
                wal.tear_tail(self.tear_bytes)
            raise SimulatedCrash(f"server killed at {point} #{c}")


def _as_windows(value) -> Tuple[Tuple[float, float], ...]:
    """Normalize a crash entry: one ``(t0, t1)`` pair or a list of pairs."""
    seq = list(value)
    if len(seq) == 2 and all(isinstance(x, (int, float)) for x in seq):
        return ((float(seq[0]), float(seq[1])),)
    return tuple((float(a), float(b)) for a, b in seq)


@dataclasses.dataclass(frozen=True)
class Decision:
    """Outcome of the fault layer for ONE send."""
    latencies: Tuple[float, ...]   # one entry per delivered copy ((),) = drop
    fifo: bool = True              # clamp behind earlier traffic on the pair?

    @property
    def dropped(self) -> bool:
        return len(self.latencies) == 0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative network-fault description (see module docstring).

    ``latency_table`` maps ``(src, dst)`` node-id pairs to a one-way
    latency, overriding ``latency``; ``straggler`` maps a node id to a
    multiplier applied to every message it sends OR receives (multipliers
    compose). ``drop`` / ``duplicate`` / ``reorder`` are probabilities per
    update-class message; a reordered copy gets ``reorder_delay`` extra
    latency AND is exempted from the per-pair FIFO clamp, so it genuinely
    overtakes later traffic. ``crash`` maps a node id to its
    ``(t_down, t_up)`` outage window in transport time."""
    latency: float = 0.0
    latency_table: Optional[Mapping] = None       # (src, dst) -> latency
    jitter: float = 0.0                           # uniform [0, jitter)
    straggler: Mapping[str, float] = dataclasses.field(default_factory=dict)
    drop: float = 0.0
    duplicate: float = 0.0
    reorder: float = 0.0
    reorder_delay: float = 0.0
    crash: Mapping[str, Tuple[float, float]] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        for name in ("drop", "duplicate", "reorder"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be a probability, got {p}")
        for node, value in dict(self.crash).items():
            for t0, t1 in _as_windows(value):
                if t1 < t0:
                    raise ValueError(
                        f"crash window for {node!r} is reversed: "
                        f"({t0}, {t1})")

    # -- helpers ------------------------------------------------------------

    def one_way(self, src: str, dst: str) -> float:
        """Deterministic part of the src->dst latency (no jitter draw)."""
        base = self.latency
        if self.latency_table is not None:
            base = self.latency_table.get((src, dst), base)
        return (base * float(self.straggler.get(src, 1.0))
                * float(self.straggler.get(dst, 1.0)))

    def windows(self, node: str) -> Tuple[Tuple[float, float], ...]:
        """The node's crash windows (possibly several), normalized."""
        value = self.crash.get(node)
        return _as_windows(value) if value is not None else ()

    def is_down(self, node: str, t: float) -> bool:
        return any(t0 <= t < t1 for t0, t1 in self.windows(node))

    def decide(self, src: str, dst: str, kind: str,
               rng: np.random.Generator) -> Decision:
        """Fault decision for one send. ALWAYS consumes the same number of
        rng draws for a given message class, so a fault taken on one
        message never perturbs the stream another message sees — runs stay
        comparable across plans that differ only in probabilities."""
        lat = self.one_way(src, dst)
        if self.jitter > 0.0:
            lat += float(rng.uniform(0.0, self.jitter))
        if kind not in UPDATE_KINDS:
            return Decision(latencies=(lat,))
        # one draw each for drop/dup/reorder, unconditionally (see above)
        u_drop, u_dup, u_reord = rng.uniform(size=3)
        if u_drop < self.drop:
            return Decision(latencies=())
        lats = [lat]
        if u_dup < self.duplicate:
            lats.append(lat + max(self.jitter, 1e-3))
        if u_reord < self.reorder:
            return Decision(latencies=tuple(x + self.reorder_delay
                                            for x in lats), fifo=False)
        return Decision(latencies=tuple(lats))


class _SymmetricTable(dict):
    """Per-node latency table: ``get((src, dst))`` resolves to either
    endpoint's entry. Module-level (not a closure) so a FaultPlan carrying
    one pickles across multiprocessing spawn boundaries."""

    def get(self, key, default=0.0):
        src, dst = key
        if str(src) in self:
            return self[str(src)]
        return super().get(str(dst), default)


def symmetric_latency_table(node_ids, latencies) -> dict:
    """Build a ``latency_table`` giving node ``i`` the one-way latency
    ``latencies[i]`` on BOTH directions of its server link (the per-client
    latency-table idiom of the gaia-style sender queues). ``node_ids`` are
    the client ids; the server side is implicit (any peer)."""
    return _SymmetricTable({str(n): float(l)
                            for n, l in zip(node_ids, latencies)})
