"""Transport substrate for the real-asynchrony FAVAS deployment
(docs/architecture.md §11).

Two implementations of one actor contract:

* :class:`InProcTransport` — a single-threaded discrete-event simulator
  with a **virtual clock**: every latency, fault decision, and delivery
  order is derived from one seeded generator consumed in event order, so a
  run is a pure function of ``(actors, FaultPlan, seed)``. This is the
  *test substrate*: the async server running on it is deterministic enough
  to assert exact selection/credit bookkeeping against the simulated-clock
  ``fl_sim`` reference, fault class by fault class.
* :class:`ProcEndpoint` — the same contract over real OS processes and
  ``multiprocessing`` pipes with **wall-clock** time. Injected latencies
  ride in the message envelope (``deliver_at`` stamped by the sender, held
  back by the receiver), so the fault model is shared with the virtual
  transport; only the clock differs.

The actor contract (:class:`Actor`): nodes never block — they react to
``on_message`` / ``on_timer`` callbacks and talk through a
:class:`TransportAPI` (``send`` / ``set_timer`` / ``now``). The same
server and client objects (``launch/server.py``, ``launch/client.py``)
therefore run unmodified on either transport — which is the determinism
contract the equivalence tests lean on.

Delivery guarantees: per ``(src, dst)`` pair, delivery is FIFO (delivery
times are clamped monotone) unless the fault layer explicitly reorders a
message; update-class messages may be dropped or duplicated per the
:class:`repro.comms.faults.FaultPlan`; control messages are never dropped
(only delayed). A crashed node (InProc) receives nothing inside its outage
window and gets ``on_crash`` / ``on_rejoin`` control callbacks at the
boundaries.
"""
from __future__ import annotations

import heapq
import time
import zlib
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.comms.faults import FaultPlan, SimulatedCrash

#: minimum spacing enforced between FIFO deliveries on one (src, dst) pair
_FIFO_EPS = 1e-9


class Actor:
    """Base class for transport nodes. Handlers MUST NOT block: all waiting
    is expressed as timers, all communication as sends."""

    node_id: str = "?"

    def on_start(self, api: "TransportAPI") -> None:  # pragma: no cover
        pass

    def on_message(self, src: str, msg: Any,
                   api: "TransportAPI") -> None:  # pragma: no cover
        pass

    def on_timer(self, name: str,
                 api: "TransportAPI") -> None:  # pragma: no cover
        pass

    def on_crash(self, api: "TransportAPI") -> None:  # pragma: no cover
        pass

    def on_rejoin(self, api: "TransportAPI") -> None:  # pragma: no cover
        pass


class TransportAPI:
    """What an actor sees of its transport (one per node)."""

    node_id: str

    def now(self) -> float:
        raise NotImplementedError

    def send(self, dst: str, msg: Any) -> None:
        raise NotImplementedError

    def set_timer(self, name: str, delay: float) -> None:
        raise NotImplementedError

    def cancel_timer(self, name: str) -> None:
        raise NotImplementedError

    def stop(self) -> None:
        raise NotImplementedError


def _msg_kind(msg: Any) -> str:
    return msg.get("kind", "?") if isinstance(msg, dict) else "?"


# ---------------------------------------------------------------------------
# InProcTransport: deterministic virtual-clock event loop
# ---------------------------------------------------------------------------

class _InProcAPI(TransportAPI):
    def __init__(self, transport: "InProcTransport", node_id: str):
        self._t = transport
        self.node_id = node_id

    def now(self) -> float:
        return self._t._now

    def send(self, dst: str, msg: Any) -> None:
        self._t._send(self.node_id, dst, msg)

    def set_timer(self, name: str, delay: float) -> None:
        self._t._set_timer(self.node_id, name, delay)

    def cancel_timer(self, name: str) -> None:
        self._t._cancel_timer(self.node_id, name)

    def stop(self) -> None:
        self._t._stopped.add(self.node_id)


class InProcTransport:
    """Deterministic single-threaded discrete-event transport.

    Determinism contract (asserted by tests/test_async_server.py): with the
    same registered actors, :class:`FaultPlan` and ``seed``, two runs
    produce identical event sequences — every latency/fault draw comes from
    ONE generator consumed in event order, the event heap breaks time ties
    by insertion sequence, and nothing touches wall-clock time. ``stats``
    counts delivered/dropped/duplicated/blackholed messages so tests can
    assert the faults actually fired.
    """

    def __init__(self, plan: Optional[FaultPlan] = None, seed: int = 0):
        self.plan = plan or FaultPlan()
        self._rng = np.random.default_rng(seed)
        self._heap: list = []           # (time, seq, kind, payload...)
        self._seq = 0
        self._now = 0.0
        self._actors: Dict[str, Actor] = {}
        self._apis: Dict[str, _InProcAPI] = {}
        self._timer_tok: Dict[tuple, int] = {}
        self._fifo_last: Dict[tuple, float] = {}
        self._stopped: set = set()
        self._killed: set = set()
        self._begun = False
        self.stats = {"delivered": 0, "dropped": 0, "duplicated": 0,
                      "blackholed": 0, "events": 0, "kills": 0}

    # -- wiring -------------------------------------------------------------

    def add_actor(self, actor: Actor) -> None:
        if actor.node_id in self._actors:
            raise ValueError(f"duplicate node id {actor.node_id!r}")
        self._actors[actor.node_id] = actor
        self._apis[actor.node_id] = _InProcAPI(self, actor.node_id)

    def _push(self, t: float, kind: str, *payload) -> None:
        heapq.heappush(self._heap, (t, self._seq, kind, payload))
        self._seq += 1

    # -- API plumbing -------------------------------------------------------

    def _send(self, src: str, dst: str, msg: Any) -> None:
        if dst not in self._actors:
            raise KeyError(f"send to unknown node {dst!r}")
        decision = self.plan.decide(src, dst, _msg_kind(msg), self._rng)
        if decision.dropped:
            self.stats["dropped"] += 1
            return
        if len(decision.latencies) > 1:
            self.stats["duplicated"] += 1
        for lat in decision.latencies:
            at = self._now + max(float(lat), 0.0)
            if decision.fifo:
                last = self._fifo_last.get((src, dst), -np.inf)
                at = max(at, last + _FIFO_EPS)
                self._fifo_last[(src, dst)] = at
            self._push(at, "msg", src, dst, msg)

    def _set_timer(self, node: str, name: str, delay: float) -> None:
        tok = self._timer_tok.get((node, name), 0) + 1
        self._timer_tok[(node, name)] = tok
        self._push(self._now + max(float(delay), 0.0), "timer",
                   node, name, tok)

    def _cancel_timer(self, node: str, name: str) -> None:
        # bump the token: any in-heap firing with an older token is stale
        self._timer_tok[(node, name)] = \
            self._timer_tok.get((node, name), 0) + 1

    # -- kill / revive (the chaos-supervisor hooks) -------------------------

    def _down(self, node: str, t: float) -> bool:
        return node in self._killed or self.plan.is_down(node, t)

    def _kill(self, node: str) -> None:
        """Mark a node dead mid-handler (a SimulatedCrash escaped it):
        messages to/from it blackhole and every pending timer is
        invalidated — exactly what losing the process loses."""
        self._killed.add(node)
        self.stats["kills"] += 1
        for key in list(self._timer_tok):
            if key[0] == node:
                self._timer_tok[key] += 1

    def revive(self, actor: Actor) -> None:
        """Swap a (recovered) replacement actor in for a killed node and
        start it — the supervisor step of the chaos harness. The new
        actor's ``on_start`` runs at the current virtual time."""
        node = actor.node_id
        if node not in self._actors:
            raise KeyError(f"revive of unknown node {node!r}")
        self._actors[node] = actor
        self._apis[node] = _InProcAPI(self, node)
        self._killed.discard(node)
        actor.on_start(self._apis[node])

    def done(self) -> bool:
        """No more work: heap drained or every actor stopped."""
        return not self._heap or len(self._stopped) == len(self._actors)

    def killed_nodes(self) -> frozenset:
        """Nodes currently dead from an escaped SimulatedCrash (a
        supervisor polls this between ``run(until=...)`` slices)."""
        return frozenset(self._killed)

    # -- the event loop -----------------------------------------------------

    def run(self, until: Optional[float] = None,
            max_events: int = 2_000_000) -> None:
        """Drive the simulation until the heap drains, every actor stopped,
        virtual time passes ``until``, or ``max_events`` dispatches — the
        last is the anti-wedge guard: a protocol bug that ping-pongs
        forever raises instead of hanging the test runner.

        ``run`` is RESUMABLE: actors start (and crash windows schedule)
        only on the first call, and an event past ``until`` is pushed back
        unconsumed — so stepping the clock in slices is event-for-event
        identical to one uninterrupted run (the chaos harness interleaves
        ``run(until=...)`` with server recovery)."""
        if not self._begun:
            self._begun = True
            for node in dict(self.plan.crash):
                for t0, t1 in self.plan.windows(node):
                    self._push(float(t0), "crash", node)
                    self._push(float(t1), "rejoin", node)
            for node, actor in self._actors.items():
                actor.on_start(self._apis[node])
        n_events = 0
        while self._heap:
            if len(self._stopped) == len(self._actors):
                break
            item = heapq.heappop(self._heap)
            t, _, kind, payload = item
            if until is not None and t > until:
                heapq.heappush(self._heap, item)   # unconsumed: resumable
                self._now = max(self._now, until)
                break
            n_events += 1
            if n_events > max_events:
                raise RuntimeError(
                    f"InProcTransport exceeded {max_events} events at "
                    f"virtual time {t:.3f} — wedged protocol?")
            self._now = max(self._now, t)
            self.stats["events"] += 1
            if kind == "msg":
                src, dst, msg = payload
                if dst in self._stopped:
                    continue
                if self._down(dst, self._now) or self._down(src, self._now):
                    self.stats["blackholed"] += 1
                    continue
                self.stats["delivered"] += 1
                try:
                    self._actors[dst].on_message(src, msg, self._apis[dst])
                except SimulatedCrash:
                    self._kill(dst)
            elif kind == "timer":
                node, name, tok = payload
                if (node in self._stopped
                        or self._timer_tok.get((node, name)) != tok
                        or self._down(node, self._now)):
                    continue   # cancelled / superseded / node is down
                try:
                    self._actors[node].on_timer(name, self._apis[node])
                except SimulatedCrash:
                    self._kill(node)
            elif kind == "crash":
                (node,) = payload
                if node not in self._stopped:
                    self._actors[node].on_crash(self._apis[node])
            elif kind == "rejoin":
                (node,) = payload
                if node not in self._stopped:
                    self._actors[node].on_rejoin(self._apis[node])


# ---------------------------------------------------------------------------
# ProcEndpoint: the same contract over real processes + pipes, wall clock
# ---------------------------------------------------------------------------

def _node_seed(seed: int, node_id: str) -> int:
    return (int(seed) * 0x9E3779B1 + zlib.crc32(node_id.encode())) % (2**32)


class ProcEndpoint(TransportAPI):
    """One node's endpoint of the real multi-process transport.

    ``conns`` maps peer node ids to ``multiprocessing.Connection`` objects
    (duplex pipes — cluster.py wires a star topology around the server).
    Injected latency is decided at SEND time from a per-node seeded
    generator and shipped in the envelope as an absolute ``deliver_at``
    deadline (``time.monotonic`` is boot-anchored and shared across
    processes on Linux); the receiver parks early arrivals in a local heap
    until they are due, so wall-clock latency injection composes with real
    scheduling noise instead of replacing it. Drops and duplicates follow
    the same :class:`FaultPlan` contract as the virtual transport;
    crash windows are an InProc-only feature (real processes die for
    real — ``launch/cluster.py`` kills and respawns instead).
    """

    def __init__(self, node_id: str, conns: Dict[str, Any],
                 plan: Optional[FaultPlan] = None, seed: int = 0,
                 clock: Callable[[], float] = time.monotonic):
        self.node_id = node_id
        self.plan = plan or FaultPlan()
        self._conns = dict(conns)
        self._clock = clock
        self._rng = np.random.default_rng(_node_seed(seed, node_id))
        self._inbox: list = []          # (deliver_at, seq, src, msg)
        self._timers: list = []         # (deadline, seq, name, tok)
        self._timer_tok: Dict[str, int] = {}
        self._fifo_last: Dict[str, float] = {}
        self._seq = 0
        self._stop = False
        self.stats = {"delivered": 0, "dropped": 0, "duplicated": 0,
                      "sent": 0, "peer_gone": 0}

    # -- TransportAPI -------------------------------------------------------

    def now(self) -> float:
        return self._clock()

    def send(self, dst: str, msg: Any) -> None:
        conn = self._conns.get(dst)
        if conn is None:
            self.stats["peer_gone"] += 1
            return
        decision = self.plan.decide(self.node_id, dst, _msg_kind(msg),
                                    self._rng)
        if decision.dropped:
            self.stats["dropped"] += 1
            return
        if len(decision.latencies) > 1:
            self.stats["duplicated"] += 1
        now = self.now()
        for lat in decision.latencies:
            at = now + max(float(lat), 0.0)
            if decision.fifo:
                at = max(at, self._fifo_last.get(dst, -np.inf) + _FIFO_EPS)
                self._fifo_last[dst] = at
            try:
                conn.send((self.node_id, at, msg))
                self.stats["sent"] += 1
            except (BrokenPipeError, OSError):
                self.stats["peer_gone"] += 1
                self._conns.pop(dst, None)
                return

    def set_timer(self, name: str, delay: float) -> None:
        tok = self._timer_tok.get(name, 0) + 1
        self._timer_tok[name] = tok
        heapq.heappush(self._timers,
                       (self.now() + max(float(delay), 0.0), self._seq,
                        name, tok))
        self._seq += 1

    def cancel_timer(self, name: str) -> None:
        self._timer_tok[name] = self._timer_tok.get(name, 0) + 1

    def stop(self) -> None:
        self._stop = True

    # -- the pump -----------------------------------------------------------

    def _drain_conns(self, timeout: float) -> None:
        from multiprocessing import connection as mpc
        conns = list(self._conns.values())
        if not conns:
            time.sleep(min(timeout, 0.05))
            return
        try:
            ready = mpc.wait(conns, timeout=max(timeout, 0.0))
        except OSError:
            ready = []
        for conn in ready:
            try:
                while conn.poll():
                    src, at, msg = conn.recv()
                    heapq.heappush(self._inbox, (at, self._seq, src, msg))
                    self._seq += 1
            except (EOFError, OSError):
                for k, v in list(self._conns.items()):
                    if v is conn:
                        self._conns.pop(k)

    def run(self, actor: Actor, until: Optional[float] = None) -> None:
        """Pump loop: wait on the pipes with a timeout equal to the next
        timer/delivery deadline, then fire everything due in time order.
        ``until`` is a wall-clock **duration** bound (seconds from entry) —
        the anti-wedge guard for smoke tests."""
        deadline_abs = None if until is None else self.now() + until
        actor.on_start(self)
        while not self._stop:
            now = self.now()
            if deadline_abs is not None and now >= deadline_abs:
                break
            # fire everything due, interleaved in time order
            while not self._stop:
                t_timer = self._timers[0][0] if self._timers else np.inf
                t_msg = self._inbox[0][0] if self._inbox else np.inf
                if min(t_timer, t_msg) > now:
                    break
                if t_timer <= t_msg:
                    _, _, name, tok = heapq.heappop(self._timers)
                    if self._timer_tok.get(name) == tok:
                        actor.on_timer(name, self)
                else:
                    _, _, src, msg = heapq.heappop(self._inbox)
                    self.stats["delivered"] += 1
                    actor.on_message(src, msg, self)
            if self._stop:
                break
            t_next = min(self._timers[0][0] if self._timers else np.inf,
                         self._inbox[0][0] if self._inbox else np.inf)
            if deadline_abs is not None:
                t_next = min(t_next, deadline_abs)
            timeout = 0.1 if np.isinf(t_next) \
                else min(max(t_next - self.now(), 0.0), 0.1)
            self._drain_conns(timeout)

    def close(self) -> None:
        for conn in self._conns.values():
            try:
                conn.close()
            except OSError:
                pass
        self._conns.clear()
