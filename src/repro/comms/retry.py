"""Retry/timeout/exponential-backoff policy for the client push path.

The update push (client -> server) is the one unreliable message class in
the fault model (see :mod:`repro.comms.faults`): it can be dropped or
duplicated. The client therefore keeps every un-ACKed update and re-sends
it on a backoff schedule until the server acknowledges (possibly as
*stale*, when the round already closed) or the attempt budget runs out.
Timers come from the transport, so the same policy is exact under the
virtual clock and approximate under the wall clock.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class BackoffPolicy:
    """Exponential backoff with a cap: attempt ``k`` (0-based) waits
    ``min(base * factor**k, max_delay)`` before re-sending, up to
    ``max_attempts`` total sends. No jitter here — retry determinism is
    part of the InProcTransport equivalence contract; wall-clock jitter is
    injected by the fault layer instead."""
    base: float = 0.5
    factor: float = 2.0
    max_delay: float = 8.0
    max_attempts: int = 6

    def __post_init__(self):
        if self.base <= 0 or self.factor < 1.0 or self.max_attempts < 1:
            raise ValueError(f"invalid backoff policy {self}")

    def delay(self, attempt: int) -> float:
        """Wait before send ``attempt + 1`` (attempt is the 0-based index
        of the send that just happened)."""
        return min(self.base * self.factor ** attempt, self.max_delay)

    def exhausted(self, attempt: int) -> bool:
        """True once ``attempt`` sends have been made and no more are
        allowed."""
        return attempt >= self.max_attempts
