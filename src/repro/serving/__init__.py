from repro.serving.scheduler import Request, ContinuousBatcher
