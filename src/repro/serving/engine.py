"""Slot-wise decode engine: adapts the model's ``decode_step`` (single
shared position) to the continuous batcher's per-slot positions by vmapping
the per-sample decode over the slot axis. The batched KV cache lives here
as engine state; shapes stay static across steps.

Axis bookkeeping: with scanned layers the cache leaves are (L, B, S, ...)
— the slot axis is 1; list-structured caches put it at 0. We build a
matching in/out-axes pytree once and vmap over it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model import ModelConfig, init_cache, decode_step


def _batch_axes_tree(cache, cfg: ModelConfig):
    stacked = cfg.uniform_stack()

    def one(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        return 1 if (stacked and "layers" in names) else 0
    return jax.tree_util.tree_map_with_path(one, cache)


class DecodeEngine:
    def __init__(self, params, cfg: ModelConfig, batch_slots: int,
                 max_seq: int, cache_dtype=jnp.bfloat16):
        self.params = params
        self.cfg = cfg
        self.B = batch_slots
        self.cache = init_cache(cfg, batch_slots, max_seq, dtype=cache_dtype)
        axes = _batch_axes_tree(self.cache, cfg)

        def one(cache_row, token_row, pos):
            c = jax.tree_util.tree_map(
                lambda x, a: jnp.expand_dims(x, a), cache_row, axes)
            logits, c = decode_step(params, cfg, c, token_row[None], pos)
            c = jax.tree_util.tree_map(lambda x, a: jnp.squeeze(x, a), c, axes)
            return logits[0], c

        @jax.jit
        def stepped(cache, tokens, pos):
            logits, cache = jax.vmap(
                one, in_axes=(axes, 0, 0), out_axes=(0, axes))(
                cache, tokens, pos)
            return logits, cache
        self._step = stepped

    def step_fn(self, tokens, pos):
        """tokens (B,1) int32, pos (B,) int32 -> logits (B,1,V)."""
        logits, self.cache = self._step(self.cache, tokens, pos)
        return logits
