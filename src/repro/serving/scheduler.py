"""Continuous batching for the decode server (vLLM-style slot scheduler,
TPU-shaped: fixed batch slots, static shapes, no paging — the KV cache is
the dense (B, S, H, hd) block the dry-run lowers; slot reuse replaces
paged attention, which has no TPU-native analogue at these shapes).

The scheduler owns:
  * a FIFO admission queue of Requests;
  * B fixed decode slots, each a row of the batched KV cache;
  * per-slot position counters and EOS/length termination.

Every engine step decodes ONE token for all live slots (the decode_32k
shape); prompt tokens are fed through the same step path (prefill-by-decode
keeps shapes static; a fused prefill for long prompts is the prefill_32k
path). Newly freed slots are refilled from the queue between steps — the
"continuous" part.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                 # next absolute position to write
    prompt_left: int = 0

    @property
    def live(self) -> bool:
        return self.req is not None


class ContinuousBatcher:
    """Drives ``decode_step`` over B slots with continuous admission.

    decode_fn(token (B,1) int32, pos (B,) int32) -> logits (B, 1, V) and
    must internally update the per-slot caches at each slot's own position
    (the engine passes per-slot positions; see serve loop below).
    """

    def __init__(self, batch_slots: int, step_fn: Callable, *,
                 vocab_raw: int, pad_id: int = 0, seed: int = 0):
        self.B = batch_slots
        self.step_fn = step_fn
        self.vocab_raw = vocab_raw
        self.pad_id = pad_id
        self.queue: List[Request] = []
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.finished: Dict[int, Request] = {}
        self.key = jax.random.PRNGKey(seed)
        self.steps = 0

    # ----------------------------------------------------------------- API
    def submit(self, req: Request):
        self.queue.append(req)

    def has_work(self) -> bool:
        return bool(self.queue) or any(s.live for s in self.slots)

    def run(self, max_steps: int = 10_000, temperature: float = 0.0):
        while self.has_work() and self.steps < max_steps:
            self.step(temperature)
        return self.finished

    # ---------------------------------------------------------------- core
    def _admit(self):
        for s in self.slots:
            if not s.live and self.queue:
                req = self.queue.pop(0)
                s.req = req
                s.pos = 0
                s.prompt_left = len(req.prompt)

    def _next_inputs(self):
        toks = np.full((self.B, 1), self.pad_id, np.int32)
        pos = np.zeros((self.B,), np.int32)
        for i, s in enumerate(self.slots):
            if not s.live:
                continue
            r = s.req
            if s.prompt_left > 0:
                toks[i, 0] = r.prompt[len(r.prompt) - s.prompt_left]
            else:
                toks[i, 0] = r.output[-1] if r.output else r.prompt[-1]
            pos[i] = s.pos
        return jnp.asarray(toks), jnp.asarray(pos)

    def step(self, temperature: float = 0.0):
        self._admit()
        if not any(s.live for s in self.slots):
            return
        toks, pos = self._next_inputs()
        logits = self.step_fn(toks, pos)                 # (B, 1, V)
        self.steps += 1
        logits = logits[:, -1, :self.vocab_raw]
        if temperature > 0:
            self.key, sub = jax.random.split(self.key)
            nxt = np.asarray(jax.random.categorical(
                sub, logits / temperature))
        else:
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i, s in enumerate(self.slots):
            if not s.live:
                continue
            r = s.req
            s.pos += 1
            if s.prompt_left > 0:
                s.prompt_left -= 1
                if s.prompt_left > 0:
                    continue                             # still prefilling
            token = int(nxt[i])
            r.output.append(token)
            stop = (len(r.output) >= r.max_new_tokens
                    or (r.eos_id is not None and token == r.eos_id))
            if stop:
                r.done = True
                self.finished[r.uid] = r
                self.slots[i] = _Slot()                  # free the slot

    # ------------------------------------------------------------- stats
    def utilization(self) -> float:
        return sum(s.live for s in self.slots) / self.B
