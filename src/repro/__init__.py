"""repro — FAVAS/FAVANO (asynchronous federated averaging with unbiased
straggler reweighting) as a multi-pod JAX training/inference framework."""
__version__ = "1.0.0"
