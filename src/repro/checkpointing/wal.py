"""Crash-safe write-ahead log + snapshots for the async FAVAS server
(docs/architecture.md §12).

The durability layer under ``launch/server.py::FavasAsyncServer``: every
protocol transition that affects the aggregate (round start, each admitted
update, round close) is appended to an on-disk log BEFORE its effects are
acknowledged, so a restarted server recovers as

    latest valid snapshot  +  replay of the WAL records after it.

Format
------
A **record** is a CRC-framed pickled payload::

    [u32 length][u32 crc32(payload)][payload bytes]

Appends are optionally fsynced. On replay, a record whose header is
incomplete, whose payload is shorter than ``length``, or whose CRC
mismatches is treated as a **torn tail**: replay stops there and reports
``torn=True`` — exactly the state a crash mid-``write`` leaves behind.
Admitted updates are logged in their wire-exact representation (LUQ codes
+ scales when the server runs ``quant_bits > 0``, raw float32 rows
otherwise), so replay rebuilds the pending set bit-for-bit.

**Segments** (``wal_<idx>.seg``) are append-only and strictly ordered by
index. A **snapshot** (``snap_<step>.ck``) is one framed record written to
a tmp file, fsynced, and atomically renamed into place (then the directory
is fsynced), carrying the segment index replay should resume from; after a
snapshot lands, older segments and snapshots are pruned. A torn snapshot
therefore never shadows an older valid one: :func:`latest_snapshot` CRC-
checks candidates newest-first and skips unreadable ones.

Payloads are pickled (own files, own process — the arrays round-trip
bit-exactly, including packed uint8 LUQ codes and f32 scales).
"""
from __future__ import annotations

import os
import pickle
import re
import struct
import zlib
from typing import Any, List, Optional, Tuple

_HDR = struct.Struct("<II")
_SEG_RE = re.compile(r"wal_(\d+)\.seg")
_SNAP_RE = re.compile(r"snap_(\d+)\.ck")


def _encode(obj: Any) -> bytes:
    return pickle.dumps(obj, protocol=4)


def frame(obj: Any) -> bytes:
    """One CRC-framed record: header + payload."""
    payload = _encode(obj)
    return _HDR.pack(len(payload), zlib.crc32(payload)) + payload


def read_frames(data: bytes) -> Tuple[List[Any], bool]:
    """Decode consecutive framed records. Returns ``(records, torn)`` —
    ``torn`` is True when the buffer ends in an incomplete or CRC-invalid
    record (everything before it is returned)."""
    out: List[Any] = []
    off = 0
    n = len(data)
    while off < n:
        if n - off < _HDR.size:
            return out, True
        length, crc = _HDR.unpack_from(data, off)
        start = off + _HDR.size
        end = start + length
        if end > n:
            return out, True
        payload = data[start:end]
        if zlib.crc32(payload) != crc:
            return out, True
        out.append(pickle.loads(payload))
        off = end
    return out, False


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------

def segment_files(directory: str) -> List[Tuple[int, str]]:
    """``(index, path)`` of every WAL segment, ascending."""
    if not os.path.isdir(directory):
        return []
    out = []
    for f in os.listdir(directory):
        m = _SEG_RE.fullmatch(f)
        if m:
            out.append((int(m.group(1)), os.path.join(directory, f)))
    return sorted(out)


def _fsync_dir(directory: str) -> None:
    try:
        fd = os.open(directory, os.O_RDONLY)
    except OSError:  # pragma: no cover - platform without dir fds
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class WalWriter:
    """Append-only writer. Each :class:`WalWriter` opens a FRESH segment
    (max existing index + 1), so a recovering server never appends into a
    possibly-torn predecessor file — the old tail stays readable exactly
    as the crash left it.

    ``fsync=True`` (the default) makes every append durable before it
    returns — the write-ahead contract the server's ack path relies on.
    """

    def __init__(self, directory: str, *, fsync: bool = True):
        self.directory = directory
        self.fsync = bool(fsync)
        os.makedirs(directory, exist_ok=True)
        segs = segment_files(directory)
        self._seg_idx = (segs[-1][0] + 1) if segs else 1
        self._open_segment()

    def _open_segment(self) -> None:
        self.path = os.path.join(self.directory,
                                 f"wal_{self._seg_idx:08d}.seg")
        self._f = open(self.path, "ab")
        _fsync_dir(self.directory)

    @property
    def segment_index(self) -> int:
        return self._seg_idx

    def append(self, obj: Any) -> None:
        self._f.write(frame(obj))
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    def rotate(self) -> int:
        """Seal the current segment and start the next. Returns the NEW
        segment index (what a snapshot taken now should record as its
        replay start)."""
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()
        self._seg_idx += 1
        self._open_segment()
        return self._seg_idx

    def tear_tail(self, nbytes: int) -> None:
        """Chaos hook: truncate the current segment by ``nbytes`` —
        models a crash mid-write leaving a torn final record (replay must
        tolerate it)."""
        self._f.flush()
        size = self._f.tell()
        self._f.truncate(max(size - int(nbytes), 0))
        os.fsync(self._f.fileno())

    def close(self) -> None:
        try:
            self._f.flush()
            os.fsync(self._f.fileno())
        finally:
            self._f.close()


def replay(directory: str, start_seg: int = 0) -> Tuple[List[Any], dict]:
    """Read every record from segments ``>= start_seg`` in index order.

    Returns ``(records, meta)``; ``meta["torn"]`` is True when a segment
    ended in a torn/CRC-invalid record. Replay stops at the first tear —
    records in LATER segments (there are none in a crash, but belt and
    braces) are not trusted past a tear."""
    records: List[Any] = []
    meta = {"torn": False, "segments": 0}
    for idx, path in segment_files(directory):
        if idx < start_seg:
            continue
        with open(path, "rb") as f:
            recs, torn = read_frames(f.read())
        records.extend(recs)
        meta["segments"] += 1
        if torn:
            meta["torn"] = True
            break
    return records, meta


def prune_segments(directory: str, before: int) -> int:
    """Delete segments with index < ``before`` (covered by a snapshot)."""
    n = 0
    for idx, path in segment_files(directory):
        if idx < before:
            os.unlink(path)
            n += 1
    if n:
        _fsync_dir(directory)
    return n


# ---------------------------------------------------------------------------
# snapshots
# ---------------------------------------------------------------------------

def snapshot_files(directory: str) -> List[Tuple[int, str]]:
    if not os.path.isdir(directory):
        return []
    out = []
    for f in os.listdir(directory):
        m = _SNAP_RE.fullmatch(f)
        if m:
            out.append((int(m.group(1)), os.path.join(directory, f)))
    return sorted(out)


def save_snapshot(directory: str, step: int, payload: Any) -> str:
    """One framed+CRC'd record, written tmp -> fsync -> atomic rename ->
    dir fsync. A crash at ANY point leaves either the old snapshot set or
    the complete new file — never a half-written visible snapshot."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"snap_{step:08d}.ck")
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        f.write(frame(payload))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    _fsync_dir(directory)
    return final


def load_snapshot(path: str) -> Any:
    with open(path, "rb") as f:
        recs, torn = read_frames(f.read())
    if torn or len(recs) != 1:
        raise ValueError(f"snapshot {path!r} is torn or malformed")
    return recs[0]


def latest_snapshot(directory: str) -> Optional[str]:
    """Newest snapshot that actually loads (CRC-valid, complete). Torn or
    unreadable candidates are skipped, not returned."""
    for _, path in reversed(snapshot_files(directory)):
        try:
            load_snapshot(path)
            return path
        except (ValueError, OSError, pickle.UnpicklingError, EOFError):
            continue
    return None


def prune_snapshots(directory: str, keep: int = 2) -> int:
    """Keep the newest ``keep`` snapshots, delete the rest."""
    snaps = snapshot_files(directory)
    n = 0
    for _, path in snaps[:-keep] if keep > 0 else snaps:
        os.unlink(path)
        n += 1
    if n:
        _fsync_dir(directory)
    return n
