"""Checkpointing: pytree <-> flat .npz with '/'-joined key paths (orbax is
not available offline). Crash-safe write: tmp file -> fsync -> atomic
rename -> directory fsync, so power loss at any point leaves either the
old checkpoint set or the complete new file, never a torn visible one;
:func:`latest_checkpoint` additionally validates candidates newest-first
and skips any that do not load (a torn or truncated file never shadows an
older good checkpoint). Restores go into the reference tree's structure
and dtypes, so sharded trees round-trip after a device_get.
"""
from __future__ import annotations

import os
import re
import tempfile
import zipfile
from typing import Any, Optional

import jax
import numpy as np


def _write_npz_atomic(directory: str, final: str, flat: dict) -> str:
    """tmp + fsync + rename + dir-fsync — the same durability ladder as
    checkpointing/wal.py snapshots."""
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    try:
        dfd = os.open(directory, os.O_RDONLY)
    except OSError:  # pragma: no cover - platform without dir fds
        return final
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)
    return final


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name == "bfloat16":       # npz can't serialize ml_dtypes
            arr = arr.astype(np.float32)       # lossless widening
        flat[_path_str(path)] = arr
    final = os.path.join(directory, f"ckpt_{step:08d}.npz")
    return _write_npz_atomic(directory, final, flat)


def load_checkpoint(path: str, reference: Any) -> Any:
    """Restore into ``reference``'s structure (shapes/dtypes validated)."""
    with np.load(path) as data:
        paths, treedef = jax.tree_util.tree_flatten_with_path(reference)
        leaves = []
        for p, ref in paths:
            key = _path_str(p)
            if key not in data:
                raise KeyError(f"checkpoint missing {key}")
            arr = data[key]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"{key}: shape {arr.shape} != {ref.shape}")
            leaves.append(np.asarray(jax.numpy.asarray(arr).astype(ref.dtype)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


_META_KEY = "__engine_dtypes__"


def save_engine_checkpoint(directory: str, step: int, state: Any) -> str:
    """Save a ``core.round_engine.EngineState`` — hot flat buffers,
    counters, staleness, the rng KEY CHAIN, and (paged states) the hot-id
    vector plus the codec-encoded cold pools (packed uint8 codes and f32
    scales serialize natively).

    Rides the generic '/'-joined-path npz layout of :func:`save_checkpoint`
    but additionally records every leaf's ORIGINAL dtype under
    ``__engine_dtypes__``, so :func:`load_engine_checkpoint` can tell a
    genuinely-f32 buffer from a losslessly widened bf16 one and refuse a
    silently-casting restore. Round-trip is exact to the bit for every
    dtype the engine stores (tests/test_paged_engine.py)."""
    os.makedirs(directory, exist_ok=True)
    flat, meta = {}, []
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        key = _path_str(path)
        arr = np.asarray(jax.device_get(leaf))
        meta.append(f"{key}:{arr.dtype.name}")
        if arr.dtype.name == "bfloat16":
            arr = arr.astype(np.float32)   # lossless widening
        flat[key] = arr
    flat[_META_KEY] = np.array(meta)
    final = os.path.join(directory, f"ckpt_{step:08d}.npz")
    return _write_npz_atomic(directory, final, flat)


def load_engine_checkpoint(path: str, state_template: Any) -> Any:
    """Restore an ``EngineState`` into ``state_template``'s structure.

    Stricter than :func:`load_checkpoint`: besides shapes, leaf DTYPES are
    validated against the recorded originals — restoring a bf16 engine's
    checkpoint into an f32 engine (or a 4-bit cold pool into an 8-bit one)
    raises instead of silently casting. Checkpoints written by the generic
    :func:`save_checkpoint` (no dtype record) still load, dtype-unchecked,
    so pre-existing run directories keep restoring."""
    with np.load(path) as data:
        recorded = {}
        if _META_KEY in data:
            for item in data[_META_KEY]:
                k, _, dt = str(item).rpartition(":")
                recorded[k] = dt
        paths, treedef = jax.tree_util.tree_flatten_with_path(state_template)
        leaves = []
        for p, ref in paths:
            key = _path_str(p)
            if key not in data:
                raise KeyError(f"checkpoint missing {key}")
            want = np.dtype(ref.dtype).name
            if recorded and recorded.get(key) != want:
                raise ValueError(
                    f"{key}: checkpoint dtype {recorded.get(key)} != state "
                    f"dtype {want} (engine layout change)")
            arr = data[key]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"{key}: shape {arr.shape} != {ref.shape}")
            leaves.append(jax.numpy.asarray(arr).astype(ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _readable(path: str) -> bool:
    """Cheap integrity probe: the zip central directory must parse and
    every member must decompress (CRC-checked by zipfile). Catches torn
    tails, truncation, and half-written files without materializing
    arrays."""
    try:
        with zipfile.ZipFile(path) as z:
            return z.testzip() is None
    except (zipfile.BadZipFile, OSError, EOFError):
        return False


def latest_checkpoint(directory: str) -> Optional[str]:
    """Newest checkpoint that actually LOADS. Unreadable/torn candidates
    (a crash mid-write predating the atomic-rename path, a truncated
    copy) are skipped, never returned — recovery must not wedge on the
    highest-numbered file being garbage."""
    if not os.path.isdir(directory):
        return None
    found = []
    for f in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d+)\.npz", f)
        if m:
            found.append((int(m.group(1)), os.path.join(directory, f)))
    for _, path in sorted(found, reverse=True):
        if _readable(path):
            return path
    return None
