"""Checkpointing: pytree <-> flat .npz with '/'-joined key paths (orbax is
not available offline). Atomic write via tmp-rename; restores into the
reference tree's structure and dtypes, so sharded trees round-trip after a
device_get.
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name == "bfloat16":       # npz can't serialize ml_dtypes
            arr = arr.astype(np.float32)       # lossless widening
        flat[_path_str(path)] = arr
    final = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, final)
    return final


def load_checkpoint(path: str, reference: Any) -> Any:
    """Restore into ``reference``'s structure (shapes/dtypes validated)."""
    with np.load(path) as data:
        paths, treedef = jax.tree_util.tree_flatten_with_path(reference)
        leaves = []
        for p, ref in paths:
            key = _path_str(p)
            if key not in data:
                raise KeyError(f"checkpoint missing {key}")
            arr = data[key]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"{key}: shape {arr.shape} != {ref.shape}")
            leaves.append(np.asarray(jax.numpy.asarray(arr).astype(ref.dtype)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    best = None
    for f in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d+)\.npz", f)
        if m and (best is None or int(m.group(1)) > best[0]):
            best = (int(m.group(1)), os.path.join(directory, f))
    return best[1] if best else None
