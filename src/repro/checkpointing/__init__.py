from repro.checkpointing import wal
from repro.checkpointing.ckpt import (save_checkpoint, load_checkpoint,
                                      latest_checkpoint,
                                      save_engine_checkpoint,
                                      load_engine_checkpoint)
