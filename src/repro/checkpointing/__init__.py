from repro.checkpointing.ckpt import save_checkpoint, load_checkpoint, latest_checkpoint
