"""Docs validity check (run by the CI ``docs`` job).

Verifies that README.md and docs/architecture.md only reference things that
exist:

* every repo-relative path mentioned (``src/...``, ``tests/...``,
  ``examples/...``, ``benchmarks/...``, ``docs/...``, ``experiments/...``)
  resolves to a real file or directory;
* every ``python -m <module>`` in a fenced shell block imports under
  PYTHONPATH=src (spec lookup only — nothing is executed);
* every ``python <script.py>`` in a fenced shell block points at a real
  file.

Usage:  python tools/check_docs.py
Exit status 0 = docs are consistent with the tree.
"""
from __future__ import annotations

import importlib.util
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ("README.md", "docs/architecture.md")

PATH_RE = re.compile(
    r"\b((?:src|tests|examples|benchmarks|docs|experiments|tools)"
    r"/[\w./\-]+)")
MODULE_RE = re.compile(r"python\s+-m\s+([\w.]+)")
SCRIPT_RE = re.compile(r"python\s+([\w/.\-]+\.py)")


def fenced_blocks(text: str):
    return re.findall(r"```(?:bash|sh|console)?\n(.*?)```", text, re.S)


def _resolves(mod: str) -> bool:
    try:
        return importlib.util.find_spec(mod) is not None
    except (ImportError, ModuleNotFoundError, ValueError):
        return False


def main() -> int:
    sys.path.insert(0, os.path.join(ROOT, "src"))
    sys.path.insert(0, ROOT)          # benchmarks/ is a root-level package
    errors = []
    for doc in DOCS:
        path = os.path.join(ROOT, doc)
        if not os.path.exists(path):
            errors.append(f"{doc}: missing")
            continue
        text = open(path).read()
        for ref in sorted(set(PATH_RE.findall(text))):
            ref = ref.rstrip(".")
            # globs / placeholder patterns are not literal paths
            if "*" in ref or "{" in ref or ref.endswith("/"):
                continue
            if not os.path.exists(os.path.join(ROOT, ref)):
                errors.append(f"{doc}: references nonexistent path {ref!r}")
        for block in fenced_blocks(text):
            for mod in MODULE_RE.findall(block):
                if not _resolves(mod):
                    errors.append(f"{doc}: `python -m {mod}` does not resolve")
            for script in SCRIPT_RE.findall(block):
                if not os.path.exists(os.path.join(ROOT, script)):
                    errors.append(f"{doc}: `python {script}` — no such file")
    if errors:
        print("\n".join(errors))
        return 1
    print(f"docs OK: {', '.join(DOCS)} consistent with the tree")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
