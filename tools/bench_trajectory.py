"""Perf-trajectory collation: one table over every repo-root BENCH_*.json.

Each performance PR leaves its headline numbers in a root ``BENCH_<name>.
json`` artifact (written by the matching ``benchmarks/<name>_bench.py``
full run). This tool collates them into a single table — the repo's
performance trajectory at a glance — and is printed at the end of the CI
``bench`` job so every run shows the full history, not just the benchmark
it exercised.

Usage:  python tools/bench_trajectory.py [--root PATH]
Exit status 0 when every BENCH file parses (missing files are fine — the
trajectory grows PR by PR); 1 on a corrupt file.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _get(d, *path, default=None):
    for p in path:
        if not isinstance(d, dict) or p not in d:
            return default
        d = d[p]
    return d


def _headline(name: str, d: dict) -> str:
    """One human-meaningful cell per trajectory file (same spirit as
    ``benchmarks.run._derive``, but over the persisted artifacts)."""
    if name == "round_loop":
        s32 = _get(d, "cpu_oracle", "superstep", "32", default={})
        return (f"superstep32 {s32.get('rounds_per_sec', 0):.0f} r/s "
                f"(x{s32.get('speedup_vs_host_loop', 0):.2f} vs host loop)")
    if name == "data_plane":
        rows = [r for r in d.get("chunk_sweep_n64", []) if r.get("chunk") == 32]
        if rows:
            r = rows[0]
            return (f"device plane {_get(r, 'device', 'rounds_per_sec', default=0):.0f} r/s "
                    f"(x{_get(r, 'device', 'speedup_vs_host_v1', default=0):.2f} vs host)")
    if name == "paged_state":
        pop = _get(d, "max_population_at_fixed_memory",
                   "population_ratio_paged_vs_dense", default=0)
        rel = _get(d, "throughput_n1024_chunk32", "paged_over_dense",
                   default=0)
        return f"population x{pop:.1f} @16GiB, rounds/sec x{rel:.2f}"
    if name == "quant_fused":
        r = (d.get("sweep") or [{}])[-1]
        return (f"fused x{r.get('fused_over_unfused', 0):.2f} r/s, "
                f"progress bytes x{r.get('progress_bytes_ratio', 0):.1f} smaller")
    if name == "async_server":
        return (f"real {_get(d, 'real', 'rounds_per_sec', default=0):.1f} r/s vs "
                f"sim {_get(d, 'simulated', 'rounds_per_sec', default=0):.1f} r/s, "
                f"selection_identical={d.get('selection_identical')}")
    if name == "recovery":
        ov = _get(d, "overhead", "overhead_frac", default=0)
        return (f"WAL overhead {ov * 100:.1f}%, "
                f"bit_exact={_get(d, 'overhead', 'bit_exact')}")
    if name == "streaming":
        pop = _get(d, "max_population_at_fixed_device_memory",
                   "population_ratio_host_vs_device", default=0)
        ceil = _get(d, "max_population_at_fixed_device_memory",
                    "host_placement", "max_population_at_budget", default=0)
        rel = _get(d, "throughput_n1024_chunk32", "host_over_device",
                   default=0)
        return (f"host tier x{pop:.0f} population ({ceil:,} @16GiB), "
                f"rounds/sec x{rel:.2f} vs device placement")
    keys = [k for k in d if k not in ("config", "note")]
    return f"keys: {', '.join(keys[:4])}"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=ROOT)
    args = ap.parse_args()
    files = sorted(glob.glob(os.path.join(args.root, "BENCH_*.json")))
    if not files:
        print("no BENCH_*.json trajectory files yet")
        return 0
    rows, bad = [], 0
    for path in files:
        name = os.path.basename(path)[len("BENCH_"):-len(".json")]
        try:
            with open(path) as f:
                d = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            rows.append((name, f"CORRUPT: {e}"))
            bad += 1
            continue
        rows.append((name, _headline(name, d)))
    width = max(len(n) for n, _ in rows)
    print("perf trajectory (repo-root BENCH_*.json):")
    for name, cell in rows:
        print(f"  {name:<{width}}  {cell}")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
