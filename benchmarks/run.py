# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness (deliverable d): one entry per paper table/figure plus
the roofline/kernel harnesses. ``--full`` runs paper-scale FL simulations
(slow); the default quick mode keeps CPU CI in minutes.

  PYTHONPATH=src python -m benchmarks.run [--full] [--smoke] [--only NAME]

``--smoke`` asks each benchmark that supports it (data_plane_bench,
paged_state_bench, streaming_bench, quant_fused_bench, async_server_bench,
recovery_bench) for its cheapest defensible check;
smoke artifacts go
to ``*_smoke.json`` and never overwrite the canonical files. Benchmarks
without a smoke path just run their quick mode.
"""
from __future__ import annotations

import argparse
import time
import traceback


# benchmarks re-run on the accelerator tier (``--tier device``): the
# kernel-facing subset whose numbers change with a real backend
DEVICE_TIER = {"kernel_bench", "round_loop_bench", "paged_state_bench",
               "streaming_bench", "roofline_table"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--tier", default="host", choices=["host", "device"],
                    help="host (default): the CPU-oracle suite. device: "
                         "re-run the kernel-facing benchmarks on the real "
                         "accelerator backend — with no TPU/GPU present "
                         "this SKIPS CLEANLY (exit 0), so the CI job is a "
                         "no-op off-accelerator")
    args, _ = ap.parse_known_args()
    quick = not args.full
    smoke = args.smoke

    if args.tier == "device":
        import jax
        backend = jax.default_backend()
        if backend not in ("tpu", "gpu"):
            print(f"tier=device: no accelerator backend "
                  f"(jax.default_backend()={backend!r}) — skipping cleanly")
            raise SystemExit(0)

    from benchmarks import (fl_paper, theory_table, kernel_bench,
                            roofline_table, ablation_reweight,
                            round_loop_bench, data_plane_bench,
                            paged_state_bench, quant_fused_bench,
                            async_server_bench, recovery_bench,
                            streaming_bench)

    suite = [
        ("table1_theory", lambda: theory_table.run(quick)),
        ("kernel_bench", lambda: kernel_bench.run(quick)),
        ("round_loop_bench", lambda: round_loop_bench.run(quick)),
        ("data_plane_bench", lambda: data_plane_bench.run(quick,
                                                          smoke=smoke)),
        ("paged_state_bench", lambda: paged_state_bench.run(quick,
                                                            smoke=smoke)),
        ("streaming_bench", lambda: streaming_bench.run(quick, smoke=smoke)),
        ("quant_fused_bench", lambda: quant_fused_bench.run(quick,
                                                            smoke=smoke)),
        ("async_server_bench", lambda: async_server_bench.run(quick,
                                                              smoke=smoke)),
        ("recovery_bench", lambda: recovery_bench.run(quick, smoke=smoke)),
        ("roofline_table", lambda: roofline_table.run(quick)),
        ("fig1_table2_mnist", lambda: fl_paper.fig1_table2(quick)),
        ("fig2_stragglers_1of9fast", lambda: fl_paper.fig2_stragglers(quick)),
        ("fig3a_cifar", lambda: fl_paper.fig3a_cifar(quick)),
        ("fig3b_tinyimagenet_proxy", lambda: fl_paper.fig3b_tiny(quick)),
        ("fig7_quant_luq", lambda: fl_paper.fig7_quant(quick)),
        ("ablation_reweight", lambda: ablation_reweight.run(quick)),
    ]
    if args.tier == "device":
        suite = [(n, f) for n, f in suite if n in DEVICE_TIER]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suite:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            out = fn()
            us = (time.perf_counter() - t0) * 1e6
            derived = _derive(name, out)
            print(f"{name},{us:.0f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name},NA,ERROR:{type(e).__name__}")
    raise SystemExit(1 if failures else 0)


def _derive(name: str, out) -> str:
    """A one-cell human-meaningful summary per benchmark."""
    try:
        if name.startswith("table1"):
            t = out["table1"]
            best = min(t, key=t.get)
            return f"best_bound={best}"
        if name == "kernel_bench":
            return (f"round_fused={out['favas_round_fused_jnp_us']:.0f}us"
                    f";unfused={out['favas_round_unfused_jnp_us']:.0f}us")
        if name == "round_loop_bench":
            o = out["cpu_oracle"]
            s32 = o["superstep"].get("32", {})
            return (f"host={o['host_loop']['rounds_per_sec']:.0f}r/s"
                    f";superstep32={s32.get('rounds_per_sec', 0):.0f}r/s"
                    f";x{s32.get('speedup_vs_host_loop', 0):.2f}")
        if name == "data_plane_bench":
            rows32 = [r for r in out["chunk_sweep_n64"] if r["chunk"] == 32]
            r = rows32[0]
            return (f"host={r['host_v1']['rounds_per_sec']:.0f}r/s"
                    f";device={r['device']['rounds_per_sec']:.0f}r/s"
                    f";x{r['device']['speedup_vs_host_v1']:.2f}")
        if name == "paged_state_bench":
            if "ratio" in out:                       # --smoke shape
                return f"smoke_bytes_ratio=x{out['ratio']:.2f}"
            pop = out["max_population_at_fixed_memory"]
            t = out["throughput_n1024_chunk32"]
            return (f"pop=x{pop['population_ratio_paged_vs_dense']:.1f}"
                    f";rps=x{t['paged_over_dense']:.2f}")
        if name == "streaming_bench":
            if "host_over_device" in out:            # --smoke shape
                return f"smoke_host_rps=x{out['host_over_device']:.2f}"
            pop = out["max_population_at_fixed_device_memory"]
            t = out["throughput_n1024_chunk32"]
            return (f"pop=x{pop['population_ratio_host_vs_device']:.0f}"
                    f";rps=x{t['host_over_device']:.2f}")
        if name == "quant_fused_bench":
            r32 = out["sweep"][-1]
            return (f"n{r32['n_clients']}_fused="
                    f"{r32['fused']['rounds_per_sec']:.0f}r/s"
                    f";x{r32['fused_over_unfused']:.2f}"
                    f";bytes_x{r32['progress_bytes_ratio']:.1f}")
        if name == "ablation_reweight":
            return ";".join(
                f"{k}={v['final_mean']:.3f}/rec{v['slow_class_recall']:.3f}"
                for k, v in out.items())
        if name == "async_server_bench":
            return (f"real={out['real']['rounds_per_sec']:.1f}r/s"
                    f";sim={out['simulated']['rounds_per_sec']:.1f}r/s"
                    f";sel_eq={out['selection_identical']}"
                    f";clean={out['clean']}")
        if name == "recovery_bench":
            ov = out["overhead"]
            rec = out["recovery_vs_length"][-1]
            return (f"wal_overhead={ov['overhead_frac'] * 100:.1f}%"
                    f";bit_exact={ov['bit_exact']}"
                    f";recover_{rec['rounds']}r="
                    f"{rec['recovery_s'] * 1e3:.0f}ms")
        if name == "roofline_table":
            ok = sum(1 for r in out if r["status"] == "ok")
            sk = sum(1 for r in out if r["status"] == "skipped")
            return f"ok={ok};skipped={sk}"
        if name.startswith("fig7"):
            fp = out.get("favas_bits32", {}).get("final_mean")
            q4 = out.get("favas_bits4", {}).get("final_mean")
            return f"fp32={fp:.3f};luq4={q4:.3f}"
        finals = {m: r["final_mean"] for m, r in out.items()}
        order = sorted(finals, key=finals.get, reverse=True)
        return ";".join(f"{m}={finals[m]:.3f}" for m in order)
    except Exception:  # noqa: BLE001
        return "ok"


if __name__ == '__main__':
    main()
