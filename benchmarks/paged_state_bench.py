"""Paged client state benchmark: resident population at fixed memory +
rounds/sec vs the dense engine (docs/architecture.md §9).

The residency layer virtualizes the (n, D) client/init buffers: a hot
working set of ``s_max`` full-precision rows plus LUQ cold pools holding
all n clients at ``cold_bits`` per weight. Two measurements:

* **residency sweep** — actual ``EngineState`` resident bytes (hot stacks
  + cold pools + bookkeeping, measured off the live arrays via
  ``RoundEngine.resident_bytes``) for dense vs paged at n in {1e3, 1e4,
  1e5}. From two population sizes we fit bytes/client and report the MAX
  RESIDENT POPULATION at a fixed memory budget (16 GiB, an HBM-class
  device) for each engine — the headline ratio the layer exists for
  (acceptance: paged fits >= 4x the dense population).
* **throughput sweep** — end-to-end rounds/sec of ``RoundEngine.
  run_device`` (device data plane, one dispatch per 32-round chunk) at
  n = 1024: dense vs paged (s_max = 256, 4-bit cold pools). Paging adds
  the select -> gather+dequant -> requant+scatter rim around the fused
  round; the acceptance gate is paged >= 0.75x dense rounds/sec — the
  memory headroom may not cost more than a quarter of the throughput.

Results go to ``experiments/bench/paged_state.json`` AND the repo-root
``BENCH_paged_state.json`` (the perf-trajectory file).

  PYTHONPATH=src:. python benchmarks/paged_state_bench.py [--full|--smoke]

``--smoke`` (the CI ``paged`` job) runs the cheapest defensible check and
exits non-zero if the paged state is not strictly smaller than the dense
state at n = 4096; smoke artifacts go to ``paged_state_smoke.json`` and
never overwrite the canonical files.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_artifact
from repro.core.favas import FavasConfig, client_lambdas
from repro.core.paging import encoded_nbytes
from repro.core.round_engine import RoundEngine, engine_resident_bytes_by_tier
from repro.data.device_corpus import make_classification_corpus
from repro.models.classifier import classifier_loss, mlp_apply, mlp_init

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

D_IN, D_HIDDEN, N_CLASSES = 16, 16, 10
K, B = 1, 2
S_MAX, COLD_BITS = 256, 4
BUDGET_BYTES = 16 * 1024 ** 3          # 16 GiB — an HBM-class device


def _make_engine(n_clients: int, *, paged: bool):
    key = jax.random.PRNGKey(0)
    params = mlp_init(key, D_IN, D_HIDDEN, N_CLASSES)
    s_sel = min(64, max(n_clients // 4, 1))
    fcfg = FavasConfig(n_clients=n_clients, s_selected=s_sel,
                       local_steps=K, eta=0.1)

    def lfn(p, b):
        return classifier_loss(p, mlp_apply, b["x"], b["y"], N_CLASSES)

    kw = {}
    if paged:
        kw = dict(residency="paged", s_max=min(S_MAX, n_clients),
                  cold_bits=COLD_BITS)
    eng = RoundEngine(params, fcfg, lfn,
                      lambdas=jnp.asarray(client_lambdas(fcfg)),
                      use_kernel=False, **kw)
    return eng, fcfg, params, key


def _resident_bytes(n_clients: int, *, paged: bool) -> int:
    eng, fcfg, params, key = _make_engine(n_clients, paged=paged)
    state = eng.init_state(params, key)
    b = eng.resident_bytes(state)
    # tier split (docs/architecture.md §13): everything here is device
    # placement, so the host tier must be EMPTY and the device tier must
    # be exactly the headline number — benchmarks/streaming_bench.py owns
    # the host-placement side of this identity
    tiers = engine_resident_bytes_by_tier(state)
    if tiers["host"] != 0 or tiers["device"] != b:
        raise SystemExit(f"FAIL: tier accounting drift at n={n_clients}: "
                         f"{tiers} vs resident_bytes {b}")
    jax.tree_util.tree_map(lambda x: x.delete(),
                           jax.tree_util.tree_leaves(state))
    return int(b)


def _cold_accounting(n_clients: int) -> list:
    """Predicted vs measured cold-pool bytes per client, per bucket.

    ``LuqCodec.bytes_per_row`` is the ACCOUNTING used by the residency
    story (docs/architecture.md §9/§10); ``encoded_nbytes`` measures the
    live encoded arrays. The two must agree EXACTLY — the bytes_per_row
    arithmetic used to hard-code a single ``+ 4`` scale regardless of the
    shard count, so this assertion pins the fix."""
    eng, fcfg, params, key = _make_engine(n_clients, paged=True)
    spec = eng.spec
    state = eng.init_state(params, key)
    out = []
    for b in range(spec.n_buckets):
        pred = spec.cold_codec.bytes_per_row(
            spec.bucket_padded[b], spec.bucket_dtypes[b],
            shards=spec.shards(b))
        got = encoded_nbytes(state.cold[b]) / n_clients
        if got != pred:
            raise SystemExit(
                f"FAIL: cold-pool accounting drift in bucket {b}: "
                f"bytes_per_row predicts {pred} B/client but the encoded "
                f"pool measures {got} B/client")
        out.append({"bucket": b, "dtype": str(spec.bucket_dtypes[b]),
                    "shards": spec.shards(b),
                    "predicted_bytes_per_row": int(pred),
                    "measured_bytes_per_row": got})
    jax.tree_util.tree_map(lambda x: x.delete(),
                           jax.tree_util.tree_leaves(state))
    return out


def _fit_population(points: list, budget: int) -> dict:
    """bytes(n) is affine in n (per-client pools + fixed hot/server cost):
    fit on the two largest measured populations and invert at the budget."""
    (n1, b1), (n2, b2) = points[-2], points[-1]
    per_client = (b2 - b1) / (n2 - n1)
    fixed = b1 - per_client * n1
    return {
        "bytes_per_client": per_client,
        "fixed_bytes": fixed,
        "max_population_at_budget": int((budget - fixed) / per_client),
    }


def _throughput(n_clients: int, rounds: int, chunk: int, *,
                paged: bool, reps: int = 2) -> dict:
    """rounds/sec of the device data plane: resident corpus, one
    ``run_device`` dispatch per chunk (the PR-5 trainer loop)."""
    eng, fcfg, params, key = _make_engine(n_clients, paged=paged)
    rng = np.random.default_rng(0)
    n_rows = 8192
    x = rng.normal(0, 1, (n_rows, D_IN)).astype(np.float32)
    y = rng.integers(0, N_CLASSES, n_rows).astype(np.int32)
    per = n_rows // n_clients
    parts = [rng.choice(n_rows, max(int(per * rng.uniform(0.5, 1.5)), B),
                        replace=False)
             for _ in range(n_clients)]
    corpus = make_classification_corpus(x, y, parts, B)
    state = eng.init_state(params, key)
    state, m = eng.run_device(state, corpus, chunk)        # compile
    np.asarray(m["loss"])
    best = float("inf")
    for _ in range(reps):
        state = eng.init_state(params, key)
        t0 = time.perf_counter()
        for _ in range(rounds // chunk):
            state, m = eng.run_device(state, corpus, chunk)
            np.asarray(m["loss"])
        jax.block_until_ready(state.server)
        best = min(best, time.perf_counter() - t0)
    return {"seconds": best, "rounds_per_sec": rounds / best}


def run(quick: bool = True, smoke: bool = False) -> dict:
    if smoke:
        n = 4096
        dense_b = _resident_bytes(n, paged=False)
        paged_b = _resident_bytes(n, paged=True)
        rows = {
            "config": {"n_clients": n, "s_max": S_MAX,
                       "cold_bits": COLD_BITS},
            "dense_bytes": dense_b, "paged_bytes": paged_b,
            "ratio": dense_b / paged_b,
            "cold_accounting": _cold_accounting(n),
            "note": "CI smoke gate: paged EngineState must be strictly "
                    "smaller than dense at n = 4096, and the codec's "
                    "bytes_per_row accounting must match the measured "
                    "encoded pool exactly.",
        }
        save_artifact("paged_state_smoke", rows)
        return rows

    populations = [1_000, 10_000, 100_000]
    residency = []
    for n in populations:
        dense_b = _resident_bytes(n, paged=False)
        paged_b = _resident_bytes(n, paged=True)
        residency.append({"n_clients": n, "dense_bytes": dense_b,
                          "paged_bytes": paged_b,
                          "ratio": dense_b / paged_b})
    dense_fit = _fit_population(
        [(r["n_clients"], r["dense_bytes"]) for r in residency], BUDGET_BYTES)
    paged_fit = _fit_population(
        [(r["n_clients"], r["paged_bytes"]) for r in residency], BUDGET_BYTES)
    pop_ratio = (paged_fit["max_population_at_budget"]
                 / dense_fit["max_population_at_budget"])

    rounds = 64 if quick else 256
    t_dense = _throughput(1024, rounds, 32, paged=False)
    t_paged = _throughput(1024, rounds, 32, paged=True)
    rel = t_paged["rounds_per_sec"] / t_dense["rounds_per_sec"]

    rows = {
        "config": {"d_in": D_IN, "d_hidden": D_HIDDEN, "K": K, "batch": B,
                   "s_max": S_MAX, "cold_bits": COLD_BITS,
                   "budget_bytes": BUDGET_BYTES,
                   "model": "classifier MLP under core.round_engine."
                            "RoundEngine (jnp oracle path, CPU)"},
        "residency_sweep": residency,
        "cold_accounting_n1000": _cold_accounting(1_000),
        "max_population_at_fixed_memory": {
            "dense": dense_fit, "paged": paged_fit,
            "population_ratio_paged_vs_dense": pop_ratio,
        },
        "throughput_n1024_chunk32": {
            "rounds": rounds,
            "dense": t_dense, "paged": t_paged,
            "paged_over_dense": rel,
        },
        "note": "residency = measured EngineState bytes (hot stacks + LUQ "
                "cold pools + bookkeeping) at init; max population inverts "
                "the affine bytes(n) fit at a 16 GiB budget. throughput = "
                "device-plane rounds/sec, one run_device dispatch per "
                "32-round chunk. Acceptance: population ratio >= 4x with "
                "paged/dense rounds/sec >= 0.75x.",
    }
    save_artifact("paged_state", rows)
    with open(os.path.join(ROOT, "BENCH_paged_state.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main() -> int:
    smoke = "--smoke" in sys.argv
    rows = run(quick="--full" not in sys.argv, smoke=smoke)
    if smoke:
        r = rows["ratio"]
        if rows["paged_bytes"] >= rows["dense_bytes"]:
            print(f"FAIL: paged state {rows['paged_bytes']} B >= dense "
                  f"{rows['dense_bytes']} B at n={rows['config']['n_clients']}")
            return 1
        print(f"smoke OK: paged {rows['paged_bytes']} B vs dense "
              f"{rows['dense_bytes']} B ({r:.2f}x smaller) at n=4096")
        return 0
    for r in rows["residency_sweep"]:
        print(f"n={r['n_clients']:7d} | dense {r['dense_bytes']:>12,} B | "
              f"paged {r['paged_bytes']:>12,} B | {r['ratio']:.2f}x")
    pop = rows["max_population_at_fixed_memory"]
    print(f"max population @16GiB: dense "
          f"{pop['dense']['max_population_at_budget']:,} | paged "
          f"{pop['paged']['max_population_at_budget']:,} "
          f"({pop['population_ratio_paged_vs_dense']:.1f}x)")
    t = rows["throughput_n1024_chunk32"]
    print(f"rounds/sec n=1024 chunk=32: dense "
          f"{t['dense']['rounds_per_sec']:.1f} | paged "
          f"{t['paged']['rounds_per_sec']:.1f} "
          f"({t['paged_over_dense']:.2f}x)")
    ok = (pop["population_ratio_paged_vs_dense"] >= 4.0
          and t["paged_over_dense"] >= 0.75)
    if not ok:
        print("FAIL: acceptance targets missed (need >= 4x population and "
              ">= 0.75x rounds/sec)")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
