"""Kernel microbenchmarks for the FAVAS round hot path.

Measures the REAL round aggregation path the engine runs
(``favas_fused_ref`` — aggregation + selected-client reset in one
expression, what ``core/round_engine.py`` executes on CPU and what the
Pallas kernel streams on TPU) against the seed's unfused multi-pass
arithmetic (eq. 3 msgs, line-10 sum, two reset sweeps as separate
full-buffer passes). A client-count sweep (n in {64, 256, 1024, 4096},
constant n*D resident client elements) records fused-vs-seed bytes moved and
throughput at production federation sizes — the regime the tiled
client-axis kernel exists for. Also validates the multi-output Pallas
kernel in interpret mode at a small resident shape AND a tiled
(n > CLIENT_TILE) shape (structural check; interpret-mode *timing* is
meaningless — TPU is the target).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed, save_artifact
from repro.kernels import ref
from repro.kernels.favas_agg import CLIENT_TILE, TILE, favas_fused_pallas
from repro.kernels.ops import luq_quantize


def _round_unfused(server, clients, inits, alpha, mask, s):
    """The seed's per-pass round arithmetic on flat buffers: each line is a
    separate full-buffer sweep in the unfused HLO."""
    a = alpha[:, None]
    m = mask[:, None]
    prog = clients - inits                                   # pass 1
    msgs = inits + prog / a                                  # pass 2
    total = jnp.sum(m * msgs, axis=0)                        # pass 3 (reduce)
    server_new = (server + total) / (s + 1.0)
    clients_new = m * server_new[None] + (1.0 - m) * clients  # pass 4
    inits_new = m * server_new[None] + (1.0 - m) * inits      # pass 5
    return server_new, clients_new, inits_new


def run(quick=True):
    key = jax.random.PRNGKey(0)
    n, D = (8, 1 << 20) if quick else (32, 1 << 24)
    ks = jax.random.split(key, 5)
    server = jax.random.normal(ks[0], (D,))
    clients = jax.random.normal(ks[1], (n, D))
    inits = jax.random.normal(ks[2], (n, D))
    alpha = jax.random.uniform(ks[3], (n,), minval=1.0, maxval=8.0)
    mask = (jax.random.uniform(ks[4], (n,)) > 0.5).astype(jnp.float32)
    s = 4.0

    # full round: aggregation + reset — fused (engine path) vs seed multi-pass
    fused = jax.jit(lambda *a: ref.favas_fused_ref(*a, s))
    unfused = jax.jit(lambda *a: _round_unfused(*a, s))
    t_fused = timed(fused, server, clients, inits, alpha, mask, reps=10)
    t_unfused = timed(unfused, server, clients, inits, alpha, mask, reps=10)

    # aggregation only (the seed's single-output kernel scope)
    agg_ref = jax.jit(lambda *a: ref.favas_agg_ref(*a, s))
    t_agg = timed(agg_ref, server, clients, inits, alpha, mask, reps=10)

    x = jax.random.normal(key, (D,))
    luq_ref_fn = jax.jit(lambda x, k: luq_quantize(x, 4, k, use_kernel=False))
    t_luq = timed(luq_ref_fn, x, key, reps=10)

    # structural validation of the multi-output Pallas kernel (interpret):
    # one resident shape, one tiled shape (client blocks + row padding)
    kernel_ok = True
    for nv, Dv in ((4, 5000), (CLIENT_TILE * 2 + 7, 3000)):
        kv = jax.random.split(jax.random.PRNGKey(1), 5)
        sv = jax.random.normal(kv[0], (Dv,))
        cv = jax.random.normal(kv[1], (nv, Dv))
        iv = jax.random.normal(kv[2], (nv, Dv))
        av = jax.random.uniform(kv[3], (nv,), minval=1.0, maxval=8.0)
        mv = (jax.random.uniform(kv[4], (nv,)) > 0.5).astype(jnp.float32)
        got = favas_fused_pallas(sv, cv, iv, av, mv, 2.0, interpret=True)
        want = ref.favas_fused_ref(sv, cv, iv, av, mv, 2.0)
        kernel_ok = kernel_ok and all(
            np.allclose(np.asarray(g), np.asarray(w), rtol=1e-6, atol=1e-6)
            for g, w in zip(got, want))

    # client-count sweep at constant total resident bytes: the engine's
    # fused round (what the tiled kernel streams on TPU) vs the seed's
    # multi-pass arithmetic, n from demo scale to production federations
    # 2^23 quick / 2^24 full keeps D >= TILE at n=4096, so every sweep point
    # really does hold the same element count (constant working set)
    sweep_elems = 1 << (23 if quick else 24)   # elements per (n, D) operand
    n_sweep = []
    for ns in (64, 256, 1024, 4096):
        Ds = max(sweep_elems // ns, TILE)
        kw = jax.random.split(jax.random.PRNGKey(ns), 5)
        sw = jax.random.normal(kw[0], (Ds,))
        cw = jax.random.normal(kw[1], (ns, Ds))
        iw = jax.random.normal(kw[2], (ns, Ds))
        aw = jax.random.uniform(kw[3], (ns,), minval=1.0, maxval=8.0)
        mw = (jax.random.uniform(kw[4], (ns,)) > 0.5).astype(jnp.float32)
        ssel = float(mw.sum())
        t_f = timed(jax.jit(lambda *a: ref.favas_fused_ref(*a, ssel)),
                    sw, cw, iw, aw, mw, reps=5)
        t_u = timed(jax.jit(lambda *a: _round_unfused(*a, ssel)),
                    sw, cw, iw, aw, mw, reps=5)
        bytes_n = (4 * ns + 2) * Ds * 4
        n_sweep.append({
            "n": ns, "D": Ds, "bytes": bytes_n,
            "fused_us": t_f, "unfused_us": t_u,
            "fused_gbps": bytes_n / (t_f * 1e-6) / 1e9,
            "unfused_gbps": bytes_n / (t_u * 1e-6) / 1e9,
            "speedup": t_u / t_f,
        })

    # sharded-vs-replicated round: runs in a forced-8-device subprocess
    # (only launch/dryrun.py and spawned children ever fake the topology)
    sharded = _run_sharded_subprocess()

    bytes_round = (4 * n + 2) * D * 4        # r/w server + clients + inits
    bytes_agg = (2 * n + 2) * D * 4
    rows = {
        "favas_round_fused_jnp_us": t_fused,
        "favas_round_fused_gbps": bytes_round / (t_fused * 1e-6) / 1e9,
        "favas_round_unfused_jnp_us": t_unfused,
        "favas_round_unfused_gbps": bytes_round / (t_unfused * 1e-6) / 1e9,
        "favas_agg_jnp_us": t_agg,
        "favas_agg_gbps": bytes_agg / (t_agg * 1e-6) / 1e9,
        "luq_jnp_us": t_luq,
        "elements": D,
        "clients": n,
        "client_tile": CLIENT_TILE,
        "n_sweep": n_sweep,
        "sharded_round": sharded,
        "fused_kernel_interpret_matches_ref": bool(kernel_ok),
        "note": "fused = the engine's real round path (agg + reset, one pass);"
                " unfused = the seed's multi-pass arithmetic. n_sweep holds"
                " n*D (the resident client working set) constant while n"
                " scales to production federation sizes (the tiled"
                " client-axis regime). Pallas"
                " kernels validated vs these refs in tests/; interpret-mode"
                " timing is not meaningful, TPU is the target.",
    }
    save_artifact("kernel_bench", rows)
    return rows


# ---------------------------------------------------------------------------
# Sharded-vs-replicated round (docs/architecture.md §6)
# ---------------------------------------------------------------------------

def _run_sharded_subprocess(timeout: int = 900) -> dict:
    """Spawn ``python -m benchmarks.kernel_bench --sharded-child`` under
    XLA_FLAGS=--xla_force_host_platform_device_count=8 and parse its JSON.
    The fake topology must never leak into this process (see
    tests/conftest.py), hence the subprocess."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.kernel_bench",
             "--sharded-child"],
            capture_output=True, text=True, env=env, cwd=root,
            timeout=timeout)
        if out.returncode != 0:
            return {"status": "error", "stderr": out.stderr[-2000:]}
        return json.loads(out.stdout.strip().splitlines()[-1])
    except Exception as e:  # noqa: BLE001 — benchmarks record, don't die
        return {"status": "error", "error": f"{type(e).__name__}: {e}"}


def _sharded_child():
    """Child body: time the fused FAVAS round on flat buffers sharded over
    an 8-way ("model",) mesh (shard_map/pjit dispatch via
    ``round_engine.fused_bucket_update``) vs the replicated single-device
    engine at the same shapes, and audit the sharded HLO for all-gathers.
    CPU "devices" here are host threads, so the us columns measure overhead
    structure, not TPU speedup — the all_gather_bytes column is the point:
    the sharded round moves NO full buffer across the mesh."""
    from jax.sharding import NamedSharding
    from repro.core import round_engine
    from repro.launch.mesh import make_model_mesh
    from repro.launch.roofline import collective_ops

    mesh = make_model_mesh(8)
    rec = {"status": "ok", "devices": int(jax.device_count()), "sweep": []}
    for ns, Ds in ((32, 1 << 14), (256, 1 << 14)):
        tree = {"wq": {"w": jnp.zeros((Ds // 128, 128), jnp.float32)}}
        spec_s = round_engine.make_flat_spec(tree, n_clients=ns,
                                             shard_axes=[1], model_shards=8)
        spec_r = round_engine.make_flat_spec(tree, n_clients=ns)
        kw = jax.random.split(jax.random.PRNGKey(ns), 5)
        rows = spec_s.n_padded or ns
        srv = jax.random.normal(kw[0], (spec_s.bucket_padded[0],))
        cli = jax.random.normal(kw[1], (rows, spec_s.bucket_padded[0]))
        ini = jax.random.normal(kw[2], (rows, spec_s.bucket_padded[0]))
        alpha = jnp.pad(jax.random.uniform(kw[3], (ns,), minval=1.0,
                                           maxval=8.0), (0, rows - ns),
                        constant_values=1.0)
        mask = jnp.pad((jax.random.uniform(kw[4], (ns,)) > 0.5)
                       .astype(jnp.float32), (0, rows - ns))
        s = float(mask.sum())
        sh = round_engine.engine_sharding(spec_s, mesh)
        srv_s = jax.device_put(srv, sh.server[0])
        cli_s = jax.device_put(cli, sh.clients[0])
        ini_s = jax.device_put(ini, sh.inits[0])

        step_sh = jax.jit(lambda w, c, i, a, m: round_engine.fused_bucket_update(
            spec_s, 0, w, c, i, a, m, s, n_logical=ns, mesh=mesh,
            use_kernel=False))
        step_rep = jax.jit(lambda w, c, i, a, m: round_engine.fused_bucket_update(
            spec_r, 0, w, c, i, a, m, s, n_logical=ns, use_kernel=False))
        t_sh = timed(step_sh, srv_s, cli_s, ini_s, alpha, mask, reps=5)
        t_rep = timed(step_rep, srv, cli, ini, alpha, mask, reps=5)
        hlo = step_sh.lower(srv_s, cli_s, ini_s, alpha, mask).compile().as_text()
        ag = [b for kind, b in collective_ops(hlo) if kind == "all-gather"]
        bytes_n = (4 * rows + 2) * spec_s.bucket_padded[0] * 4
        rec["sweep"].append({
            "n": ns, "D": spec_s.bucket_padded[0], "bytes": bytes_n,
            "sharded_us": t_sh, "replicated_us": t_rep,
            "sharded_gbps": bytes_n / (t_sh * 1e-6) / 1e9,
            "replicated_gbps": bytes_n / (t_rep * 1e-6) / 1e9,
            "all_gather_ops": len(ag),
            "all_gather_bytes_max": max(ag) if ag else 0,
            "full_buffer_bytes": spec_s.bucket_padded[0] * 4,
        })
    rec["note"] = ("8 forced host devices: timing shows structure/overhead "
                   "only (TPU is the target); all_gather_bytes_max == 0 is "
                   "the acceptance signal — the sharded round never "
                   "gathers a full flat buffer.")
    print(json.dumps(rec))


if __name__ == "__main__":
    if "--sharded-child" in sys.argv:
        _sharded_child()
    else:
        run(quick="--full" not in sys.argv)
