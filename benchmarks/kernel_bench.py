"""Kernel microbenchmarks: fused Pallas path (interpret on CPU — structural
check; MXU timings are a TPU artifact) vs the jnp oracle, plus the jitted
oracle timing that the CPU CI actually optimizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timed, save_artifact
from repro.kernels import ref
from repro.kernels.ops import favas_aggregate_flat, luq_quantize


def run(quick=True):
    key = jax.random.PRNGKey(0)
    n, D = (8, 1 << 20) if quick else (32, 1 << 24)
    ks = jax.random.split(key, 5)
    server = jax.random.normal(ks[0], (D,))
    clients = jax.random.normal(ks[1], (n, D))
    inits = jax.random.normal(ks[2], (n, D))
    alpha = jax.random.uniform(ks[3], (n,), minval=1.0, maxval=8.0)
    mask = (jax.random.uniform(ks[4], (n,)) > 0.5).astype(jnp.float32)

    agg_ref = jax.jit(lambda *a: ref.favas_agg_ref(*a, 4.0))
    t_ref = timed(agg_ref, server, clients, inits, alpha, mask, reps=10)

    x = jax.random.normal(key, (D,))
    luq_ref_fn = jax.jit(lambda x, k: luq_quantize(x, 4, k, use_kernel=False))
    t_luq = timed(luq_ref_fn, x, key, reps=10)

    bytes_agg = (2 * n + 2) * D * 4
    rows = {
        "favas_agg_jnp_us": t_ref,
        "favas_agg_gbps": bytes_agg / (t_ref * 1e-6) / 1e9,
        "luq_jnp_us": t_luq,
        "elements": D,
        "clients": n,
        "note": "Pallas kernels validated vs these refs in tests/test_kernels.py;"
                " interpret-mode timing is not meaningful, TPU is the target.",
    }
    save_artifact("kernel_bench", rows)
    return rows
