"""Round-loop throughput benchmark: per-round host loop vs one-dispatch
supersteps (docs/architecture.md §7).

FAVAS server rounds are deliberately cheap and frequent (wait + interact =
7 time units, App. C.2), so at small/medium model sizes end-to-end rounds/
sec is bounded by per-round HOST overhead — jit dispatch, the blocking
``float(metrics["loss"])`` sync, python loop bookkeeping — not device
FLOPs. This bench measures exactly that regime on the engine's two driver
modes:

* **host loop** — the pre-superstep trainer behavior: one
  ``RoundEngine.step`` dispatch per round plus a per-round blocking metric
  fetch;
* **superstep** — ``RoundEngine.run`` over chunks of T rounds: one jitted,
  donated ``lax.scan`` dispatch and ONE stacked metrics fetch per chunk,
  for T in {1, 8, 32, 128}. T=1 isolates the sync removal (same dispatch
  count as the host loop); larger T amortizes dispatch too. The two modes
  are bit-exact (tests/test_superstep.py), so this is a pure overhead
  comparison.

Both the CPU jnp-oracle path and the interpret-mode Pallas kernel path are
timed (interpret timing measures structure, not TPU speed — the oracle
numbers are the CPU acceptance signal: superstep chunk=32 must beat the
host loop by >= 3x). Batches are device-resident up front so H2D does not
pollute the dispatch measurement (the trainer overlaps H2D via
``data.pipeline.BatchPrefetcher`` anyway).

Results go to ``experiments/bench/round_loop.json`` AND the repo-root
``BENCH_round_loop.json`` (the perf-trajectory file).

  PYTHONPATH=src:. python benchmarks/round_loop_bench.py [--full|--smoke]

``--smoke`` (the CI ``bench-smoke`` job) shrinks the sweep and exits
non-zero if the superstep is slower than the host loop.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_artifact
from repro.core.favas import FavasConfig, client_lambdas
from repro.core.round_engine import RoundEngine
from repro.models.classifier import classifier_loss, mlp_apply, mlp_init

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

D_IN, D_HIDDEN, N_CLASSES = 16, 16, 10
N_CLIENTS, K, B = 8, 1, 2


def _make_engine(use_kernel):
    key = jax.random.PRNGKey(0)
    params = mlp_init(key, D_IN, D_HIDDEN, N_CLASSES)
    fcfg = FavasConfig(n_clients=N_CLIENTS, s_selected=3, local_steps=K,
                      eta=0.1)

    def lfn(p, b):
        return classifier_loss(p, mlp_apply, b["x"], b["y"], N_CLASSES)

    eng = RoundEngine(params, fcfg, lfn,
                      lambdas=jnp.asarray(client_lambdas(fcfg)),
                      use_kernel=use_kernel)
    return eng, fcfg, params, key


def _batches(fcfg, rounds: int):
    """(T, n, R, B, d) x / (T, n, R, B) y, device-resident."""
    kx, ky = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(kx, (rounds, N_CLIENTS, fcfg.R, B, D_IN))
    y = jax.random.randint(ky, (rounds, N_CLIENTS, fcfg.R, B), 0, N_CLASSES)
    return {"x": jax.block_until_ready(x), "y": jax.block_until_ready(y)}


def _host_loop(eng, params, key, batches, rounds: int) -> float:
    """Pre-superstep driver: per-round dispatch + per-round blocking loss
    fetch. Returns seconds for ``rounds`` rounds."""
    state = eng.init_state(params, key)
    one = {k: v[0] for k, v in batches.items()}
    state, m = eng.step(state, one)                      # compile
    float(m["loss"])
    state = eng.init_state(params, key)
    t0 = time.perf_counter()
    for t in range(rounds):
        state, m = eng.step(state, {k: v[t] for k, v in batches.items()})
        float(m["loss"])                                 # the per-round sync
    jax.block_until_ready(state.server)
    return time.perf_counter() - t0


def _superstep_loop(eng, params, key, batches, rounds: int,
                    chunk: int) -> float:
    """Superstep driver: one ``run`` dispatch per T-round chunk, one stacked
    metrics fetch per chunk. Returns seconds for ``rounds`` rounds."""
    state = eng.init_state(params, key)
    first = {k: v[:chunk] for k, v in batches.items()}
    state, m = eng.run(state, first)                     # compile
    np.asarray(m["loss"])
    state = eng.init_state(params, key)
    t0 = time.perf_counter()
    for lo in range(0, rounds, chunk):
        state, m = eng.run(state,
                           {k: v[lo:lo + chunk] for k, v in batches.items()})
        np.asarray(m["loss"])                            # one fetch per chunk
    jax.block_until_ready(state.server)
    return time.perf_counter() - t0


def _sweep(use_kernel, rounds: int, chunks, reps: int = 3) -> dict:
    """Best-of-``reps`` per driver mode (per-dispatch host overhead is what
    is being measured; OS scheduling noise only ever ADDS time)."""
    eng, fcfg, params, key = _make_engine(use_kernel)
    batches = _batches(fcfg, rounds)
    t_host = min(_host_loop(eng, params, key, batches, rounds)
                 for _ in range(reps))
    rec = {
        "rounds": rounds,
        "host_loop": {"seconds": t_host, "rounds_per_sec": rounds / t_host},
        "superstep": {},
    }
    for c in chunks:
        if rounds % c:
            continue
        t = min(_superstep_loop(eng, params, key, batches, rounds, c)
                for _ in range(reps))
        rec["superstep"][str(c)] = {
            "seconds": t,
            "rounds_per_sec": rounds / t,
            "speedup_vs_host_loop": t_host / t,
        }
    return rec


def run(quick: bool = True, smoke: bool = False) -> dict:
    chunks = (1, 8, 32, 128)
    if smoke:
        oracle = _sweep(use_kernel=False, rounds=64, chunks=(1, 8, 32))
        interp = None
    else:
        oracle = _sweep(use_kernel=False, rounds=128 if quick else 512,
                        chunks=chunks)
        # interpret-mode Pallas inside the scan: structural validation that
        # the kernel path composes with supersteps; timing is NOT a TPU
        # proxy (interpret mode runs the kernel body op-by-op)
        interp = _sweep(use_kernel=True, rounds=32, chunks=(1, 32))
    rows = {
        "config": {"n_clients": N_CLIENTS, "K": K, "batch": B,
                   "d_in": D_IN, "d_hidden": D_HIDDEN,
                   "model": "classifier MLP (fl_sim's paper-experiment "
                            "model) under core.round_engine.RoundEngine"},
        "cpu_oracle": oracle,
        "interpret_kernel": interp,
        "note": "host_loop = one jitted round dispatch + blocking loss "
                "fetch per round (the pre-superstep trainer); superstep = "
                "RoundEngine.run scanning T rounds per dispatch with one "
                "stacked metrics fetch per chunk. Bit-exact modes, so "
                "speedup is pure host-overhead removal. Acceptance: "
                "cpu_oracle superstep['32'].speedup_vs_host_loop >= 3.",
    }
    if smoke:
        # reduced sweep: keep it OUT of the canonical perf-trajectory
        # artifacts (a smoke run must never clobber the full records)
        save_artifact("round_loop_smoke", rows)
    else:
        save_artifact("round_loop", rows)
        with open(os.path.join(ROOT, "BENCH_round_loop.json"), "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def main() -> int:
    smoke = "--smoke" in sys.argv
    rows = run(quick="--full" not in sys.argv, smoke=smoke)
    oracle = rows["cpu_oracle"]
    print(f"host loop : {oracle['host_loop']['rounds_per_sec']:8.1f} rounds/s")
    for c, r in oracle["superstep"].items():
        print(f"chunk {c:>4}: {r['rounds_per_sec']:8.1f} rounds/s "
              f"({r['speedup_vs_host_loop']:.2f}x)")
    if smoke:
        # the CI gate is the ISSUE acceptance chunk size specifically —
        # chunk=1 sits near 1.0x by design (sync removal only), so "any
        # chunk beats the host loop" would be a vacuous check
        spd32 = oracle["superstep"]["32"]["speedup_vs_host_loop"]
        if spd32 < 1.0:
            print(f"FAIL: 32-round superstep at {spd32:.2f}x — slower than "
                  f"the per-round host loop")
            return 1
        print(f"smoke OK: 32-round superstep at {spd32:.2f}x >= host loop")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
