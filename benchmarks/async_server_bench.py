"""Async-server benchmark: the real ProcTransport deployment under
injected latencies vs the deterministic simulated baseline
(docs/architecture.md §11).

Two runs of the SAME deployment config and fault plan:

* **simulated** — :func:`repro.launch.cluster.run_inproc` on the
  virtual-clock transport (the fl_sim-equivalent substrate). Its wall time
  is pure compute: virtual rounds cost no wall-clock waiting, so its
  rounds/sec is the ceiling the real deployment is paying scheduling +
  IPC + injected latency against.
* **real** — :func:`repro.launch.cluster.run_proc`: one OS process per
  client over pipes, wall-clock round cadence ``round_dur``, the same
  injected latency plan.

Recorded per run: rounds/sec, the STALENESS DISTRIBUTION (the local-step
count q of every admitted update — the eq. 3 alpha numerators), admitted /
short-poll counts, and (real) per-child exit codes. The key
sanity row: the two selection streams are identical (shared key chain) and
the staleness distributions are close — real asynchrony reproduces the
simulated clock's client-progress profile, not just its convergence.

Results go to ``experiments/bench/async_server.json`` AND the repo-root
``BENCH_async_server.json`` (the perf-trajectory file).

  PYTHONPATH=src:. python benchmarks/async_server_bench.py [--full|--smoke]

``--smoke`` (the CI ``async`` job) runs a 2-client 20-round deployment and
exits non-zero unless every round completed, updates were admitted, and
every child exited cleanly; smoke artifacts go to
``async_server_smoke.json`` and never overwrite the canonical files.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks.common import save_artifact
from repro.comms import FaultPlan
from repro.launch.cluster import _smoke_data, run_inproc, run_proc
from repro.launch.server import AsyncConfig

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _staleness_summary(staleness) -> dict:
    q = np.asarray(staleness, np.float64)
    if q.size == 0:
        return {"count": 0}
    return {"count": int(q.size), "mean": float(q.mean()),
            "p50": float(np.percentile(q, 50)),
            "p90": float(np.percentile(q, 90)),
            "max": float(q.max()),
            "hist": {str(int(v)): int(c) for v, c in
                     zip(*np.unique(q.astype(np.int64),
                                    return_counts=True))}}


def _row(tag: str, result: dict, wall: float) -> dict:
    res = result["server"]
    return {"mode": tag,
            "rounds": res["rounds"],
            "wall_s": wall,
            "rounds_per_sec": res["rounds"] / max(wall, 1e-9),
            "admitted": res["stats"]["admitted"],
            "short_polls": res["stats"]["short_polls"],
            "late": res["stats"]["late"],
            "final_accuracy": res["final_accuracy"],
            "staleness": _staleness_summary(res["staleness"]),
            "transport": result["transport"]}


def run(quick: bool = True, smoke: bool = False) -> dict:
    if smoke:
        clients, rounds, round_dur = 2, 20, 0.4
    elif quick:
        clients, rounds, round_dur = 3, 30, 0.4
    else:
        clients, rounds, round_dur = 4, 80, 0.5
    s = max(1, clients // 2)
    K = 4
    cfg = AsyncConfig(n_clients=clients, s_selected=s, K=K, batch_size=16,
                      rounds=rounds, round_dur=round_dur,
                      fast_step_time=round_dur / K,
                      slow_step_time=round_dur / 2.0, seed=0)
    plan = FaultPlan(latency=0.02, jitter=0.01)
    data = _smoke_data(clients, 0)

    t0 = time.monotonic()
    sim = run_inproc(cfg, data, d_hidden=16, plan=plan, seed=0)
    sim_wall = time.monotonic() - t0
    real = run_proc(cfg, data, d_hidden=16, plan=plan, seed=0)

    out = {
        "config": {"clients": clients, "selected": s, "K": K,
                   "rounds": rounds, "round_dur": round_dur,
                   "latency": plan.latency, "jitter": plan.jitter},
        "simulated": _row("inproc", sim, sim_wall),
        "real": _row("proc", real, real["wall_time"]),
        "selection_identical": (sim["server"]["selection"]
                                == real["server"]["selection"]),
        "exitcodes": real["exitcodes"],
        "clean": real["clean"],
    }
    name = "async_server_smoke" if smoke else "async_server"
    save_artifact(name, out)
    if not smoke:
        with open(os.path.join(ROOT, "BENCH_async_server.json"), "w") as f:
            json.dump(out, f, indent=1)
    return out


def main(argv=None) -> int:
    smoke = "--smoke" in (argv or sys.argv[1:])
    quick = "--full" not in (argv or sys.argv[1:])
    out = run(quick, smoke=smoke)
    print(json.dumps(out, indent=2, default=float))
    if smoke:
        ok = (out["clean"] and out["real"]["rounds"] >= out["config"]["rounds"]
              and out["real"]["admitted"] > 0)
        if not ok:
            print("SMOKE GATE FAILED: real deployment did not complete "
                  "cleanly", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
