"""Table 1: analytic units-of-time to epsilon for all five methods, plus the
straggler-severity sweep that illustrates the paper's tau_max discussion
(two workers, 1 vs 1000 time units -> FedBuff/AsyncSGD degrade, FAVAS not).
"""
from __future__ import annotations

from benchmarks.common import save_artifact
from repro.core.theory import TheoryParams, units_of_time


def run(quick=True):
    base = TheoryParams()
    table = units_of_time(base)
    sweep = {}
    for slow in (16.0, 100.0, 1000.0):
        sweep[f"slow={slow:g}"] = units_of_time(
            TheoryParams(slow_step_time=slow))
    out = {"table1": table, "straggler_sweep": sweep}
    save_artifact("table1_theory", out)
    return out
