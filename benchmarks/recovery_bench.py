"""Recovery benchmark: what durability costs and what restarts cost
(docs/architecture.md §12).

Two questions, one deployment config:

* **WAL overhead** — the same deterministic in-proc run with and without
  the write-ahead log armed (fsync'd appends + periodic snapshot/rotate).
  The virtual clock makes the comparison pure compute: any wall-time gap
  IS the durability tax. Target: <= 10% slowdown (recorded as
  ``meets_target``); the trajectory must be BIT-EXACT either way — a WAL
  that perturbs the aggregate is a bug, not an overhead.
* **recovery time vs WAL length** — kill nothing, just measure
  :func:`repro.launch.server.recover_server` against logs of growing
  length (``ckpt_every=0``: pure replay from round 0), plus the
  snapshotted case showing replay work stays bounded by the checkpoint
  interval instead of growing with history.

Results go to ``experiments/bench/recovery.json`` AND the repo-root
``BENCH_recovery.json`` (the perf-trajectory file).

  PYTHONPATH=src:. python benchmarks/recovery_bench.py [--full|--smoke]

``--smoke`` (the CI ``chaos`` job) runs the cheapest defensible check and
exits non-zero unless the WAL'd run is bit-exact vs the plain run, a
recovery from its log reproduces the same buckets, and the overhead is
within the (noise-padded) smoke bound; smoke artifacts go to
``recovery_smoke.json`` and never overwrite the canonical files.
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import save_artifact
from repro.checkpointing import wal
from repro.launch.cluster import _smoke_data, recovered_server, run_inproc
from repro.launch.server import AsyncConfig

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: the headline durability-tax target (full mode reports against this)
TARGET_OVERHEAD = 0.10
#: smoke gate: padded for CI timer noise on short runs
SMOKE_OVERHEAD_BOUND = 0.50


def _cfg(rounds: int, bits: int = 0) -> AsyncConfig:
    return AsyncConfig(n_clients=6, s_selected=2, K=5, batch_size=16,
                       rounds=rounds, round_dur=7.0, quant_bits=bits,
                       seed=0)


def _bit_exact(a, b) -> bool:
    sa, sb = a["server_actor"], b["server_actor"]
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(sa.srv_f, sb.srv_f))


def _timed_run(cfg, data, wal_dir=None, ckpt_every=0):
    t0 = time.monotonic()
    out = run_inproc(cfg, data, d_hidden=16, seed=0,
                     wal_dir=wal_dir, ckpt_every=ckpt_every)
    return out, time.monotonic() - t0


def _wal_bytes(d: str) -> int:
    return sum(os.path.getsize(p) for _, p in wal.segment_files(d)) \
        + sum(os.path.getsize(p) for _, p in wal.snapshot_files(d))


def run(quick: bool = True, smoke: bool = False) -> dict:
    if smoke:
        rounds, lengths = 6, (6,)
    elif quick:
        rounds, lengths = 12, (4, 8, 12)
    else:
        rounds, lengths = 24, (6, 12, 24)
    data = _smoke_data(6, 0)
    cfg = _cfg(rounds)

    # -- overhead: plain vs WAL'd, same seed, bit-exact required ------------
    # warmup pays the jit compile off-clock; best-of-N wall time is the
    # noise-robust estimator for the durability tax
    reps = 1 if smoke else 2
    _timed_run(cfg, data)
    plain, t_plain = _timed_run(cfg, data)
    for _ in range(reps - 1):
        t_plain = min(t_plain, _timed_run(cfg, data)[1])
    work = tempfile.mkdtemp(prefix="recovery_bench_")
    try:
        wd = os.path.join(work, "overhead")
        walled, t_wal = _timed_run(cfg, data, wal_dir=wd, ckpt_every=4)
        for _ in range(reps - 1):
            shutil.rmtree(wd)
            t_wal = min(t_wal, _timed_run(cfg, data, wal_dir=wd,
                                          ckpt_every=4)[1])
        overhead = t_wal / max(t_plain, 1e-9) - 1.0
        out = {
            "config": {"rounds": rounds, "clients": 6, "selected": 2,
                       "ckpt_every": 4},
            "overhead": {
                "plain_s": t_plain, "wal_s": t_wal,
                "overhead_frac": overhead,
                "target_frac": TARGET_OVERHEAD,
                "meets_target": overhead <= TARGET_OVERHEAD,
                "bit_exact": _bit_exact(plain, walled),
                "wal_bytes": _wal_bytes(wd),
            },
        }

        # -- recovery time vs WAL length (pure replay, no snapshots) -------
        rows = []
        for L in lengths:
            lcfg = _cfg(L)
            ldir = os.path.join(work, f"len{L}")
            lrun, _ = _timed_run(lcfg, data, wal_dir=ldir, ckpt_every=0)
            records, _ = wal.replay(ldir)
            t0 = time.monotonic()
            srv = recovered_server(lcfg, data, d_hidden=16, wal_dir=ldir)
            t_rec = time.monotonic() - t0
            exact = all(np.array_equal(np.asarray(x), np.asarray(y))
                        for x, y in zip(lrun["server_actor"].srv_f,
                                        srv.srv_f))
            rows.append({"rounds": L, "wal_records": len(records),
                         "wal_bytes": _wal_bytes(ldir),
                         "recovery_s": t_rec, "bit_exact": exact})
        out["recovery_vs_length"] = rows

        # -- snapshots bound the replay ------------------------------------
        sdir = os.path.join(work, "snap")
        srun, _ = _timed_run(cfg, data, wal_dir=sdir, ckpt_every=2)
        t0 = time.monotonic()
        srv = recovered_server(cfg, data, d_hidden=16, wal_dir=sdir,
                               ckpt_every=2)
        out["recovery_with_snapshots"] = {
            "ckpt_every": 2, "recovery_s": time.monotonic() - t0,
            "replayed_records": srv.replay_meta["records"],
            "bit_exact": all(
                np.array_equal(np.asarray(x), np.asarray(y))
                for x, y in zip(srun["server_actor"].srv_f, srv.srv_f)),
        }
    finally:
        shutil.rmtree(work, ignore_errors=True)

    name = "recovery_smoke" if smoke else "recovery"
    save_artifact(name, out)
    if not smoke:
        with open(os.path.join(ROOT, "BENCH_recovery.json"), "w") as f:
            json.dump(out, f, indent=1)
    return out


def main(argv=None) -> int:
    smoke = "--smoke" in (argv or sys.argv[1:])
    quick = "--full" not in (argv or sys.argv[1:])
    out = run(quick, smoke=smoke)
    print(json.dumps(out, indent=2, default=float))
    if smoke:
        ov = out["overhead"]
        ok = (ov["bit_exact"]
              and ov["overhead_frac"] <= SMOKE_OVERHEAD_BOUND
              and all(r["bit_exact"] for r in out["recovery_vs_length"])
              and out["recovery_with_snapshots"]["bit_exact"])
        if not ok:
            print("SMOKE GATE FAILED: durability perturbed the trajectory "
                  "or overhead blew the bound", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
