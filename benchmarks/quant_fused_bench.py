"""Quantized-transport round benchmark: codes-in fused rounds vs the
unfused decode -> round -> requant composition (docs/architecture.md §10).

Both arms run the same logical FAVAS[QNN] loop: the transmitted progress
lives as bit-packed LUQ codes + per-row scales between rounds, each round
aggregates the decoded progress and re-encodes the post-reset deltas for
the next round. The difference is the TRANSPORT:

* **unfused** (the pre-PR-7 composition) — three separate jitted
  dispatches per round: ``luq_decode_rows`` materializes the dense (n, D)
  f32 progress in HBM, ``favas_fused_flat`` consumes it, and
  ``luq_encode_rows`` re-encodes. The dense progress buffer crosses HBM
  twice per round (decode write + round read) on top of the dispatch
  overhead.
* **fused** — ONE jitted ``lax.scan`` over the whole chunk whose body
  feeds the codes straight into ``favas_fused_flat(progress_codes=...)``
  (dequantized inside the round — per VMEM tile on the kernel path) and
  re-encodes via ``kernels.ops.cold_requant_rows``. No standalone decode
  dispatch, no host round-trips inside the chunk.

Acceptance (the ISSUE-7 gate, checked in smoke mode and recorded in the
artifact): fused rounds/sec >= unfused rounds/sec at chunk 32.

Results go to ``experiments/bench/quant_fused.json`` AND the repo-root
``BENCH_quant_fused.json`` (the perf-trajectory file).

  PYTHONPATH=src:. python benchmarks/quant_fused_bench.py [--full|--smoke]

``--smoke`` (the CI ``quant-kernel`` job) runs n = 256 only and exits
non-zero if the fused arm is slower; smoke artifacts go to
``quant_fused_smoke.json`` and never overwrite the canonical files.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks.common import save_artifact
from repro.core.paging import luq_decode_rows, luq_encode_rows
from repro.kernels.ops import cold_requant_rows, favas_fused_flat

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

D = 2048
BITS = 4
CHUNK = 32
S_FRAC = 0.25


def _setup(n: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    server = jax.random.normal(ks[0], (D,), jnp.float32)
    clients = jax.random.normal(ks[1], (n, D), jnp.float32)
    inits = jax.random.normal(ks[2], (n, D), jnp.float32)
    alpha = jax.random.uniform(ks[3], (n,), minval=0.5, maxval=2.0)
    s = max(int(n * S_FRAC), 1)
    mask = (jnp.arange(n) < s).astype(jnp.float32)
    mask = jax.random.permutation(ks[4], mask)
    enc0 = luq_encode_rows(clients - inits, BITS, ks[5])
    return server, clients, inits, alpha, mask, float(s), enc0, key


def _run_unfused(n: int, reps: int) -> dict:
    """Host loop, three dispatches per round: decode -> dense round ->
    requant. The (n, D) f32 progress exists in HBM between dispatches."""
    server, clients, inits, alpha, mask, s, enc0, key = _setup(n)

    decode = jax.jit(lambda e: luq_decode_rows(e, BITS, jnp.float32))
    rnd = jax.jit(lambda srv, cli, ini, prog: favas_fused_flat(
        srv, cli, ini, alpha, mask, s, progress=prog, use_kernel=False))
    requant = jax.jit(lambda cli, ini, k: luq_encode_rows(
        cli.astype(jnp.float32) - ini.astype(jnp.float32), BITS, k))

    def chunk(srv, cli, ini, enc):
        for r in range(CHUNK):
            prog = decode(enc)
            srv, cli, ini = rnd(srv, cli, ini, prog)
            enc = requant(cli, ini, jax.random.fold_in(key, r))
        return srv, cli, ini, enc

    out = chunk(server, clients, inits, enc0)          # compile
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = chunk(server, clients, inits, enc0)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return {"seconds": best, "rounds_per_sec": CHUNK / best,
            "dispatches_per_round": 3,
            "progress_hbm_bytes_per_round": n * D * 4}


def _run_fused(n: int, reps: int) -> dict:
    """One jitted scan per chunk; the body consumes codes directly."""
    server, clients, inits, alpha, mask, s, enc0, key = _setup(n)

    def body(carry, r):
        srv, cli, ini, enc = carry
        srv, cli, ini = favas_fused_flat(
            srv, cli, ini, alpha, mask, s, progress_codes=enc,
            progress_bits=BITS, use_kernel=False)
        enc = cold_requant_rows(
            cli.astype(jnp.float32) - ini.astype(jnp.float32), BITS,
            jax.random.fold_in(key, r), use_kernel=False)
        return (srv, cli, ini, enc), jnp.zeros(())

    @jax.jit
    def chunk(srv, cli, ini, enc):
        (srv, cli, ini, enc), _ = jax.lax.scan(
            body, (srv, cli, ini, enc), jnp.arange(CHUNK))
        return srv, cli, ini, enc

    out = chunk(server, clients, inits, enc0)          # compile
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = chunk(server, clients, inits, enc0)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return {"seconds": best, "rounds_per_sec": CHUNK / best,
            "dispatches_per_round": 1.0 / CHUNK,
            "progress_hbm_bytes_per_round": n * (D * BITS // 8 + 4)}


def run(quick: bool = True, smoke: bool = False) -> dict:
    reps = 2 if (quick or smoke) else 4
    populations = [256] if smoke else ([256, 1024] if quick
                                       else [256, 1024, 4096])
    sweep = []
    for n in populations:
        unf = _run_unfused(n, reps)
        fus = _run_fused(n, reps)
        sweep.append({
            "n_clients": n,
            "unfused": unf, "fused": fus,
            "fused_over_unfused": (fus["rounds_per_sec"]
                                   / unf["rounds_per_sec"]),
            "progress_bytes_ratio": (unf["progress_hbm_bytes_per_round"]
                                     / fus["progress_hbm_bytes_per_round"]),
        })
    rows = {
        "config": {"D": D, "bits": BITS, "chunk": CHUNK,
                   "selected_fraction": S_FRAC,
                   "backend": jax.default_backend(),
                   "note": "jnp oracle path (CPU container); the kernel "
                           "path additionally dequantizes per VMEM tile "
                           "on TPU"},
        "sweep": sweep,
        "acceptance": "fused rounds/sec >= unfused rounds/sec at chunk 32",
    }
    if smoke:
        save_artifact("quant_fused_smoke", rows)
        return rows
    save_artifact("quant_fused", rows)
    with open(os.path.join(ROOT, "BENCH_quant_fused.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main() -> int:
    smoke = "--smoke" in sys.argv
    rows = run(quick="--full" not in sys.argv, smoke=smoke)
    ok = True
    for r in rows["sweep"]:
        rel = r["fused_over_unfused"]
        print(f"n={r['n_clients']:5d} | unfused "
              f"{r['unfused']['rounds_per_sec']:8.1f} r/s | fused "
              f"{r['fused']['rounds_per_sec']:8.1f} r/s | x{rel:.2f} | "
              f"progress bytes x{r['progress_bytes_ratio']:.1f} smaller")
        ok = ok and rel >= 1.0
    if not ok:
        print("FAIL: fused codes-in rounds slower than the unfused "
              "decode->round->requant composition at chunk 32")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
