"""Host-offloaded cold tier benchmark: device-resident population at fixed
HBM + overlapped streaming throughput (docs/architecture.md §13).

``cold_placement="host"`` moves the LUQ cold pools out of device memory:
the device holds only the s_max-row hot stacks plus per-client bookkeeping,
and each superstep streams a churn-bounded slab (2*T*s_churn+1 rows) in and
out around the dispatch — overlapped with compute by
``core.streaming.engine_run_stream``. Two measurements:

* **device-tier residency sweep** — ``RoundEngine.resident_bytes_by_tier``
  at n in {1e3, 1e4, 1e5} for device vs host cold placement. The affine
  bytes(n) fit is inverted at a 16 GiB device budget: the headline is the
  MAX POPULATION whose engine state fits on one HBM-class device
  (acceptance: host placement fits >= 3x the device-paged ceiling AND
  lands past 10^7 clients — host-tier bytes scale with n but are NOT
  device bytes, and are reported separately).
* **throughput** — rounds/sec at n = 1024, 32-round chunks, device data
  plane: device placement (``run_device`` per chunk) vs host placement,
  both sequential (prologue/dispatch/epilogue per chunk) and overlapped
  (``engine_run_stream``, slab gather/upload of chunk j+1 concurrent with
  chunk j's dispatch). Acceptance: host rounds/sec >= 0.75x device — the
  population headroom may not cost more than a quarter of the throughput.

Results go to ``experiments/bench/streaming.json`` AND the repo-root
``BENCH_streaming.json`` (the perf-trajectory file).

  PYTHONPATH=src:. python benchmarks/streaming_bench.py [--full|--smoke]

``--smoke`` (the CI ``streaming`` job) runs the n = 1024 chunk-32
throughput comparison plus the tier-accounting identities and exits
non-zero if host placement falls under 0.75x device placement; smoke
artifacts go to ``streaming_smoke.json`` and never overwrite the
canonical files.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_artifact
from repro.core.favas import FavasConfig, client_lambdas
from repro.core.round_engine import RoundEngine, engine_resident_bytes_by_tier
from repro.core.streaming import HostColdPool, engine_run_stream
from repro.data.device_corpus import make_classification_corpus
from repro.models.classifier import classifier_loss, mlp_apply, mlp_init

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

D_IN, D_HIDDEN, N_CLASSES = 16, 16, 10
K, B = 1, 2
S_MAX, COLD_BITS = 256, 4
BUDGET_BYTES = 16 * 1024 ** 3          # 16 GiB — an HBM-class device


def _make_engine(n_clients: int, *, placement: str):
    key = jax.random.PRNGKey(0)
    params = mlp_init(key, D_IN, D_HIDDEN, N_CLASSES)
    s_sel = min(64, max(n_clients // 4, 1))
    fcfg = FavasConfig(n_clients=n_clients, s_selected=s_sel,
                       local_steps=K, eta=0.1)

    def lfn(p, b):
        return classifier_loss(p, mlp_apply, b["x"], b["y"], N_CLASSES)

    eng = RoundEngine(params, fcfg, lfn,
                      lambdas=jnp.asarray(client_lambdas(fcfg)),
                      use_kernel=False, residency="paged",
                      s_max=min(S_MAX, n_clients), cold_bits=COLD_BITS,
                      cold_placement=placement)
    return eng, fcfg, params, key


def _tier_bytes(n_clients: int, *, placement: str) -> dict:
    eng, fcfg, params, key = _make_engine(n_clients, placement=placement)
    state = eng.init_state(params, key)
    tiers = engine_resident_bytes_by_tier(state)
    # accounting identities the tier split must keep (bench-level assert):
    # the DEVICE number is exactly resident_bytes, host placement banks
    # the whole cold pool on the host tier, device placement uses none
    assert tiers["device"] == eng.resident_bytes(state)
    if placement == "host":
        assert isinstance(state.cold, HostColdPool)
        assert tiers["host"] == state.cold.nbytes and tiers["host"] > 0
    else:
        assert tiers["host"] == 0
    if placement == "host":
        state = dataclasses.replace(state, cold=None)
    jax.tree_util.tree_map(lambda x: x.delete(),
                           jax.tree_util.tree_leaves(state))
    return {k: int(v) for k, v in tiers.items()}


def _fit_population(points: list, budget: int) -> dict:
    """device bytes(n) is affine in n; fit on the two largest populations
    and invert at the budget (same estimator as paged_state_bench)."""
    (n1, b1), (n2, b2) = points[-2], points[-1]
    per_client = (b2 - b1) / (n2 - n1)
    fixed = b1 - per_client * n1
    return {
        "device_bytes_per_client": per_client,
        "fixed_device_bytes": fixed,
        "max_population_at_budget": int((budget - fixed) / per_client),
    }


def _corpus(n_clients: int, rng):
    n_rows = 8192
    x = rng.normal(0, 1, (n_rows, D_IN)).astype(np.float32)
    y = rng.integers(0, N_CLASSES, n_rows).astype(np.int32)
    per = n_rows // n_clients
    parts = [rng.choice(n_rows, max(int(per * rng.uniform(0.5, 1.5)), B),
                        replace=False)
             for _ in range(n_clients)]
    return make_classification_corpus(x, y, parts, B)


def _throughput(n_clients: int, rounds: int, chunk: int, *,
                placement: str, overlap: bool = False,
                reps: int = 2) -> dict:
    """rounds/sec on the device data plane, one chunk-round superstep per
    dispatch. ``overlap=True`` (host placement only) drives the chunks
    through ``engine_run_stream`` so slab gather/upload of chunk j+1 runs
    concurrently with chunk j's dispatch."""
    eng, fcfg, params, key = _make_engine(n_clients, placement=placement)
    corpus = _corpus(n_clients, np.random.default_rng(0))
    n_chunks = rounds // chunk
    state = eng.init_state(params, key)
    if overlap:
        state, m = engine_run_stream(eng, state, n_chunks=1,
                                     chunk_rounds=chunk, corpus=corpus)
    else:
        state, m = eng.run_device(state, corpus, chunk)        # compile
    np.asarray(m["loss"])
    best = float("inf")
    for _ in range(reps):
        state = eng.init_state(params, key)
        t0 = time.perf_counter()
        if overlap:
            state, m = engine_run_stream(eng, state, n_chunks=n_chunks,
                                         chunk_rounds=chunk, corpus=corpus)
            np.asarray(m["loss"])
        else:
            for _ in range(n_chunks):
                state, m = eng.run_device(state, corpus, chunk)
                np.asarray(m["loss"])
        jax.block_until_ready(state.server)
        best = min(best, time.perf_counter() - t0)
    if placement == "host":
        state = dataclasses.replace(state, cold=None)
    jax.tree_util.tree_map(lambda x: x.delete(),
                           jax.tree_util.tree_leaves(state))
    return {"seconds": best, "rounds_per_sec": rounds / best}


def run(quick: bool = True, smoke: bool = False) -> dict:
    if smoke:
        n, rounds, chunk = 1024, 64, 32
        tiers_d = _tier_bytes(n, placement="device")
        tiers_h = _tier_bytes(n, placement="host")
        t_dev = _throughput(n, rounds, chunk, placement="device")
        t_host = _throughput(n, rounds, chunk, placement="host",
                             overlap=True)
        rel = t_host["rounds_per_sec"] / t_dev["rounds_per_sec"]
        rows = {
            "config": {"n_clients": n, "rounds": rounds, "chunk": chunk,
                       "s_max": S_MAX, "cold_bits": COLD_BITS},
            "tier_bytes": {"device_placement": tiers_d,
                           "host_placement": tiers_h},
            "device_placement": t_dev,
            "host_placement_overlapped": t_host,
            "host_over_device": rel,
            "note": "CI smoke gate: overlapped host-placement rounds/sec "
                    "must stay >= 0.75x device placement at n = 1024, "
                    "32-round chunks, and the tier accounting identities "
                    "must hold.",
        }
        save_artifact("streaming_smoke", rows)
        return rows

    populations = [1_000, 10_000, 100_000]
    residency = []
    for n in populations:
        td = _tier_bytes(n, placement="device")
        th = _tier_bytes(n, placement="host")
        residency.append({"n_clients": n,
                          "device_placement": td, "host_placement": th,
                          "device_bytes_ratio": td["device"] / th["device"]})
    fit_dev = _fit_population(
        [(r["n_clients"], r["device_placement"]["device"])
         for r in residency], BUDGET_BYTES)
    fit_host = _fit_population(
        [(r["n_clients"], r["host_placement"]["device"])
         for r in residency], BUDGET_BYTES)
    pop_ratio = (fit_host["max_population_at_budget"]
                 / fit_dev["max_population_at_budget"])

    rounds = 64 if quick else 256
    t_dev = _throughput(1024, rounds, 32, placement="device")
    t_host_seq = _throughput(1024, rounds, 32, placement="host")
    t_host_ovl = _throughput(1024, rounds, 32, placement="host",
                             overlap=True)
    rel = t_host_ovl["rounds_per_sec"] / t_dev["rounds_per_sec"]

    rows = {
        "config": {"d_in": D_IN, "d_hidden": D_HIDDEN, "K": K, "batch": B,
                   "s_max": S_MAX, "cold_bits": COLD_BITS,
                   "budget_bytes": BUDGET_BYTES,
                   "model": "classifier MLP under core.round_engine."
                            "RoundEngine (jnp oracle path, CPU)"},
        "residency_sweep": residency,
        "max_population_at_fixed_device_memory": {
            "device_placement": fit_dev, "host_placement": fit_host,
            "population_ratio_host_vs_device": pop_ratio,
        },
        "throughput_n1024_chunk32": {
            "rounds": rounds,
            "device_placement": t_dev,
            "host_placement_sequential": t_host_seq,
            "host_placement_overlapped": t_host_ovl,
            "overlap_gain": (t_host_ovl["rounds_per_sec"]
                             / t_host_seq["rounds_per_sec"]),
            "host_over_device": rel,
        },
        "note": "residency = measured per-tier EngineState bytes at init; "
                "max population inverts the affine DEVICE bytes(n) fit at "
                "a 16 GiB budget (host placement keeps only the s_max hot "
                "stacks + per-client bookkeeping on device, so its ceiling "
                "passes 10^7 clients; the cold pools live in host memory "
                "and are streamed per 32-round chunk). throughput = device "
                "data plane, one superstep dispatch per chunk; overlapped "
                "= engine_run_stream double-buffered slab prefetch. "
                "Acceptance: population ratio >= 3x with ceiling past "
                "10^7, and overlapped host >= 0.75x device rounds/sec.",
    }
    save_artifact("streaming", rows)
    with open(os.path.join(ROOT, "BENCH_streaming.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main() -> int:
    smoke = "--smoke" in sys.argv
    rows = run(quick="--full" not in sys.argv, smoke=smoke)
    if smoke:
        rel = rows["host_over_device"]
        if rel < 0.75:
            print(f"FAIL: overlapped host placement at {rel:.2f}x device "
                  f"rounds/sec (need >= 0.75x)")
            return 1
        host_rps = rows["host_placement_overlapped"]["rounds_per_sec"]
        print(f"smoke OK: host {host_rps:.1f} r/s vs device "
              f"{rows['device_placement']['rounds_per_sec']:.1f} r/s "
              f"({rel:.2f}x) at n=1024 chunk=32")
        return 0
    for r in rows["residency_sweep"]:
        td, th = r["device_placement"], r["host_placement"]
        print(f"n={r['n_clients']:7d} | device placement {td['device']:>12,}"
              f" B on-device | host placement {th['device']:>10,} B "
              f"on-device + {th['host']:>12,} B host "
              f"({r['device_bytes_ratio']:.0f}x fewer device bytes)")
    pop = rows["max_population_at_fixed_device_memory"]
    print(f"max population @16GiB device: device placement "
          f"{pop['device_placement']['max_population_at_budget']:,} | "
          f"host placement "
          f"{pop['host_placement']['max_population_at_budget']:,} "
          f"({pop['population_ratio_host_vs_device']:.0f}x)")
    t = rows["throughput_n1024_chunk32"]
    print(f"rounds/sec n=1024 chunk=32: device "
          f"{t['device_placement']['rounds_per_sec']:.1f} | host seq "
          f"{t['host_placement_sequential']['rounds_per_sec']:.1f} | host "
          f"overlapped {t['host_placement_overlapped']['rounds_per_sec']:.1f}"
          f" ({t['host_over_device']:.2f}x device, overlap gain "
          f"{t['overlap_gain']:.2f}x)")
    ok = (pop["population_ratio_host_vs_device"] >= 3.0
          and pop["host_placement"]["max_population_at_budget"] > 10 ** 7
          and t["host_over_device"] >= 0.75)
    if not ok:
        print("FAIL: acceptance targets missed (need >= 3x population, "
              "ceiling past 1e7 clients, and >= 0.75x rounds/sec)")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
