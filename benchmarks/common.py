"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import json
import os
import time

import numpy as np

ART_DIR = os.environ.get("REPRO_BENCH_DIR", "experiments/bench")


def save_artifact(name: str, obj) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=lambda o: (
            o.tolist() if isinstance(o, np.ndarray) else str(o)))
    return path


def classification_data(preset: str, n_clients: int, *, non_iid: bool,
                        n_train=6000, n_test=1500, seed=0):
    from repro.data import (make_classification, partition_iid,
                            partition_label_skew)
    x, y, xt, yt = make_classification(preset, n_train=n_train, n_test=n_test,
                                       seed=seed)
    if non_iid:
        parts = partition_label_skew(y, n_clients, 2, seed=seed)
    else:
        parts = partition_iid(len(y), n_clients, seed=seed)
    return (x, y, xt, yt, parts)


def timed(fn, *args, reps=20, warmup=3):
    import jax
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6   # us per call
