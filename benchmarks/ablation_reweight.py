"""Ablation (ours, beyond the paper's tables): isolate eq. (3).

Setup where unbiasedness *must* matter: client speed is CORRELATED with
data — the slow two-thirds of clients exclusively hold classes C/2..C−1,
the fast third holds classes 0..C/2−1. Without reweighting, fast clients'
larger raw progress dominates every server average and the model starves on
the slow clients' classes. FAVAS's alpha-reweighting equalizes expected
contributions, so both unbiased variants should beat alpha=1 on balanced
test accuracy. (When speed and data are uncorrelated, the bias is nearly
free — fast clients cover all classes — which is why this ablation pins the
correlated regime; the paper's Sec. 5 comparisons keep it implicit.)
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_artifact
from repro.core.fl_sim import SimConfig, run_simulation
from repro.data import make_classification


def _correlated_parts(y: np.ndarray, n_clients: int, n_slow: int, seed: int):
    """Clients [0, n_slow) draw only classes >= C/2; the rest < C/2."""
    rng = np.random.default_rng(seed)
    C = int(y.max()) + 1
    hi = np.where(y >= C // 2)[0]
    lo = np.where(y < C // 2)[0]
    rng.shuffle(hi)
    rng.shuffle(lo)
    parts = [np.sort(p) for p in np.array_split(hi, n_slow)]
    parts += [np.sort(p) for p in np.array_split(lo, n_clients - n_slow)]
    return parts


def run(quick=True):
    n, s = (24, 6) if quick else (60, 12)
    n_slow = 2 * n // 3
    total = 1400.0 if quick else 3500.0
    out = {}
    for rw in ("stochastic", "deterministic", "none"):
        finals, slow_recalls = [], []
        for seed in (0,):
            x, y, xt, yt = make_classification("mnist-like", n_train=8000,
                                               n_test=1500, seed=seed)
            parts = _correlated_parts(y, n, n_slow, seed)
            cfg = SimConfig(method="favas", n_clients=n, s_selected=s, K=20,
                            eta=0.5, total_time=total, eval_every=total / 2,
                            slow_fraction=n_slow / n, slow_step_time=32.0,
                            batch_size=64, reweight=rw, permute_speeds=False,
                            seed=seed)
            r = run_simulation(cfg, (x, y, xt, yt, parts), d_hidden=96)
            finals.append(r["final_accuracy"])
            # recall on the slow clients' classes — the bias-sensitive metric
            from repro.models.classifier import mlp_apply
            import jax.numpy as jnp
            C = int(y.max()) + 1
            mask = yt >= C // 2
            pred = np.asarray(jnp.argmax(
                mlp_apply(r["server"], jnp.asarray(xt[mask])), -1))
            slow_recalls.append(float((pred == yt[mask]).mean()))
        out[rw] = {"final_mean": float(np.mean(finals)),
                   "final_std": float(np.std(finals)),
                   "slow_class_recall": float(np.mean(slow_recalls))}
    save_artifact("ablation_reweight", out)
    return out
