"""Roofline table (deliverable g): collates the dry-run artifacts under
experiments/dryrun into the per-(arch x shape x mesh) three-term table that
EXPERIMENTS.md §Roofline embeds.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import save_artifact

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")

HEADER = ("arch,shape,mesh,variant,status,compute_s,memory_s,collective_s,"
          "dominant,model_flops,useful_ratio,temp_bytes,arg_bytes,coll_bytes")


def rows():
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        variant = r.get("variant", "base")
        if r.get("status") == "skipped":
            out.append(dict(arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                            variant=variant, status="skipped"))
            continue
        if r.get("status") != "ok":
            out.append(dict(arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                            variant=variant, status="error", error=r.get("error")))
            continue
        rf = r["roofline"]
        out.append(dict(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"], variant=variant,
            status="ok",
            compute_s=rf["compute_s"], memory_s=rf["memory_s"],
            collective_s=rf["collective_s"], dominant=rf["dominant"],
            model_flops=rf["model_flops"], useful_ratio=rf["useful_ratio"],
            temp_bytes=r["memory"]["temp_bytes"],
            arg_bytes=r["memory"]["argument_bytes"],
            coll_bytes=r["collectives"]["total_bytes"]))
    return out


def run(quick=True):
    table = rows()
    print(HEADER)
    for r in table:
        if r["status"] != "ok":
            print(f"{r['arch']},{r['shape']},{r['mesh']},{r['variant']},"
                  f"{r['status']},,,,,,,,,")
            continue
        print(f"{r['arch']},{r['shape']},{r['mesh']},{r['variant']},ok,"
              f"{r['compute_s']:.3e},{r['memory_s']:.3e},"
              f"{r['collective_s']:.3e},{r['dominant']},"
              f"{r['model_flops']:.3e},{r['useful_ratio']:.3f},"
              f"{r['temp_bytes']},{r['arg_bytes']},{r['coll_bytes']:.3e}")
    save_artifact("roofline_table", table)
    return table
