"""Paper experiment benchmarks (one per figure/table of Sec. 5):

  fig1_table2   — MNIST-like non-IID, 1/3 slow: FedAvg/QuAFL/FedBuff/FAVAS
                  accuracy vs simulated time (Fig. 1, Table 2 col 2)
  fig2_stragglers — same but 8/9 slow (Table 2 col 3, Fig. 2): FedBuff's
                  fast-client bias vs FAVAS robustness
  fig3a_cifar   — CIFAR-like non-IID (Fig. 3a)
  fig3b_tiny    — TinyImageNet-like proxy, 200 classes, IID (Fig. 3b)
  fig7_quant    — FAVAS[QNN] LUQ quantization + selection-size sweep (Fig. 7)

Real datasets are not fetchable offline; dimensionality/class counts match
and the *relative* paper claims are what EXPERIMENTS.md validates.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import classification_data, save_artifact
from repro.core.fl_sim import SimConfig, run_simulation

METHODS = ["fedavg", "quafl", "fedbuff", "favas"]


def _grid(quick: bool):
    # K=20 local steps and FedBuff Z=10 are the paper's settings (Sec. 5).
    if quick:
        return dict(n_clients=27, s_selected=6, K=20, buffer_z=10,
                    total_time=1400.0, eval_every=350.0, batch_size=64,
                    n_train=8000)
    return dict(n_clients=60, s_selected=12, K=20, buffer_z=10,
                total_time=3500.0, eval_every=500.0, batch_size=96,
                n_train=12000)


def _run_methods(preset, *, non_iid, slow_fraction, quick, eta=0.5, seeds=(0,),
                 methods=METHODS, d_hidden=96, quant_bits=0, s_override=None,
                 slow_step_time=16.0):
    g = _grid(quick)
    rows = {}
    for method in methods:
        finals, curves = [], []
        for seed in seeds:
            data = classification_data(preset, g["n_clients"], non_iid=non_iid,
                                       n_train=g["n_train"], seed=seed)
            cfg = SimConfig(method=method, n_clients=g["n_clients"],
                            s_selected=s_override or g["s_selected"],
                            K=g["K"], buffer_z=g["buffer_z"], eta=eta,
                            total_time=g["total_time"],
                            eval_every=g["eval_every"],
                            batch_size=g["batch_size"],
                            slow_fraction=slow_fraction,
                            slow_step_time=slow_step_time,
                            quant_bits=quant_bits if method == "favas" else 0,
                            seed=seed)
            r = run_simulation(cfg, data, d_hidden=d_hidden)
            finals.append(r["final_accuracy"])
            curves.append({"times": r["times"].tolist(),
                           "accuracy": r["accuracy"].tolist(),
                           "variance": r["variance"].tolist()})
        rows[method] = {"final_mean": float(np.mean(finals)),
                        "final_std": float(np.std(finals)),
                        "curves": curves}
    return rows


def fig1_table2(quick=True):
    rows = _run_methods("mnist-like", non_iid=True, slow_fraction=1 / 3,
                        quick=quick)
    save_artifact("fig1_table2_mnist_noniid", rows)
    return rows


def fig2_stragglers(quick=True):
    """1/9 fast clients. slow_step_time=64 (vs 16 in fig1): the paper's
    geometric speed model gives slow clients a long staleness tail; our
    deterministic clock needs a larger fast/slow ratio to match that regime
    (EXPERIMENTS.md §Repro discusses the mapping)."""
    rows = _run_methods("mnist-like", non_iid=True, slow_fraction=8 / 9,
                        quick=quick, slow_step_time=64.0,
                        methods=["fedavg", "quafl", "fedbuff", "favas"])
    save_artifact("fig2_mnist_noniid_1of9fast", rows)
    return rows


def fig3a_cifar(quick=True):
    rows = _run_methods("cifar-like", non_iid=True, slow_fraction=1 / 3,
                        quick=quick, eta=0.3, seeds=(0,))
    save_artifact("fig3a_cifar_noniid", rows)
    return rows


def fig3b_tiny(quick=True):
    rows = _run_methods("tiny-like", non_iid=False, slow_fraction=1 / 3,
                        quick=quick, eta=0.3, seeds=(0,), d_hidden=128)
    save_artifact("fig3b_tiny_iid", rows)
    return rows


def fig7_quant(quick=True):
    out = {}
    for bits in (0, 4, 3):
        rows = _run_methods("cifar-like", non_iid=True, slow_fraction=1 / 3,
                            quick=quick, eta=0.3, seeds=(0,),
                            methods=["favas"], quant_bits=bits)
        out[f"favas_bits{bits or 32}"] = rows["favas"]
    for s in ((3, 10) if quick else (5, 20, 50)):
        rows = _run_methods("cifar-like", non_iid=True, slow_fraction=1 / 3,
                            quick=quick, eta=0.3, seeds=(0,),
                            methods=["favas"], s_override=s)
        out[f"favas_s{s}"] = rows["favas"]
    save_artifact("fig7_quant_and_s", out)
    return out
