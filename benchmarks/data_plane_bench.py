"""Data-plane throughput benchmark: host batch generation vs the
on-device resident corpus (docs/architecture.md §8).

After PR 4 made the round path one-dispatch supersteps, the last host work
per chunk is batch GENERATION itself — the per-round × per-client ×
per-step numpy loops in ``data/pipeline.py``. This bench measures
end-to-end rounds/sec of the two data planes on the same engine:

* **host plane** — the PR-4 trainer behavior: ``FederatedBatcher.
  superstep_batch`` on a background ``BatchPrefetcher`` thread (generation
  + H2D overlap compute), one ``RoundEngine.run`` dispatch per chunk.
  Recorded for both rng streams: ``v1`` (the original per-(client, step)
  ``rng.choice`` loops — the default) and ``v2`` (vectorized gathers, one
  generator call per round);
* **device plane** — the corpus + per-client partition tables resident on
  device (``data.device_corpus.DeviceCorpus``), one ``RoundEngine.
  run_device`` dispatch per chunk, minibatch indices sampled INSIDE the
  scan. Zero host batch work per round.

Two sweeps: chunk ∈ {1, 8, 32, 128} at fixed n, and n_clients ∈
{64, 256, 1024} at chunk 32 — host generation scales with n × R × B
python-loop iterations while the device plane scales with one gather, so
the gap must WIDEN with n (the ISSUE-5 acceptance signal). The planes are
statistically equivalent, not stream-identical (jax vs numpy PRNG), so
this is a throughput comparison of equivalent training runs.

Results go to ``experiments/bench/data_plane.json`` AND the repo-root
``BENCH_data_plane.json`` (the perf-trajectory file).

  PYTHONPATH=src:. python benchmarks/data_plane_bench.py [--full|--smoke]

``--smoke`` (the CI ``data-plane`` job) shrinks the sweep and exits
non-zero if the device plane is slower than the host plane at chunk 32.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_artifact
from repro.core.favas import FavasConfig, client_lambdas
from repro.core.round_engine import RoundEngine
from repro.data.device_corpus import make_classification_corpus
from repro.data.pipeline import BatchPrefetcher, FederatedBatcher
from repro.models.classifier import classifier_loss, mlp_apply, mlp_init

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

D_IN, D_HIDDEN, N_CLASSES = 16, 16, 10
K, B = 1, 2
N_ROWS = 8192          # corpus rows — constant across the n_clients sweep


def _data(n_clients: int, seed: int = 0):
    """Synthetic corpus + ragged IID partitions (sizes vary ±50% so the
    padded index table genuinely exercises the masked-rows invariant)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (N_ROWS, D_IN)).astype(np.float32)
    y = rng.integers(0, N_CLASSES, N_ROWS).astype(np.int32)
    per = N_ROWS // n_clients
    parts = [rng.choice(N_ROWS, max(int(per * rng.uniform(0.5, 1.5)), B),
                        replace=False)
             for _ in range(n_clients)]
    return x, y, parts


def _make_engine(n_clients: int):
    key = jax.random.PRNGKey(0)
    params = mlp_init(key, D_IN, D_HIDDEN, N_CLASSES)
    fcfg = FavasConfig(n_clients=n_clients, s_selected=max(n_clients // 4, 1),
                       local_steps=K, eta=0.1)

    def lfn(p, b):
        return classifier_loss(p, mlp_apply, b["x"], b["y"], N_CLASSES)

    eng = RoundEngine(params, fcfg, lfn,
                      lambdas=jnp.asarray(client_lambdas(fcfg)),
                      use_kernel=False)
    return eng, fcfg, params, key


def _host_plane(eng, fcfg, params, key, data, rounds: int, chunk: int,
                stream: str) -> float:
    """The PR-4 trainer loop: prefetcher-overlapped numpy generation, one
    superstep dispatch per chunk, one stacked metrics fetch. Seconds for
    ``rounds`` rounds INCLUDING generation (that is the point)."""
    x, y, parts = data
    n_chunks = rounds // chunk

    def run_once() -> float:
        batcher = FederatedBatcher(x, y, parts, B, seed=1, stream=stream)

        def make_chunk(i):
            xs, ys = batcher.superstep_batch(chunk, fcfg.R)
            return {"x": xs, "y": ys}

        state = eng.init_state(params, key)
        with BatchPrefetcher(make_chunk, n_steps=n_chunks) as pf:
            t0 = time.perf_counter()
            for _ in range(n_chunks):
                state, m = eng.run(state, pf.get())
                np.asarray(m["loss"])
            jax.block_until_ready(state.server)
            return time.perf_counter() - t0

    # compile warmup outside the timed region
    warm = FederatedBatcher(x, y, parts, B, seed=1, stream=stream)
    xs, ys = warm.superstep_batch(chunk, fcfg.R)
    state = eng.init_state(params, key)
    state, m = eng.run(state, {"x": jnp.asarray(xs), "y": jnp.asarray(ys)})
    np.asarray(m["loss"])
    return run_once()


def _device_plane(eng, fcfg, params, key, data, rounds: int,
                  chunk: int) -> float:
    """Resident-corpus loop: upload once, then one ``run_device`` dispatch
    per chunk — no host generation anywhere. Seconds for ``rounds``."""
    x, y, parts = data
    corpus = make_classification_corpus(x, y, parts, B)
    state = eng.init_state(params, key)
    state, m = eng.run_device(state, corpus, chunk)        # compile
    np.asarray(m["loss"])
    state = eng.init_state(params, key)
    t0 = time.perf_counter()
    for _ in range(rounds // chunk):
        state, m = eng.run_device(state, corpus, chunk)
        np.asarray(m["loss"])
    jax.block_until_ready(state.server)
    return time.perf_counter() - t0


def _compare(n_clients: int, rounds: int, chunk: int, reps: int = 2) -> dict:
    eng, fcfg, params, key = _make_engine(n_clients)
    data = _data(n_clients)
    t_h1 = min(_host_plane(eng, fcfg, params, key, data, rounds, chunk, "v1")
               for _ in range(reps))
    t_h2 = min(_host_plane(eng, fcfg, params, key, data, rounds, chunk, "v2")
               for _ in range(reps))
    t_d = min(_device_plane(eng, fcfg, params, key, data, rounds, chunk)
              for _ in range(reps))
    return {
        "n_clients": n_clients, "rounds": rounds, "chunk": chunk,
        "host_v1": {"seconds": t_h1, "rounds_per_sec": rounds / t_h1},
        "host_v2": {"seconds": t_h2, "rounds_per_sec": rounds / t_h2},
        "device": {"seconds": t_d, "rounds_per_sec": rounds / t_d,
                   "speedup_vs_host_v1": t_h1 / t_d,
                   "speedup_vs_host_v2": t_h2 / t_d},
    }


def run(quick: bool = True, smoke: bool = False) -> dict:
    if smoke:
        chunk_rows = [_compare(64, rounds=64, chunk=c, reps=1)
                      for c in (1, 32)]
        n_rows = []
    else:
        rounds = 128 if quick else 512
        chunk_rows = [_compare(64, rounds=rounds, chunk=c)
                      for c in (1, 8, 32, 128)]
        n_rows = [_compare(n, rounds=64, chunk=32)
                  for n in (64, 256, 1024)]
    rows = {
        "config": {"K": K, "batch": B, "d_in": D_IN, "d_hidden": D_HIDDEN,
                   "corpus_rows": N_ROWS,
                   "model": "classifier MLP under core.round_engine."
                            "RoundEngine (jnp oracle path, CPU)"},
        "chunk_sweep_n64": chunk_rows,
        "n_clients_sweep_chunk32": n_rows,
        "note": "host_v1/host_v2 = prefetcher-overlapped numpy generation "
                "(original rng.choice loops / vectorized v2 stream) + one "
                "RoundEngine.run dispatch per chunk; device = resident "
                "DeviceCorpus, minibatch indices sampled inside the scan "
                "(RoundEngine.run_device). Planes are statistically "
                "equivalent (jax vs numpy PRNG stream). Acceptance: "
                "device rounds/sec >= host_v1 at chunk 32, gap widening "
                "over the n_clients sweep.",
    }
    if smoke:
        save_artifact("data_plane_smoke", rows)
    else:
        save_artifact("data_plane", rows)
        with open(os.path.join(ROOT, "BENCH_data_plane.json"), "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def main() -> int:
    smoke = "--smoke" in sys.argv
    rows = run(quick="--full" not in sys.argv, smoke=smoke)
    for r in rows["chunk_sweep_n64"] + rows["n_clients_sweep_chunk32"]:
        d = r["device"]
        print(f"n={r['n_clients']:5d} chunk={r['chunk']:4d} | "
              f"host_v1 {r['host_v1']['rounds_per_sec']:8.1f} r/s | "
              f"host_v2 {r['host_v2']['rounds_per_sec']:8.1f} r/s | "
              f"device {d['rounds_per_sec']:8.1f} r/s "
              f"({d['speedup_vs_host_v1']:.2f}x vs v1)")
    gate = [r for r in rows["chunk_sweep_n64"] if r["chunk"] == 32]
    if smoke and gate:
        spd = gate[0]["device"]["speedup_vs_host_v1"]
        if spd < 1.0:
            print(f"FAIL: device plane at {spd:.2f}x — slower than the "
                  f"host plane at chunk 32")
            return 1
        print(f"smoke OK: device plane at {spd:.2f}x >= host plane "
              f"(chunk 32)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
