"""Layer-level oracle tests: each fused/blocked implementation against a
naive reference computation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (blockwise_attention, decode_attention,
                                    apply_rope, apply_mrope)
from repro.models.ssm import ssd_scan
from repro.models.rglru import rglru_apply, rglru_decode, rglru_init, rglru_init_cache
from repro.models.moe import moe_apply, moe_init


def _naive_attention(q, k, v, causal=True, window=0):
    """O(S^2)-memory softmax attention with GQA, f32."""
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, hd) / np.sqrt(hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, Hq, hd)


@pytest.mark.parametrize("Sq,Hq,Hkv,window,block", [
    (33, 4, 4, 0, 8), (64, 8, 2, 0, 16), (40, 4, 1, 16, 8), (16, 2, 2, 0, 64)])
def test_blockwise_attention_matches_naive(Sq, Hq, Hkv, window, block):
    key = jax.random.PRNGKey(Sq)
    hd = 16
    q = jax.random.normal(key, (2, Sq, Hq, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, Sq, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, Sq, Hkv, hd))
    got = blockwise_attention(q, k, v, causal=True, window=window,
                              block_kv=block)
    want = _naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_masks_future():
    key = jax.random.PRNGKey(0)
    B, S, H, hd = 2, 12, 2, 8
    q = jax.random.normal(key, (B, 1, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    out_5 = decode_attention(q, k, v, 5)
    # poisoning positions >= 5 must not change the output
    k2 = k.at[:, 5:].set(1e3)
    v2 = v.at[:, 5:].set(-1e3)
    out_5b = decode_attention(q, k2, v2, 5)
    np.testing.assert_allclose(np.asarray(out_5), np.asarray(out_5b))


def test_rope_preserves_norm_and_relative_phase():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (1, 8, 2, 32))
    pos = jnp.arange(8)[None].astype(jnp.int32)
    y = apply_rope(x, jnp.broadcast_to(pos, (1, 8)), 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # inner products depend only on relative positions
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, 1, 32))
    kk = jax.random.normal(jax.random.fold_in(key, 2), (1, 8, 1, 32))
    def score(shift):
        p = (jnp.arange(8) + shift)[None].astype(jnp.int32)
        qr = apply_rope(q, jnp.broadcast_to(p, (1, 8)), 1e4)
        kr = apply_rope(kk, jnp.broadcast_to(p, (1, 8)), 1e4)
        return jnp.einsum("bshd,bthd->st", qr[:, 2:3], kr[:, 5:6])
    np.testing.assert_allclose(np.asarray(score(0)), np.asarray(score(13)),
                               rtol=1e-4, atol=1e-4)


def test_mrope_sections_shapes():
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 6, 2, 64))
    pos3 = jnp.broadcast_to(jnp.arange(6, dtype=jnp.int32), (3, 2, 6))
    y = apply_mrope(x, pos3, 1e4, sections=(8, 12, 12))
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()


def test_ssd_matches_naive_recurrence():
    """Chunked SSD vs the O(S) sequential state recurrence."""
    key = jax.random.PRNGKey(3)
    B, S, H, P, G, N = 1, 32, 2, 4, 1, 8
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)))
    Bc = jax.random.normal(jax.random.fold_in(key, 3), (B, S, G, N))
    Cc = jax.random.normal(jax.random.fold_in(key, 4), (B, S, G, N))
    y_chunk, hT = ssd_scan(x, dt, A, Bc, Cc, chunk=8)

    h = np.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        dA = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])     # (B,H)
        Bt = np.repeat(np.asarray(Bc[:, t]), H // G, axis=1)         # (B,H,N)
        Ct = np.repeat(np.asarray(Cc[:, t]), H // G, axis=1)
        dBx = np.einsum("bh,bhn,bhp->bhpn", np.asarray(dt[:, t]), Bt,
                        np.asarray(x[:, t]))
        h = h * dA[..., None, None] + dBx
        ys.append(np.einsum("bhpn,bhn->bhp", h, Ct))
    want = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), want, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hT), h, rtol=2e-3, atol=2e-3)


def test_rglru_scan_matches_step_loop():
    from repro.models.model import ModelConfig
    cfg = ModelConfig(d_model=16, rnn_width=16, conv_width=4)
    key = jax.random.PRNGKey(4)
    p = rglru_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 10, 16))
    full = rglru_apply(p, cfg, x, compute_dtype=jnp.float32)
    cache = rglru_init_cache(cfg, 2, jnp.float32)
    outs = []
    for t in range(10):
        y, cache = rglru_decode(p, cfg, x[:, t:t + 1], cache,
                                compute_dtype=jnp.float32)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=1e-3, atol=1e-3)


def test_moe_gates_and_capacity():
    from repro.models.model import ModelConfig
    cfg = ModelConfig(arch_type="moe", d_model=32, d_ff=64, n_experts=4,
                      top_k=2)
    key = jax.random.PRNGKey(5)
    p = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, 32))
    y, aux = moe_apply(p, cfg, x, capacity_factor=8.0,
                       compute_dtype=jnp.float32)
    assert y.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3      # E*sum(f*P) >= 1 by Cauchy-Schwarz
    # with huge capacity, halving capacity_factor can only drop tokens:
    y2, _ = moe_apply(p, cfg, x, capacity_factor=0.25,
                      compute_dtype=jnp.float32)
    assert np.isfinite(np.asarray(y2)).all()
