"""Substrate tests: data partitioners, pipelines, optimizers, checkpointing,
sharding rules, theory calculator, FL simulator."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (make_classification, make_lm_corpus, partition_iid,
                        partition_label_skew, FederatedBatcher, lm_round_batch)
from repro.optim import sgd, momentum, adamw, cosine_schedule
from repro.checkpointing import save_checkpoint, load_checkpoint, latest_checkpoint
from repro.sharding.rules import check_divisible, spec_for
from repro.core.theory import TheoryParams, units_of_time, favas_speed_constants
from repro.core.fl_sim import SimConfig, run_simulation


# ------------------------------ data ---------------------------------------

def test_partition_label_skew_covers_all_samples():
    _, y, _, _ = make_classification("mnist-like", n_train=2000, n_test=10)
    parts = partition_label_skew(y, 10, 2, seed=0)
    allidx = np.concatenate(parts)
    assert len(allidx) == 2000 and len(np.unique(allidx)) == 2000
    for p in parts:
        assert len(np.unique(y[p])) <= 2        # non-IID: <=2 classes/client


def test_partition_iid():
    parts = partition_iid(1000, 7)
    assert sum(len(p) for p in parts) == 1000


def test_federated_batcher_shapes():
    x, y, _, _ = make_classification("mnist-like", n_train=1000, n_test=10)
    parts = partition_iid(1000, 5)
    b = FederatedBatcher(x, y, parts, 16)
    xs, ys = b.round_batch(3)
    assert xs.shape == (5, 3, 16, 784) and ys.shape == (5, 3, 16)


def test_lm_corpus_and_round_batch():
    toks, doms = make_lm_corpus(500, 50_000, n_domains=4)
    assert toks.max() < 500
    rng = np.random.default_rng(0)
    batch = lm_round_batch(toks, doms, 4, 2, 3, 64, rng)
    assert batch.shape == (4, 2, 3, 64)
    assert batch.dtype == np.int32


# ------------------------------ optim --------------------------------------

@pytest.mark.parametrize("opt", [sgd(0.1), momentum(0.1), adamw(0.1)])
def test_optimizers_minimize_quadratic(opt):
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for t in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(g, state, params, jnp.int32(t))
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_cosine_schedule_shape():
    fn = cosine_schedule(1.0, warmup=10, total=100)
    assert float(fn(jnp.int32(0))) == 0.0
    assert abs(float(fn(jnp.int32(10))) - 1.0) < 1e-6
    assert float(fn(jnp.int32(100))) < 0.2


# ------------------------------ checkpoint ---------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    p = save_checkpoint(str(tmp_path), 3, tree)
    assert latest_checkpoint(str(tmp_path)) == p
    back = load_checkpoint(p, tree)
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


# ------------------------------ sharding -----------------------------------

def test_check_divisible_drops_bad_axes():
    sizes = {"model": 16, "data": 16}
    assert check_divisible((24, 64), ("model", None), sizes) == (None, None)
    assert check_divisible((32, 64), ("model", None), sizes) == ("model", None)
    assert check_divisible((256,), (("data", "model"),), {"model": 16, "data": 16}
                           ) == ((("data", "model")),)
    # 128 is NOT divisible by the 256-way combined axis -> replicate
    assert check_divisible((128,), (("data", "model"),), {"model": 16, "data": 16}
                           ) == (None,)


def test_spec_rules():
    sizes = {"model": 16, "data": 16, "pod": 2}
    s = spec_for("layers/attn/wq/w", (2, 4096, 4096), sizes, prefix=(None,))
    assert tuple(s) == (None, None, "model")
    s = spec_for("embed/table", (51968, 1024), sizes)
    assert tuple(s) == ("model", None)
    s = spec_for("layers/mlp/down", (2, 40, 512, 1536), sizes, prefix=(None,))
    assert tuple(s) == (None, None, "model", None)
    s = spec_for("layers/0/rnn/out/w", (2560, 2560), sizes)
    assert tuple(s) == ("model", None)


def test_param_specs_smoke():
    """All specs materialize on a 1-device mesh (divisibility -> replicate)."""
    from repro.configs import get_reduced_config
    from repro.models.model import init_params
    from repro.sharding.rules import param_specs
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ["llama3-8b", "granite-moe-3b-a800m", "mamba2-1.3b",
                 "recurrentgemma-2b"]:
        cfg = get_reduced_config(arch)
        params = jax.eval_shape(
            lambda k, c=cfg: init_params(k, c),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = param_specs(params, mesh, cfg)
        assert len(jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: x is None or hasattr(x, "index"))) > 0


# ------------------------------ theory -------------------------------------

def test_units_of_time_all_positive():
    T = units_of_time(TheoryParams())
    assert set(T) == {"FedAvg", "FedBuff", "AsyncSGD", "QuAFL", "FAVAS"}
    assert all(v > 0 for v in T.values())


def test_favas_bound_insensitive_to_straggler_severity():
    """The paper's headline: FedBuff/AsyncSGD bounds grow with tau_max
    (slow/fast ratio); FAVAS's does not grow comparably."""
    mild = TheoryParams(slow_step_time=16.0)
    harsh = TheoryParams(slow_step_time=1000.0)
    Tm, Th = units_of_time(mild), units_of_time(harsh)
    growth_fedbuff = Th["FedBuff"] / Tm["FedBuff"]
    growth_favas = Th["FAVAS"] / Tm["FAVAS"]
    assert growth_fedbuff > 3.0 * growth_favas


def test_speed_constants_finite():
    a, b = favas_speed_constants(TheoryParams())
    assert np.isfinite(a) and np.isfinite(b) and a > 0 and b >= 1.0


# ------------------------------ FL simulator --------------------------------

@pytest.mark.parametrize("method", ["favas", "quafl", "fedavg", "fedbuff",
                                    "asyncsgd"])
def test_fl_sim_short_run(method):
    x, y, xt, yt = make_classification("mnist-like", n_train=600, n_test=200,
                                       seed=0)
    parts = partition_label_skew(y, 6, 2, seed=0)
    cfg = SimConfig(method=method, n_clients=6, s_selected=2, K=3,
                    total_time=120, eval_every=60, eta=0.2, batch_size=32)
    r = run_simulation(cfg, (x, y, xt, yt, parts), d_hidden=32)
    assert (np.diff(r["times"]) >= 0).all()
    assert np.isfinite(r["accuracy"]).all()
    assert 0.0 <= r["final_accuracy"] <= 1.0


# ------------------------------ metrics ------------------------------------

def test_metrics_logger_jsonl(tmp_path):
    from repro.utils.metrics import MetricsLogger
    import json as _json
    p = str(tmp_path / "m.jsonl")
    lg = MetricsLogger(p, window=3)
    for t in range(5):
        lg.log(t, loss=float(10 - t))
    assert abs(lg.mean("loss") - 7.0) < 1e-9      # mean of last 3: 8,7,6
    lg.close()
    lines = [_json.loads(l) for l in open(p)]
    assert len(lines) == 5 and lines[-1]["loss"] == 6.0
