"""The tiled-client-axis test tier (PR 2).

The fused FAVAS round kernel streams (CLIENT_TILE, TILE) client blocks
through a VMEM scratch accumulator so n scales to thousands. This file
proves that regime:

* parity of the tiled kernels (interpret mode) against the shape-agnostic
  jnp oracles across n x dtype x progress sweeps, including n not a
  multiple of CLIENT_TILE and D not a multiple of TILE;
* a 1-ULP-at-accumulator-scale bound at the production client count
  (n=1024) — the tiled kernel reorders the client reduction (per-block
  partial sums accumulated sequentially), so parity is bounded by ULPs of
  |server| + sum_i |mask_i * msg_i| per lane, before the 1/(s+1) division;
* the VMEM budget of the production shape (n=1024, D=2^20), asserted from
  the declared block shapes — the tiled footprint is independent of n and D;
* a hypothesis property: FlatSpec flatten/unflatten round-trips mixed-dtype
  stacked pytrees bit-exactly for arbitrary n (client-axis padding on);
* engine semantics at large n (slow tier): engine_round with n=512 / n=500
  on a tiny model matches favas_round_reference exactly, padded bucket
  tails stay zero after 3 rounds, and stale/selected metrics match the mask;
* regression: the unified guarded LUQ scale maps all-zero inputs to zero
  output (no 0/0) on every path.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FavasConfig, favas_init, favas_round,
                        favas_round_reference, client_lambdas)
from repro.core import round_engine
from repro.core.quant import luq_quantize as quant_luq
from repro.kernels import ops, ref
from repro.kernels.favas_agg import (CLIENT_TILE, TILE, favas_agg_pallas,
                                     favas_fused_pallas,
                                     fused_block_vmem_bytes)


def _fused_inputs(n, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    server = jax.random.normal(ks[0], (D,), dtype)
    clients = jax.random.normal(ks[1], (n, D), dtype)
    inits = jax.random.normal(ks[2], (n, D), dtype)
    alpha = jax.random.uniform(ks[3], (n,), minval=1.0, maxval=8.0)
    mask = (jax.random.uniform(ks[4], (n,)) > 0.5).astype(jnp.float32)
    return server, clients, inits, alpha, mask, float(mask.sum())


# ---------------------------------------------------------------------------
# Tiled kernel parity vs the shape-agnostic oracle
# ---------------------------------------------------------------------------

# D=2500 is not a multiple of TILE (lane padding path) and spans two lane
# tiles; n=257/1000 are not multiples of CLIENT_TILE (row padding path);
# n=64/257/1000 exceed CLIENT_TILE=32 (tiled two-phase path); n=1/7 keep
# the resident single-sweep path so both dispatches stay covered.
@pytest.mark.parametrize("n", [1, 7, 64, 257, 1000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("quantized", [False, True])
def test_fused_tiled_matches_oracle(n, dtype, quantized):
    D = 2500
    server, clients, inits, alpha, mask, s = _fused_inputs(
        n, D, dtype, seed=n + 17 * quantized)
    progress = None
    if quantized:
        # FAVAS[QNN]: the transmitted progress is LUQ-quantized
        progress = ops.luq_quantize(
            (clients - inits).astype(jnp.float32), 4,
            jax.random.PRNGKey(n), use_kernel=False).astype(dtype)
    got = favas_fused_pallas(server, clients, inits, alpha, mask, s,
                             progress=progress, interpret=True)
    want = ref.favas_fused_ref(server, clients, inits, alpha, mask, s,
                               progress=progress)
    tol = (dict(rtol=1e-6, atol=1e-6) if dtype == jnp.float32
           else dict(rtol=8e-3, atol=8e-3))
    for name, g, w in zip(("server", "clients", "inits"), got, want):
        assert g.dtype == w.dtype and g.shape == w.shape
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   err_msg=name, **tol)
    if quantized:
        # resets keep the full-precision client state (Remark 1)
        unsel = np.asarray(mask) == 0.0
        np.testing.assert_array_equal(
            np.asarray(got[1], np.float32)[unsel],
            np.asarray(clients, np.float32)[unsel])


@pytest.mark.parametrize("n,D", [(64, 4097), (257, 3000)])
def test_agg_tiled_matches_ref(n, D):
    """The single-output aggregation kernel's tiled path (one sweep, scratch
    accumulator + @pl.when epilogue)."""
    server, clients, inits, alpha, mask, s = _fused_inputs(n, D, jnp.float32,
                                                           seed=n)
    out_k = favas_agg_pallas(server, clients, inits, alpha, mask, s)
    out_r = ref.favas_agg_ref(server, clients, inits, alpha, mask, s)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


def test_fused_tiled_one_ulp_at_accumulator_scale():
    """Production client count: the tiled kernel reorders the client-axis
    reduction, so the only daylight vs the oracle is summation order. Bound
    it by 1 fp32 ULP of the accumulator magnitude per lane
    (|server| + sum_i |mask_i * msg_i|), scaled by the 1/(s+1) division."""
    n, D = 1024, 6144
    server, clients, inits, alpha, mask, s = _fused_inputs(n, D, jnp.float32,
                                                           seed=11)
    got = favas_fused_pallas(server, clients, inits, alpha, mask, s,
                             interpret=True)
    want = ref.favas_fused_ref(server, clients, inits, alpha, mask, s)
    msg = (np.asarray(inits, np.float64)
           + (np.asarray(clients, np.float64) - np.asarray(inits, np.float64))
           / np.asarray(alpha, np.float64)[:, None])
    acc_scale = (np.abs(np.asarray(server, np.float64))
                 + np.sum(np.abs(np.asarray(mask, np.float64)[:, None] * msg),
                          axis=0))
    ulp = np.spacing(acc_scale.astype(np.float32)) / (s + 1.0)   # per lane
    srv_diff = np.abs(np.asarray(got[0], np.float64)
                      - np.asarray(want[0], np.float64))
    assert np.all(srv_diff <= ulp), float((srv_diff / ulp).max())
    # the reset outputs blend s_new with untouched state, so the same
    # per-lane bound applies to every row
    for g, w in zip(got[1:], want[1:]):
        d = np.abs(np.asarray(g, np.float64) - np.asarray(w, np.float64))
        assert np.all(d <= ulp[None, :]), float((d / ulp[None, :]).max())


def test_fused_vmem_budget_production_shape():
    """Acceptance: n=1024, D=2^20 per-grid-step VMEM <= 2 MiB, asserted from
    the declared block shapes. The tiled footprint must be independent of
    both n and D — that is what lets the engine scale."""
    budget = 2 * 1024 * 1024
    got = fused_block_vmem_bytes(1024, jnp.float32)
    assert got <= budget, got
    assert fused_block_vmem_bytes(1024, jnp.float32, progress=True) <= budget
    # block shapes carry no D term at all, and no n term beyond CLIENT_TILE:
    # n=2^20 clients costs the same VMEM as n=1024 (only HBM grows)
    assert fused_block_vmem_bytes(1 << 20, jnp.float32) == got
    # the declared blocks: (1,T) server in/out + 2x(CT,T) rows in/out
    # + 2x(CT,1) f32 scalars + 2x(1,T) f32 scratch
    expect = (2 * TILE * 4 + 4 * CLIENT_TILE * TILE * 4
              + 2 * CLIENT_TILE * 4 + 2 * TILE * 4)
    assert got == expect


def test_fused_tiled_zero_selection():
    """s = 0, n > CLIENT_TILE: server passes through, clients untouched."""
    n, D = CLIENT_TILE * 3 + 5, 300
    server, clients, inits, alpha, _, _ = _fused_inputs(n, D, jnp.float32, 3)
    mask = jnp.zeros((n,), jnp.float32)
    srv, cli, ini = favas_fused_pallas(server, clients, inits, alpha, mask,
                                       0.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(srv), np.asarray(server))
    np.testing.assert_array_equal(np.asarray(cli), np.asarray(clients))
    np.testing.assert_array_equal(np.asarray(ini), np.asarray(inits))


# ---------------------------------------------------------------------------
# FlatSpec client-axis padding: deterministic round-trip cases
# (the hypothesis fuzz over arbitrary n/layouts lives in
#  tests/test_flat_spec_properties.py — hypothesis is an optional dep)
# ---------------------------------------------------------------------------

_LEAF_DTYPES = (np.float32, np.float16, np.int32)


def check_stacked_roundtrip_bit_exact(n, client_tile, seed, layout):
    """flatten_stacked -> unflatten_stacked is bit-exact for arbitrary n and
    mixed-dtype trees, with the client axis padded to the client tile.
    ``layout``: sequence of (leaf_shape, dtype_index into _LEAF_DTYPES)."""
    rng = np.random.default_rng(seed)
    tree = {}
    for k, (shape, di) in enumerate(layout):
        dt = _LEAF_DTYPES[di]
        # +-2^10 is exactly representable in every tested dtype (fp16 incl.)
        raw = rng.integers(-(2 ** 10), 2 ** 10,
                           size=(n,) + tuple(shape)).astype(dt)
        tree[f"leaf{k}"] = jnp.asarray(raw)
    template = jax.tree_util.tree_map(lambda x: x[0], tree)
    spec = round_engine.make_flat_spec(template, n_clients=n,
                                       client_tile=client_tile)
    if n > client_tile:
        assert spec.n_padded % client_tile == 0 and spec.n_padded >= n
    else:
        assert spec.n_padded == n
    bufs = round_engine.flatten_stacked(spec, tree)
    for b, buf in enumerate(bufs):
        assert buf.shape == (spec.n_padded, spec.bucket_padded[b])
        # padded rows are zero — the invariant the round update preserves
        np.testing.assert_array_equal(np.asarray(buf)[n:], 0)
    back = round_engine.unflatten_stacked(spec, bufs)
    for key in tree:
        a, b = np.asarray(tree[key]), np.asarray(back[key])
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(a.view(np.uint8), b.view(np.uint8))


@pytest.mark.parametrize("n,client_tile", [(1, 4), (3, 4), (5, 4), (23, 8),
                                           (64, 8)])
def test_flat_spec_stacked_roundtrip_cases(n, client_tile):
    layout = [((2, 3), 0), ((7,), 1), ((), 2), ((4,), 0), ((1, 1, 5), 1)]
    check_stacked_roundtrip_bit_exact(n, client_tile, seed=n, layout=layout)


# ---------------------------------------------------------------------------
# Engine semantics at large n (slow tier — tier-1 stays fast)
# ---------------------------------------------------------------------------

def _tiny_setup(n, s, seed=0):
    fcfg = FavasConfig(n_clients=n, s_selected=s, local_steps=2, eta=0.05,
                       seed=seed)
    key = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(key, (8, 16)),
              "b": jnp.zeros((16,))}

    def lfn(p, batch):
        pred = batch["x"] @ p["w"] + p["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    lambdas = jnp.asarray(client_lambdas(fcfg))
    return fcfg, params, lfn, lambdas


def _tiny_batch(rng, n, R):
    return {"x": jnp.asarray(rng.normal(size=(n, R, 4, 8)), jnp.float32),
            "y": jnp.asarray(rng.normal(size=(n, R, 4, 16)), jnp.float32)}


@pytest.mark.slow
@pytest.mark.parametrize("n", [512, 500])   # 500: n % CLIENT_TILE != 0
def test_engine_large_n_matches_reference(n):
    """engine_round at production n reproduces the seed's per-leaf reference
    exactly — through the client-padded flat buffers — and the metrics
    (selected, stale_rounds) match the selection mask."""
    fcfg, params, lfn, lambdas = _tiny_setup(n, s=64)
    state = favas_init(params, fcfg, jax.random.PRNGKey(0))
    step_new = jax.jit(functools.partial(favas_round, cfg=fcfg, loss_fn=lfn,
                                         lambdas=lambdas))
    step_ref = jax.jit(functools.partial(favas_round_reference, cfg=fcfg,
                                         loss_fn=lfn, lambdas=lambdas))
    rng = np.random.default_rng(1)
    s_new = s_ref = state
    for _ in range(3):
        batch = _tiny_batch(rng, n, fcfg.R)
        s_new, m_new = step_new(s_new, batch)
        s_ref, m_ref = step_ref(s_ref, batch)
        for leaf_a, leaf_b in zip(
                jax.tree_util.tree_leaves((s_new.server, s_new.clients,
                                           s_new.inits)),
                jax.tree_util.tree_leaves((s_ref.server, s_ref.clients,
                                           s_ref.inits))):
            np.testing.assert_array_equal(np.asarray(leaf_a),
                                          np.asarray(leaf_b))
        np.testing.assert_array_equal(np.asarray(s_new.counters),
                                      np.asarray(s_ref.counters))
        np.testing.assert_array_equal(np.asarray(s_new.stale),
                                      np.asarray(s_ref.stale))
        # stale/selected metrics vs the mask (selection resets stale to 0;
        # Gumbel top-s selects exactly s clients)
        mask = np.asarray(s_ref.stale) == 0
        assert float(m_new["selected"]) == float(mask.sum()) == fcfg.s_selected
        assert float(m_new["stale_rounds"]) == float(np.asarray(s_new.stale).max())
        assert float(m_new["loss"]) == float(m_ref["loss"])


@pytest.mark.slow
def test_engine_large_n_padded_tails_stay_zero():
    """RoundEngine with n=500 (padded to 512 rows): after 3 rounds every
    padded client row and every padded lane tail is still exactly zero, and
    the kernel path agrees with the oracle path."""
    n = 500
    fcfg, params, lfn, lambdas = _tiny_setup(n, s=64)
    eng = round_engine.RoundEngine(params, fcfg, lfn, lambdas=lambdas)
    assert eng.spec.n_padded == 512 and eng.spec.client_tile == CLIENT_TILE
    key = jax.random.PRNGKey(0)
    est = eng.init_state(params, key)
    rng = np.random.default_rng(2)
    for _ in range(3):
        est, m = eng.step(est, _tiny_batch(rng, n, fcfg.R))
        assert np.isfinite(float(m["loss"]))
    for b in range(eng.spec.n_buckets):
        np.testing.assert_array_equal(np.asarray(est.clients[b][n:]), 0)
        np.testing.assert_array_equal(np.asarray(est.inits[b][n:]), 0)
        np.testing.assert_array_equal(
            np.asarray(est.server[b][eng.spec.bucket_sizes[b]:]), 0)
    assert np.isfinite(float(eng.variance(est)))
    # one more round through the forced interpret-kernel path (the tiled
    # kernel inside a real jitted round) stays numerically with the oracle.
    # NOTE the order: eng.step donates its input state, so the non-donating
    # kernel-path step must consume ``est`` first.
    step_k = jax.jit(functools.partial(
        round_engine.engine_round, eng.spec, cfg=fcfg, loss_fn=lfn,
        lambdas=lambdas, det_alpha=None, use_kernel=True))
    batch = _tiny_batch(rng, n, fcfg.R)
    est_k, _ = step_k(est, batch)
    est_o, _ = eng.step(est, batch)
    for bo, bk in zip(est_o.server, est_k.server):
        np.testing.assert_allclose(np.asarray(bo), np.asarray(bk),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# LUQ guarded scale — all-zero input regression
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("path", ["ops_oracle", "ops_kernel", "core_sim"])
def test_luq_all_zero_input_is_exact_zero(path):
    """The unified guarded scale (core.quant.luq_scale) maps all-zero leaves
    to scale 1.0, so every LUQ path returns exact zeros with no NaN/inf."""
    x = jnp.zeros((513,), jnp.float32)
    key = jax.random.PRNGKey(7)
    if path == "ops_oracle":
        q = ops.luq_quantize(x, 4, key, use_kernel=False)
    elif path == "ops_kernel":
        q = ops.luq_quantize(x, 4, key, use_kernel=True)
    else:
        q = quant_luq(x, 4, key)
    q = np.asarray(q)
    assert np.all(np.isfinite(q))
    np.testing.assert_array_equal(q, 0.0)
