"""Tests for the §Perf optimization features: int8 KV cache, activation
sequence-sharding, the distributed FedAvg baseline, and the dry-run
integration (subprocess — the only place 512 fake devices exist).
"""
import dataclasses
import functools
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.favas import FavasConfig
from repro.core.fedavg import fedavg_round
from repro.models.model import init_params, forward, init_cache, decode_step, loss_fn

B, S = 2, 16


def test_int8_kv_cache_close_to_bf16():
    cfg = get_reduced_config("llama3-8b")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size_raw)
    full, _ = forward(params, cfg, {"tokens": toks})
    cache = init_cache(cfg8, B, S, dtype=jnp.float32)
    assert cache["layers"]["k"].dtype == jnp.int8
    logits = None
    for t in range(S):
        logits, cache = decode_step(params, cfg8, cache, toks[:, t:t + 1],
                                    jnp.int32(t))
    err = float(jnp.max(jnp.abs(full[:, -1] - logits[:, 0])))
    assert err < 0.15, f"int8 KV error too large: {err}"


def test_act_seq_axis_numerically_identical():
    """Sharding constraints must not change values (1-device mesh)."""
    cfg = get_reduced_config("qwen3-4b")
    cfg_s = dataclasses.replace(cfg, act_seq_axis="model")
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size_raw)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    base, _ = forward(params, cfg, {"tokens": toks})
    with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") \
            else mesh:
        opt, _ = jax.jit(lambda p, b: forward(p, cfg_s, b))(
            params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(base), np.asarray(opt),
                               rtol=1e-5, atol=1e-4)


def test_fedavg_round_trains():
    cfg = get_reduced_config("qwen3-4b")
    fcfg = FavasConfig(n_clients=4, s_selected=2, local_steps=3, eta=0.05)
    key = jax.random.PRNGKey(2)
    server = init_params(key, cfg)
    lfn = lambda p, b: loss_fn(p, cfg, b)
    step = jax.jit(functools.partial(fedavg_round, cfg=fcfg, loss_fn=lfn))
    rng = np.random.default_rng(0)
    losses = []
    for _ in range(6):
        toks = rng.integers(0, cfg.vocab_size_raw,
                            (4, fcfg.local_steps, B, S)).astype(np.int32)
        server, key, m = step(server, key, {"tokens": jnp.asarray(toks)})
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


@pytest.mark.slow
def test_dryrun_one_combo_subprocess():
    """The 512-device dry-run must succeed end-to-end (cheapest combo)."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "recurrentgemma-2b", "--shape", "long_500k", "--mesh", "multi"],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "1 ok" in out.stdout
