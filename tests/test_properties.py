"""Hypothesis property tests on the system's invariants:
* LUQ quantization is unbiased and grid-valued (paper Remark 1/5);
* the FAVAS reweighting is unbiased (Lemma 10, both alpha variants);
* client sampling: S_t is uniform s-of-n without replacement;
* speed moments: pmf normalization and bounds for E ∧ K.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is an optional test dependency; without the guard the whole
# tier-1 suite dies at collection (pytest stops on a collection error)
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.quant import luq_quantize
from repro.core.sampler import (sample_increments, sample_selection,
                                moments_at_poll, make_lambdas)

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 5), st.integers(0, 10_000))
def test_luq_unbiased(bits, seed):
    """E[Q(x)] = x: average many independent quantizations."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (256,))
    reps = 400
    keys = jax.random.split(jax.random.fold_in(key, 1), reps)
    qs = jax.vmap(lambda k: luq_quantize(x, bits, k))(keys)
    mean = np.asarray(jnp.mean(qs, axis=0))
    scale = float(jnp.max(jnp.abs(x)))
    # MC error ~ scale/sqrt(reps); allow 5 sigma
    np.testing.assert_allclose(mean, np.asarray(x), atol=5 * scale / np.sqrt(reps))


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 6), st.integers(0, 10_000))
def test_luq_error_bound(bits, seed):
    """||Q(x) - x||_inf <= scale (Remark 5's r_d exists)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (128,)) * 10.0
    q = luq_quantize(x, bits, jax.random.fold_in(key, 1))
    scale = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(q - x))) <= scale + 1e-5


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(0, 10_000))
def test_selection_mask_is_uniform_s_of_n(s, seed):
    n = 8
    s = min(s, n)
    key = jax.random.PRNGKey(seed)
    m = sample_selection(key, n, s)
    assert float(m.sum()) == s
    assert set(np.unique(np.asarray(m))) <= {0.0, 1.0}
    # uniformity: over many draws each client selected ~ s/n
    keys = jax.random.split(key, 2000)
    ms = jax.vmap(lambda k: sample_selection(k, n, s))(keys)
    freq = np.asarray(ms.mean(0))
    np.testing.assert_allclose(freq, s / n, atol=0.06)


@settings(max_examples=20, deadline=None)
@given(st.floats(0.05, 0.9), st.integers(0, 10_000))
def test_increments_shifted_geometric(lam, seed):
    lambdas = jnp.full((4096,), lam, jnp.float32)
    d = sample_increments(jax.random.PRNGKey(seed), lambdas)
    d = np.asarray(d)
    assert d.min() >= 1
    np.testing.assert_allclose(d.mean(), 1.0 / lam, rtol=0.15)


@settings(max_examples=20, deadline=None)
@given(st.floats(0.05, 0.95), st.integers(2, 30), st.floats(0.05, 0.8))
def test_poll_moments_sane(lam, K, poll_p):
    p_pos, e1, e2, einv = moments_at_poll(lam, K, poll_p)
    assert 0.999 <= p_pos <= 1.0001          # shifted geometric: E >= 1 a.s.
    assert 1.0 - 1e-6 <= e1 <= K + 1e-6
    assert e1 ** 2 <= e2 + 1e-6 <= K * e1 + 1e-6
    assert 1.0 / K - 1e-9 <= einv <= 1.0 + 1e-6


def test_reweighting_unbiased_monte_carlo():
    """Lemma 10: with Y_q iid mean mu and S = E ∧ K independent,
    E[(1/alpha) sum_{q<=S} Y_q] = mu for both alpha variants."""
    rng = np.random.default_rng(0)
    K, lam, mu = 8, 0.35, 1.7
    reps = 200_000
    # per-poll steps: shifted geometric capped at K (single round poll)
    E = np.minimum(rng.geometric(lam, reps), K)
    Y = rng.normal(mu, 1.0, (reps, K))
    csum = np.cumsum(Y, axis=1)
    sums = csum[np.arange(reps), E - 1]
    # stochastic alpha = P(E>0) * E∧K = E (P=1 here)
    m1 = np.mean(sums / E)
    # deterministic alpha = E[E∧K]
    alpha_det = np.mean(E)
    m2 = np.mean(sums) / alpha_det
    se = 3.0 / np.sqrt(reps) * 4
    assert abs(m1 - mu) < se * K
    assert abs(m2 - mu) < se * K


def test_make_lambdas_fractions():
    lam = make_lambdas(30, slow_fraction=1 / 3, lam_fast=1 / 16, lam_slow=0.5)
    assert lam.shape == (30,)
    assert (lam == 0.5).sum() == 10
    assert (lam == 1 / 16).sum() == 20
