"""Durability & crash-recovery suite (docs/architecture.md §12).

The headline claim: a FAVAS server killed at an ADVERSARIAL durability
point — mid-round with partial admissions, between the durable close
record and its effects, at a fresh round start, or mid-WAL-write leaving
a torn final record — and restarted from snapshot + WAL replay finishes
the run with buckets BIT-EXACT to an uninterrupted run on the same seed,
for raw and LUQ-quantized admission alike. The argument: buckets depend
only on the selection stream (re-derived from the logged key chain), the
admitted sets (the close records), the admitted entries (the admit
records, wire-exact), and the q values — none of which see the clock, so
stretching a round across a crash is invisible to the aggregate.

Around the headline:

* wal.py unit coverage — CRC framing, torn-tail tolerance at EVERY
  truncation offset, segment rotation/pruning, snapshot atomicity and
  torn-snapshot skipping;
* the exactly-once ledger — a retransmit of an update that was durably
  admitted (before or after a crash) is acked-but-ignored, never
  double-admitted;
* the harvest-timer race — a late duplicate arriving after an early
  close is stale-acked, not admitted into the next round;
* ckpt.py hardening — ``latest_checkpoint`` skips torn/unreadable
  candidates instead of wedging recovery on them;
* AsyncConfig validation — nonsense deployments are rejected at
  construction, not at round 40;
* the real-process supervisor (slow) — SIGKILL the server child behind
  its pipe proxies, respawn with ``recover=True``, and the run still
  completes every round with a nonzero crash count.
"""
import os
import signal

import jax
import numpy as np
import pytest

from repro.checkpointing import wal
from repro.checkpointing.ckpt import latest_checkpoint, save_checkpoint
from repro.comms import FaultPlan, ServerCrashSwitch, SimulatedCrash
from repro.launch.cluster import (_smoke_data, run_inproc, run_inproc_chaos,
                                  run_proc_supervised)
from repro.launch.server import (AsyncConfig, FavasAsyncServer,
                                 recover_server)

# -- per-test wedge guard ----------------------------------------------------

TEST_TIMEOUT_S = 300


@pytest.fixture(autouse=True)
def _per_test_timeout():
    """Fail fast instead of hanging the runner if a transport wedges."""
    if not hasattr(signal, "SIGALRM"):     # non-POSIX: no guard
        yield
        return

    def _alarm(signum, frame):
        raise RuntimeError(
            f"test exceeded the {TEST_TIMEOUT_S}s wedge guard")

    old = signal.signal(signal.SIGALRM, _alarm)
    signal.alarm(TEST_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


# -- wal.py: framing, torn tails, segments, snapshots ------------------------

def test_frame_roundtrip():
    recs = [{"kind": "round_start", "round": 0},
            {"kind": "admit", "entry": {"q": np.int32(3),
                                        "codes0": np.arange(7, dtype=np.uint8),
                                        "scale0": np.float32(0.25)}},
            {"kind": "close", "admitted": ["client0", "client3"]}]
    blob = b"".join(wal.frame(r) for r in recs)
    back, torn = wal.read_frames(blob)
    assert not torn
    assert len(back) == len(recs)
    np.testing.assert_array_equal(back[1]["entry"]["codes0"],
                                  recs[1]["entry"]["codes0"])
    assert back[2] == recs[2]


def test_read_frames_torn_at_every_offset():
    """Truncating the buffer at ANY byte boundary yields the whole-record
    prefix plus torn=True — never an exception, never a partial record."""
    recs = [{"i": i, "pad": "x" * i} for i in range(4)]
    blob = b"".join(wal.frame(r) for r in recs)
    whole = []
    off = 0
    for r in recs:
        off += len(wal.frame(r))
        whole.append(off)
    boundaries = {0, *whole}
    for cut in range(len(blob) + 1):
        got, torn = wal.read_frames(blob[:cut])
        assert len(got) == sum(1 for o in whole if o <= cut)
        assert torn == (cut not in boundaries)


def test_read_frames_crc_corruption():
    blob = wal.frame({"a": 1}) + wal.frame({"b": 2})
    bad = blob[:len(blob) - 3] + bytes([blob[-3] ^ 0xFF]) + blob[-2:]
    got, torn = wal.read_frames(bad)
    assert torn and len(got) == 1 and got[0] == {"a": 1}


def test_wal_writer_rotation_and_replay(tmp_path):
    d = str(tmp_path)
    w = wal.WalWriter(d)
    assert w.segment_index == 1
    w.append({"n": 1})
    w.append({"n": 2})
    assert w.rotate() == 2
    w.append({"n": 3})
    w.close()
    recs, meta = wal.replay(d)
    assert [r["n"] for r in recs] == [1, 2, 3]
    assert meta == {"torn": False, "segments": 2}
    # replay from the rotated segment skips the sealed one
    recs2, _ = wal.replay(d, start_seg=2)
    assert [r["n"] for r in recs2] == [3]
    # pruning below the start segment deletes only the sealed file
    assert wal.prune_segments(d, before=2) == 1
    assert [i for i, _ in wal.segment_files(d)] == [2]


def test_wal_writer_reopen_never_appends_into_torn_tail(tmp_path):
    d = str(tmp_path)
    w = wal.WalWriter(d)
    w.append({"n": 1})
    w.append({"n": 2})
    w.tear_tail(3)                      # crash mid-write of record 2
    w.close()
    w2 = wal.WalWriter(d)               # the restarted server's writer
    assert w2.segment_index == 2        # fresh segment, torn tail untouched
    w2.append({"n": 3})
    w2.close()
    recs, meta = wal.replay(d)
    assert [r["n"] for r in recs] == [1]
    assert meta["torn"]                 # replay stopped at the tear


def test_snapshot_roundtrip_and_torn_skip(tmp_path):
    d = str(tmp_path)
    wal.save_snapshot(d, 2, {"round": 2, "x": np.arange(5)})
    p3 = wal.save_snapshot(d, 3, {"round": 3})
    with open(p3, "r+b") as f:          # tear the NEWEST snapshot
        f.truncate(os.path.getsize(p3) - 2)
    best = wal.latest_snapshot(d)
    assert best is not None and best.endswith("snap_00000002.ck")
    state = wal.load_snapshot(best)
    assert state["round"] == 2
    np.testing.assert_array_equal(state["x"], np.arange(5))
    with pytest.raises(ValueError):
        wal.load_snapshot(p3)


def test_prune_snapshots_keeps_newest(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4):
        wal.save_snapshot(d, s, {"s": s})
    assert wal.prune_snapshots(d, keep=2) == 2
    assert [s for s, _ in wal.snapshot_files(d)] == [3, 4]


# -- ckpt.py hardening (satellite) -------------------------------------------

def test_latest_checkpoint_skips_torn_candidate(tmp_path):
    d = str(tmp_path)
    good = save_checkpoint(d, 1, {"w": np.arange(4, dtype=np.float32)})
    # a higher-numbered file that is garbage (pre-atomic-write crash relic)
    with open(os.path.join(d, "ckpt_00000002.npz"), "wb") as f:
        f.write(b"PK\x03\x04 not actually a zip")
    assert latest_checkpoint(d) == good
    # truncated copy of a real checkpoint is also skipped
    blob = open(good, "rb").read()
    with open(os.path.join(d, "ckpt_00000003.npz"), "wb") as f:
        f.write(blob[:len(blob) // 2])
    assert latest_checkpoint(d) == good


# -- AsyncConfig validation (satellite) --------------------------------------

@pytest.mark.parametrize("kw", [
    {"round_dur": 0.0}, {"round_dur": -1.0},
    {"n_clients": 0}, {"n_clients": -2},
    {"quant_bits": 3}, {"quant_bits": 16}, {"quant_bits": -4},
    {"harvest_frac": 0.0}, {"harvest_frac": 1.5},
    {"n_clients": 2, "s_selected": 3},
])
def test_async_config_rejects_nonsense(kw):
    base = dict(n_clients=4, s_selected=2)
    base.update(kw)
    with pytest.raises(ValueError):
        AsyncConfig(**base)


@pytest.mark.parametrize("bits", [0, 2, 4, 8])
def test_async_config_accepts_codec_widths(bits):
    assert AsyncConfig(quant_bits=bits).quant_bits == bits


# -- exactly-once ledger + harvest race (driven handlers) --------------------

class _FakeAPI:
    """Minimal TransportAPI capturing sends/timers, for driving the
    server's handlers synchronously."""
    node_id = "server"

    def __init__(self):
        self.sent = []
        self.timers = []

    def now(self):
        return 0.0

    def send(self, dst, msg):
        self.sent.append((dst, msg))

    def set_timer(self, name, delay):
        self.timers.append((name, delay))

    def cancel_timer(self, name):
        pass

    def stop(self):
        pass


def _mk_server(**kw):
    from repro.models.classifier import mlp_init
    params0 = mlp_init(jax.random.PRNGKey(0), 8, 8, 3)
    cfg = AsyncConfig(n_clients=4, s_selected=2, K=4, rounds=4,
                      **{k: v for k, v in kw.items()
                         if k in AsyncConfig.__dataclass_fields__})
    srv = FavasAsyncServer(
        cfg, params0,
        wal_dir=kw.get("wal_dir"), ckpt_every=kw.get("ckpt_every", 0))
    api = _FakeAPI()
    srv.on_start(api)
    srv.on_timer("barrier", api)
    srv.on_timer("round", api)          # opens round 0
    return srv, api, params0


def _push(srv, client, rnd, seq, api, q=3, jiggle=1.0):
    rng = np.random.default_rng(seq + 11)
    bufs = [np.asarray(b)
            + jiggle * rng.standard_normal(b.shape).astype(np.float32)
            for b in srv._server_payload()]
    srv.on_message(client, {"kind": "update", "round": rnd, "q": q,
                            "seq": seq, "params": bufs}, api)


def _acks(api, dst):
    return [m for d, m in api.sent if d == dst and m.get("kind") == "ack"]


def test_ledger_dedups_retransmit_same_incarnation():
    srv, api, _ = _mk_server()
    c = srv._polled[0]
    _push(srv, c, 0, 0, api)
    assert srv.stats["admitted"] == 1
    buckets = [np.array(srv.pending[c][k]) for k in sorted(srv.pending[c])]
    _push(srv, c, 0, 0, api)            # retransmit, same (round, seq)
    assert srv.stats["admitted"] == 1   # not double-admitted
    assert srv.stats["dedup"] == 1
    assert len(_acks(api, c)) == 2      # but still acked (retries must stop)
    for k, v in zip(sorted(srv.pending[c]), buckets):
        np.testing.assert_array_equal(np.asarray(srv.pending[c][k]), v)


def test_ledger_dedups_retransmit_across_restart(tmp_path):
    """The acceptance regression: update admitted + WAL-logged, server
    dies before acking, client retransmits into the RECOVERED server —
    acked-but-ignored, exactly one admission survives."""
    wd = str(tmp_path)
    srv, api, params0 = _mk_server(wal_dir=wd)
    c = srv._polled[0]
    _push(srv, c, 0, 0, api)
    assert srv.stats["admitted"] == 1
    entry = {k: np.array(v) for k, v in srv.pending[c].items()}

    srv2 = recover_server(srv.cfg, params0, wd)   # the old process is gone
    api2 = _FakeAPI()
    srv2.on_start(api2)                 # resume protocol, not the barrier
    assert srv2.epoch == 1
    assert srv2.stats["recoveries"] == 1
    assert [m["kind"] for _, m in api2.sent].count("recover") == 4
    # replay rebuilt the pending admission bit-exactly
    assert srv2.stats["admitted"] == 1
    for k, v in entry.items():
        np.testing.assert_array_equal(np.asarray(srv2.pending[c][k]), v)
    # the retransmit (client never saw an ack) is dedup-acked, not admitted
    _push(srv2, c, 0, 0, api2)
    assert srv2.stats["admitted"] == 1
    assert srv2.stats["dedup"] == 1
    acks = _acks(api2, c)
    assert acks and acks[-1]["stale"] is False    # round still open


def test_harvest_race_late_duplicate_not_admitted_next_round():
    """Satellite regression: all polled clients deliver -> early close;
    a duplicate of an ADMITTED round-0 update arriving after the close
    (the harvest-timer race window) is stale-acked and must not leak
    into round 1's pending set."""
    srv, api, _ = _mk_server()
    polled = list(srv._polled)
    for i, c in enumerate(polled):
        _push(srv, c, 0, i, api)
    assert srv.stats["rounds"] == 1     # early close fired
    assert not srv._open and not srv.pending
    n_stale = len(srv.staleness)

    late = polled[0]
    _push(srv, late, 0, 0, api)         # the straggling duplicate copy
    assert srv.stats["dedup"] == 1
    assert _acks(api, late)[-1]["stale"] is True
    assert not srv.pending              # NOT admitted anywhere

    srv.on_timer("round", api)          # round 1 opens
    assert srv._open and srv.round == 1
    assert not srv.pending              # and starts empty
    assert len(srv.staleness) == n_stale
    # an unstamped duplicate (no seq) after close is also stale-acked
    srv2, api2, _ = _mk_server()
    for i, c in enumerate(srv2._polled):
        _push(srv2, c, 0, i, api2)
    dup = {"kind": "update", "round": 0, "q": 3,
           "params": srv2._server_payload()}
    srv2.on_message(srv2._polled[0], dup, api2)
    assert _acks(api2, srv2._polled[0])[-1]["stale"] is True
    assert srv2.stats["late"] == 1


# -- the headline: adversarial kills, bit-exact recovery ---------------------

N, S, ROUNDS = 6, 2, 8


def _cfg(bits=0):
    return AsyncConfig(n_clients=N, s_selected=S, K=5, eta=0.2,
                       batch_size=16, rounds=ROUNDS, round_dur=7.0,
                       quant_bits=bits, seed=0)


@pytest.fixture(scope="module")
def data():
    return _smoke_data(N, 0)


@pytest.fixture(scope="module")
def baseline(data):
    """Uninterrupted runs, one per codec width."""
    return {bits: run_inproc(_cfg(bits), data, d_hidden=16, seed=0)
            for bits in (0, 4)}


def _assert_bit_exact(base, out):
    a, b = base["server_actor"], out["server_actor"]
    for x, y in zip(a.srv_f, b.srv_f):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(a.cli_f, b.cli_f):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert base["server"]["selection"] == out["server"]["selection"]
    assert base["server"]["alpha"] == out["server"]["alpha"]
    # staleness is logged in admission-arrival order, which recovery's
    # re-timed round may permute WITHIN a round — the multiset is exact
    assert sorted(base["server"]["staleness"]) == \
        sorted(out["server"]["staleness"])


@pytest.mark.parametrize("bits", [0, 4])
@pytest.mark.parametrize("point,at,tear", [
    ("admit", 3, 0),        # mid-round, partial admissions already durable
    ("close", 2, 0),        # between the durable close and its resets
    ("round_start", 4, 0),  # fresh round logged, no tick ever sent
    ("admit", 2, 3),        # crash MID-write: torn final record on disk
])
def test_kill_and_recover_bit_exact(data, baseline, tmp_path, bits, point,
                                    at, tear):
    out = run_inproc_chaos(
        _cfg(bits), data, d_hidden=16, wal_dir=str(tmp_path), ckpt_every=3,
        kills=[ServerCrashSwitch(point=point, at=at, tear_bytes=tear)],
        seed=0)
    assert out["recoveries"] == 1
    assert out["transport"]["kills"] == 1
    assert out["server"]["rounds"] == ROUNDS
    assert out["server"]["stats"]["recoveries"] == 1
    _assert_bit_exact(baseline[bits], out)
    if tear:
        # the recovered server really did replay up to a torn tail
        assert out["server_actor"].replay_meta["torn"] is True


def test_double_kill_recovers_twice(data, baseline, tmp_path):
    """Two kills in one run — the second incarnation dies too and the
    THIRD still lands bit-exact (snapshot + replay composes)."""
    out = run_inproc_chaos(
        _cfg(0), data, d_hidden=16, wal_dir=str(tmp_path), ckpt_every=2,
        kills=[ServerCrashSwitch(point="admit", at=2),
               ServerCrashSwitch(point="close", at=2)],
        seed=0)
    assert out["recoveries"] == 2
    assert out["server"]["stats"]["recoveries"] == 2
    assert out["server"]["rounds"] == ROUNDS
    _assert_bit_exact(baseline[0], out)
    # checkpoints rotated and pruned along the way
    assert wal.snapshot_files(str(tmp_path))


def test_chaos_without_checkpoints_pure_replay(data, baseline, tmp_path):
    """ckpt_every=0: recovery is a FULL log replay from round 0 — the
    snapshot is an optimization, not a correctness ingredient."""
    out = run_inproc_chaos(
        _cfg(0), data, d_hidden=16, wal_dir=str(tmp_path), ckpt_every=0,
        kills=[ServerCrashSwitch(point="close", at=5)], seed=0)
    assert out["recoveries"] == 1
    assert not wal.snapshot_files(str(tmp_path))
    _assert_bit_exact(baseline[0], out)


def test_stepped_run_equals_single_run(data, baseline):
    """The chaos harness's run(until=...) slicing is event-for-event
    identical to one uninterrupted run — the resumability precondition."""
    from repro.comms import InProcTransport
    from repro.launch.cluster import build_deployment
    cfg = _cfg(0)
    server, clients = build_deployment(cfg, data, d_hidden=16)
    t = InProcTransport(None, seed=0)
    t.add_actor(server)
    for c in clients:
        t.add_actor(c)
    horizon = 0.0
    while True:
        horizon += cfg.round_dur / 4.0
        t.run(until=horizon)
        if t.done():
            break
        assert horizon < 100 * ROUNDS * cfg.round_dur
    base = baseline[0]
    res = server.result()
    assert res["selection"] == base["server"]["selection"]
    assert res["alpha"] == base["server"]["alpha"]
    assert res["staleness"] == base["server"]["staleness"]
    for x, y in zip(base["server_actor"].srv_f, server.srv_f):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_simulated_crash_switch_counts_and_fires_once():
    sw = ServerCrashSwitch(point="close", at=2)
    sw.hit("admit")
    sw.hit("close")
    with pytest.raises(SimulatedCrash):
        sw.hit("close")
    assert sw.fired
    sw.hit("close")                     # no re-raise after firing
    assert sw.counts == {"admit": 1, "close": 2}


def test_wal_overhead_run_matches_plain_run(data, baseline, tmp_path):
    """Arming the WAL (no crash) must not perturb the trajectory."""
    out = run_inproc(_cfg(0), data, d_hidden=16, seed=0,
                     wal_dir=str(tmp_path), ckpt_every=2)
    _assert_bit_exact(baseline[0], out)
    assert wal.segment_files(str(tmp_path))
    assert wal.snapshot_files(str(tmp_path))


# -- the real multi-process supervisor ---------------------------------------

@pytest.mark.slow
def test_proc_supervisor_kill_restart_smoke(tmp_path, data):
    """SIGKILL the real server child mid-run; the supervisor respawns it
    with recover=True behind the same client pipes and the deployment
    still completes every round."""
    cfg = AsyncConfig(n_clients=2, s_selected=1, K=4, batch_size=16,
                      rounds=40, round_dur=0.5,
                      fast_step_time=0.1, slow_step_time=0.2, seed=0)
    x, y, xt, yt, _ = data
    from repro.data.partition import partition_iid
    parts = partition_iid(len(y), 2, seed=0)
    out = run_proc_supervised(cfg, (x, y, xt, yt, parts), d_hidden=16,
                              plan=FaultPlan(latency=0.02), seed=0,
                              timeout=180.0, wal_dir=str(tmp_path),
                              ckpt_every=5, kill_at=(8.0,))
    assert out["crashes"] == 1
    assert out["server"] is not None, "no result from the final incarnation"
    assert out["clean"], f"child exit codes: {out['exitcodes']}"
    assert out["server"]["rounds"] == cfg.rounds
    assert out["server"]["stats"]["admitted"] > 0
