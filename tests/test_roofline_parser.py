"""Unit tests for the HLO roofline parser (launch/roofline.py): trip-count
multiplication, wire-byte factors, bf16 dtype correction, dot-FLOP
accounting — on hand-written HLO snippets with known answers.
"""
import numpy as np

from repro.launch.roofline import (parse_hlo_collectives, _wire_factor,
                                   _shape_bytes, analytic_flops,
                                   model_param_counts)

HLO = """
HloModule test

%body_1 (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%gte), replica_groups=[16,16]<=[256], metadata={op_name="jit(f)/...d,df->...f/dot_general"}
  %d = f32[128,256]{1,0} dot(%ar, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%cond_1 (p: (s32[], f32[128,256])) -> pred[] {
  %c = s32[] constant(4)
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256] parameter(0)
  %ag = f32[64,512]{1,0} all-gather(%a), replica_groups=[32,8]<=[256], dimensions={1}
  %w = (s32[], f32[128,256]) while(%t), condition=%cond_1, body=%body_1, backend_config={"known_trip_count":{"n":"4"}}
}
"""


def test_wire_factors():
    assert _wire_factor("all-reduce", 16) == 2 * 15 / 16
    assert _wire_factor("all-gather", 8) == 7 / 8
    assert _wire_factor("reduce-scatter", 4) == 3.0
    assert _wire_factor("collective-permute", 2) == 1.0
    assert _wire_factor("all-reduce", 1) == 0.0


def test_shape_bytes():
    assert _shape_bytes("f32", "128,256") == 128 * 256 * 4
    assert _shape_bytes("bf16", "8") == 16
    assert _shape_bytes("pred", "") == 1


def test_parser_trip_counts_and_kinds():
    r = parse_hlo_collectives(HLO)
    # all-reduce inside body x4 trips, output 128*256*4 B, factor 2*15/16
    ar_out = 128 * 256 * 4
    assert r["bytes_by_kind"]["all-reduce"] == 4 * ar_out
    np.testing.assert_allclose(r["wire_bytes_by_kind"]["all-reduce"],
                               4 * ar_out * 2 * 15 / 16)
    # entry all-gather once, group size 8
    ag_out = 64 * 512 * 4
    np.testing.assert_allclose(r["wire_bytes_by_kind"]["all-gather"],
                               ag_out * 7 / 8)
    # dot inside body: out 128*256 elems x contracting 256 x 2 flops x 4 trips
    np.testing.assert_allclose(r["dot_flops"], 4 * 2 * 128 * 256 * 256)


def test_parser_bf16_correction():
    r = parse_hlo_collectives(HLO, bf16_dot_comms=True)
    ar_out = 128 * 256 * 4 // 2            # tagged dot_general -> halved
    assert r["bytes_by_kind"]["all-reduce"] == 4 * ar_out
    # the all-gather has no dot tag -> unchanged
    assert r["bytes_by_kind"]["all-gather"] == 64 * 512 * 4


def test_analytic_flops_moe_uses_active_params():
    from repro.configs import get_config
    dense = get_config("llama3-8b")
    moe = get_config("phi3.5-moe-42b-a6.6b")
    info = {"seq": 4096, "global_batch": 256, "kind": "train"}
    cm = model_param_counts(moe)
    assert cm["active"] < cm["total"]
    fd = analytic_flops(dense, info, 256, local_steps=8)
    fm = analytic_flops(moe, info, 256, local_steps=8)
    # phi3.5 total 42B but active 6.6B-ish: flops must track active
    assert fm["params"]["total"] > 35e9
    assert fm["params"]["active"] < 9e9
    assert fd["model_flops"] > 0 and fm["model_flops"] > 0
