"""Sharded flat-buffer engine acceptance tests (docs/architecture.md §6).

These run on a forced 8-device CPU topology:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m pytest -q tests/test_sharded_engine.py -m "not slow"

which is exactly what the CI ``sharded`` job executes. Without >= 8 visible
devices every device-gated test here SKIPS (the tier-1 suite must keep
seeing the real 1-device topology — see tests/conftest.py); the slow-marked
``test_sharded_engine_subprocess`` self-runs this file under the flag so
plain environments still exercise the suite end-to-end.

What is proven:

* the sharded engine is BIT-EXACT against the single-device engine (and
  against ``favas_round_reference``) across n in {7, 257} x {fp32, bf16},
  for both the pjit oracle path and the shard_map + Pallas-interpret kernel
  path. Bit-exactness holds because every per-lane operation of the round
  is elementwise over the lane axis and the client reduction is not
  model-sharded — partitioning the lanes cannot reorder any float sum. The
  test loss is elementwise-gradient (mean of squares per leaf) so local SGD
  is shard-invariant too; only the scalar *loss metric* may differ in
  summation order and is compared approximately.
* the SUPERSTEP scan (``engine_multi_round``) on the mesh is bit-exact vs
  sequential sharded rounds and vs the single-device superstep, for the
  oracle, kernel, and quantized paths — the mesh leg of the
  tests/test_superstep.py parity matrix.
* per-shard padded lane tails and padded client rows stay exactly zero.
* the compiled round contains NO all-gather at full-flat-buffer size
  (``launch.roofline.collective_ops`` census over ``compiled.as_text()``),
  and ``launch.dryrun.normalize_cost_analysis`` stays usable on the
  sharded executable.
"""
import functools
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import round_engine
from repro.core.favas import FavasConfig, client_lambdas, favas_init, \
    favas_round_reference
from repro.launch.mesh import make_model_mesh

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def make_params(dtype, *, extra_f32_leaf: bool = True):
    """Small pytree whose paths hit the sharding/rules.py regexes: column-,
    row-, and vocab-sharded leaves (dims divisible by 8), one replicated
    leaf, and optionally a second-dtype leaf to force a mixed bucket set."""
    def f(*s, seed=0, dt=dtype):
        size = int(np.prod(s))
        v = np.linspace(-1.0, 1.0, size).reshape(s) * (1.0 + 0.1 * seed)
        return jnp.asarray(v, dt)
    tree = {
        "embed": {"table": f(16, 6, seed=1)},            # ("model", None)
        "blk": {"wq": {"w": f(6, 16, seed=2),            # (None, "model")
                       "b": f(16, seed=3)},              # ("model",)
                "wo": {"w": f(16, 6, seed=4)},           # ("model", None)
                "q_norm": {"scale": f(6, seed=5)}},      # replicated
        "mlp": {"down": {"w": f(16, 5, seed=6)}},        # ("model", None)
    }
    if extra_f32_leaf and dtype != jnp.float32:
        tree["blk"]["q_norm"]["scale"] = f(6, seed=5, dt=jnp.float32)
    return tree


def quad_loss(p, b):
    """Elementwise-gradient loss: d/dp_i mean_j (p_j - t)^2 = 2 (p_i - t)/N
    touches no cross-shard reduction, so the SGD trajectory is bit-exact
    under model sharding (the scalar loss VALUE is reduction-ordered and
    only compared approximately)."""
    t = b["t"]
    return sum(jnp.mean((l.astype(jnp.float32) - t) ** 2)
               for l in jax.tree_util.tree_leaves(p))


def _trees_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        assert x.dtype == y.dtype and x.shape == y.shape
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def _setup(n, dtype, *, quant_bits=0):
    mesh = make_model_mesh(8)
    params = make_params(dtype)
    fcfg = FavasConfig(n_clients=n, s_selected=min(3, n), local_steps=2,
                       eta=0.1, quant_bits=quant_bits)
    lambdas = jnp.asarray(client_lambdas(fcfg))
    spec_s = round_engine.make_flat_spec(params, n_clients=n, mesh=mesh)
    spec_r = round_engine.make_flat_spec(params, n_clients=n)
    assert max(spec_s.bucket_shards) == 8, "mesh spec must shard something"
    assert spec_s.mesh_axis == "model"
    key = jax.random.PRNGKey(0)
    st_s = jax.device_put(round_engine.engine_init(spec_s, params, fcfg, key),
                          round_engine.engine_sharding(spec_s, mesh))
    st_r = round_engine.engine_init(spec_r, params, fcfg, key)
    batch = {"t": jnp.linspace(0.0, 1.0, n * fcfg.R).reshape(n, fcfg.R)}
    return mesh, params, fcfg, lambdas, spec_s, spec_r, st_s, st_r, batch, key


def _steps(spec_s, spec_r, mesh, fcfg, lambdas, use_kernel):
    step_s = jax.jit(functools.partial(
        round_engine.engine_round, spec_s, cfg=fcfg, loss_fn=quad_loss,
        lambdas=lambdas, mesh=mesh, use_kernel=use_kernel))
    step_r = jax.jit(functools.partial(
        round_engine.engine_round, spec_r, cfg=fcfg, loss_fn=quad_loss,
        lambdas=lambdas, use_kernel=use_kernel))
    return step_s, step_r


@needs8
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["fp32", "bf16"])
@pytest.mark.parametrize("n", [7, 257])
def test_sharded_engine_bit_exact_vs_single_device(n, dtype):
    """Oracle (pjit) path: 3 rounds sharded vs single-device, all state
    bit-exact, plus a reference-implementation cross-check."""
    (mesh, params, fcfg, lambdas, spec_s, spec_r,
     st_s, st_r, batch, key) = _setup(n, dtype)
    step_s, step_r = _steps(spec_s, spec_r, mesh, fcfg, lambdas, False)
    ref_state = favas_init(params, fcfg, key)
    step_ref = jax.jit(functools.partial(
        favas_round_reference, cfg=fcfg, loss_fn=quad_loss, lambdas=lambdas))
    # reference needs the batch stacked like _local_training feeds it
    for _ in range(3):
        st_s, m_s = step_s(st_s, batch)
        st_r, m_r = step_r(st_r, batch)
        ref_state, m_f = step_ref(ref_state, batch)
        np.testing.assert_allclose(float(m_s["loss"]), float(m_r["loss"]),
                                   rtol=1e-6)
        assert float(m_s["selected"]) == float(m_r["selected"])
    _trees_equal(round_engine.engine_server_params(spec_s, st_s),
                 round_engine.engine_server_params(spec_r, st_r))
    _trees_equal(round_engine.unflatten_stacked(spec_s, st_s.clients),
                 round_engine.unflatten_stacked(spec_r, st_r.clients))
    _trees_equal(round_engine.unflatten_stacked(spec_s, st_s.inits),
                 round_engine.unflatten_stacked(spec_r, st_r.inits))
    np.testing.assert_array_equal(np.asarray(st_s.counters),
                                  np.asarray(st_r.counters))
    # and the seed reference agrees with both
    _trees_equal(round_engine.engine_server_params(spec_s, st_s),
                 ref_state.server)
    _trees_equal(round_engine.unflatten_stacked(spec_s, st_s.clients),
                 ref_state.clients)


@needs8
@pytest.mark.parametrize("n", [7, 40])
def test_sharded_kernel_path_bit_exact(n):
    """shard_map + Pallas interpret kernel per shard vs the single-device
    kernel path — n=40 exercises the tiled (n > CLIENT_TILE) client axis."""
    (mesh, params, fcfg, lambdas, spec_s, spec_r,
     st_s, st_r, batch, key) = _setup(n, jnp.float32)
    step_s, step_r = _steps(spec_s, spec_r, mesh, fcfg, lambdas, True)
    for _ in range(2):
        st_s, _ = step_s(st_s, batch)
        st_r, _ = step_r(st_r, batch)
    _trees_equal(round_engine.engine_server_params(spec_s, st_s),
                 round_engine.engine_server_params(spec_r, st_r))
    _trees_equal(round_engine.unflatten_stacked(spec_s, st_s.clients),
                 round_engine.unflatten_stacked(spec_r, st_r.clients))


@needs8
def test_sharded_quantized_progress_bit_exact():
    """FAVAS[QNN] on the sharded engine: LUQ scales are max-based (order-
    insensitive) and the PRNG draws are sharding-invariant, so even the
    quantized round is bit-exact vs single-device."""
    (mesh, params, fcfg, lambdas, spec_s, spec_r,
     st_s, st_r, batch, key) = _setup(7, jnp.float32, quant_bits=4)
    step_s, step_r = _steps(spec_s, spec_r, mesh, fcfg, lambdas, False)
    for _ in range(2):
        st_s, _ = step_s(st_s, batch)
        st_r, _ = step_r(st_r, batch)
    _trees_equal(round_engine.engine_server_params(spec_s, st_s),
                 round_engine.engine_server_params(spec_r, st_r))
    _trees_equal(round_engine.unflatten_stacked(spec_s, st_s.inits),
                 round_engine.unflatten_stacked(spec_r, st_r.inits))


@needs8
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["fp32", "bf16"])
@pytest.mark.parametrize("n", [7, 257])
def test_sharded_superstep_bit_exact(n, dtype):
    """engine_multi_round on the mesh: a 5-round superstep scan equals 5
    sequential sharded rounds AND the single-device superstep — the mesh leg
    of the tests/test_superstep.py parity matrix (scan composes with the
    shard_map/pjit per-bucket dispatch without re-dispatching per round)."""
    (mesh, params, fcfg, lambdas, spec_s, spec_r,
     st_s, st_r, batch, key) = _setup(n, dtype)
    step_s, _step_r = _steps(spec_s, spec_r, mesh, fcfg, lambdas, False)
    multi_s = jax.jit(functools.partial(
        round_engine.engine_multi_round, spec_s, cfg=fcfg, loss_fn=quad_loss,
        lambdas=lambdas, mesh=mesh, use_kernel=False))
    multi_r = jax.jit(functools.partial(
        round_engine.engine_multi_round, spec_r, cfg=fcfg, loss_fn=quad_loss,
        lambdas=lambdas, use_kernel=False))
    T = 5
    batches = {"t": jnp.stack([batch["t"] * (1.0 + 0.1 * t)
                               for t in range(T)])}
    st_seq = st_s
    for t in range(T):
        st_seq, _ = step_s(st_seq, {"t": batches["t"][t]})
    st_sup, m_sup = multi_s(st_s, batches)
    st_rep, m_rep = multi_r(st_r, batches)
    assert m_sup["loss"].shape == (T,)
    for getter in (lambda s: round_engine.engine_server_params(spec_s, s),
                   lambda s: round_engine.unflatten_stacked(spec_s, s.clients),
                   lambda s: round_engine.unflatten_stacked(spec_s, s.inits)):
        _trees_equal(getter(st_seq), getter(st_sup))
    # sharded superstep == single-device superstep, tree-for-tree
    _trees_equal(round_engine.engine_server_params(spec_s, st_sup),
                 round_engine.engine_server_params(spec_r, st_rep))
    _trees_equal(round_engine.unflatten_stacked(spec_s, st_sup.clients),
                 round_engine.unflatten_stacked(spec_r, st_rep.clients))
    np.testing.assert_array_equal(np.asarray(st_sup.counters),
                                  np.asarray(st_rep.counters))


@needs8
def test_sharded_superstep_quantized_and_kernel_paths():
    """The superstep scan composes with FAVAS[QNN] quantization and with the
    shard_map + interpret-Pallas kernel path, staying bit-exact vs the
    sequential sharded rounds."""
    for quant, use_kernel in ((4, False), (0, True)):
        (mesh, params, fcfg, lambdas, spec_s, spec_r,
         st_s, _st_r, batch, key) = _setup(7, jnp.float32, quant_bits=quant)
        step_s, _ = _steps(spec_s, spec_r, mesh, fcfg, lambdas, use_kernel)
        multi_s = jax.jit(functools.partial(
            round_engine.engine_multi_round, spec_s, cfg=fcfg,
            loss_fn=quad_loss, lambdas=lambdas, mesh=mesh,
            use_kernel=use_kernel))
        T = 3
        batches = {"t": jnp.stack([batch["t"]] * T)}
        st_seq = st_s
        for t in range(T):
            st_seq, _ = step_s(st_seq, {"t": batches["t"][t]})
        st_sup, _ = multi_s(st_s, batches)
        _trees_equal(round_engine.engine_server_params(spec_s, st_seq),
                     round_engine.engine_server_params(spec_s, st_sup))
        _trees_equal(round_engine.unflatten_stacked(spec_s, st_seq.clients),
                     round_engine.unflatten_stacked(spec_s, st_sup.clients))


@needs8
def test_sharded_padded_tails_stay_zero():
    """Per-shard lane tails and padded client rows must remain exactly zero
    after rounds — the invariant that makes per-shard padding safe."""
    (mesh, params, fcfg, lambdas, spec_s, _spec_r,
     st_s, _st_r, batch, key) = _setup(257, jnp.float32)
    step_s, _ = _steps(spec_s, _spec_r, mesh, fcfg, lambdas, False)
    for _ in range(2):
        st_s, _ = step_s(st_s, batch)
    n = spec_s.n_clients
    for b in range(spec_s.n_buckets):
        S = spec_s.shards(b)
        used = spec_s.bucket_shard_sizes[b]
        srv = np.asarray(st_s.server[b], np.float32).reshape(
            S, spec_s.bucket_shard_padded[b])
        assert np.all(srv[:, used:] == 0.0), f"server tail bucket {b}"
        cli = np.asarray(st_s.clients[b], np.float32)
        assert np.all(cli[n:] == 0.0), f"padded client rows bucket {b}"
        cli3 = cli.reshape(cli.shape[0], S, spec_s.bucket_shard_padded[b])
        assert np.all(cli3[:, :, used:] == 0.0), f"client lane tails bucket {b}"


@needs8
def test_sharded_round_has_no_full_buffer_gather():
    """Acceptance check: the compiled sharded round's collective census has
    no all-gather at (or above) full-flat-buffer size, and the normalized
    cost analysis remains readable."""
    (mesh, params, fcfg, lambdas, spec_s, _spec_r,
     st_s, _st_r, batch, key) = _setup(7, jnp.float32)
    step_s, _ = _steps(spec_s, _spec_r, mesh, fcfg, lambdas, False)
    compiled = step_s.lower(st_s, batch).compile()
    hlo = compiled.as_text()
    from repro.launch.roofline import collective_ops
    full_bytes = min(
        p * jnp.dtype(dt).itemsize
        for p, dt, S in zip(spec_s.bucket_padded, spec_s.bucket_dtypes,
                            spec_s.bucket_shards) if S > 1)
    gathers = [b for kind, b in collective_ops(hlo) if kind == "all-gather"]
    assert all(b < full_bytes for b in gathers), (
        f"full-buffer all-gather in the round: {gathers} >= {full_bytes}")
    # the jax-version-portable cost accessor must work on this executable
    from repro.launch.dryrun import normalize_cost_analysis
    cost = normalize_cost_analysis(compiled.cost_analysis())
    assert isinstance(cost, dict)


@needs8
def test_device_corpus_gather_stays_shard_local():
    """Device data plane on the mesh (docs/architecture.md §8): with a
    REPLICATED corpus, the in-scan minibatch gather is shard-local — the
    compiled device-plane superstep contains NO all-gather at (or above)
    full-corpus size — and the sharded device plane stays bit-exact
    against the single-device device plane (same key chain, same sampled
    indices, elementwise-gradient loss)."""
    import functools as ft
    from repro.data.device_corpus import make_classification_corpus
    (mesh, params, fcfg, lambdas, spec_s, spec_r,
     st_s, st_r, _batch, key) = _setup(7, jnp.float32)
    rng = np.random.default_rng(0)
    N = 2048
    x = rng.normal(0, 1, (N, 8)).astype(np.float32)
    y = rng.integers(0, 4, N).astype(np.int32)
    parts = [rng.choice(N, rng.integers(5, 200), replace=False)
             for _ in range(fcfg.n_clients)]
    corpus_s = make_classification_corpus(x, y, parts, batch=2, mesh=mesh)
    corpus_r = make_classification_corpus(x, y, parts, batch=2)

    def corpus_loss(p, b):
        # elementwise gradient (see quad_loss); the batch enters only
        # through a replicated scalar, so sharding cannot reorder sums
        t = jnp.mean(b["x"]) + 0.01 * jnp.mean(b["y"].astype(jnp.float32))
        return sum(jnp.mean((l.astype(jnp.float32) - t) ** 2)
                   for l in jax.tree_util.tree_leaves(p))

    multi_s = jax.jit(ft.partial(
        round_engine.engine_multi_round, spec_s, cfg=fcfg,
        loss_fn=corpus_loss, lambdas=lambdas, mesh=mesh, use_kernel=False),
        static_argnames=("n_rounds",))
    multi_r = jax.jit(ft.partial(
        round_engine.engine_multi_round, spec_r, cfg=fcfg,
        loss_fn=corpus_loss, lambdas=lambdas, use_kernel=False),
        static_argnames=("n_rounds",))
    st_sup, m_s = multi_s(st_s, corpus=corpus_s, n_rounds=4)
    st_rep, m_r = multi_r(st_r, corpus=corpus_r, n_rounds=4)
    assert m_s["loss"].shape == (4,)
    _trees_equal(round_engine.engine_server_params(spec_s, st_sup),
                 round_engine.engine_server_params(spec_r, st_rep))
    _trees_equal(round_engine.unflatten_stacked(spec_s, st_sup.clients),
                 round_engine.unflatten_stacked(spec_r, st_rep.clients))
    # collective census: nothing may gather the corpus (or more) per chunk
    hlo = multi_s.lower(st_s, corpus=corpus_s,
                        n_rounds=4).compile().as_text()
    from repro.launch.roofline import collective_ops
    corpus_bytes = x.nbytes
    gathers = [b for kind, b in collective_ops(hlo) if kind == "all-gather"]
    assert all(b < corpus_bytes for b in gathers), (
        f"full-corpus all-gather in the device-plane superstep: "
        f"{gathers} >= {corpus_bytes}")


@needs8
@pytest.mark.parametrize("n", [7, 257])
def test_sharded_paged_passthrough_bit_exact(n):
    """Paged residency on the mesh (docs/architecture.md §9): with the
    passthrough codec at s_max == n, the sharded paged superstep is
    bit-exact against BOTH the sharded dense superstep and the
    single-device paged superstep — the cold pools shard like the §6
    buckets, and evict/promote adds no cross-shard reduction."""
    (mesh, params, fcfg, lambdas, spec_s, spec_r,
     st_s, st_r, batch, key) = _setup(n, jnp.float32)
    spec_p = round_engine.make_flat_spec(params, n_clients=n, mesh=mesh,
                                         residency="paged")
    assert spec_p.paged and spec_p.s_max == n
    st_p = jax.device_put(round_engine.engine_init(spec_p, params, fcfg, key),
                          round_engine.engine_sharding(spec_p, mesh))
    multi_p = jax.jit(functools.partial(
        round_engine.engine_multi_round, spec_p, cfg=fcfg, loss_fn=quad_loss,
        lambdas=lambdas, mesh=mesh, use_kernel=False))
    multi_s = jax.jit(functools.partial(
        round_engine.engine_multi_round, spec_s, cfg=fcfg, loss_fn=quad_loss,
        lambdas=lambdas, mesh=mesh, use_kernel=False))
    T = 4
    batches = {"t": jnp.stack([batch["t"] * (1.0 + 0.1 * t)
                               for t in range(T)])}
    st_pp, m_p = multi_p(st_p, batches)
    st_ss, m_s = multi_s(st_s, batches)
    _trees_equal(round_engine.engine_server_params(spec_p, st_pp),
                 round_engine.engine_server_params(spec_s, st_ss))
    _trees_equal(round_engine.unflatten_stacked(spec_p, st_pp.clients),
                 round_engine.unflatten_stacked(spec_s, st_ss.clients))
    _trees_equal(round_engine.unflatten_stacked(spec_p, st_pp.inits),
                 round_engine.unflatten_stacked(spec_s, st_ss.inits))
    np.testing.assert_array_equal(np.asarray(st_pp.counters),
                                  np.asarray(st_ss.counters))
    np.testing.assert_array_equal(np.asarray(st_pp.key),
                                  np.asarray(st_ss.key))
    np.testing.assert_array_equal(np.asarray(m_p["loss"]),
                                  np.asarray(m_s["loss"]))
    # ... and against the single-device paged engine
    spec_p1 = round_engine.make_flat_spec(params, n_clients=n,
                                          residency="paged")
    st_p1 = round_engine.engine_init(spec_p1, params, fcfg, key)
    multi_p1 = jax.jit(functools.partial(
        round_engine.engine_multi_round, spec_p1, cfg=fcfg,
        loss_fn=quad_loss, lambdas=lambdas, use_kernel=False))
    st_p1, _ = multi_p1(st_p1, batches)
    _trees_equal(round_engine.unflatten_stacked(spec_p, st_pp.clients),
                 round_engine.unflatten_stacked(spec_p1, st_p1.clients))
    np.testing.assert_array_equal(np.asarray(st_pp.hot_ids),
                                  np.asarray(st_p1.hot_ids))


@needs8
def test_sharded_paged_luq_cold_pool_no_full_gather():
    """s_max < n with 4-bit cold pools on the mesh: the round runs, stays
    finite, cold codes stay uint8, and the compiled paged superstep has no
    all-gather at (or above) full-cold-pool size — evict (requant+scatter)
    and promote (gather+dequant) are shard-local."""
    from repro.core.paging import LuqCodec, encoded_nbytes
    n, s_max = 40, 8
    mesh = make_model_mesh(8)
    params = make_params(jnp.float32)
    fcfg = FavasConfig(n_clients=n, s_selected=3, local_steps=2, eta=0.1)
    lambdas = jnp.asarray(client_lambdas(fcfg))
    spec = round_engine.make_flat_spec(params, n_clients=n, mesh=mesh,
                                       residency="paged", s_max=s_max,
                                       cold_codec=LuqCodec(bits=4))
    key = jax.random.PRNGKey(1)
    st = jax.device_put(round_engine.engine_init(spec, params, fcfg, key),
                        round_engine.engine_sharding(spec, mesh))
    cold_bytes = min(encoded_nbytes(st.cold[b])
                     for b in range(spec.n_buckets) if spec.shards(b) > 1)
    multi = jax.jit(functools.partial(
        round_engine.engine_multi_round, spec, cfg=fcfg, loss_fn=quad_loss,
        lambdas=lambdas, mesh=mesh, use_kernel=False))
    batch = {"t": jnp.linspace(0.0, 1.0, n * fcfg.R).reshape(n, fcfg.R)}
    batches = {"t": jnp.stack([batch["t"]] * 6)}
    lowered = multi.lower(st, batches)
    st, ms = multi(st, batches)
    assert np.all(np.isfinite(np.asarray(ms["loss"])))
    assert st.cold[0]["init"]["codes"].dtype == jnp.uint8
    assert np.asarray(st.hot_ids).shape == (s_max,)
    from repro.launch.roofline import collective_ops
    hlo = lowered.compile().as_text()
    gathers = [b for kind, b in collective_ops(hlo) if kind == "all-gather"]
    assert all(b < cold_bytes for b in gathers), (
        f"cold-pool-sized all-gather in the paged superstep: "
        f"{gathers} >= {cold_bytes}")


@needs8
def test_sharded_codes_in_progress_parity():
    """Codes-in transport on the mesh (docs/architecture.md §10): the
    transmitted progress reaches ``fused_bucket_update`` as packed LUQ
    codes + per-(row, shard) scales. The oracle branch is element-EXACT vs
    the ``luq_decode_rows`` -> ``favas_fused_ref`` composition (it IS that
    composition, with output shardings pinned), and the shard_map +
    interpret-Pallas codes-in branch — each device dequantizing its own
    lane segment against its own scale column, no collectives — matches
    within 2 fp32 ULPs of the per-lane accumulator magnitude (the
    tests/test_quant_fused.py budget: in-VMEM dequant contraction plus the
    client-reduction order)."""
    from repro.core.paging import luq_decode_rows
    from repro.kernels import ref
    from repro.kernels.ops import cold_requant_rows
    n, bits = 7, 4
    mesh = make_model_mesh(8)
    params = make_params(jnp.float32)
    fcfg = FavasConfig(n_clients=n, s_selected=3, local_steps=1, eta=0.1,
                       quant_bits=bits)
    spec = round_engine.make_flat_spec(params, n_clients=n, mesh=mesh)
    b = next(i for i in range(spec.n_buckets) if spec.shards(i) == 8)
    key = jax.random.PRNGKey(0)
    st = jax.device_put(round_engine.engine_init(spec, params, fcfg, key),
                        round_engine.engine_sharding(spec, mesh))
    rows, Dp = st.clients[b].shape
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    trained = st.clients[b] + 0.1 * jax.random.normal(ks[0], (rows, Dp))
    alpha = jax.random.uniform(ks[1], (rows,), minval=1.0, maxval=8.0)
    mask = jnp.where(jnp.arange(rows) < n,
                     (jax.random.uniform(ks[2], (rows,)) > 0.5)
                     .astype(jnp.float32), 0.0)
    s = float(mask.sum())
    delta = trained.astype(jnp.float32) - st.inits[b].astype(jnp.float32)
    enc = cold_requant_rows(delta, bits, jax.random.PRNGKey(2),
                            shards=8, use_kernel=False)
    prog = luq_decode_rows(enc, bits, jnp.float32, shards=8)
    want = ref.favas_fused_ref(st.server[b], trained, st.inits[b],
                               alpha, mask, s, progress=prog)
    got_o = round_engine.fused_bucket_update(
        spec, b, st.server[b], trained, st.inits[b], alpha, mask, s,
        progress_codes_b=enc, progress_bits=bits, mesh=mesh,
        use_kernel=False)
    for name, g, w in zip(("server", "clients", "inits"), got_o, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)
    got_k = round_engine.fused_bucket_update(
        spec, b, st.server[b], trained, st.inits[b], alpha, mask, s,
        progress_codes_b=enc, progress_bits=bits, mesh=mesh,
        use_kernel=True)
    msg = (np.asarray(st.inits[b], np.float64)
           + np.asarray(prog, np.float64)
           / np.asarray(alpha, np.float64)[:, None])
    acc = (np.abs(np.asarray(st.server[b], np.float64))
           + np.sum(np.abs(np.asarray(mask, np.float64)[:, None] * msg),
                    axis=0))
    ulp = 2.0 * np.spacing(acc.astype(np.float32)) / (s + 1.0)
    d = np.abs(np.asarray(got_k[0], np.float64)
               - np.asarray(want[0], np.float64))
    assert np.all(d <= ulp), float((d / ulp).max())
    for g, w in zip(got_k[1:], want[1:]):
        d = np.abs(np.asarray(g, np.float64) - np.asarray(w, np.float64))
        assert np.all(d <= ulp[None, :]), float((d / ulp[None, :]).max())


@needs8
def test_sharded_engine_quant_fused_round():
    """The full quant_fused round on the mesh: the per-bucket encodes use
    shards=spec.shards(b) so both dispatch paths consume the SAME codes;
    kernel vs oracle states agree to kernel-ULP level after two rounds,
    and the compiled codes-in round still has no full-flat-buffer
    all-gather — the codes and their scale columns stay shard-local."""
    (mesh, params, fcfg, lambdas, spec_s, _spec_r,
     st_o, _st_r, batch, key) = _setup(7, jnp.float32, quant_bits=4)
    st_k = jax.device_put(round_engine.engine_init(spec_s, params, fcfg, key),
                          round_engine.engine_sharding(spec_s, mesh))
    step_o = jax.jit(functools.partial(
        round_engine.engine_round, spec_s, cfg=fcfg, loss_fn=quad_loss,
        lambdas=lambdas, mesh=mesh, use_kernel=False, quant_fused=True))
    step_k = jax.jit(functools.partial(
        round_engine.engine_round, spec_s, cfg=fcfg, loss_fn=quad_loss,
        lambdas=lambdas, mesh=mesh, use_kernel=True, quant_fused=True))
    for _ in range(2):
        st_o, m_o = step_o(st_o, batch)
        st_k, m_k = step_k(st_k, batch)
    assert np.all(np.isfinite(np.asarray(m_o["loss"])))
    for a, b in zip(st_o.server + st_o.clients, st_k.server + st_k.clients):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)
    from repro.launch.roofline import collective_ops
    hlo = step_o.lower(st_o, batch).compile().as_text()
    full_bytes = min(
        p * jnp.dtype(dt).itemsize
        for p, dt, S in zip(spec_s.bucket_padded, spec_s.bucket_dtypes,
                            spec_s.bucket_shards) if S > 1)
    gathers = [b for kind, b in collective_ops(hlo) if kind == "all-gather"]
    assert all(b < full_bytes for b in gathers), (
        f"full-buffer all-gather in the codes-in round: "
        f"{gathers} >= {full_bytes}")


def test_flat_spec_invariants_without_devices():
    """Sharding-aware layout metadata needs no devices: explicit shard_axes
    + model_shards give the same bucket structure tier-1 can verify."""
    tree = {"a": jnp.zeros((8, 6)), "b": jnp.zeros((5,)),
            "c": jnp.zeros((4, 4), jnp.bfloat16)}
    spec = round_engine.make_flat_spec(tree, tile=8, n_clients=3,
                                       shard_axes=[0, None, 1],
                                       model_shards=4)
    for b in range(spec.n_buckets):
        assert (spec.bucket_padded[b]
                == spec.shards(b) * spec.bucket_shard_padded[b])
        assert spec.bucket_shard_padded[b] % 8 == 0
    # non-dividing nominated dim falls back to the replicated bucket
    spec2 = round_engine.make_flat_spec(tree, tile=8, shard_axes=[0, 0, 1],
                                        model_shards=4)
    b_of_b = spec2.bucket_of[1]          # leaf "b": (5,) % 4 != 0
    assert spec2.shards(b_of_b) == 1 and spec2.shard_axes[1] is None


@pytest.mark.slow
def test_sharded_engine_subprocess():
    """Self-run this file under the forced-8-device flag so environments
    without the flag still get full sharded coverage (the CI ``sharded``
    job runs the same command directly)."""
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-m", "not slow",
         "tests/test_sharded_engine.py"],
        capture_output=True, text=True, env=env, cwd=root, timeout=1200)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "skipped" not in out.stdout.lower() or "passed" in out.stdout
