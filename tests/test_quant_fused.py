"""The quantized-transport (codes-in) test tier (docs/architecture.md §10).

FAVAS[QNN]'s transmitted progress now lives as bit-packed LUQ codes +
per-(row, shard) scales all the way into the round: this file pins

* **dispatch regression** — ``cold_requant_rows`` / ``cold_dequant_rows``
  with ``use_kernel=True`` actually EXECUTE the code-emitting Pallas
  kernels (this dispatch used to be a silent no-op that fell through to
  the jnp path), and the kernel output is bit-identical to the oracle
  under the same PRNG key;
* **oracle composition** — the codes-in round
  (``favas_fused_flat(progress_codes=...)``) is element-EXACT against
  ``luq_decode_rows`` -> ``favas_fused_ref`` across
  n in {7, 257} x {fp32, bf16} x bits in {2, 4, 8};
* **kernel-path parity** — the fused kernel that dequantizes per VMEM
  tile matches the same composition to 1 fp32 ULP at accumulator scale
  (the tests/test_tiled_kernel.py bound: the kernel body compiles as one
  fused XLA computation, so FMA contraction and — on the tiled path —
  the client-reduction reorder cost at most 1 ULP of
  |server| + sum |mask * msg| per lane), including shard-segmented
  scales, lane padding, and the n=257 row-padded tiled path;
* **no dense materialization** — the compiled paged quantized round
  (``quant_fused=True``) and an isolated cold evict/promote cycle never
  define an f32/bf16 ``[population, D]`` array in their HLO
  (``launch.roofline.dense_materializations``, the §10 acceptance gate);
* **VMEM budget** — the codec term of ``fused_block_vmem_bytes`` keeps
  the per-grid-step footprint under 2 MiB at n=1024 / D=2^20.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import round_engine
from repro.core.favas import FavasConfig, client_lambdas
from repro.core.paging import luq_decode_rows, luq_encode_rows, make_codec
from repro.kernels import ops, ref
from repro.kernels.favas_agg import favas_fused_pallas, fused_block_vmem_bytes
from repro.launch.roofline import dense_materializations


def _fused_inputs(n, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    server = jax.random.normal(ks[0], (D,), dtype)
    clients = jax.random.normal(ks[1], (n, D), dtype)
    inits = jax.random.normal(ks[2], (n, D), dtype)
    alpha = jax.random.uniform(ks[3], (n,), minval=1.0, maxval=8.0)
    mask = (jax.random.uniform(ks[4], (n,)) > 0.5).astype(jnp.float32)
    return server, clients, inits, alpha, mask, float(mask.sum())


def _encode_delta(clients, inits, bits, seed=0, shards=1):
    """The engine's transport encoding: f32 delta -> codes + scales."""
    delta = clients.astype(jnp.float32) - inits.astype(jnp.float32)
    return luq_encode_rows(delta, bits, jax.random.PRNGKey(100 + seed),
                           shards=shards)


def _oracle_round(server, clients, inits, alpha, mask, s, enc, bits,
                  shards=1):
    """The §10 reference composition: decode to dense f32, run the ref."""
    prog = luq_decode_rows(enc, bits, jnp.float32, shards=shards)
    return ref.favas_fused_ref(server, clients, inits, alpha, mask, s,
                               progress=prog)


def _assert_exact(got, want):
    for name, g, w in zip(("server", "clients", "inits"), got, want):
        assert g.dtype == w.dtype and g.shape == w.shape, name
        np.testing.assert_array_equal(np.asarray(g, np.float32),
                                      np.asarray(w, np.float32),
                                      err_msg=name)


def _assert_ulp_bounded(got, want, server, inits, alpha, mask, s, enc, bits,
                        shards=1):
    """Kernel-path bound: 2 fp32 ULPs of the per-lane accumulator magnitude
    |server| + sum_i |mask_i * msg_i|, scaled by the 1/(s+1) division —
    the test_tiled_kernel.py idiom with one extra ULP of budget. The
    kernel body is one fused XLA computation, so vs the op-by-op oracle it
    pays (a) FMA contraction of the in-VMEM dequant + msg expressions
    (<= 1 ULP of the contribution) and (b) — on the tiled path — the
    client-reduction reorder (<= 1 ULP of the accumulator)."""
    prog = np.asarray(luq_decode_rows(enc, bits, jnp.float32,
                                      shards=shards), np.float64)
    msg = (np.asarray(inits, np.float64)
           + prog / np.asarray(alpha, np.float64)[:, None])
    acc_scale = (np.abs(np.asarray(server, np.float64))
                 + np.sum(np.abs(np.asarray(mask, np.float64)[:, None] * msg),
                          axis=0))
    ulp = 2.0 * np.spacing(acc_scale.astype(np.float32)) / (s + 1.0)
    srv_diff = np.abs(np.asarray(got[0], np.float64)
                      - np.asarray(want[0], np.float64))
    assert np.all(srv_diff <= ulp), float((srv_diff / ulp).max())
    # the reset outputs blend s_new with untouched state, so the same
    # per-lane bound covers every row
    for g, w in zip(got[1:], want[1:]):
        assert g.dtype == w.dtype and g.shape == w.shape
        d = np.abs(np.asarray(g, np.float64) - np.asarray(w, np.float64))
        if g.dtype == jnp.bfloat16:
            # bf16 rounding of two values <=1 fp32 ULP apart can land one
            # bf16 step apart: widen the bound by the bf16 quantum
            bstep = np.spacing(
                np.abs(np.asarray(w, np.float32))) * 2.0 ** 16
            assert np.all(d <= np.maximum(ulp[None, :], bstep))
        else:
            assert np.all(d <= ulp[None, :]), float((d / ulp[None, :]).max())


# ---------------------------------------------------------------------------
# Dispatch regression: use_kernel=True executes the Pallas codec
# ---------------------------------------------------------------------------

def test_requant_use_kernel_true_executes_pallas(monkeypatch):
    """``cold_requant_rows(use_kernel=True)`` must dispatch
    ``kernels.luq.luq_encode_pallas`` (patched at the ``ops`` import site —
    the bug this pins was exactly a dispatch that never reached it), and
    the kernel encoding must be bit-identical to the jnp oracle under the
    same key."""
    calls = []
    real = ops.luq_encode_pallas

    def spy(*a, **k):
        calls.append(1)
        return real(*a, **k)

    monkeypatch.setattr(ops, "luq_encode_pallas", spy)
    x = jax.random.normal(jax.random.PRNGKey(0), (7, 1024), jnp.float32)
    key = jax.random.PRNGKey(42)
    enc_k = ops.cold_requant_rows(x, 4, key, use_kernel=True)
    assert calls, "use_kernel=True never reached luq_encode_pallas"
    enc_o = ops.cold_requant_rows(x, 4, key, use_kernel=False)
    assert enc_k["codes"].dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(enc_k["codes"]),
                                  np.asarray(enc_o["codes"]))
    np.testing.assert_array_equal(np.asarray(enc_k["scale"]),
                                  np.asarray(enc_o["scale"]))


def test_dequant_use_kernel_true_executes_pallas(monkeypatch):
    calls = []
    real = ops.luq_decode_pallas

    def spy(*a, **k):
        calls.append(1)
        return real(*a, **k)

    monkeypatch.setattr(ops, "luq_decode_pallas", spy)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 1024), jnp.float32)
    enc = ops.cold_requant_rows(x, 4, jax.random.PRNGKey(2),
                                use_kernel=False)
    dec_k = ops.cold_dequant_rows(enc, 4, jnp.float32, use_kernel=True)
    assert calls, "use_kernel=True never reached luq_decode_pallas"
    dec_o = ops.cold_dequant_rows(enc, 4, jnp.float32, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(dec_k), np.asarray(dec_o))


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("shards", [1, 2])
def test_requant_kernel_oracle_bit_identical(bits, shards):
    """Both eviction-path encoders draw the SAME (rows, D) uniform fields
    from the key, so the packed codes and scales agree bit for bit at
    every width and shard count (rows not a multiple of ENC_ROWS: the
    kernel's row padding must not leak)."""
    x = jax.random.normal(jax.random.PRNGKey(3), (11, 2048), jnp.float32)
    key = jax.random.PRNGKey(9 + bits)
    enc_k = ops.cold_requant_rows(x, bits, key, shards=shards,
                                  use_kernel=True)
    enc_o = ops.cold_requant_rows(x, bits, key, shards=shards,
                                  use_kernel=False)
    np.testing.assert_array_equal(np.asarray(enc_k["codes"]),
                                  np.asarray(enc_o["codes"]))
    np.testing.assert_array_equal(np.asarray(enc_k["scale"]),
                                  np.asarray(enc_o["scale"]))
    # and the decoders invert identically
    dec_k = ops.cold_dequant_rows(enc_k, bits, jnp.float32, shards=shards,
                                  use_kernel=True)
    dec_o = ops.cold_dequant_rows(enc_o, bits, jnp.float32, shards=shards,
                                  use_kernel=False)
    np.testing.assert_array_equal(np.asarray(dec_k), np.asarray(dec_o))


# ---------------------------------------------------------------------------
# Codes-in round: oracle composition (element-exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["fp32", "bf16"])
@pytest.mark.parametrize("n", [7, 257])
def test_codes_in_oracle_composition_exact(n, dtype, bits):
    """``favas_fused_flat(progress_codes=..., use_kernel=False)`` ==
    decode -> ``favas_fused_ref``, element for element: the codes-in round
    is the SAME mathematical round, only the transport changed."""
    D = 1000
    server, clients, inits, alpha, mask, s = _fused_inputs(
        n, D, dtype, seed=n + bits)
    enc = _encode_delta(clients, inits, bits, seed=bits)
    got = ops.favas_fused_flat(server, clients, inits, alpha, mask, s,
                               progress_codes=enc, progress_bits=bits,
                               use_kernel=False)
    want = _oracle_round(server, clients, inits, alpha, mask, s, enc, bits)
    _assert_exact(got, want)
    # resets keep the full-precision client state (paper Remark 1)
    unsel = np.asarray(mask) == 0.0
    np.testing.assert_array_equal(
        np.asarray(got[1], np.float32)[unsel],
        np.asarray(clients, np.float32)[unsel])


def test_codes_in_rejects_dense_progress_too():
    server, clients, inits, alpha, mask, s = _fused_inputs(
        4, 256, jnp.float32)
    enc = _encode_delta(clients, inits, 4)
    with pytest.raises(ValueError, match="mutually exclusive"):
        ops.favas_fused_flat(server, clients, inits, alpha, mask, s,
                             progress=clients - inits, progress_codes=enc,
                             progress_bits=4, use_kernel=False)


# ---------------------------------------------------------------------------
# Codes-in round: kernel path (per-VMEM-tile dequant)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["fp32", "bf16"])
def test_codes_in_kernel_resident_parity(dtype, bits):
    """Resident path (n <= CLIENT_TILE): the in-kernel dequant
    (``dequant_block``, mirroring ``luq_decode_rows``
    expression-for-expression) composed with the resident-order client
    reduction stays within the 1-ULP accumulator bound of the oracle
    composition."""
    n, D = 7, 2048
    server, clients, inits, alpha, mask, s = _fused_inputs(
        n, D, dtype, seed=bits)
    enc = _encode_delta(clients, inits, bits, seed=bits)
    got = ops.favas_fused_flat(server, clients, inits, alpha, mask, s,
                               progress_codes=enc, progress_bits=bits,
                               use_kernel=True)
    want = _oracle_round(server, clients, inits, alpha, mask, s, enc, bits)
    _assert_ulp_bounded(got, want, server, inits, alpha, mask, s, enc, bits)


def test_codes_in_kernel_sharded_scales_parity():
    """progress_shards > 1: each lane segment dequantizes against its own
    scale column — the layout the §6 mesh path slices per device."""
    n, D, bits, shards = 7, 4096, 4, 2
    server, clients, inits, alpha, mask, s = _fused_inputs(
        n, D, jnp.float32, seed=5)
    enc = _encode_delta(clients, inits, bits, shards=shards)
    got = ops.favas_fused_flat(server, clients, inits, alpha, mask, s,
                               progress_codes=enc, progress_bits=bits,
                               progress_shards=shards, use_kernel=True)
    prog = luq_decode_rows(enc, bits, jnp.float32, shards=shards)
    want = ref.favas_fused_ref(server, clients, inits, alpha, mask, s,
                               progress=prog)
    _assert_ulp_bounded(got, want, server, inits, alpha, mask, s, enc, bits,
                        shards=shards)


def test_codes_in_kernel_lane_padding_parity():
    """D not a multiple of TILE: the padded code bytes are zero, zero codes
    decode to exact zeros, so the lane tail stays a no-op through the
    codec (the same invariant the dense operands rely on)."""
    n, D, bits = 7, 300, 4
    server, clients, inits, alpha, mask, s = _fused_inputs(
        n, D, jnp.float32, seed=7)
    enc = _encode_delta(clients, inits, bits)
    got = ops.favas_fused_flat(server, clients, inits, alpha, mask, s,
                               progress_codes=enc, progress_bits=bits,
                               use_kernel=True)
    want = _oracle_round(server, clients, inits, alpha, mask, s, enc, bits)
    _assert_ulp_bounded(got, want, server, inits, alpha, mask, s, enc, bits)


def test_codes_in_kernel_tiled_ulp_at_accumulator_scale():
    """Tiled path (n > CLIENT_TILE, row padding at n=257): adds the
    client-reduction reorder on top of the dequant contraction — still
    within the shared accumulator-scale ULP budget."""
    n, D, bits = 257, 2048, 4
    server, clients, inits, alpha, mask, s = _fused_inputs(
        n, D, jnp.float32, seed=13)
    enc = _encode_delta(clients, inits, bits)
    got = ops.favas_fused_flat(server, clients, inits, alpha, mask, s,
                               progress_codes=enc, progress_bits=bits,
                               use_kernel=True)
    want = _oracle_round(server, clients, inits, alpha, mask, s, enc, bits)
    _assert_ulp_bounded(got, want, server, inits, alpha, mask, s, enc, bits)


# ---------------------------------------------------------------------------
# VMEM budget: the codec term
# ---------------------------------------------------------------------------

def test_codes_in_vmem_budget_production_shape():
    """Acceptance: n=1024, D=2^20, fp32, every width — the per-grid-step
    footprint with the packed-codes + scale blocks stays under 2 MiB, and
    below the dense-progress operand it replaces."""
    for bits in (2, 4, 8):
        total = fused_block_vmem_bytes(1024, jnp.float32, codec_bits=bits)
        assert total <= 2 * 1024 ** 2, (bits, total)
        assert total < fused_block_vmem_bytes(1024, jnp.float32,
                                              progress=True)
    with pytest.raises(ValueError, match="mutually exclusive"):
        fused_block_vmem_bytes(1024, jnp.float32, progress=True,
                               codec_bits=4)


# ---------------------------------------------------------------------------
# Engine integration: quant_fused transport
# ---------------------------------------------------------------------------

def _params():
    w = jnp.asarray(np.linspace(-1.0, 1.0, 256).reshape(16, 16), jnp.float32)
    b = jnp.asarray(np.linspace(0.5, 1.5, 5), jnp.float32)
    return {"w": w, "b": b}


def _loss(p, batch):
    return sum(jnp.mean((l.astype(jnp.float32) - batch["t"]) ** 2)
               for l in jax.tree_util.tree_leaves(p))


def _batches(fcfg, T, seed=0):
    vals = np.linspace(0.0, 1.0, T * fcfg.n_clients * fcfg.R) + 0.01 * seed
    return {"t": jnp.asarray(vals.reshape(T, fcfg.n_clients, fcfg.R),
                             jnp.float32)}


def _quant_engine(n, *, use_kernel, quant_fused, **paging):
    params = _params()
    fcfg = FavasConfig(n_clients=n, s_selected=max(n // 10, 2),
                       local_steps=2, eta=0.1, quant_bits=4)
    eng = round_engine.RoundEngine(
        params, fcfg, _loss, lambdas=jnp.asarray(client_lambdas(fcfg)),
        use_kernel=use_kernel, quant_fused=quant_fused, **paging)
    return eng, fcfg, params


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["oracle", "kernel"])
def test_engine_quant_fused_runs_finite_paged(use_kernel):
    """End to end on the paged engine: codes-in transport + LUQ cold pools
    + the Pallas codec path all composed, several rounds, finite loss and
    finite hot state."""
    eng, fcfg, params = _quant_engine(10, use_kernel=use_kernel,
                                      quant_fused=True, residency="paged",
                                      s_max=4, cold_bits=4)
    state = eng.init_state(params, jax.random.PRNGKey(6))
    state, ms = eng.run(state, _batches(fcfg, 3))
    assert np.all(np.isfinite(np.asarray(ms["loss"])))
    for c in state.clients:
        assert np.all(np.isfinite(np.asarray(c, np.float32)))


def test_engine_quant_fused_matches_unfused_quantization_level():
    """quant_fused changes the TRANSPORT, not the statistics: a dense
    engine with codes-in transport stays finite and close to the tree-space
    quantized engine (different PRNG streams -> not bit-equal, but the
    same 4-bit unbiased noise scale)."""
    T = 5
    fused, fcfg, params = _quant_engine(7, use_kernel=False,
                                        quant_fused=True)
    tree, _, _ = _quant_engine(7, use_kernel=False, quant_fused=False)
    key = jax.random.PRNGKey(8)
    sf, mf = fused.run(fused.init_state(params, key), _batches(fcfg, T))
    st, mt = tree.run(tree.init_state(params, key), _batches(fcfg, T))
    lf = np.asarray(mf["loss"])
    lt = np.asarray(mt["loss"])
    assert np.all(np.isfinite(lf)) and np.all(np.isfinite(lt))
    np.testing.assert_allclose(lf, lt, rtol=0.15)


# ---------------------------------------------------------------------------
# HLO gates: no dense (population, D) float materialization
# ---------------------------------------------------------------------------

def test_hlo_gate_paged_quant_round_never_densifies_population():
    """Compile the FULL paged quantized round (codes-in transport) at
    n=40 / s_max=16 and census the HLO: no op may define an f32/bf16
    [40, >=128] array. The hot stacks legitimately live at s_max rows;
    the full population exists only as uint8 code pools + narrow scale
    columns. (Feature dims are kept < 128 so batch inputs can't trip the
    gate — only a dense decode of the population could.)"""
    n, s_max = 40, 16
    eng, fcfg, params = _quant_engine(n, use_kernel=False, quant_fused=True,
                                      residency="paged", s_max=s_max,
                                      cold_bits=4)
    state = eng.init_state(params, jax.random.PRNGKey(0))
    batch = jax.tree_util.tree_map(lambda x: x[0], _batches(fcfg, 1))
    hlo = eng._round.lower(state, batch).compile().as_text()
    dense = dense_materializations(hlo, rows=n)
    assert dense == [], (
        "compiled paged round materializes the full population densely: "
        f"{dense[:5]}")


def test_hlo_gate_cold_cycle_touches_churn_rows_only():
    """An isolated jitted evict/promote cycle (gather s_churn rows ->
    decode_pair -> encode_pair -> scatter back) over an n=40-row LUQ pool:
    the compiled program defines dense float arrays at the CHURN row count
    only — never at the pool population (40) nor the full working set
    (16). A decode of the whole pool would be the §10 bug reborn at the
    residency layer."""
    n, s_max, s_churn, D = 40, 16, 4, 256
    codec = make_codec(4)
    cli = jax.random.normal(jax.random.PRNGKey(1), (n, D), jnp.float32)
    ini = jax.random.normal(jax.random.PRNGKey(2), (n, D), jnp.float32)
    pool = codec.encode_pair(cli, ini, jax.random.PRNGKey(3),
                             use_kernel=False)

    def cycle(pool, idx, key):
        rows = jax.tree_util.tree_map(lambda p: p[idx], pool)
        c, i = codec.decode_pair(rows, jnp.float32, use_kernel=False)
        enc = codec.encode_pair(c, i, key, use_kernel=False)
        return jax.tree_util.tree_map(
            lambda p, e: p.at[idx].set(e.astype(p.dtype)), pool, enc)

    idx = jnp.arange(s_churn)
    hlo = (jax.jit(cycle)
           .lower(pool, idx, jax.random.PRNGKey(4)).compile().as_text())
    for rows in (n, s_max):
        dense = dense_materializations(hlo, rows=rows)
        assert dense == [], (rows, dense[:5])
    # the cycle is not a no-op: the churn rows' floats DO materialize
    assert dense_materializations(hlo, rows=s_churn), (
        "gate sanity: the churn-row decode should be visible in the HLO")
