"""Per-kernel validation (task spec c): sweep shapes/dtypes and
assert_allclose against the ref.py pure-jnp oracles, interpret=True on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref, ops
from repro.kernels.favas_agg import favas_agg_pallas
from repro.kernels.luq import luq_pallas


@pytest.mark.parametrize("n,D", [(2, 17), (4, 1000), (8, 2048), (16, 4097),
                                 (32, 65536)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_favas_agg_kernel_matches_ref(n, D, dtype):
    key = jax.random.PRNGKey(n * 1000 + D)
    ks = jax.random.split(key, 5)
    server = jax.random.normal(ks[0], (D,), dtype)
    clients = jax.random.normal(ks[1], (n, D), dtype)
    inits = jax.random.normal(ks[2], (n, D), dtype)
    alpha = jax.random.uniform(ks[3], (n,), minval=1.0, maxval=8.0)
    mask = (jax.random.uniform(ks[4], (n,)) > 0.5).astype(jnp.float32)
    s = float(mask.sum())
    out_k = favas_agg_pallas(server, clients, inits, alpha, mask, s)
    out_r = ref.favas_agg_ref(server, clients, inits, alpha, mask, s)
    # kernel fuses (mask*init + coef*(client-init)) * 1/(s+1); the ref
    # divides — identical in f32, but the bf16 OUTPUT cast can differ by
    # 1 ULP (~2^-8 relative) on either side.
    tol = dict(rtol=2e-6, atol=2e-6) if dtype == jnp.float32 else \
        dict(rtol=8e-3, atol=8e-3)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), **tol)


@pytest.mark.parametrize("shape", [(64,), (1000,), (33, 129), (4, 5, 6)])
@pytest.mark.parametrize("bits", [2, 3, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_luq_kernel_matches_ref(shape, bits, dtype):
    key = jax.random.PRNGKey(sum(shape) + bits)
    x = jax.random.normal(key, shape, dtype)
    up = jax.random.uniform(jax.random.fold_in(key, 1), shape)
    ur = jax.random.uniform(jax.random.fold_in(key, 2), shape)
    out_k = luq_pallas(x, up, ur, bits)
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)))
    out_r = ref.luq_ref(x, up, ur, scale, bits)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=1e-6, atol=1e-6)
    assert out_k.dtype == x.dtype and out_k.shape == x.shape


def test_luq_output_is_on_grid():
    """Every quantized magnitude must be scale * 2^{-j} or 0."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (4096,))
    q = ops.luq_quantize(x, 3, key, use_kernel=True)
    scale = float(jnp.max(jnp.abs(x)))
    mags = np.abs(np.asarray(q)) / scale
    nz = mags[mags > 0]
    logs = np.log2(nz)
    np.testing.assert_allclose(logs, np.round(logs), atol=1e-5)
    assert logs.min() >= -(2 ** 2 - 1)


def test_ops_tree_aggregation_matches_loop():
    """favas_aggregate_tree == naive python-loop oracle on a small pytree."""
    key = jax.random.PRNGKey(4)
    n = 4
    tree = {"a": jax.random.normal(key, (8, 6)),
            "b": {"c": jax.random.normal(key, (11,))}}
    C = jax.tree_util.tree_map(
        lambda x: jax.random.normal(jax.random.fold_in(key, 1),
                                    (n,) + x.shape), tree)
    I = jax.tree_util.tree_map(
        lambda x: jax.random.normal(jax.random.fold_in(key, 2),
                                    (n,) + x.shape), tree)
    alpha = jnp.array([1.0, 2.0, 4.0, 8.0])
    mask = jnp.array([1.0, 0.0, 1.0, 0.0])
    s = 2.0
    got = ops.favas_aggregate_tree(tree, C, I, alpha, mask, s, use_kernel=True)

    def naive(w, Cl, Il):
        acc = np.asarray(w, np.float64).copy()
        for i in range(n):
            if float(mask[i]):
                msg = np.asarray(Il[i], np.float64) + (
                    np.asarray(Cl[i], np.float64)
                    - np.asarray(Il[i], np.float64)) / float(alpha[i])
                acc += msg
        return acc / (s + 1.0)
    want = jax.tree_util.tree_map(naive, tree, C, I)
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(g), w, rtol=1e-5, atol=1e-5)
