"""Device data plane tests (docs/architecture.md §8).

* **numpy-mirror bit-exactness** — the on-device index sampling
  (``uniform_to_indices`` over the padded partition table / LM window
  bounds) equals the numpy mirror element-exactly under a fixed key,
  across n ∈ {7, 257} × {classification, LM}. The mirror consumes the
  same uniforms (the PRNG stream is jax's; the *math* from uniforms to
  rows is what the mirror pins down — the same contract PR 4 used for
  ``credit_steps``).
* **ragged-partition padding invariants** — padded table entries are
  never sampled: every gathered row belongs to the owning client's real
  partition, over many keys, even with wildly ragged partition sizes.
* **zero host work per chunk** — ``RoundEngine.run_device`` is ONE
  compiled dispatch per chunk (the dispatch-count guard of
  tests/test_superstep.py, re-proven for the device plane), its compiled
  HLO scans on-device, and the chunk equals the sequential
  split-key-then-sample-then-step reference exactly (array-for-array).
* **host-plane equivalence** — the simulator converges the same with
  ``data_plane="device"`` as with the host plane on the structured
  corpus (statistical equivalence; streams differ by design).

The forced-8-device mesh leg (replicated corpus, shard-local gather, no
full-corpus all-gather) lives in tests/test_sharded_engine.py with the
rest of the mesh tier.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import round_engine
from repro.core.favas import FavasConfig, client_lambdas
from repro.data.device_corpus import (DeviceCorpus, make_classification_corpus,
                                      make_lm_device_corpus,
                                      mirror_lm_starts,
                                      mirror_partition_indices,
                                      sample_partition_indices)
from repro.models.classifier import classifier_loss, mlp_apply, mlp_init

D_IN, N_CLASSES = 8, 5


def _ragged_data(n, n_rows=600, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n_rows, D_IN)).astype(np.float32)
    y = rng.integers(0, N_CLASSES, n_rows).astype(np.int32)
    # wildly ragged: sizes from 1 to ~n_rows/2
    parts = [rng.choice(n_rows, rng.integers(1, max(n_rows // 2, 2)),
                        replace=False) for _ in range(n)]
    return x, y, parts


@pytest.mark.parametrize("n", [7, 257])
def test_classification_sampler_matches_numpy_mirror(n):
    """Device indices == numpy mirror, element-exact, and the gathered
    batch equals the mirror's numpy gather."""
    x, y, parts = _ragged_data(n)
    corpus = make_classification_corpus(x, y, parts, batch=3)
    R = 4
    key = jax.random.PRNGKey(42)
    j_dev = np.asarray(sample_partition_indices(key, corpus.lengths, R, 3))
    u = np.asarray(jax.random.uniform(key, (n, R, 3)))
    lengths = np.asarray(corpus.lengths)
    j_np = mirror_partition_indices(u, lengths)
    np.testing.assert_array_equal(j_dev, j_np)
    assert np.all(j_np < lengths[:, None, None])
    # full batch equality through the table gather
    b = corpus.sample_round_batch(key, R)
    table = np.asarray(corpus.idx)
    rows = table[np.arange(n)[:, None, None], j_np]
    np.testing.assert_array_equal(np.asarray(b["x"]), x[rows])
    np.testing.assert_array_equal(np.asarray(b["y"]), y[rows])


@pytest.mark.parametrize("n", [7, 257])
def test_lm_sampler_matches_numpy_mirror(n):
    from repro.data import make_lm_corpus
    tokens, domains = make_lm_corpus(64, 30_000, n_domains=5, seed=1)
    seq = 6
    corpus = make_lm_device_corpus(tokens, domains, n, batch=2, seq=seq)
    R = 3
    key = jax.random.PRNGKey(7)
    b = corpus.sample_round_batch(key, R)
    u = np.asarray(jax.random.uniform(key, (n, R, 2)))
    starts = mirror_lm_starts(u, np.asarray(corpus.lo), np.asarray(corpus.span))
    want = tokens[starts[..., None] + np.arange(seq)]
    np.testing.assert_array_equal(np.asarray(b["tokens"]), want)
    # starts stay inside each client's domain-skew window
    lo, span = np.asarray(corpus.lo), np.asarray(corpus.span)
    assert np.all(starts >= lo[:, None, None])
    assert np.all(starts < (lo + span)[:, None, None])


def test_masked_rows_never_sampled():
    """Padded table entries (index 0 fill) must be unreachable: every
    sampled row is a member of the owning client's real partition, across
    many keys — the ragged-padding invariant."""
    n = 9
    x, y, parts = _ragged_data(n, seed=3)
    corpus = make_classification_corpus(x, y, parts, batch=4)
    part_sets = [set(int(v) for v in p) for p in parts]
    table = np.asarray(corpus.idx)
    lengths = np.asarray(corpus.lengths)
    for s in range(25):
        j = np.asarray(sample_partition_indices(
            jax.random.PRNGKey(s), corpus.lengths, 5, 4))
        assert np.all(j < lengths[:, None, None])
        rows = table[np.arange(n)[:, None, None], j]
        for i in range(n):
            assert set(rows[i].ravel().tolist()) <= part_sets[i], (
                f"client {i} sampled rows outside its partition")


def test_corpus_rejects_empty_partition():
    x, y, parts = _ragged_data(4)
    with pytest.raises(ValueError, match="non-empty"):
        make_classification_corpus(x, y, parts[:3] + [np.array([], int)],
                                   batch=2)


def test_corpus_is_a_jit_stable_pytree():
    """DeviceCorpus round-trips tree_flatten/unflatten and jits without
    retracing per call (static aux, array leaves)."""
    x, y, parts = _ragged_data(5)
    corpus = make_classification_corpus(x, y, parts, batch=2)
    leaves, treedef = jax.tree_util.tree_flatten(corpus)
    corpus2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert corpus2.kind == "classification" and corpus2.batch == 2
    traces = {"n": 0}

    @jax.jit
    def f(c, key):
        traces["n"] += 1
        return c.sample_round_batch(key, 2)["y"]

    f(corpus, jax.random.PRNGKey(0))
    f(corpus2, jax.random.PRNGKey(1))
    assert traces["n"] == 1


# ---------------------------------------------------------------------------
# The engine on the device plane
# ---------------------------------------------------------------------------

def _engine(n=6, batch=3):
    x, y, parts = _ragged_data(n, seed=5)
    corpus = make_classification_corpus(x, y, parts, batch=batch)
    key = jax.random.PRNGKey(0)
    params = mlp_init(key, D_IN, 8, N_CLASSES)
    fcfg = FavasConfig(n_clients=n, s_selected=2, local_steps=2, eta=0.1)

    def lfn(p, b):
        return classifier_loss(p, mlp_apply, b["x"], b["y"], N_CLASSES)

    eng = round_engine.RoundEngine(
        params, fcfg, lfn, lambdas=jnp.asarray(client_lambdas(fcfg)))
    return eng, fcfg, params, corpus, key


def test_run_device_matches_sequential_key_split():
    """run_device(T) == the sequential reference: split one batch key off
    the carried chain per round, sample on device, engine.step — exactly
    the scan body, driven from the host. Array-for-array equality proves
    the device plane's RNG chain is the documented one."""
    eng, fcfg, params, corpus, key = _engine()
    T = 9
    s_dev = eng.init_state(params, key)
    s_dev, ms = eng.run_device(s_dev, corpus, T)
    st = eng.init_state(params, key)
    seq_losses = []
    for _ in range(T):
        k, kb = jax.random.split(st.key)
        st = dataclasses.replace(st, key=k)
        batch = corpus.sample_round_batch(kb, fcfg.R)
        st, m = eng.step(st, batch)
        seq_losses.append(float(m["loss"]))
    for a, b in zip(s_dev.server + s_dev.clients + s_dev.inits,
                    st.server + st.clients + st.inits):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(s_dev.counters),
                                  np.asarray(st.counters))
    np.testing.assert_array_equal(np.asarray(s_dev.key), np.asarray(st.key))
    np.testing.assert_array_equal(np.asarray(ms["loss"]),
                                  np.asarray(seq_losses, np.float32))


def test_run_device_single_dispatch_no_host_batch_work():
    """The ISSUE-5 acceptance guard: a compiled 32-round device-plane chunk
    is ONE dispatch into ONE compiled callable (<= 2 XLA executions with
    the metrics fetch), the loop lives on-device (a `while` op in the
    HLO), and there is no host batch-generation machinery at all — the
    only host-side inputs per chunk are the donated state and the
    (already-resident) corpus buffers."""
    eng, fcfg, params, corpus, key = _engine()
    state = eng.init_state(params, key)
    calls = {"n": 0}
    orig = eng._multi_device

    def wrap(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    eng._multi_device = wrap
    try:
        state, m = eng.run_device(state, corpus, 32)       # compile + run
        del m
        calls["n"] = 0
        state, m = eng.run_device(state, corpus, 32)       # cache hit
        del m
        assert calls["n"] == 1, "a device-plane chunk must be ONE dispatch"
        assert eng.dispatch_count == 2
    finally:
        eng._multi_device = orig
    hlo = orig.lower(state, corpus=corpus, n_rounds=32).compile().as_text()
    assert "while" in hlo, "device-plane superstep HLO has no on-device loop"


def test_run_device_donates_buffers():
    eng, fcfg, params, corpus, key = _engine()
    state = eng.init_state(params, key)
    prev = state
    state, m = eng.run_device(state, corpus, 4)
    del m
    assert prev.server[0].is_deleted(), "run_device must donate the state"
    # the corpus must NOT be donated — it is reused every chunk
    assert not corpus.x.is_deleted()
    state, m = eng.run_device(state, corpus, 4)
    assert bool(jnp.isfinite(m["loss"]).all())


def test_engine_multi_round_corpus_validation():
    eng, fcfg, params, corpus, key = _engine()
    state = eng.init_state(params, key)
    batches = {"x": jnp.zeros((2, 6, 2, 3, D_IN)),
               "y": jnp.zeros((2, 6, 2, 3), jnp.int32)}
    with pytest.raises(ValueError, match="not both"):
        round_engine.engine_multi_round(
            eng.spec, state, batches, cfg=fcfg, loss_fn=eng.loss_fn,
            lambdas=eng.lambdas, corpus=corpus, n_rounds=2)
    with pytest.raises(ValueError, match="n_rounds"):
        round_engine.engine_multi_round(
            eng.spec, state, cfg=fcfg, loss_fn=eng.loss_fn,
            lambdas=eng.lambdas, corpus=corpus)


def test_device_plane_simulation_matches_host_plane_convergence():
    """fl_sim with data_plane="device" trains comparably to the host plane
    on the structured corpus — the statistical-equivalence contract (the
    jax-PRNG stream replaces numpy's, so curves match in distribution,
    not bit-for-bit)."""
    from benchmarks.common import classification_data
    from repro.core.fl_sim import SimConfig, run_simulation
    data = classification_data("mnist-like", 8, non_iid=True,
                               n_train=1500, n_test=400)
    kw = dict(method="favas", n_clients=8, s_selected=3, K=5,
              total_time=350.0, eval_every=350.0, batch_size=32, seed=0)
    res_h = run_simulation(SimConfig(**kw), data)
    res_d = run_simulation(SimConfig(data_plane="device", **kw), data)
    # both train away from chance (1/10) and land in the same band
    assert res_h["final_accuracy"] > 0.12
    assert res_d["final_accuracy"] > 0.12
    assert abs(res_d["final_accuracy"] - res_h["final_accuracy"]) < 0.25
