"""LUQ cold-codec property tests (core.paging + kernels.ops wrappers).

The paged engine's cold pools hold every client's progress as bit-packed
LUQ codes; this file pins the codec down:

* pack/unpack is a bijection for bits in {2, 4, 8};
* decode(encode(x)) equals ``kernels.ref.luq_ref`` element-for-element for
  the same uniforms — the codec is the code-emitting form of the one LUQ
  grid the repo already ships (kernel, oracle, and simulator paths), not a
  fourth quantizer;
* the round-trip error obeys the analytic LUQ bound
  ``|Q(x) - x| <= max(|x|, scale * 2^-(L-1))`` per element, for every bit
  width, over adversarial inputs: all-zero tiles (the PR 2 guarded-scale
  regression, extended from tests/test_tiled_kernel.py), denormal scales,
  and bf16 rows;
* the grid is unbiased in expectation (stochastic prune + stochastic
  exponent rounding), the property FAVAS[QNN]'s analysis needs (Remark 1);
* per-(row, shard) scales are shard-local maxima, and the pair codec
  (init + progress-vs-decoded-init) reconstructs within the composed bound;
* the shared scale guard (``kernels.luq.guard_scale``) maps zero to 1.0,
  passes positive/+Inf through, and PROPAGATES NaN — a poisoned row decodes
  loudly non-finite while its per-row scale isolates the finite neighbours;
* the code-emitting Pallas kernels (``kernels.luq``) are a bijection
  through the in-kernel pack/unpack and bit-identical to this oracle for
  bits x shards, including the {1, 8}-shard scale layouts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import paging
from repro.core.paging import (LuqCodec, PassthroughCodec, luq_decode_rows,
                               luq_encode_rows, pack_codes, unpack_codes)
from repro.kernels import ops, ref

BITS = [2, 4, 8]


def _levels(bits):
    return 2 ** (bits - 1) - 1


def _min_level(bits):
    return 2.0 ** (-(_levels(bits) - 1))


def _rows(kind, rows=5, D=256, seed=0):
    """Adversarial row families the codec must survive."""
    rng = np.random.default_rng(seed)
    if kind == "normal":
        x = rng.normal(size=(rows, D)).astype(np.float32)
    elif kind == "zero":
        x = np.zeros((rows, D), np.float32)
    elif kind == "zero_tile":
        # one all-zero row inside otherwise-normal rows: the per-row guarded
        # scale must isolate it (scale 1.0 -> exact zero decode)
        x = rng.normal(size=(rows, D)).astype(np.float32)
        x[rows // 2] = 0.0
    elif kind == "denormal":
        # scales below the f32 normal range: the grid divides by max|x|
        # and must stay finite
        x = (rng.normal(size=(rows, D)) * 1e-40).astype(np.float32)
    elif kind == "bf16":
        x = np.asarray(jnp.asarray(rng.normal(size=(rows, D)),
                                   jnp.bfloat16).astype(jnp.float32))
    else:
        raise ValueError(kind)
    return jnp.asarray(x)


# ---------------------------------------------------------------------------
# Packing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", BITS)
def test_pack_unpack_bijection(bits):
    rng = np.random.default_rng(bits)
    codes = jnp.asarray(rng.integers(0, 2 ** bits, size=(7, 256)), jnp.uint8)
    packed = pack_codes(codes, bits)
    assert packed.dtype == jnp.uint8
    assert packed.shape == (7, 256 * bits // 8)
    np.testing.assert_array_equal(np.asarray(unpack_codes(packed, bits)),
                                  np.asarray(codes))


def test_pack_rejects_indivisible_columns():
    with pytest.raises(ValueError):
        pack_codes(jnp.zeros((2, 7), jnp.uint8), 2)


# ---------------------------------------------------------------------------
# The codec IS the repo's LUQ grid (same uniforms -> same values as luq_ref)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("kind", ["normal", "zero_tile", "bf16"])
def test_codec_matches_luq_ref_same_uniforms(bits, kind):
    x = _rows(kind, seed=bits)
    key = jax.random.PRNGKey(bits * 11 + 1)
    enc = luq_encode_rows(x, bits, key)
    got = np.asarray(luq_decode_rows(enc, bits, jnp.float32))
    # re-draw the encoder's uniforms and push them through the oracle with
    # the codec's per-row scale
    k1, k2 = jax.random.split(key)
    up = jax.random.uniform(k1, x.shape)
    ur = jax.random.uniform(k2, x.shape)
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(scale > 0, scale, 1.0)
    want = np.asarray(ref.luq_ref(x, up, ur, scale, bits))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Round-trip error bound vs bits, over adversarial inputs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("kind", ["normal", "zero", "zero_tile", "denormal",
                                  "bf16"])
def test_roundtrip_error_bound(bits, kind):
    """|Q(x) - x| <= max(|x|, scale * min_level) per element: inside the
    grid the stochastic exponent rounding moves at most one octave
    (|q - m| <= 2^e <= m), below it the stochastic prune moves at most
    min_level. The slack factor covers f32 evaluation of the grid."""
    x = _rows(kind, seed=17 + bits)
    dec = np.asarray(luq_decode_rows(
        luq_encode_rows(x, bits, jax.random.PRNGKey(3 + bits)),
        bits, jnp.float32))
    assert np.all(np.isfinite(dec))
    xf = np.asarray(x, np.float32)
    scale = np.abs(xf).max(axis=1, keepdims=True)
    scale = np.where(scale > 0, scale, 1.0)
    bound = np.maximum(np.abs(xf), scale * _min_level(bits)) * (1 + 1e-5)
    assert np.all(np.abs(dec - xf) <= bound), \
        f"max excess {np.max(np.abs(dec - xf) - bound)}"
    if kind in ("zero", "zero_tile"):
        zero_rows = np.all(xf == 0, axis=1)
        np.testing.assert_array_equal(dec[zero_rows], 0.0)
    # representable magnitudes never vanish: pruning only happens BELOW the
    # smallest grid level. Not asserted for the denormal family: XLA's CPU
    # backend flushes denormal operands/results to zero (FTZ/DAZ), so the
    # compiled grid legitimately maps the whole row to zero there — which
    # the |x|-sided bound above already accepts.
    if kind != "denormal":
        big = np.abs(xf) >= scale * _min_level(bits)
        assert np.all(dec[big] != 0)


@pytest.mark.parametrize("bits", BITS)
def test_grid_is_unbiased(bits):
    """E[Q(x)] = x over the stochastic prune + exponent rounding: average
    many independent encodes of one row and check the error shrinks to well
    under a single-draw quantization step."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.uniform(-1.0, 1.0, size=(1, 256)), jnp.float32)
    reps = 512
    keys = jax.random.split(jax.random.PRNGKey(9), reps)
    dec = jax.vmap(lambda k: luq_decode_rows(
        luq_encode_rows(x, bits, k), bits, jnp.float32))(keys)
    mean = np.asarray(jnp.mean(dec, axis=0))[0]
    xf = np.asarray(x)[0]
    # single-draw error is O(|x|); the mean over 512 draws must be ~20x
    # smaller (CLT: sqrt(512) ~ 22.6) — loose enough to be deterministic
    # for this fixed seed, tight enough to catch any systematic bias. The
    # 2-bit grid is just {0, scale}: per-draw variance (and so the CLT
    # noise floor of the max over 256 elements) is several times larger
    tol = 0.09 if bits == 2 else 0.05
    assert np.max(np.abs(mean - xf)) < tol * np.max(np.abs(xf))


# ---------------------------------------------------------------------------
# Shard-local scales + the pair codec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shards", [1, 4])
def test_per_shard_scales_are_segment_maxima(shards):
    x = _rows("normal", rows=3, D=256, seed=2)
    enc = luq_encode_rows(x, 4, jax.random.PRNGKey(0), shards=shards)
    assert enc["scale"].shape == (3, shards)
    seg = np.asarray(x).reshape(3, shards, 256 // shards)
    np.testing.assert_allclose(np.asarray(enc["scale"]),
                               np.abs(seg).max(axis=2), rtol=0, atol=0)
    # packed codes keep the shard-major layout: bytes per shard divide evenly
    assert enc["codes"].shape == (3, 256 * 4 // 8)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_passthrough_pair_roundtrip_is_identity(dtype):
    cli = _rows("normal", seed=3).astype(dtype)
    ini = _rows("normal", seed=4).astype(dtype)
    codec = PassthroughCodec()
    enc = codec.encode_pair(cli, ini, jax.random.PRNGKey(0))
    dc, di = codec.decode_pair(enc, dtype)
    np.testing.assert_array_equal(
        np.asarray(dc, np.float32), np.asarray(cli, np.float32))
    np.testing.assert_array_equal(
        np.asarray(di, np.float32), np.asarray(ini, np.float32))


@pytest.mark.parametrize("bits", BITS)
def test_luq_pair_roundtrip_bound(bits):
    """The pair codec measures progress against the DECODED init, so the
    client reconstruction error is one progress-quantization error, not an
    init error compounded with a progress error."""
    ini = _rows("normal", seed=6)
    cli = ini + 0.01 * _rows("normal", seed=7)
    codec = LuqCodec(bits=bits)
    enc = codec.encode_pair(cli, ini, jax.random.PRNGKey(1))
    dc, di = codec.decode_pair(enc, jnp.float32)
    prog = np.asarray(cli, np.float32) - np.asarray(di, np.float32)
    pscale = np.abs(prog).max(axis=1, keepdims=True)
    pscale = np.where(pscale > 0, pscale, 1.0)
    bound = np.maximum(np.abs(prog), pscale * _min_level(bits)) * (1 + 1e-5)
    err = np.abs(np.asarray(dc) - np.asarray(di) - prog)
    assert np.all(err <= bound)


def test_luq_codec_validates_bits():
    with pytest.raises(ValueError):
        LuqCodec(bits=3)
    assert paging.make_codec(0) == PassthroughCodec()
    assert paging.make_codec(4) == LuqCodec(bits=4)


# ---------------------------------------------------------------------------
# The shared scale guard: zero -> 1.0, Inf passes, NaN propagates
# ---------------------------------------------------------------------------

def test_guard_scale_pins():
    from repro.kernels.luq import guard_scale
    s = np.asarray(guard_scale(jnp.asarray(
        [0.0, -0.0, 2.5, np.inf, np.nan], jnp.float32)))
    assert s[0] == 1.0 and s[1] == 1.0        # zero segments -> unit scale
    assert s[2] == 2.5                        # positive passes through
    assert np.isposinf(s[3])                  # +Inf passes through
    assert np.isnan(s[4])                     # NaN PROPAGATES, never 1.0


def test_nan_row_decodes_nonfinite_and_isolates_neighbours():
    """A row whose max is NaN must decode loudly non-finite (never silently
    quantize against scale 1.0), and the per-row scales must keep the
    finite rows bit-identical to an encoding without the poisoned row."""
    key = jax.random.PRNGKey(21)
    x = np.asarray(_rows("normal", rows=5, seed=20))
    xp = x.copy()
    xp[2, 7] = np.nan
    enc = luq_encode_rows(jnp.asarray(xp), 4, key)
    assert np.isnan(np.asarray(enc["scale"])[2, 0])
    dec = np.asarray(luq_decode_rows(enc, 4, jnp.float32))
    assert not np.any(np.isfinite(dec[2])), \
        "poisoned row decoded (partly) finite"
    # same uniforms, same finite rows: codes and decodes coincide
    enc_ok = luq_encode_rows(jnp.asarray(x), 4, key)
    dec_ok = np.asarray(luq_decode_rows(enc_ok, 4, jnp.float32))
    keep = [0, 1, 3, 4]
    np.testing.assert_array_equal(np.asarray(enc["codes"])[keep],
                                  np.asarray(enc_ok["codes"])[keep])
    np.testing.assert_array_equal(dec[keep], dec_ok[keep])


def test_inf_row_scale_passes_through():
    """An Inf max passes the guard unchanged: the row's decode is driven by
    the Inf scale (non-finite where codes are non-zero), and the finite
    rows again stay isolated by their own scales."""
    key = jax.random.PRNGKey(22)
    x = np.asarray(_rows("normal", rows=4, seed=23))
    xp = x.copy()
    xp[1, 0] = np.inf
    enc = luq_encode_rows(jnp.asarray(xp), 4, key)
    assert np.isposinf(np.asarray(enc["scale"])[1, 0])
    dec = np.asarray(luq_decode_rows(enc, 4, jnp.float32))
    assert not np.all(np.isfinite(dec[1]))
    enc_ok = luq_encode_rows(jnp.asarray(x), 4, key)
    keep = [0, 2, 3]
    np.testing.assert_array_equal(np.asarray(enc["codes"])[keep],
                                  np.asarray(enc_ok["codes"])[keep])


# ---------------------------------------------------------------------------
# Kernel-path codec: in-kernel pack/unpack bijection + oracle parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", BITS)
def test_kernel_pack_unpack_bijection(bits):
    from repro.kernels.luq import pack_block, unpack_block
    rng = np.random.default_rng(31 + bits)
    codes = jnp.asarray(rng.integers(0, 2 ** bits, size=(8, 512)), jnp.int32)
    packed = pack_block(codes, bits)
    assert packed.dtype == jnp.uint8
    assert packed.shape == (8, 512 * bits // 8)
    np.testing.assert_array_equal(np.asarray(unpack_block(packed, bits)),
                                  np.asarray(codes))
    # and the in-kernel layout IS the storage layout (core.paging)
    np.testing.assert_array_equal(
        np.asarray(packed),
        np.asarray(pack_codes(codes.astype(jnp.uint8), bits)))


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("shards", [1, 8])
def test_kernel_codec_bit_identical_to_oracle(bits, shards):
    """``luq_encode_pallas``/``luq_decode_pallas`` (interpret mode) against
    the jnp oracle under shared uniforms: identical packed codes, identical
    per-(row, shard) scales, identical decodes — at rows=11 the kernel's
    ENC_ROWS padding must not leak either."""
    from repro.kernels.luq import luq_decode_pallas, luq_encode_pallas
    rows, D = 11, 4096
    x = _rows("normal", rows=rows, D=D, seed=40 + bits)
    key = jax.random.PRNGKey(50 + bits + shards)
    k1, k2 = jax.random.split(key)
    up = jax.random.uniform(k1, (rows, D))
    ur = jax.random.uniform(k2, (rows, D))
    enc_k = luq_encode_pallas(x, up, ur, bits, shards=shards, interpret=True)
    enc_o = luq_encode_rows(x, bits, key, shards=shards)
    np.testing.assert_array_equal(np.asarray(enc_k["codes"]),
                                  np.asarray(enc_o["codes"]))
    np.testing.assert_array_equal(np.asarray(enc_k["scale"]),
                                  np.asarray(enc_o["scale"]))
    dec_k = luq_decode_pallas(enc_k, bits, jnp.float32, shards=shards,
                              interpret=True)
    dec_o = luq_decode_rows(enc_o, bits, jnp.float32, shards=shards)
    np.testing.assert_array_equal(np.asarray(dec_k), np.asarray(dec_o))


def test_ops_wrappers_are_the_codec_entry_points():
    """kernels.ops.cold_requant_rows / cold_dequant_rows are the dispatch
    points the paged engine uses; they must be the paging implementations
    exactly (same keys -> same codes)."""
    x = _rows("normal", seed=8)
    key = jax.random.PRNGKey(2)
    a = ops.cold_requant_rows(x, 4, key)
    b = luq_encode_rows(x, 4, key)
    np.testing.assert_array_equal(np.asarray(a["codes"]),
                                  np.asarray(b["codes"]))
    np.testing.assert_array_equal(
        np.asarray(ops.cold_dequant_rows(a, 4, jnp.float32)),
        np.asarray(luq_decode_rows(b, 4, jnp.float32)))
