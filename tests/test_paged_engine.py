"""Paged client state: the hot/cold residency layer (docs/architecture.md §9).

* **parity lattice** — with the passthrough codec at ``s_max == n`` the
  paged engine is BIT-EXACT with the dense engine: same states, same
  counters, same key chain, same (T,)-stacked metrics, across
  n in {7, 257} x {fp32, bf16} x {plain, quant_bits=4}, on both data
  planes (host batches and the resident device corpus). The paged body
  consumes ``k_sel`` before the gather instead of after local SGD, but the
  four-way split is unchanged, so the RNG streams coincide; at s_max == n
  the hot stacks use the dense row layout and padded shape, so every fp32
  reduction tree coincides too. (The forced-8-device mesh variant lives in
  tests/test_sharded_engine.py.)
* **residency invariants at s_max < n** — cold clients are FROZEN: their
  counters and cold-pool bytes do not move until promotion; every selected
  client is hot; hot_ids stay sorted/unique; evict -> promote under the
  passthrough codec is the identity.
* **metrics guard** — loss is live-step-weighted over the SELECTED HOT SET
  only, and ``engine_variance`` sums over hot rows only: a client at the
  counter cap contributes zero weight (not a dragged-down mean), and a
  round where nobody steps yields 0.0, not NaN — the zero-live-step
  masking regression.
* **checkpointing** — ``save_engine_checkpoint`` / ``load_engine_checkpoint``
  round-trip a paged EngineState (hot stacks, cold pools, hot_ids, rng key
  chain) to bit-equality, and refuse dtype-mismatched restores.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import load_engine_checkpoint, save_engine_checkpoint
from repro.core import round_engine
from repro.core.favas import FavasConfig, client_lambdas
from repro.data.device_corpus import make_classification_corpus
from repro.models.classifier import classifier_loss, mlp_apply, mlp_init


def _params(dtype):
    """Tiny mixed-bucket pytree (one leaf stays f32 when dtype is bf16)."""
    w = jnp.asarray(np.linspace(-1.0, 1.0, 48).reshape(8, 6), dtype)
    b = jnp.asarray(np.linspace(0.5, 1.5, 5), jnp.float32)
    return {"w": w, "b": b}


def _loss(p, batch):
    return sum(jnp.mean((l.astype(jnp.float32) - batch["t"]) ** 2)
               for l in jax.tree_util.tree_leaves(p))


def _batches(fcfg, T, seed=0):
    vals = np.linspace(0.0, 1.0, T * fcfg.n_clients * fcfg.R) + 0.01 * seed
    return {"t": jnp.asarray(vals.reshape(T, fcfg.n_clients, fcfg.R),
                             jnp.float32)}


def _engine(dtype, quant_bits=0, n=5, **paging):
    params = _params(dtype)
    fcfg = FavasConfig(n_clients=n, s_selected=2, local_steps=2, eta=0.1,
                      quant_bits=quant_bits)
    eng = round_engine.RoundEngine(
        params, fcfg, _loss, lambdas=jnp.asarray(client_lambdas(fcfg)),
        **paging)
    return eng, fcfg, params


def _assert_states_equal(a, b):
    for x, y in zip(a.server + a.clients + a.inits,
                    b.server + b.clients + b.inits):
        assert x.dtype == y.dtype and x.shape == y.shape
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
    np.testing.assert_array_equal(np.asarray(a.counters), np.asarray(b.counters))
    np.testing.assert_array_equal(np.asarray(a.stale), np.asarray(b.stale))
    np.testing.assert_array_equal(np.asarray(a.key), np.asarray(b.key))
    assert int(a.t) == int(b.t)


# ---------------------------------------------------------------------------
# Parity lattice: paged(passthrough, s_max == n) == dense, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["fp32", "bf16"])
@pytest.mark.parametrize("n", [7, 257])
def test_paged_passthrough_bit_exact_vs_dense(n, dtype):
    T = 5 if n == 7 else 3
    dense, fcfg, params = _engine(dtype, n=n)
    paged, _, _ = _engine(dtype, n=n, residency="paged")   # s_max -> n
    assert paged.spec.paged and paged.spec.s_max == n
    key = jax.random.PRNGKey(3)
    sd = dense.init_state(params, key)
    sp = paged.init_state(params, key)
    batches = _batches(fcfg, T)
    sd, md = dense.run(sd, batches, n_rounds=T)
    sp, mp = paged.run(sp, batches, n_rounds=T)
    _assert_states_equal(sd, sp)
    np.testing.assert_array_equal(np.asarray(sp.hot_ids), np.arange(n))
    for k in ("loss", "mean_steps", "selected", "stale_rounds"):
        np.testing.assert_array_equal(np.asarray(md[k]), np.asarray(mp[k]),
                                      err_msg=k)
    # variance agrees too: at s_max == n the hot set is everyone
    np.testing.assert_array_equal(np.asarray(dense.variance(sd)),
                                  np.asarray(paged.variance(sp)))


def test_paged_quant4_bit_exact_vs_dense():
    """FAVAS[QNN] transmitted-progress quantization composes with paging:
    the hot-space k_q is the dense k_q (codec keys are FOLDED off it, never
    split), so the quantized engines agree bit-for-bit as well."""
    T = 7
    dense, fcfg, params = _engine(jnp.float32, quant_bits=4, n=7)
    paged, _, _ = _engine(jnp.float32, quant_bits=4, n=7, residency="paged")
    key = jax.random.PRNGKey(5)
    sd, md = dense.run(dense.init_state(params, key), _batches(fcfg, T))
    sp, mp = paged.run(paged.init_state(params, key), _batches(fcfg, T))
    _assert_states_equal(sd, sp)
    np.testing.assert_array_equal(np.asarray(md["loss"]),
                                  np.asarray(mp["loss"]))


def test_paged_sequential_matches_superstep():
    """The paged round scans: run(T) == T step() calls (the superstep
    contract of §7 extends to the paged body — the carried hot_ids and cold
    pools ride the scan carry)."""
    T = 6
    eng, fcfg, params = _engine(jnp.float32, n=5, residency="paged")
    key = jax.random.PRNGKey(1)
    s_seq = eng.init_state(params, key)
    s_sup = eng.init_state(params, key)
    batches = _batches(fcfg, T)
    for t in range(T):
        s_seq, _ = eng.step(
            s_seq, jax.tree_util.tree_map(lambda x: x[t], batches))
    s_sup, _ = eng.run(s_sup, batches)
    _assert_states_equal(s_seq, s_sup)
    np.testing.assert_array_equal(np.asarray(s_seq.hot_ids),
                                  np.asarray(s_sup.hot_ids))
    for a, b in zip(jax.tree_util.tree_leaves(s_seq.cold),
                    jax.tree_util.tree_leaves(s_sup.cold)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_paged_device_plane_bit_exact_vs_dense():
    """Device data plane: the paged scan body gathers corpus rows for the
    hot working set only, but the index/uniform draws run at full n off the
    same batch key — at s_max == n the gathered batch IS the dense batch."""
    n, T = 6, 9
    rng = np.random.default_rng(5)
    x = rng.normal(0, 1, (120, 4)).astype(np.float32)
    y = rng.integers(0, 3, 120).astype(np.int32)
    parts = np.array_split(rng.permutation(120), n)
    corpus = make_classification_corpus(x, y, parts, batch=3)
    params = mlp_init(jax.random.PRNGKey(0), 4, 8, 3)
    fcfg = FavasConfig(n_clients=n, s_selected=2, local_steps=2, eta=0.1)

    def lfn(p, b):
        return classifier_loss(p, mlp_apply, b["x"], b["y"], 3)

    lam = jnp.asarray(client_lambdas(fcfg))
    dense = round_engine.RoundEngine(params, fcfg, lfn, lambdas=lam)
    paged = round_engine.RoundEngine(params, fcfg, lfn, lambdas=lam,
                                     residency="paged")
    key = jax.random.PRNGKey(7)
    sd, md = dense.run_device(dense.init_state(params, key), corpus, T)
    sp, mp = paged.run_device(paged.init_state(params, key), corpus, T)
    _assert_states_equal(sd, sp)
    np.testing.assert_array_equal(np.asarray(md["loss"]),
                                  np.asarray(mp["loss"]))


# ---------------------------------------------------------------------------
# Residency invariants at s_max < n
# ---------------------------------------------------------------------------

def test_paged_cold_clients_are_frozen():
    """One step from init at s_max < n: every selected client is hot, cold
    clients' counters do not move, and clients that have never been hot
    still hold their initial cold encoding (the server row, verbatim under
    the passthrough codec)."""
    n, s_max = 11, 4
    eng, fcfg, params = _engine(jnp.float32, n=n, residency="paged",
                                s_max=s_max)
    state = eng.init_state(params, jax.random.PRNGKey(2))
    counters0 = np.asarray(state.counters).copy()
    cold0 = jax.tree_util.tree_map(lambda x: np.asarray(x).copy(), state.cold)
    batches = _batches(fcfg, 1)
    state, m = eng.step(
        state, jax.tree_util.tree_map(lambda x: x[0], batches))
    hot = np.asarray(state.hot_ids)
    assert hot.shape == (s_max,)
    assert np.all(np.diff(hot) > 0), "hot_ids must stay sorted and unique"
    # selected clients have staleness 0 -> they always make the working set
    stale = np.asarray(state.stale)
    selected = np.where(stale == 0)[0]
    assert float(m["selected"]) == fcfg.s_selected
    assert set(selected.tolist()) <= set(hot.tolist())
    # frozen cold clients: counters untouched
    cold_ids = np.setdiff1d(np.arange(n), hot)
    np.testing.assert_array_equal(np.asarray(state.counters)[cold_ids],
                                  counters0[cold_ids])
    # never-hot clients (outside the initial working set AND the new one)
    # still hold their init encoding, byte for byte
    never_hot = np.setdiff1d(cold_ids, np.arange(s_max))
    assert never_hot.size > 0
    for b0, b1 in zip(jax.tree_util.tree_leaves(cold0),
                      jax.tree_util.tree_leaves(state.cold)):
        np.testing.assert_array_equal(np.asarray(b1)[never_hot],
                                      b0[never_hot])


def test_paged_evict_promote_roundtrip_is_identity():
    """Under the passthrough codec the evict scatter parks a client's rows
    byte-for-byte: whenever id 0 leaves the hot set, its cold-pool entry
    equals the hot buffers it left with, and the entry does not move for
    as long as it stays cold (promotion is a pure gather of those bytes —
    the s_max == n parity lattice pins the gather side)."""
    n, s_max, T = 9, 3, 30
    eng, fcfg, params = _engine(jnp.float32, n=n, residency="paged",
                                s_max=s_max)
    state = eng.init_state(params, jax.random.PRNGKey(4))
    batches = _batches(fcfg, T)
    snapshot, was_member = None, True
    seen_evict = seen_frozen = False
    for t in range(T):
        # copy BEFORE step: the jitted round donates the previous state
        prev_hot = np.asarray(state.hot_ids).tolist()
        prev_cli = [np.asarray(c).copy() for c in state.clients]
        prev_ini = [np.asarray(c).copy() for c in state.inits]
        state, _ = eng.step(
            state, jax.tree_util.tree_map(lambda x: x[t], batches))
        hot = np.asarray(state.hot_ids).tolist()
        if was_member and 0 not in hot:
            # id 0 was just evicted: the scatter wrote its round-start rows
            pos = prev_hot.index(0)
            snapshot = [(c[pos], i[pos]) for c, i in zip(prev_cli, prev_ini)]
            seen_evict = True
        if 0 not in hot and snapshot is not None:
            # frozen while cold: the entry equals the eviction snapshot
            for bucket, (cs, inis) in zip(state.cold, snapshot):
                np.testing.assert_array_equal(np.asarray(bucket["cli"])[0], cs)
                np.testing.assert_array_equal(np.asarray(bucket["init"])[0],
                                              inis)
            seen_frozen = True
        if 0 in hot:
            snapshot = None
        was_member = 0 in hot
    assert seen_evict and seen_frozen, (
        "client 0 never went cold in 30 rounds (selection rng drifted? "
        "lower s_max or raise T)")


def test_paged_resident_bytes_below_dense():
    """The point of the layer: at 4-bit cold pools the paged state is
    strictly smaller than the dense state, even counting the hot stacks
    and the bookkeeping vectors."""
    n, s_max = 64, 8
    dense, _, params = _engine(jnp.float32, n=n)
    paged, _, _ = _engine(jnp.float32, n=n, residency="paged",
                          s_max=s_max, cold_bits=4)
    key = jax.random.PRNGKey(0)
    db = dense.resident_bytes(dense.init_state(params, key))
    pb = paged.resident_bytes(paged.init_state(params, key))
    assert pb < db, f"paged {pb} B >= dense {db} B"


def test_paged_runs_with_luq_cold_pool():
    """s_max < n with a real LUQ cold codec: the engine runs end to end,
    hot membership evolves, everything stays finite."""
    n, s_max, T = 10, 4, 12
    eng, fcfg, params = _engine(jnp.float32, n=n, residency="paged",
                                s_max=s_max, cold_bits=4)
    state = eng.init_state(params, jax.random.PRNGKey(6))
    state, ms = eng.run(state, _batches(fcfg, T))
    assert np.all(np.isfinite(np.asarray(ms["loss"])))
    for c in state.clients:
        assert np.all(np.isfinite(np.asarray(c, np.float32)))
    assert state.cold[0]["init"]["codes"].dtype == jnp.uint8


def test_paged_rejects_selection_larger_than_hot_set():
    eng, fcfg, params = _engine(jnp.float32, n=8, residency="paged", s_max=1)
    with pytest.raises(ValueError, match="s_max"):
        eng.init_state(params, jax.random.PRNGKey(0))


def test_paged_superstep_donates_state():
    eng, fcfg, params = _engine(jnp.float32, n=5, residency="paged")
    state = eng.init_state(params, jax.random.PRNGKey(0))
    prev = state
    state, m = eng.run(state, _batches(fcfg, 4))
    del m
    assert prev.server[0].is_deleted(), "paged superstep must donate"
    assert prev.cold[0]["cli"].is_deleted(), "cold pools must be donated too"


# ---------------------------------------------------------------------------
# Metrics guard: live-step weighting over the selected hot set
# ---------------------------------------------------------------------------

def test_loss_is_live_step_weighted_over_hot_set():
    """Regression for the zero-live-step masking bug, at the paging layer:
    with a constant per-step loss of 1.0, the weighted metric must be
    EXACTLY 1.0 whenever any hot client steps — an implementation that
    averages over all hot clients (counting capped, zero-live clients)
    would report < 1.0; one that divides by zero would report NaN."""
    n = 5

    def unit_loss(p, batch):
        # constant loss with zero gradient: every live step contributes 1.0
        del batch
        return 1.0 + 0.0 * sum(jnp.sum(l.astype(jnp.float32))
                               for l in jax.tree_util.tree_leaves(p))

    params = _params(jnp.float32)
    fcfg = FavasConfig(n_clients=n, s_selected=2, local_steps=3, eta=0.1)
    eng = round_engine.RoundEngine(
        params, fcfg, unit_loss,
        lambdas=jnp.full((n,), 10.0, jnp.float32),   # everyone steps
        residency="paged")
    state = eng.init_state(params, jax.random.PRNGKey(0))
    # cap one client's counter at K: it runs ZERO live steps this round
    state = dataclasses.replace(
        state, counters=state.counters.at[1].set(fcfg.local_steps))
    batch = jax.tree_util.tree_map(lambda x: x[0], _batches(fcfg, 1))
    state, m = eng.step(state, batch)
    np.testing.assert_allclose(float(m["loss"]), 1.0, rtol=1e-6)
    # everyone capped -> zero live steps in the whole round: 0.0, never NaN
    state = dataclasses.replace(
        state, counters=jnp.full((n,), fcfg.local_steps, jnp.int32))
    state, m = eng.step(state, batch)
    assert float(m["loss"]) == 0.0 and np.isfinite(float(m["loss"]))


def test_engine_variance_sums_hot_rows_only():
    """engine_variance on a paged state charges the HOT working set only;
    decoding frozen cold clients into a live-progress metric would be the
    variance-level version of the masking bug."""
    n, s_max = 9, 3
    eng, fcfg, params = _engine(jnp.float32, n=n, residency="paged",
                                s_max=s_max)
    state = eng.init_state(params, jax.random.PRNGKey(3))
    state, _ = eng.run(state, _batches(fcfg, 6))
    want = 0.0
    for srv, cli in zip(state.server, state.clients):
        diff = (np.asarray(cli, np.float32)[:s_max]
                - np.asarray(srv, np.float32)[None])
        want += float(np.sum(diff ** 2))
    np.testing.assert_allclose(float(eng.variance(state)), want, rtol=1e-6)
    # dense states still sum over the full logical population
    dense, _, _ = _engine(jnp.float32, n=n)
    sd = dense.init_state(params, jax.random.PRNGKey(3))
    sd, _ = dense.run(sd, _batches(fcfg, 6))
    wd = 0.0
    for srv, cli in zip(sd.server, sd.clients):
        diff = (np.asarray(cli, np.float32)[:n]
                - np.asarray(srv, np.float32)[None])
        wd += float(np.sum(diff ** 2))
    np.testing.assert_allclose(float(dense.variance(sd)), wd, rtol=1e-6)


# ---------------------------------------------------------------------------
# Checkpointing: EngineState round-trips to bit-equality
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cold_bits", [0, 4], ids=["passthrough", "luq4"])
def test_engine_checkpoint_roundtrip_paged(tmp_path, cold_bits):
    """save -> load restores EVERY leaf of a trained paged state to
    bit-equality: hot stacks, counters, staleness, the rng key chain,
    hot_ids, and the cold pools (packed uint8 codes + scales)."""
    eng, fcfg, params = _engine(jnp.float32, n=7, residency="paged",
                                s_max=3, cold_bits=cold_bits)
    state = eng.init_state(params, jax.random.PRNGKey(9))
    state, _ = eng.run(state, _batches(fcfg, 5))
    path = save_engine_checkpoint(str(tmp_path), 5, state)
    restored = load_engine_checkpoint(path, state)
    la = jax.tree_util.tree_leaves(state)
    lb = jax.tree_util.tree_leaves(restored)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # the restored state is live: the engine keeps running from it
    restored, ms = eng.run(restored, _batches(fcfg, 2, seed=1))
    assert np.all(np.isfinite(np.asarray(ms["loss"])))


def test_engine_checkpoint_roundtrip_dense_bf16(tmp_path):
    """bf16 hot buffers widen losslessly to f32 on disk and narrow back on
    restore (exact: widening bf16 -> f32 is injective)."""
    eng, fcfg, params = _engine(jnp.bfloat16, n=5)
    state = eng.init_state(params, jax.random.PRNGKey(1))
    state, _ = eng.run(state, _batches(fcfg, 3))
    path = save_engine_checkpoint(str(tmp_path), 3, state)
    restored = load_engine_checkpoint(path, state)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_engine_checkpoint_refuses_dtype_mismatch(tmp_path):
    """Restoring into a template with a different leaf dtype raises instead
    of silently casting (the recorded-dtype guard). Same tree STRUCTURE,
    one leaf dtype changed — an engine-layout change, not a missing key."""
    eng, fcfg, params = _engine(jnp.float32, n=5, residency="paged")
    state = eng.init_state(params, jax.random.PRNGKey(0))
    path = save_engine_checkpoint(str(tmp_path), 0, state)
    template = dataclasses.replace(state,
                                   stale=state.stale.astype(jnp.int16))
    with pytest.raises(ValueError, match="dtype"):
        load_engine_checkpoint(path, template)
