"""Flat-buffer round engine tests:

* parity of the multi-output fused Pallas kernel (interpret mode) against
  the jnp oracle — bit-for-bit in fp32 for all three outputs across odd D
  (padding path), n in {1, 4, 64}, and bf16 params;
* flatten/unflatten round-trips (mixed-dtype buckets included);
* regression: ``favas_round`` on the engine reproduces the seed's per-leaf
  tree_map implementation (``favas_round_reference``) exactly.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import (FavasConfig, favas_init, favas_round,
                        favas_round_reference, client_lambdas)
from repro.core import round_engine
from repro.kernels import ref
from repro.kernels.favas_agg import favas_fused_pallas
from repro.models.model import init_params, loss_fn
from repro.utils.tree import tree_map, tree_sq_dist


def _fused_inputs(n, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    server = jax.random.normal(ks[0], (D,), dtype)
    clients = jax.random.normal(ks[1], (n, D), dtype)
    inits = jax.random.normal(ks[2], (n, D), dtype)
    alpha = jax.random.uniform(ks[3], (n,), minval=1.0, maxval=8.0)
    mask = (jax.random.uniform(ks[4], (n,)) > 0.5).astype(jnp.float32)
    return server, clients, inits, alpha, mask, float(mask.sum())


@pytest.mark.parametrize("n", [1, 4, 64])
@pytest.mark.parametrize("D", [17, 1000, 2048, 4097])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_kernel_matches_oracle(n, D, dtype):
    args = _fused_inputs(n, D, dtype, seed=n * 1000 + D)
    got = favas_fused_pallas(*args, interpret=True)
    want = ref.favas_fused_ref(*args)
    for name, g, w in zip(("server", "clients", "inits"), got, want):
        assert g.dtype == w.dtype and g.shape == w.shape
        g32 = np.asarray(g, np.float32)
        w32 = np.asarray(w, np.float32)
        # the kernel body and the oracle are the same jnp expressions, but
        # XLA compiles them separately (FMA contraction, blocked n-row
        # reductions), so "bit-for-bit" holds only up to 1 fp32 ULP
        tol = dict(rtol=2e-7, atol=2e-7) if dtype == jnp.float32 else \
            dict(rtol=8e-3, atol=8e-3)
        np.testing.assert_allclose(g32, w32, err_msg=name, **tol)


def test_fused_kernel_zero_selection():
    """s = 0 (no client selected): server' = server / 1, clients untouched."""
    n, D = 4, 300
    server, clients, inits, alpha, _, _ = _fused_inputs(n, D, jnp.float32, 3)
    mask = jnp.zeros((n,), jnp.float32)
    srv, cli, ini = favas_fused_pallas(server, clients, inits, alpha, mask,
                                       0.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(srv), np.asarray(server))
    np.testing.assert_array_equal(np.asarray(cli), np.asarray(clients))
    np.testing.assert_array_equal(np.asarray(ini), np.asarray(inits))


def test_flat_spec_roundtrip_mixed_dtypes():
    tree = {
        "w": jnp.arange(7 * 5, dtype=jnp.float32).reshape(7, 5),
        "b": jnp.ones((13,), jnp.bfloat16),
        "scale": jnp.full((3, 2, 2), 2.5, jnp.float32),
    }
    spec = round_engine.make_flat_spec(tree)
    assert spec.n_buckets == 2
    assert all(p % round_engine.TILE == 0 for p in spec.bucket_padded)
    bufs = round_engine.flatten_tree(spec, tree)
    back = round_engine.unflatten_tree(spec, bufs)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # stacked round-trip
    n = 3
    stacked = tree_map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree)
    sbufs = round_engine.flatten_stacked(spec, stacked)
    sback = round_engine.unflatten_stacked(spec, sbufs)
    for a, b in zip(jax.tree_util.tree_leaves(stacked),
                    jax.tree_util.tree_leaves(sback)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_flat_spec_sharded_layout_roundtrip():
    """The shard-major (dtype, sharding group) bucket layout is pure
    metadata — flatten/unflatten must be bit-exact with explicit shard axes
    and no mesh, including the stacked (client-padded) path."""
    tree = {
        "wq": {"w": jnp.arange(6 * 16, dtype=jnp.float32).reshape(6, 16)},
        "wo": {"w": jnp.arange(16 * 5, dtype=jnp.float32).reshape(16, 5) * .5},
        "scale": jnp.arange(7, dtype=jnp.float32),
        "bf": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
    }
    # leaves (sorted keys): bf, scale, wo/w (row-sharded), wq/w (col-sharded)
    spec = round_engine.make_flat_spec(tree, tile=8, n_clients=5,
                                       client_tile=4, shard_axes=[None, None, 0, 1],
                                       model_shards=4)
    assert spec.bucket_shards == (1, 1, 4)
    assert all(p == spec.shards(b) * spec.bucket_shard_padded[b]
               for b, p in enumerate(spec.bucket_padded))
    back = round_engine.unflatten_tree(spec, round_engine.flatten_tree(spec, tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    n = 5
    stacked = tree_map(lambda x: jnp.stack([x * (i + 1) for i in range(n)]), tree)
    sback = round_engine.unflatten_stacked(
        spec, round_engine.flatten_stacked(spec, stacked))
    for a, b in zip(jax.tree_util.tree_leaves(stacked),
                    jax.tree_util.tree_leaves(sback)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def _setup(n=4, s=2, K=4, **fkw):
    cfg = get_reduced_config("qwen3-4b")
    fcfg = FavasConfig(n_clients=n, s_selected=s, local_steps=K, eta=0.05,
                       seed=0, **fkw)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    state = favas_init(params, fcfg, key)
    lambdas = jnp.asarray(client_lambdas(fcfg))

    def lfn(p, b):
        return loss_fn(p, cfg, b)
    return cfg, fcfg, state, lfn, lambdas


def test_favas_round_matches_reference_impl():
    """The engine-backed favas_round must reproduce the seed's per-leaf
    tree_map implementation: same PRNG stream, same arithmetic — the server
    update (and the client/init resets) agree exactly in fp32."""
    cfg, fcfg, state, lfn, lambdas = _setup()
    step_new = jax.jit(functools.partial(favas_round, cfg=fcfg, loss_fn=lfn,
                                         lambdas=lambdas))
    step_ref = jax.jit(functools.partial(favas_round_reference, cfg=fcfg,
                                         loss_fn=lfn, lambdas=lambdas))
    rng = np.random.default_rng(0)
    s_new, s_ref = state, state
    for _ in range(3):
        toks = rng.integers(0, cfg.vocab_size_raw,
                            (fcfg.n_clients, fcfg.R, 2, 32)).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks)}
        s_new, m_new = step_new(s_new, batch)
        s_ref, m_ref = step_ref(s_ref, batch)
        assert float(tree_sq_dist(s_new.server, s_ref.server)) == 0.0
        assert float(tree_sq_dist(s_new.clients, s_ref.clients)) == 0.0
        assert float(tree_sq_dist(s_new.inits, s_ref.inits)) == 0.0
        np.testing.assert_array_equal(np.asarray(s_new.counters),
                                      np.asarray(s_ref.counters))
        assert float(m_new["loss"]) == float(m_ref["loss"])
        assert float(m_new["stale_rounds"]) == float(m_ref["stale_rounds"])


def test_favas_round_matches_reference_impl_quantized():
    """FAVAS[QNN]: quantization is communication-only — the engine must
    quantize the transmitted progress with the seed's per-leaf keys/scales
    while unselected clients keep full-precision local state, reproducing
    the reference exactly."""
    cfg, fcfg, state, lfn, lambdas = _setup(K=2, quant_bits=4)
    step_new = jax.jit(functools.partial(favas_round, cfg=fcfg, loss_fn=lfn,
                                         lambdas=lambdas))
    step_ref = jax.jit(functools.partial(favas_round_reference, cfg=fcfg,
                                         loss_fn=lfn, lambdas=lambdas))
    rng = np.random.default_rng(5)
    s_new, s_ref = state, state
    for _ in range(2):
        toks = rng.integers(0, cfg.vocab_size_raw,
                            (fcfg.n_clients, fcfg.R, 2, 16)).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks)}
        s_new, _ = step_new(s_new, batch)
        s_ref, _ = step_ref(s_ref, batch)
        assert float(tree_sq_dist(s_new.server, s_ref.server)) == 0.0
        assert float(tree_sq_dist(s_new.clients, s_ref.clients)) == 0.0
        assert float(tree_sq_dist(s_new.inits, s_ref.inits)) == 0.0


def test_fused_kernel_explicit_progress_matches_oracle():
    """The QNN kernel variant (explicit progress operand) matches the
    oracle, and the reset outputs keep full-precision clients."""
    n, D = 4, 3001
    server, clients, inits, alpha, mask, s = _fused_inputs(n, D, jnp.float32, 9)
    prog = jax.random.normal(jax.random.PRNGKey(10), (n, D))
    got = favas_fused_pallas(server, clients, inits, alpha, mask, s,
                             progress=prog, interpret=True)
    want = ref.favas_fused_ref(server, clients, inits, alpha, mask, s,
                               progress=prog)
    for name, g, w in zip(("server", "clients", "inits"), got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-7, atol=2e-7, err_msg=name)
    # unselected rows of clients_new must be the original full-precision
    # clients, untouched by the progress operand
    unsel = np.asarray(mask) == 0.0
    np.testing.assert_array_equal(np.asarray(got[1])[unsel],
                                  np.asarray(clients)[unsel])


def test_favas_round_forced_kernel_path():
    """use_kernel=True (interpret on CPU) stays numerically close to the
    oracle path through a full round on a real model."""
    cfg, fcfg, state, lfn, lambdas = _setup(K=2)
    step_o = jax.jit(functools.partial(favas_round, cfg=fcfg, loss_fn=lfn,
                                       lambdas=lambdas, use_kernel=False))
    step_k = jax.jit(functools.partial(favas_round, cfg=fcfg, loss_fn=lfn,
                                       lambdas=lambdas, use_kernel=True))
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab_size_raw,
                        (fcfg.n_clients, fcfg.R, 2, 16)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks)}
    s_o, _ = step_o(state, batch)
    s_k, _ = step_k(state, batch)
    assert float(tree_sq_dist(s_o.server, s_k.server)) < 1e-10


def test_engine_state_held_across_rounds():
    """RoundEngine: flat buffers persist, donation works, metrics flow, and
    the exported server pytree matches the buffers."""
    cfg, fcfg, state, lfn, lambdas = _setup(K=2)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    eng = round_engine.RoundEngine(params, fcfg, lfn, lambdas=lambdas)
    est = eng.init_state(params, key)
    rng = np.random.default_rng(2)
    for t in range(2):
        toks = rng.integers(0, cfg.vocab_size_raw,
                            (fcfg.n_clients, fcfg.R, 2, 16)).astype(np.int32)
        est, m = eng.step(est, {"tokens": jnp.asarray(toks)})
        assert np.isfinite(float(m["loss"]))
        assert int(est.t) == t + 1
    out = eng.server_params(est)
    flat_again = round_engine.flatten_tree(eng.spec, out)
    for a, b in zip(flat_again, est.server):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.isfinite(float(eng.variance(est)))
