"""End-to-end behaviour tests for the FAVAS system."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import (FavasConfig, favas_init, favas_round, favas_variance,
                        favas_mu, client_lambdas, deterministic_alphas)
from repro.data import make_lm_corpus
from repro.data.pipeline import lm_round_batch
from repro.models.model import init_params, loss_fn
from repro.utils.tree import tree_map, tree_sq_dist


def _setup(arch="qwen3-4b", n=4, s=2, K=4, eta=0.05, seed=0, **fkw):
    cfg = get_reduced_config(arch)
    fcfg = FavasConfig(n_clients=n, s_selected=s, local_steps=K, eta=eta,
                       seed=seed, **fkw)
    key = jax.random.PRNGKey(seed)
    params = init_params(key, cfg)
    state = favas_init(params, fcfg, key)
    lambdas = jnp.asarray(client_lambdas(fcfg))

    def lfn(p, b):
        return loss_fn(p, cfg, b)
    step = jax.jit(functools.partial(favas_round, cfg=fcfg, loss_fn=lfn,
                                     lambdas=lambdas))
    return cfg, fcfg, state, step


@functools.lru_cache(maxsize=None)
def _corpus(vocab, n_domains):
    return make_lm_corpus(vocab, 60_000, n_domains=n_domains, seed=0)


def _batch(cfg, fcfg, rng, B=2, S=32):
    # The trainer's structured corpus, NOT uniform random tokens: uniform
    # tokens have entropy log(V) = 6.24 nats, so no amount of training can
    # reduce the loss below that — the seed test only ever "passed" because
    # idle clients' zero contributions dragged the old loss metric down.
    tokens, domains = _corpus(cfg.vocab_size_raw, fcfg.n_clients)
    toks = lm_round_batch(tokens, domains, fcfg.n_clients, fcfg.R, B, S, rng)
    return {"tokens": jnp.asarray(toks)}


def test_favas_training_reduces_loss():
    cfg, fcfg, state, step = _setup()
    rng = np.random.default_rng(0)
    losses, stales = [], []
    for _ in range(12):
        state, m = step(state, _batch(cfg, fcfg, rng))
        losses.append(float(m["loss"]))
        stales.append(float(m["stale_rounds"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-4:]) < np.mean(losses[:4]) - 0.3
    # the live-step-weighted loss must not re-spike to init level (log V)
    init_level = float(np.log(cfg.vocab_size_raw))
    assert max(losses[4:]) < init_level - 0.1, losses
    assert max(stales) <= 2 * fcfg.n_clients, stales


def test_favas_round_counters_and_selection():
    cfg, fcfg, state, step = _setup(n=6, s=3)
    rng = np.random.default_rng(1)
    for _ in range(5):
        state, m = step(state, _batch(cfg, fcfg, rng))
        assert float(m["selected"]) == 3
        q = np.asarray(state.counters)
        assert q.min() >= 0 and q.max() <= fcfg.local_steps


def test_selected_clients_reset_to_server():
    """After a round, every client is either at the new server model (just
    selected, counter 0) or has nonzero counter."""
    cfg, fcfg, state, step = _setup(n=4, s=2)
    rng = np.random.default_rng(2)
    state, _ = step(state, _batch(cfg, fcfg, rng))
    q = np.asarray(state.counters)
    for i in range(fcfg.n_clients):
        ci = tree_map(lambda x: x[i], state.clients)
        d = float(tree_sq_dist(ci, state.server))
        if q[i] == 0:
            assert d < 1e-6, f"selected client {i} not reset (d={d})"
        else:
            assert d > 0.0


def test_variance_and_mu_finite():
    cfg, fcfg, state, step = _setup()
    rng = np.random.default_rng(3)
    for _ in range(3):
        state, _ = step(state, _batch(cfg, fcfg, rng))
    assert np.isfinite(float(favas_variance(state)))
    mu = favas_mu(state)
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree_util.tree_leaves(mu))


def test_deterministic_reweight_round():
    cfg, fcfg, state, _ = _setup(reweight="deterministic")
    det = jnp.asarray(deterministic_alphas(fcfg))
    lambdas = jnp.asarray(client_lambdas(fcfg))

    def lfn(p, b):
        return loss_fn(p, cfg, b)
    step = jax.jit(functools.partial(favas_round, cfg=fcfg, loss_fn=lfn,
                                     lambdas=lambdas, det_alpha=det))
    rng = np.random.default_rng(4)
    state, m = step(state, _batch(cfg, fcfg, rng))
    assert np.isfinite(float(m["loss"]))


def test_quantized_round_runs():
    cfg, fcfg, state, step = _setup(quant_bits=4)
    rng = np.random.default_rng(5)
    state, m = step(state, _batch(cfg, fcfg, rng))
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(favas_variance(state)))


def test_rounds_are_reproducible():
    cfg, fcfg, s1, step = _setup(seed=7)
    _, _, s2, _ = _setup(seed=7)
    rng1, rng2 = np.random.default_rng(9), np.random.default_rng(9)
    s1, m1 = step(s1, _batch(cfg, fcfg, rng1))
    s2, m2 = step(s2, _batch(cfg, fcfg, rng2))
    assert float(m1["loss"]) == float(m2["loss"])
    assert float(tree_sq_dist(s1.server, s2.server)) == 0.0
