"""End-to-end behaviour tests for the FAVAS system."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import (FavasConfig, favas_init, favas_round, favas_variance,
                        favas_mu, client_lambdas, deterministic_alphas)
from repro.models.model import init_params, loss_fn
from repro.utils.tree import tree_map, tree_sq_dist


def _setup(arch="qwen3-4b", n=4, s=2, K=4, eta=0.05, seed=0, **fkw):
    cfg = get_reduced_config(arch)
    fcfg = FavasConfig(n_clients=n, s_selected=s, local_steps=K, eta=eta,
                       seed=seed, **fkw)
    key = jax.random.PRNGKey(seed)
    params = init_params(key, cfg)
    state = favas_init(params, fcfg, key)
    lambdas = jnp.asarray(client_lambdas(fcfg))

    def lfn(p, b):
        return loss_fn(p, cfg, b)
    step = jax.jit(functools.partial(favas_round, cfg=fcfg, loss_fn=lfn,
                                     lambdas=lambdas))
    return cfg, fcfg, state, step


def _batch(cfg, fcfg, rng, B=2, S=32):
    toks = rng.integers(0, cfg.vocab_size_raw,
                        (fcfg.n_clients, fcfg.R, B, S)).astype(np.int32)
    return {"tokens": jnp.asarray(toks)}


def test_favas_training_reduces_loss():
    cfg, fcfg, state, step = _setup()
    rng = np.random.default_rng(0)
    losses = []
    for _ in range(12):
        state, m = step(state, _batch(cfg, fcfg, rng))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-4:]) < np.mean(losses[:4]) - 0.3


def test_favas_round_counters_and_selection():
    cfg, fcfg, state, step = _setup(n=6, s=3)
    rng = np.random.default_rng(1)
    for _ in range(5):
        state, m = step(state, _batch(cfg, fcfg, rng))
        assert float(m["selected"]) == 3
        q = np.asarray(state.counters)
        assert q.min() >= 0 and q.max() <= fcfg.local_steps


def test_selected_clients_reset_to_server():
    """After a round, every client is either at the new server model (just
    selected, counter 0) or has nonzero counter."""
    cfg, fcfg, state, step = _setup(n=4, s=2)
    rng = np.random.default_rng(2)
    state, _ = step(state, _batch(cfg, fcfg, rng))
    q = np.asarray(state.counters)
    for i in range(fcfg.n_clients):
        ci = tree_map(lambda x: x[i], state.clients)
        d = float(tree_sq_dist(ci, state.server))
        if q[i] == 0:
            assert d < 1e-6, f"selected client {i} not reset (d={d})"
        else:
            assert d > 0.0


def test_variance_and_mu_finite():
    cfg, fcfg, state, step = _setup()
    rng = np.random.default_rng(3)
    for _ in range(3):
        state, _ = step(state, _batch(cfg, fcfg, rng))
    assert np.isfinite(float(favas_variance(state)))
    mu = favas_mu(state)
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree_util.tree_leaves(mu))


def test_deterministic_reweight_round():
    cfg, fcfg, state, _ = _setup(reweight="deterministic")
    det = jnp.asarray(deterministic_alphas(fcfg))
    lambdas = jnp.asarray(client_lambdas(fcfg))

    def lfn(p, b):
        return loss_fn(p, cfg, b)
    step = jax.jit(functools.partial(favas_round, cfg=fcfg, loss_fn=lfn,
                                     lambdas=lambdas, det_alpha=det))
    rng = np.random.default_rng(4)
    state, m = step(state, _batch(cfg, fcfg, rng))
    assert np.isfinite(float(m["loss"]))


def test_quantized_round_runs():
    cfg, fcfg, state, step = _setup(quant_bits=4)
    rng = np.random.default_rng(5)
    state, m = step(state, _batch(cfg, fcfg, rng))
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(favas_variance(state)))


def test_rounds_are_reproducible():
    cfg, fcfg, s1, step = _setup(seed=7)
    _, _, s2, _ = _setup(seed=7)
    rng1, rng2 = np.random.default_rng(9), np.random.default_rng(9)
    s1, m1 = step(s1, _batch(cfg, fcfg, rng1))
    s2, m2 = step(s2, _batch(cfg, fcfg, rng2))
    assert float(m1["loss"]) == float(m2["loss"])
    assert float(tree_sq_dist(s1.server, s2.server)) == 0.0
