"""Per-architecture smoke tests (task spec f): for each assigned arch,
instantiate the REDUCED same-family variant (2 layers, d_model<=512,
<=4 experts) and run one forward + one FAVAS train round on CPU, asserting
output shapes and no NaNs. Decode consistency vs full forward is asserted
for every family too.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_reduced_config
from repro.core import FavasConfig, favas_init, favas_round, client_lambdas
from repro.models.model import (init_params, forward, loss_fn, init_cache,
                                decode_step, prefill_audio)

B, S = 2, 32


def _extras(cfg, key, B):
    b = {}
    if cfg.arch_type == "audio":
        b["enc_frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
    if cfg.arch_type == "vlm":
        b["patch_embeds"] = jax.random.normal(key, (B, 4, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_reduced_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.arch_type == "moe":
        assert cfg.n_experts <= 4
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size_raw)}
    batch.update(_extras(cfg, key, B))
    logits, aux = forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_favas_train_round(arch):
    cfg = get_reduced_config(arch)
    fcfg = FavasConfig(n_clients=2, s_selected=1, local_steps=2, eta=0.02)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    state = favas_init(params, fcfg, key)
    lambdas = jnp.asarray(client_lambdas(fcfg))

    def lfn(p, b):
        return loss_fn(p, cfg, b)
    step = jax.jit(functools.partial(favas_round, cfg=fcfg, loss_fn=lfn,
                                     lambdas=lambdas))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size_raw,
                        (2, fcfg.R, B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks)}
    if cfg.arch_type == "audio":
        batch["enc_frames"] = jax.random.normal(
            key, (2, fcfg.R, B, cfg.enc_seq, cfg.d_model))
    if cfg.arch_type == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (2, fcfg.R, B, 4, cfg.d_model))
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"])), arch
    for leaf in jax.tree_util.tree_leaves(state.server):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_forward(arch):
    cfg = get_reduced_config(arch)
    if cfg.arch_type == "moe":
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops -> exact
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (B, 16), 0, cfg.vocab_size_raw)
    batch = {"tokens": toks}
    batch.update(_extras(cfg, key, B))
    if cfg.arch_type == "vlm":
        batch.pop("patch_embeds")   # decode path is text-only
    full, _ = forward(params, cfg, batch)
    cache = init_cache(cfg, B, 16, dtype=jnp.float32)
    if cfg.arch_type == "audio":
        cache = prefill_audio(params, cfg, cache, batch["enc_frames"])
    logits = None
    for t in range(16):
        logits, cache = decode_step(params, cfg, cache, toks[:, t:t + 1],
                                    jnp.int32(t))
    err = float(jnp.max(jnp.abs(full[:, -1] - logits[:, 0])))
    # bf16 compute: blockwise-softmax (forward) vs full-softmax (decode)
    # accumulate differently; logits are O(10), so 1e-2 abs is tight enough.
    tol = 2e-2 if cfg.arch_type == "ssm" else 1e-2
    assert err < tol, f"{arch}: decode/forward mismatch {err}"
