# NOTE: no XLA_FLAGS here on purpose — tests must see the real (1-device)
# CPU topology. Only launch/dryrun.py (and subprocesses) force 512 devices.
import jax

jax.config.update("jax_enable_x64", False)
